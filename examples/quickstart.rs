//! Quickstart: ROM-compress a single layer and watch the reconstruction
//! error fall with rank — the paper's §2 mechanics in 60 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use llm_rom::linalg::{matmul, Matrix};
use llm_rom::rom::budget::rank_for_budget;
use llm_rom::rom::decompose::{decompose_weight, factors_from_eigen};
use llm_rom::util::Rng;

fn main() -> Result<()> {
    // A synthetic "layer": W (d2 x d1) and calibration inputs X whose
    // activations concentrate in a low-dimensional subspace — exactly the
    // structure LLM-ROM exploits in real transformer features.
    let (d1, d2, n, intrinsic) = (128usize, 128usize, 2048usize, 24usize);
    let mut rng = Rng::new(7);
    let w = Matrix::from_fn(d2, d1, |_, _| rng.normal() * 0.05);
    let basis = Matrix::from_fn(intrinsic, d1, |_, _| rng.normal());
    let coef = Matrix::from_fn(n, intrinsic, |_, _| rng.normal());
    let noise = Matrix::from_fn(n, d1, |_, _| rng.normal() * 0.02);
    let x = matmul(&coef, &basis).add(&noise);

    // Layer outputs and their covariance (paper §2, steps 1-2).
    let y = matmul(&x, &w.transpose());
    let cov = matmul(&y.transpose(), &y);

    println!("LLM-ROM quickstart: one {d2}x{d1} layer, {n} calibration samples");
    println!("intrinsic feature dimension: {intrinsic}\n");
    println!("{:>6} {:>8} {:>12} {:>10} {:>9}", "rank", "budget", "rel. error", "energy", "params");

    let dec = llm_rom::linalg::eigh(&cov)?;
    let y_norm = y.frobenius_norm();
    for budget in [1.0, 0.8, 0.6, 0.46, 0.33, 0.2, 0.1] {
        let rank = rank_for_budget(d2, d1, budget);
        let f = factors_from_eigen(&w, &dec, rank);
        let y_rom = matmul(&x, &f.effective_weight().transpose());
        let rel = y_rom.sub(&y).frobenius_norm() / y_norm;
        println!(
            "{rank:>6} {budget:>8.2} {rel:>12.4e} {:>9.1}% {:>9}",
            100.0 * f.energy,
            f.n_params()
        );
    }

    // The factored pair really is the same function as W_eff.
    let f = decompose_weight(&w, &cov, 24)?;
    let via_factors = matmul(&matmul(&x, &f.w2.transpose()), &f.w1.transpose());
    let via_eff = matmul(&x, &f.effective_weight().transpose());
    let diff = via_factors.sub(&via_eff).max_abs();
    println!("\nfactored form == effective dense form: max diff {diff:.2e}");
    println!("at rank ≈ intrinsic dim ({intrinsic}), the layer compresses ~{:.0}% \
              with near-zero feature error — the paper's core claim.",
        100.0 * (1.0 - f.n_params() as f64 / (d1 * d2) as f64));
    Ok(())
}
