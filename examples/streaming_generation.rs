//! Streaming generation walkthrough — the event-driven session API of the
//! unified inference core, end to end and fully offline (no AOT
//! artifacts, no PJRT):
//!
//! 1. compress a mini model offline and load it in factored form
//!    (`r(d1+d2)` MACs per token),
//! 2. drive the callback API ([`DecodeScheduler::run_streaming`]): tokens
//!    printed the instant they are sampled, interleaved across requests
//!    exactly as the continuous-batching scheduler produces them,
//! 3. drive a raw [`Session`] by hand — bounded-queue backpressure,
//!    explicit `step()`s, per-event handling, and a mid-flight
//!    `cancel()` that frees a slot for a queued request,
//! 4. mix `Score` and `Generate` requests in one session (the serve and
//!    decode front-ends share this one lifecycle),
//! 5. check the streaming invariant: concatenated `Token` events equal
//!    the batch `run()` streams, bitwise.
//!
//! ```bash
//! cargo run --release --example streaming_generation
//! ```

use std::collections::VecDeque;

use anyhow::Result;
use llm_rom::decode::{DecodeConfig, DecodeScheduler, EventKind, Sampling, StreamControl};
use llm_rom::engine::{EngineConfig, EngineCore, FinishReason, InferenceRequest};
use llm_rom::model::ModelConfig;
use llm_rom::serve::{self, ExecMode, ServeModel};

fn main() -> Result<()> {
    let cfg = ModelConfig::mini();
    println!(
        "== stage 1: offline weight-space ROM @ 50% budget (MiniLLaMA d={} L={}) ==",
        cfg.d_model, cfg.n_layers
    );
    let cm = serve::demo_artifact(&cfg, 0.5, 42)?;
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored)?;
    println!(
        "loaded factored: {}/{} matrices execute as two skinny matmuls",
        model.n_factored(),
        7 * cfg.n_layers
    );

    println!("\n== stage 2: the callback API — tokens as they are produced ==");
    let config = DecodeConfig {
        slots: 2,
        capacity: 10 + 8,
        max_new: 8,
        sampling: Sampling::Greedy,
        seed: 5,
        eos: None,
        ..DecodeConfig::default()
    };
    let scheduler = DecodeScheduler::new(&model, config);
    let reqs = llm_rom::decode::synth_gen_requests(&cfg, 5, 10, 5);
    let mut token_events = 0usize;
    let (results, stats) = scheduler.run_streaming(reqs.clone(), |ev| {
        match &ev.kind {
            EventKind::Admitted { seq } => println!("  [r{} admitted as #{seq}]", ev.id),
            EventKind::Prefilled { prompt_len, ttft_s } => {
                println!("  [r{} prefilled {prompt_len} tokens, ttft {:.2}ms]", ev.id, ttft_s * 1e3)
            }
            EventKind::Token { index, token, .. } => {
                token_events += 1;
                if *index == 0 {
                    println!("  [r{} first token: {token}]", ev.id);
                }
            }
            EventKind::Finished { reason, tokens } => {
                println!("  [r{} finished: {tokens} tokens, {}]", ev.id, reason.name())
            }
        }
        StreamControl::Continue
    })?;
    println!(
        "streamed {token_events} Token events for {} generated tokens — \
     ttft p95 {:.2}ms, inter-token p95 {:.2}ms (percentiles from the event timeline)",
        stats.generated_tokens(),
        stats.ttft.p95 * 1e3,
        stats.inter_token.p95 * 1e3,
    );
    assert_eq!(token_events, stats.generated_tokens());

    println!("\n== stage 3: a hand-driven session — backpressure and cancellation ==");
    // a deliberately tiny admission queue: submissions bounce until steps
    // drain slots (the backpressure contract of a loaded server)
    let core = EngineCore::new(
        &model,
        EngineConfig {
            slots: 2,
            queue_cap: 2,
            capacity: 10 + 8,
            max_new: 8,
            sampling: Sampling::Greedy,
            seed: 5,
            eos: None,
            ..EngineConfig::default()
        },
    );
    let mut session = core.session();
    let mut waiting: VecDeque<InferenceRequest> =
        reqs.clone().into_iter().map(Into::into).collect();
    let mut bounced = 0usize;
    let mut cancelled_id: Option<usize> = None;
    loop {
        while let Some(req) = waiting.pop_front() {
            if let Some(back) = session.try_submit(req)? {
                bounced += 1;
                waiting.push_front(back);
                break; // queue full: step the engine before resubmitting
            }
        }
        let worked = session.step()?;
        for ev in session.take_events() {
            // cancel request 3 the moment its second token appears
            if cancelled_id.is_none() {
                if let EventKind::Token { index: 1.., .. } = ev.kind {
                    if ev.id == 3 {
                        session.cancel(3);
                        cancelled_id = Some(3);
                    }
                }
            }
        }
        if !worked && waiting.is_empty() {
            break;
        }
    }
    let (hand_results, hand_stats) = session.finish();
    println!(
        "queue cap 2: {bounced} submissions bounced (backpressure), \
         {} mid-run admissions reused freed slots",
        hand_stats.mid_run_admissions
    );
    let r3 = hand_results.iter().find(|f| f.id == 3).expect("request 3 finished");
    println!(
        "request 3: cancelled mid-flight with {} tokens ({})",
        r3.tokens.len(),
        r3.reason.name()
    );
    assert_eq!(r3.reason, FinishReason::Cancelled);
    assert!(bounced > 0, "5 requests through a 2-deep queue must bounce");

    println!("\n== stage 4: Score and Generate share one session ==");
    let mixed: Vec<InferenceRequest> = reqs
        .iter()
        .take(4)
        .map(|r| {
            if r.id % 2 == 0 {
                InferenceRequest::score(r.id, r.prompt.clone())
            } else {
                InferenceRequest::generate(r.id, r.prompt.clone(), Some(4))
            }
        })
        .collect();
    let (mixed_results, mixed_stats) = core.run(mixed)?;
    for f in &mixed_results {
        match f.reason {
            FinishReason::Scored => println!(
                "  r{}: scored {} positions ({} logits)",
                f.id,
                f.prompt_len,
                f.logits.len()
            ),
            _ => println!("  r{}: generated {} tokens ({})", f.id, f.tokens.len(), f.reason.name()),
        }
    }
    println!(
        "one lifecycle, two request kinds: {} prompt positions scored + {} tokens generated",
        mixed_stats.scored_tokens, mixed_stats.generated_tokens
    );

    println!("\n== stage 5: streamed events ≡ batch run ==");
    let (batch, _) = scheduler.run(reqs.clone())?;
    let mut streamed_tokens: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
    scheduler.run_streaming(reqs, |ev| {
        if let EventKind::Token { token, .. } = ev.kind {
            streamed_tokens[ev.id].push(token);
        }
        StreamControl::Continue
    })?;
    for b in &batch {
        assert_eq!(streamed_tokens[b.id], b.tokens, "request {} diverged", b.id);
    }
    println!("all {} request streams identical, event path vs batch path", batch.len());
    Ok(())
}
