//! Budget sweep (Table 1 + the §2.1 (k, b) selection experiment).
//!
//! For each global budget, sweeps the number of trailing modules `k`
//! (solving the per-module budget that hits the target) and reports
//! accuracy — reproducing the paper's empirical finding that a *deeper,
//! gentler* schedule beats compressing few modules hard, up to a point.
//! Every point runs through the unified compression API as a
//! `CompressedModel`.
//!
//! ```bash
//! cargo run --release --example budget_sweep   # needs runs/base.rtz
//! # env: SWEEP_PER_TASK=100 SWEEP_ROWS=256
//! ```

use anyhow::{Context, Result};
use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::eval::format_table;
use llm_rom::model::ParamStore;
use llm_rom::rom::{solve_module_budget, ModuleSchedule};
use llm_rom::runtime::Runtime;

fn env_num<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let rt = Runtime::new(llm_rom::DEFAULT_ARTIFACTS)?;
    let xcfg = ExperimentConfig {
        eval_per_task: env_num("SWEEP_PER_TASK", 100usize),
        calib_rows: env_num("SWEEP_ROWS", 256usize),
        ..ExperimentConfig::default()
    };
    let exp = Experiment::new(&rt, xcfg);
    let base = ParamStore::load(&exp.cfg, "runs/base.rtz")
        .context("runs/base.rtz missing — run `repro train` or e2e_compress_eval first")?;

    for global in [0.8, 0.5] {
        let mut rows = Vec::new();
        // candidate k: sweep the feasible range, coarsely
        for k in 1..=exp.cfg.n_layers {
            let Some(b) = solve_module_budget(&exp.cfg, k, global) else {
                continue;
            };
            if k % 2 != 0 && k != exp.cfg.n_layers {
                continue; // coarse sweep: even k only (plus full depth)
            }
            let sched = ModuleSchedule { start_block: exp.cfg.n_layers - k, module_budget: b };
            let cm = exp.compress_scheduled(&base, "rom-feature", sched, None)?;
            let rep = exp.evaluate(&cm.params, false)?;
            rows.push((format!("last {k:>2} modules @ b={b:.2}"), rep));
        }
        println!(
            "{}",
            format_table(
                &format!("§2.1 schedule sweep — global budget {:.0}%", global * 100.0),
                &rows
            )
        );
    }
    Ok(())
}
