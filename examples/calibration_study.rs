//! Calibration ablations — Tables 2, 3 and 4 in one runnable driver.
//!
//! Sweeps (at a fixed 80% global budget):
//! - batch size 512 / 128 / 32 calibration rows  (Table 2)
//! - sequence length 128 / 64 / 32               (Table 3)
//! - calibration distribution: combination / arc-c-only / generic corpus
//!   (Table 4)
//!
//! ```bash
//! cargo run --release --example calibration_study   # needs runs/base.rtz
//! # env: CAL_PER_TASK=100
//! ```

use anyhow::{Context, Result};
use llm_rom::coordinator::{tables, Experiment, ExperimentConfig};
use llm_rom::model::ParamStore;
use llm_rom::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::new(llm_rom::DEFAULT_ARTIFACTS)?;
    let xcfg = ExperimentConfig {
        eval_per_task: std::env::var("CAL_PER_TASK")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(100usize),
        ..ExperimentConfig::default()
    };
    let exp = Experiment::new(&rt, xcfg);
    let base = ParamStore::load(&exp.cfg, "runs/base.rtz")
        .context("runs/base.rtz missing — run `repro train` or e2e_compress_eval first")?;

    println!("{}", tables::table2(&exp, &base, 0.8)?);
    println!("{}", tables::table3(&exp, &base, 0.8)?);
    println!("{}", tables::table4(&exp, &base, 0.8)?);
    Ok(())
}
