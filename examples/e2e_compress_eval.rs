//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! train MiniLLaMA on the synthetic world corpus (logging the loss curve)
//! → compress at 80% with the unified API (`rom-feature` and
//! `prune-activation`, both as [`CompressedModel`] artifacts through the
//! same `Compressor` trait path) → evaluate dense vs ROM vs pruned on all
//! six SynthSense tasks + perplexity → print the Table-1 block. The run is
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_compress_eval
//! # env: E2E_STEPS=600 E2E_PER_TASK=150 E2E_FT=60 to override
//! ```

use anyhow::Result;
use llm_rom::compress::CompressedModel;
use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::eval::format_table;
use llm_rom::model::macs::{self, CompressionAccounting};
use llm_rom::runtime::Runtime;
use llm_rom::util::Stopwatch;

fn env_num<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let mut sw = Stopwatch::new();
    let rt = Runtime::new(llm_rom::DEFAULT_ARTIFACTS)?;
    let xcfg = ExperimentConfig {
        train_steps: env_num("E2E_STEPS", 600usize),
        eval_per_task: env_num("E2E_PER_TASK", 150usize),
        ..ExperimentConfig::default()
    };
    let ft_steps: usize = env_num("E2E_FT", 60usize);
    let exp = Experiment::new(&rt, xcfg);

    println!("== stage 1: train MiniLLaMA ({} params, {} steps) ==",
        exp.cfg.n_params(), exp.xcfg.train_steps);
    // reuse a checkpoint if the CLI already trained one
    let base = match llm_rom::model::ParamStore::load(&exp.cfg, "runs/base.rtz") {
        Ok(p) => {
            println!("(reusing runs/base.rtz)");
            p
        }
        Err(_) => {
            let init = exp.init_params(llm_rom::DEFAULT_ARTIFACTS)?;
            let trained = exp.train(init, |step, loss, lr| {
                println!("  step {step:>4}  loss {loss:.4}  lr {lr:.1e}");
            })?;
            std::fs::create_dir_all("runs").ok();
            trained.params.save("runs/base.rtz")?;
            println!("loss curve: {:?}",
                trained.losses.iter().step_by(trained.losses.len().div_ceil(20).max(1))
                    .map(|l| (l * 100.0).round() / 100.0).collect::<Vec<_>>());
            trained.params
        }
    };
    println!("stage 1 done in {:.1}s\n", sw.lap("train"));

    println!("== stage 2: ROM compress @80% (method `rom-feature`) ==");
    let rom = exp.compress_method(&base, "rom-feature", 0.8)?;
    println!(
        "compressed {} matrices in {:.1}s ({:.2} s/layer), peak capture {:.1} MB",
        rom.timings.len(),
        rom.total_seconds(),
        rom.mean_seconds_per_layer(),
        rom.peak_capture_bytes as f64 / 1e6
    );
    println!("stage 2 done in {:.1}s\n", sw.lap("rom"));

    println!("== stage 3: pruning baseline @80% (method `prune-activation`, +{ft_steps}-step fine-tune) ==");
    let pruned = exp.compress_method(&base, "prune-activation", 0.8)?;
    let pruned_ft = if ft_steps > 0 {
        Some(exp.finetune_compressed(&pruned, ft_steps, |_, _, _| {})?)
    } else {
        None
    };
    println!("stage 3 done in {:.1}s\n", sw.lap("prune"));

    println!("== stage 4: evaluate all variants ==");
    let label = |cm: &CompressedModel| {
        let rep = cm.macs_report(&exp.cfg, 64);
        format!(
            "{}@80% ({:.2}M, {:.2}G MACs)",
            cm.provenance.method,
            rep.n_params as f64 / 1e6,
            rep.macs_giga()
        )
    };
    let dense_rep = macs::report(&exp.cfg, &CompressionAccounting::dense(), 64);
    let mut rows = Vec::new();
    rows.push((
        format!("dense ({:.2}M, {:.2}G MACs)", dense_rep.n_params as f64 / 1e6, dense_rep.macs_giga()),
        exp.evaluate(&base, true)?,
    ));
    rows.push((label(&rom), exp.evaluate(&rom.params, true)?));
    rows.push((label(&pruned), exp.evaluate(&pruned.params, true)?));
    if let Some(ft) = &pruned_ft {
        rows.push((format!("{} +ft", label(&pruned)), exp.evaluate(ft, true)?));
    }
    println!("{}", format_table("E2E: dense vs ROM vs pruning @80% budget", &rows));
    println!("stage 4 done in {:.1}s", sw.lap("eval"));
    println!("\ntotal wall time: {:.1}s — record this block in EXPERIMENTS.md", sw.total());
    Ok(())
}
