//! Ablations of the paper's two key design choices (DESIGN.md §6), driven
//! entirely through the unified `Compressor` trait:
//!
//! 1. **Feature-space vs weight-space decomposition** — registry methods
//!    `rom-feature` vs `rom-weight-svd`.
//! 2. **Error propagation** (§2) — a hand-built [`RomFeature`] with
//!    `propagate_errors: false`, run through the same
//!    [`CompressionSession`] as the registered methods (the trait is the
//!    extension point: ablation variants need no special pipeline code).
//!
//! ```bash
//! cargo run --release --example ablations        # needs runs/base.rtz
//! # env: ABL_PER_TASK=100 ABL_ROWS=256 ABL_BUDGET=0.8
//! ```

use anyhow::{Context, Result};
use llm_rom::compress::methods::RomFeature;
use llm_rom::compress::{CompressedModel, Compressor};
use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::eval::format_table;
use llm_rom::model::ParamStore;
use llm_rom::rom::paper_preset;
use llm_rom::runtime::Runtime;

fn env_num<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let rt = Runtime::new(llm_rom::DEFAULT_ARTIFACTS)?;
    let xcfg = ExperimentConfig {
        eval_per_task: env_num("ABL_PER_TASK", 100usize),
        calib_rows: env_num("ABL_ROWS", 256usize),
        ..ExperimentConfig::default()
    };
    let budget: f64 = env_num("ABL_BUDGET", 0.8f64);
    let exp = Experiment::new(&rt, xcfg);
    let base = ParamStore::load(&exp.cfg, "runs/base.rtz")
        .context("runs/base.rtz missing — run `repro train` first")?;

    let schedule = paper_preset(&exp.cfg, budget);
    let session = exp.session();
    let mut calib =
        exp.calib_stream(exp.xcfg.calib_rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);

    let mut rows = Vec::new();
    rows.push(("dense".to_string(), exp.evaluate(&base, false)?));

    // registered methods: the paper configuration and the data-free SVD
    for (label, method) in [
        ("feature + propagation (paper)", "rom-feature"),
        ("weight-space SVD (data-free)", "rom-weight-svd"),
    ] {
        let cm: CompressedModel = session.compress(method, &base, schedule, &mut calib)?;
        rows.push((label.to_string(), exp.evaluate(&cm.params, false)?));
    }

    // ablation variant: same trait, same session, one knob flipped
    let no_prop = RomFeature { propagate_errors: false };
    let cm = session.run(
        &no_prop as &dyn Compressor,
        &base,
        schedule,
        schedule.global_budget(&exp.cfg),
        &mut calib,
    )?;
    rows.push(("feature, no propagation".to_string(), exp.evaluate(&cm.params, false)?));

    println!(
        "{}",
        format_table(
            &format!(
                "Ablations @ {:.0}% budget — decomposition space & §2 propagation",
                budget * 100.0
            ),
            &rows
        )
    );
    Ok(())
}
