//! Ablations of the paper's two key design choices (DESIGN.md §6):
//!
//! 1. **Feature-space vs weight-space decomposition** — the paper's core
//!    novelty: principal components of the *activation covariance* rather
//!    than of the weight matrix itself.
//! 2. **Error propagation** (§2) — calibrating each layer against the
//!    already-compressed prefix vs against the original activations.
//!
//! ```bash
//! cargo run --release --example ablations        # needs runs/base.rtz
//! # env: ABL_PER_TASK=100 ABL_ROWS=256 ABL_BUDGET=0.8
//! ```

use anyhow::{Context, Result};
use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::eval::format_table;
use llm_rom::model::ParamStore;
use llm_rom::rom::{paper_preset, DecompositionSpace, RomConfig, RomPipeline};
use llm_rom::runtime::Runtime;

fn env_num<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let rt = Runtime::new(llm_rom::DEFAULT_ARTIFACTS)?;
    let mut xcfg = ExperimentConfig::default();
    xcfg.eval_per_task = env_num("ABL_PER_TASK", 100usize);
    xcfg.calib_rows = env_num("ABL_ROWS", 256usize);
    let budget: f64 = env_num("ABL_BUDGET", 0.8f64);
    let exp = Experiment::new(&rt, xcfg);
    let base = ParamStore::load(&exp.cfg, "runs/base.rtz")
        .context("runs/base.rtz missing — run `repro train` first")?;

    let schedule = paper_preset(&exp.cfg, budget);
    let calib = exp.calibration(exp.xcfg.calib_rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
    let pipeline = RomPipeline::new(&rt);

    let variants: [(&str, RomConfig); 3] = [
        (
            "feature + propagation (paper)",
            RomConfig { schedule, ..RomConfig::default() },
        ),
        (
            "feature, no propagation",
            RomConfig { schedule, propagate_errors: false, ..RomConfig::default() },
        ),
        (
            "weight-space SVD (data-free)",
            RomConfig { schedule, space: DecompositionSpace::Weight, ..RomConfig::default() },
        ),
    ];

    let mut rows = Vec::new();
    rows.push(("dense".to_string(), exp.evaluate(&base, false)?));
    for (label, rcfg) in variants {
        let rom = pipeline.compress(&base, &calib, &rcfg)?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((label.to_string(), rep));
    }
    println!(
        "{}",
        format_table(
            &format!("Ablations @ {:.0}% budget — decomposition space & §2 propagation", budget * 100.0),
            &rows
        )
    );
    Ok(())
}
