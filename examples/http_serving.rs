//! HTTP serving walkthrough — the `repro daemon` transport front-end,
//! end to end and fully offline (client and server in one process over
//! loopback; no AOT artifacts, no PJRT):
//!
//! 1. compress a mini model offline and load it in factored form,
//! 2. bind a [`Daemon`] on an ephemeral loopback port and serve it from
//!    a scoped thread,
//! 3. talk to it over one keep-alive connection: `/healthz`, a score
//!    request, a unary generate — typed JSON envelopes both ways,
//! 4. stream a generation over SSE: `admitted → prefilled → token* →
//!    finished`, printed frame by frame as they arrive off the socket,
//! 5. overload it deterministically: with admission paused the bounded
//!    queue fills to cap and the next request is shed with `429` +
//!    `Retry-After` (the backpressure contract of a loaded server),
//! 6. drive it open-loop with the wire-path load generator
//!    (`repro loadgen` in-process) and read the latency report,
//! 7. drain gracefully (`POST /admin/drain`): in-flight work finishes,
//!    the daemon exits and hands back its [`DaemonReport`].
//!
//! ```bash
//! cargo run --release --example http_serving
//! ```

use anyhow::{ensure, Result};
use llm_rom::daemon::{
    run_loadgen, Daemon, DaemonConfig, DaemonControl, DaemonReport, HttpClient, LoadgenConfig,
};
use llm_rom::daemon::wire;
use llm_rom::engine::{synth_token_streams, EngineConfig};
use llm_rom::model::ModelConfig;
use llm_rom::serve::{self, ExecMode, ServeModel};
use llm_rom::util::json::Json;

fn gen_body(prompt: &[i32], max_new: usize, stream: bool) -> Json {
    wire::obj(vec![
        ("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("max_new", Json::Num(max_new as f64)),
        ("stream", Json::Bool(stream)),
    ])
}

fn main() -> Result<()> {
    let cfg = ModelConfig::mini();
    println!(
        "== stage 1: offline weight-space ROM @ 50% budget (MiniLLaMA d={} L={}) ==",
        cfg.d_model, cfg.n_layers
    );
    let cm = serve::demo_artifact(&cfg, 0.5, 42)?;
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored)?;
    println!("loaded factored: {} matrices execute as two skinny matmuls", model.n_factored());

    println!("\n== stage 2: bind the daemon on an ephemeral loopback port ==");
    let engine = EngineConfig {
        slots: 2,
        queue_cap: 3,
        max_new: 8,
        capacity: 8 + 32,
        seed: 7,
        eos: None,
        ..EngineConfig::default()
    };
    let server = Daemon::bind(
        &model,
        DaemonConfig { addr: "127.0.0.1:0".into(), engine, retry_after_s: 1 },
    )?;
    let ctl = server.control();
    let addr = server.addr();
    println!("daemon listening on http://{addr} — {} slots, queue {}", engine.slots, engine.queue_cap);

    let report = std::thread::scope(|s| -> Result<DaemonReport> {
        let srv = s.spawn(move || server.serve());
        let walk = walkthrough(addr, &ctl, &cfg);
        // drain unconditionally: on success this is stage 7, on failure it
        // unblocks the daemon thread so the scope can join
        ctl.drain();
        let report = srv.join().expect("daemon thread panicked");
        walk?;
        report
    })?;

    println!("\n== stage 7: drained — the daemon's own account of the run ==");
    println!(
        "{} HTTP requests: {} inference retired ({} scored + {} generated tokens), \
         {} SSE streams, {} shed with 429",
        report.http_requests,
        report.stats.requests,
        report.stats.scored_tokens,
        report.stats.generated_tokens,
        report.sse_streams,
        report.shed_429,
    );
    // stage 5 shed exactly one; the open-loop burst may shed more
    ensure!(report.shed_429 >= 1, "stage 5 must shed at least one request");
    Ok(())
}

fn walkthrough(addr: std::net::SocketAddr, ctl: &DaemonControl, cfg: &ModelConfig) -> Result<()> {
    let prompts = synth_token_streams(cfg, 8, 8, 7);

    println!("\n== stage 3: one keep-alive connection, typed envelopes ==");
    let mut c = HttpClient::connect(addr)?;
    let health = c.get("/healthz")?.json()?;
    println!(
        "GET /healthz      -> ok={} slots={} queue {}/{}",
        health.get("ok")?,
        health.get("slots")?,
        health.get("queue_depth")?,
        health.get("queue_cap")?,
    );
    let body = wire::obj(vec![(
        "tokens",
        Json::Arr(prompts[0].iter().map(|&t| Json::Num(t as f64)).collect()),
    )]);
    let env = c.post_json("/v1/score", &body)?.json()?;
    println!(
        "POST /v1/score    -> id={} reason={} prompt_len={}",
        env.get("id")?,
        env.get("reason")?,
        env.get("prompt_len")?,
    );
    let env = c.post_json("/v1/generate", &gen_body(&prompts[1], 6, false))?.json()?;
    println!(
        "POST /v1/generate -> id={} tokens={} ({})",
        env.get("id")?,
        env.get("tokens")?,
        env.get("reason")?,
    );

    println!("\n== stage 4: the same request as an SSE stream ==");
    let mut sse = HttpClient::connect(addr)?;
    let resp = sse.post_json("/v1/generate", &gen_body(&prompts[2], 6, true))?;
    ensure!(resp.status == 200 && resp.is_sse(), "expected an SSE stream");
    while let Some(f) = sse.next_sse_frame()? {
        println!("  event: {:<9} data: {}", f.event, f.data);
        if f.event == "finished" {
            break;
        }
    }

    println!("\n== stage 5: deterministic overload — bounded queue, 429 shedding ==");
    ctl.pause(); // freeze admission so queue occupancy is exact
    let mut parked = Vec::new();
    for p in prompts.iter().skip(3).take(3) {
        let mut qc = HttpClient::connect(addr)?;
        let resp = qc.post_json("/v1/generate", &gen_body(p, 4, true))?;
        ensure!(resp.status == 200, "queued stream: status {}", resp.status);
        parked.push(qc);
    }
    while ctl.snapshot().queue_depth < 3 {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut over = HttpClient::connect(addr)?;
    let resp = over.post_json("/v1/generate", &gen_body(&prompts[6], 4, true))?;
    println!(
        "queue at 3/3 -> next request: {} (Retry-After: {})",
        resp.status,
        resp.header("retry-after").unwrap_or("-"),
    );
    ensure!(resp.status == 429, "over-capacity request must shed");
    ctl.resume();
    for mut qc in parked {
        while let Some(f) = qc.next_sse_frame()? {
            if f.event == "finished" {
                break;
            }
        }
    }
    println!("resumed: all three parked streams ran to completion");

    println!("\n== stage 6: open-loop load generation over the wire ==");
    let load = run_loadgen(&LoadgenConfig {
        addr: addr.to_string(),
        connections: 2,
        rps: 40.0,
        duration_s: 0.5,
        prompt_len: 8,
        max_new: 4,
        stream: true,
        seed: 7,
        vocab: cfg.vocab,
    })?;
    print!("{}", load.format());
    ensure!(load.ok > 0, "the burst must complete some requests");
    Ok(())
}
