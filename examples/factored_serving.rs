//! Factored-form serving walkthrough — the paper's `r(d1+d2)` inference
//! win, end to end and fully offline (no AOT artifacts, no PJRT):
//!
//! 1. compress a mini model with the data-free weight-space ROM at a 50%
//!    budget (offline `CompressionSession`),
//! 2. save the artifact to `.rtz` — the low-rank factors ride along as
//!    `⟨name⟩.__w1__` / `⟨name⟩.__w2__` sidecar entries — and reload it,
//! 3. serve the same synthetic workload through the batched engine in
//!    both execution modes, dense (`W_eff = W1·W2`) and factored
//!    (`y = (x·W2ᵀ)·W1ᵀ`),
//! 4. compare MACs/token, latency, throughput, and logits agreement.
//!
//! ```bash
//! cargo run --release --example factored_serving
//! ```

use anyhow::Result;
use llm_rom::compress::CompressedModel;
use llm_rom::coordinator::serve_table;
use llm_rom::model::ModelConfig;
use llm_rom::serve::{self, ServeConfig};

fn main() -> Result<()> {
    let cfg = ModelConfig::mini();
    let budget = 0.5;
    println!(
        "== stage 1: offline weight-space ROM @ {:.0}% budget (MiniLLaMA d={} L={}) ==",
        budget * 100.0,
        cfg.d_model,
        cfg.n_layers
    );
    let cm = serve::demo_artifact(&cfg, budget, 42)?;
    println!(
        "compressed: {} matrices factored, {} params -> {} (accounted)",
        cm.factors.len(),
        cfg.n_params(),
        cm.macs_report(&cfg, 64).n_params,
    );

    println!("\n== stage 2: factors survive .rtz serialization ==");
    std::fs::create_dir_all("runs").ok();
    let path = "runs/factored_demo.rtz";
    cm.save(path)?;
    let loaded = CompressedModel::load(&cfg, path)?;
    // iterate the *source* factors so a reload that drops entries fails
    // loudly instead of passing vacuously
    assert_eq!(loaded.factors.len(), cm.factors.len(), "factors lost across .rtz");
    let lossless = cm.factors.iter().all(|(name, orig)| {
        let f = &loaded.factors[name];
        f.rank == orig.rank
            && f.w1.data() == orig.w1.data()
            && f.w2.data() == orig.w2.data()
    });
    println!(
        "saved {path}, reloaded {} factors — lossless: {lossless}",
        loaded.factors.len()
    );
    assert!(lossless, "factor round-trip must be lossless");

    println!("\n== stage 3: serve it, dense vs factored ==");
    // the default ExecConfig uses every core; the forwards are row-sharded
    // over the worker pool but bitwise identical to a serial run
    let table = serve_table(&loaded, 8, 32, ServeConfig { workers: 2, ..Default::default() }, 7)?;
    println!("{table}");
    println!("(dense runs the re-densified W_eff; factored runs two skinny matmuls per layer)");
    Ok(())
}
