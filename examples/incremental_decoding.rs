//! Incremental decoding walkthrough — prefill → mid-run admission →
//! sampled generation, end to end and fully offline (no AOT artifacts, no
//! PJRT):
//!
//! 1. compress a mini model with the data-free weight-space ROM and load
//!    it in factored form (`r(d1+d2)` MACs per token),
//! 2. prefill a prompt through a preallocated [`KvCache`] and show that
//!    the incremental path reproduces the from-scratch forward,
//! 3. run a synthetic request fleet through the continuous-batching
//!    [`DecodeScheduler`] — more requests than slots, so finished
//!    sequences are evicted and queued requests admitted *mid-run*,
//! 4. re-run the same workload with seeded temperature/top-k sampling and
//!    show reproducibility,
//! 5. compare the executed MACs against the cache-less recompute baseline.
//!
//! ```bash
//! cargo run --release --example incremental_decoding
//! ```

use anyhow::Result;
use llm_rom::decode::{
    run_recompute, synth_gen_requests, DecodeConfig, DecodeScheduler, KvCache, Sampling,
};
use llm_rom::model::ModelConfig;
use llm_rom::serve::{self, ExecMode, ServeModel};

fn main() -> Result<()> {
    let cfg = ModelConfig::mini();
    println!(
        "== stage 1: offline weight-space ROM @ 50% budget (MiniLLaMA d={} L={}) ==",
        cfg.d_model, cfg.n_layers
    );
    let cm = serve::demo_artifact(&cfg, 0.5, 42)?;
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored)?;
    println!(
        "loaded factored: {}/{} matrices execute as two skinny matmuls",
        model.n_factored(),
        7 * cfg.n_layers
    );

    println!("\n== stage 2: prefill through a preallocated KV cache ==");
    let prompt = serve::synth_requests(&cfg, 1, 20, 7)[0].tokens.clone();
    let mut cache = KvCache::new(&cfg, 64);
    println!(
        "cache: {} layers x {} tokens capacity = {:.1} KB preallocated",
        cache.layers(),
        cache.capacity(),
        cache.bytes() as f64 / 1e3
    );
    let (inc_logits, prefill_macs) = model.forward_cached(&prompt, &mut cache)?;
    let (full_logits, full_macs) = model.forward_logits(&prompt)?;
    let max_diff = inc_logits
        .iter()
        .zip(&full_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "prefilled {} tokens (cache pos {}): max |Δlogits| vs from-scratch forward = {max_diff:.2e}",
        prompt.len(),
        cache.pos()
    );
    let (step_logits, step_macs) = model.forward_step(prompt[0], &mut cache)?;
    println!(
        "one decode step: {} logits for {} MACs (prefill was {prefill_macs}, \
         full recompute of the prefix would be {full_macs})",
        step_logits.len(),
        step_macs
    );

    println!("\n== stage 3: continuous batching — 7 requests through 3 slots ==");
    let reqs = synth_gen_requests(&cfg, 7, 12, 5);
    let config = DecodeConfig {
        slots: 3,
        capacity: 12 + 20,
        max_new: 20,
        sampling: Sampling::Greedy,
        seed: 5,
        ..DecodeConfig::default()
    };
    let scheduler = DecodeScheduler::new(&model, config);
    let (results, stats) = scheduler.run(reqs.clone())?;
    for r in &results {
        println!(
            "  request {}: admitted #{} -> {} tokens ({}), ttft {:.2}ms",
            r.id,
            r.admitted.expect("every request here runs to completion"),
            r.tokens.len(),
            r.finish.name(),
            r.ttft_s * 1e3
        );
    }
    println!(
        "peak {} active, {} mid-run admissions over {} decode rounds — \
         {:.0} tok/s, ttft p95 {:.2}ms, inter-token p95 {:.2}ms",
        stats.peak_active,
        stats.mid_run_admissions,
        stats.decode_rounds,
        stats.tokens_per_s(),
        stats.ttft.p95 * 1e3,
        stats.inter_token.p95 * 1e3
    );
    assert!(stats.mid_run_admissions > 0, "7 requests / 3 slots must admit mid-run");

    println!("\n== stage 4: seeded sampling is reproducible ==");
    let sampled = DecodeConfig {
        sampling: Sampling::TopK { k: 12, temperature: 0.8 },
        ..config
    };
    let (a, _) = DecodeScheduler::new(&model, sampled).run(reqs.clone())?;
    let (b, _) = DecodeScheduler::new(&model, sampled).run(reqs.clone())?;
    assert!(a.iter().zip(&b).all(|(x, y)| x.tokens == y.tokens));
    println!(
        "top-12 @ temp 0.8, seed {}: identical streams across runs (first request: {:?}…)",
        sampled.seed,
        &a[0].tokens[..4.min(a[0].tokens.len())]
    );

    println!("\n== stage 5: what the KV cache + factorization buy ==");
    let dense = ServeModel::from_artifact(&cm, ExecMode::Dense)?;
    let (_, recompute) = run_recompute(&dense, &reqs, &config)?;
    println!(
        "dense-recompute {:.3} MMACs/token vs factored-KV {:.3} MMACs/token — \
         {:.2}x fewer",
        recompute.macs_per_generated_token() as f64 / 1e6,
        stats.macs_per_generated_token() as f64 / 1e6,
        recompute.macs_per_generated_token() as f64
            / stats.macs_per_generated_token().max(1) as f64
    );
    Ok(())
}
