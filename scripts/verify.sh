#!/usr/bin/env bash
# Repo verification: build + test + serve smoke test + (when the
# components are installed) format and lint checks. This is the tier-1
# gate plus the optional tooling; run it from anywhere:
# `bash scripts/verify.sh` or `make verify`.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples --benches =="
cargo build --release --examples --benches

echo "== cargo test -q =="
cargo test -q

# Serve smoke test: builds a mini artifact offline, round-trips it through
# .rtz, and checks factored execution against the dense path (logits ≤1e-4,
# MACs == analytic accounting). Needs no AOT artifacts or PJRT.
echo "== repro serve --self-check =="
./target/release/repro serve --self-check

# Decode smoke test: KV-cached incremental decode ≡ full-recompute forward
# (logits ≤1e-4, identical greedy streams under continuous batching, MACs
# == analytic decode accounting, factored-KV < dense-recompute). Offline.
echo "== repro generate --self-check =="
./target/release/repro generate --self-check

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  if ! cargo fmt --check; then
    echo "verify: FAILED — cargo fmt --check drift (run \`cargo fmt\` and re-verify)" >&2
    exit 1
  fi
else
  echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "verify: OK"
