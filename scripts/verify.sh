#!/usr/bin/env bash
# Repo verification: build + test + serve smoke test + (when the
# components are installed) format and lint checks. This is the tier-1
# gate plus the optional tooling; run it from anywhere:
# `bash scripts/verify.sh` or `make verify`.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples --benches =="
cargo build --release --examples --benches

echo "== cargo test -q =="
cargo test -q

scratch=$(mktemp -d)
trap 'rm -rf "$scratch"' EXIT

# Serve + decode + streaming + daemon smoke tests, at --threads 1 AND
# --threads 4: each run asserts its own invariants (factored ≡ dense logits
# ≤1e-4, factored-quant within its stated tolerance of factored — and its
# scheduler phase runs the int8 kernels, so the t1-vs-t4 diff covers their
# determinism too — KV ≡ recompute streams, speculative draft+verify
# streams ≡ verifier-only greedy (with exact speculative MAC accounting),
# streamed events ≡ batch
# results, MACs == analytic accounting, SSE transcripts ≡ in-process event
# frames over real loopback sockets), and everything the self-checks print
# is deterministic
# — so any divergence between the two thread counts is a determinism
# regression in the exec/engine core and fails the gate here. Each check
# then re-runs with the observability plane detached (--no-obs): the
# printed output must be bitwise identical, which is the non-perturbation
# contract — attaching tracing/metrics never changes behaviour.
for check in "serve --self-check" "serve --self-check --mode factored-quant" "generate --self-check" "generate --self-check --speculative" "generate --stream --self-check" "daemon --self-check"; do
  echo "== repro $check --threads 1 =="
  if ! out_t1=$(./target/release/repro $check --threads 1); then
    echo "$out_t1"
    echo "verify: FAILED — repro $check --threads 1" >&2
    exit 1
  fi
  echo "$out_t1"
  echo "== repro $check --threads 4 =="
  if ! out_t4=$(./target/release/repro $check --threads 4); then
    echo "$out_t4"
    echo "verify: FAILED — repro $check --threads 4" >&2
    exit 1
  fi
  echo "$out_t4"
  if [ "$out_t1" != "$out_t4" ]; then
    echo "verify: FAILED — repro $check diverges between --threads 1 and 4" >&2
    diff <(echo "$out_t1") <(echo "$out_t4") >&2 || true
    exit 1
  fi
  echo "== repro $check --threads 4 --no-obs =="
  if ! out_noobs=$(./target/release/repro $check --threads 4 --no-obs); then
    echo "$out_noobs"
    echo "verify: FAILED — repro $check --threads 4 --no-obs" >&2
    exit 1
  fi
  if [ "$out_noobs" != "$out_t4" ]; then
    echo "verify: FAILED — repro $check output changes under --no-obs (observer perturbation)" >&2
    diff <(echo "$out_t4") <(echo "$out_noobs") >&2 || true
    exit 1
  fi
  echo "-- identical with and without observability"
done

# Causal-plane determinism gate: the scheduler self-check's adversarial
# tiered trace, exported as JSONL, must be byte-identical across thread
# counts — every event is denominated in rounds/sequence numbers/MACs,
# never wall clock, so any byte of difference is a determinism regression
# in the flight recorder or the scheduler it records.
echo "== flight-recorder trace: byte-identical across --threads 1 and 4 =="
./target/release/repro generate --self-check --threads 1 --trace-out "$scratch/trace_t1.jsonl" >/dev/null
./target/release/repro generate --self-check --threads 4 --trace-out "$scratch/trace_t4.jsonl" >/dev/null
if ! cmp -s "$scratch/trace_t1.jsonl" "$scratch/trace_t4.jsonl"; then
  echo "verify: FAILED — flight-recorder trace differs between --threads 1 and 4" >&2
  diff "$scratch/trace_t1.jsonl" "$scratch/trace_t4.jsonl" >&2 || true
  exit 1
fi
echo "-- trace identical ($(wc -l < "$scratch/trace_t1.jsonl") events)"

# Perf regression gate: for every BENCH_*.json committed at the repo
# root, re-run the matching benchmark with the same flags `make bench`
# uses and fail on a >15% throughput drop against the committed numbers
# (BENCH_daemon.json compares wire tokens/sec = load tokens / wall_s;
# the others compare their tokens_per_s samples position by position).
# Skips cleanly for any bench file not committed yet.
echo "== bench regression gate (>15% tokens/sec drop fails) =="
bench_tmp="$scratch"

# Every numeric sample named `key` in `file`, one per line, in order.
bench_metric() { # file key
  grep -o "\"$2\":[0-9.eE+-]*" "$1" | cut -d: -f2
}

# Compare committed vs fresh samples of one key, position by position.
bench_compare() { # name key committed fresh
  paste -d' ' <(bench_metric "$3" "$2") <(bench_metric "$4" "$2") |
    awk -v name="$1" -v key="$2" '
      $1 > 0 && $2 < 0.85 * $1 {
        printf "bench-%s %s dropped >15%%: committed %s, now %s\n", name, key, $1, $2
        bad = 1
      }
      END { exit bad }'
}

check_bench() { # name keys... -- command...
  local name=$1 committed fresh keys=() key
  shift
  while [ "$1" != "--" ]; do keys+=("$1"); shift; done
  shift
  committed="../BENCH_${name}.json"
  if [ ! -f "$committed" ]; then
    echo "-- BENCH_${name}.json not committed; skipping"
    return 0
  fi
  fresh="$bench_tmp/${name}.json"
  echo "-- re-running bench-${name} against committed BENCH_${name}.json"
  "$@" --json "$fresh" >/dev/null
  for key in "${keys[@]}"; do
    if ! bench_compare "$name" "$key" "$committed" "$fresh"; then
      echo "verify: FAILED — bench-${name} throughput regression" >&2
      exit 1
    fi
  done
  if [ "$name" = daemon ]; then
    # wire-path tokens/sec from the load generator's client-side view
    local old_tps new_tps
    old_tps=$(awk -v t="$(bench_metric "$committed" tokens | head -1)" \
                  -v w="$(bench_metric "$committed" wall_s | head -1)" \
                  'BEGIN { if (w > 0) print t / w; else print 0 }')
    new_tps=$(awk -v t="$(bench_metric "$fresh" tokens | head -1)" \
                  -v w="$(bench_metric "$fresh" wall_s | head -1)" \
                  'BEGIN { if (w > 0) print t / w; else print 0 }')
    if ! awk -v a="$old_tps" -v b="$new_tps" 'BEGIN { exit !(a <= 0 || b >= 0.85 * a) }'; then
      echo "verify: FAILED — bench-daemon tokens/sec dropped >15%: committed $old_tps, now $new_tps" >&2
      exit 1
    fi
  fi
}

check_bench serve tokens_per_s -- ./target/release/repro bench-serve
check_bench decode tokens_per_s -- ./target/release/repro bench-decode
check_bench kernels gflops tokens_per_s -- ./target/release/repro bench-kernels
check_bench parallel serve_tokens_per_s decode_tokens_per_s -- \
  ./target/release/repro bench-parallel --threads 4
check_bench daemon achieved_rps -- ./target/release/repro bench-daemon --threads 4

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  if ! cargo fmt --check; then
    echo "verify: FAILED — cargo fmt --check drift (run \`cargo fmt\` and re-verify)" >&2
    exit 1
  fi
else
  echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "verify: OK"
