#!/usr/bin/env bash
# Repo verification: build + test + (when the components are installed)
# format and lint checks. This is the tier-1 gate plus the optional
# tooling; run it from anywhere: `bash scripts/verify.sh` or `make verify`.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples --benches =="
cargo build --release --examples --benches

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --check
else
  echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "verify: OK"
