#!/usr/bin/env bash
# Repo verification: build + test + serve smoke test + (when the
# components are installed) format and lint checks. This is the tier-1
# gate plus the optional tooling; run it from anywhere:
# `bash scripts/verify.sh` or `make verify`.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples --benches =="
cargo build --release --examples --benches

echo "== cargo test -q =="
cargo test -q

# Serve + decode + streaming + daemon smoke tests, at --threads 1 AND
# --threads 4: each run asserts its own invariants (factored ≡ dense logits
# ≤1e-4, KV ≡ recompute streams, streamed events ≡ batch results, MACs ==
# analytic accounting, SSE transcripts ≡ in-process event frames over real
# loopback sockets), and everything the self-checks print is deterministic
# — so any divergence between the two thread counts is a determinism
# regression in the exec/engine core and fails the gate here.
for check in "serve --self-check" "generate --self-check" "generate --stream --self-check" "daemon --self-check"; do
  echo "== repro $check --threads 1 =="
  if ! out_t1=$(./target/release/repro $check --threads 1); then
    echo "$out_t1"
    echo "verify: FAILED — repro $check --threads 1" >&2
    exit 1
  fi
  echo "$out_t1"
  echo "== repro $check --threads 4 =="
  if ! out_t4=$(./target/release/repro $check --threads 4); then
    echo "$out_t4"
    echo "verify: FAILED — repro $check --threads 4" >&2
    exit 1
  fi
  echo "$out_t4"
  if [ "$out_t1" != "$out_t4" ]; then
    echo "verify: FAILED — repro $check diverges between --threads 1 and 4" >&2
    diff <(echo "$out_t1") <(echo "$out_t4") >&2 || true
    exit 1
  fi
done

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  if ! cargo fmt --check; then
    echo "verify: FAILED — cargo fmt --check drift (run \`cargo fmt\` and re-verify)" >&2
    exit 1
  fi
else
  echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "verify: OK"
