# LLM-ROM reproduction — top-level targets.

.PHONY: verify build test artifacts

# Tier-1 gate + optional fmt/clippy (see scripts/verify.sh).
verify:
	bash scripts/verify.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Export the AOT artifacts (HLO text + manifest + init checkpoint) into
# rust/artifacts/. Needs the python/jax toolchain from python/compile/.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
