# LLM-ROM reproduction — top-level targets.

.PHONY: verify build test bench artifacts

# Tier-1 gate + optional fmt/clippy (see scripts/verify.sh).
verify:
	bash scripts/verify.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Machine-readable serving/decoding benchmarks, tracked across PRs
# (BENCH_serve.json / BENCH_decode.json at the repo root). Offline: both
# fall back to a synthetic mini artifact when no --ckpt is given.
bench: build
	cd rust && ./target/release/repro bench-serve --json ../BENCH_serve.json
	cd rust && ./target/release/repro bench-decode --json ../BENCH_decode.json

# Export the AOT artifacts (HLO text + manifest + init checkpoint) into
# rust/artifacts/. Needs the python/jax toolchain from python/compile/.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
