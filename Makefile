# LLM-ROM reproduction — top-level targets.

.PHONY: verify build test bench artifacts

# Tier-1 gate + optional fmt/clippy (see scripts/verify.sh).
verify:
	bash scripts/verify.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Machine-readable serving/decoding/kernel/scaling/wire-path benchmarks,
# tracked across PRs (BENCH_serve.json / BENCH_decode.json /
# BENCH_kernels.json / BENCH_parallel.json / BENCH_daemon.json at the repo
# root). Offline: all fall back to a synthetic mini artifact when no
# --ckpt is given. BENCH_decode.json records TTFT/inter-token percentiles
# derived from the engine core's per-token event timeline
# (latency_source: "event-timeline"); BENCH_kernels.json captures the hot
# path's matmul variants (scalar/SIMD/packed/int8) as GFLOP/s plus
# factored vs factored-quant tokens/sec; BENCH_parallel.json captures
# 1-vs-4-thread tokens/sec and compress wall-clock so the perf trajectory
# records scaling; BENCH_daemon.json measures the full HTTP/SSE transport
# — a self-hosted daemon driven open-loop by `repro loadgen` over
# loopback.
bench: build
	cd rust && ./target/release/repro bench-serve --json ../BENCH_serve.json
	cd rust && ./target/release/repro bench-decode --json ../BENCH_decode.json
	cd rust && ./target/release/repro bench-kernels --json ../BENCH_kernels.json
	cd rust && ./target/release/repro bench-parallel --threads 4 --json ../BENCH_parallel.json
	cd rust && ./target/release/repro bench-daemon --threads 4 --json ../BENCH_daemon.json

# Export the AOT artifacts (HLO text + manifest + init checkpoint) into
# rust/artifacts/. Needs the python/jax toolchain from python/compile/.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
