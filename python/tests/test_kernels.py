"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; the kernels are only trusted through these
comparisons (interpret=True makes them bit-comparable on CPU).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    causal_attention,
    covariance,
    covariance_blocked_feature,
    lowrank_matmul,
    multihead_causal_attention,
    rmsnorm,
)
from compile.kernels import ref

_SETTINGS = dict(max_examples=20, deadline=None)


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------- cov

@settings(**_SETTINGS)
@given(
    n=st.integers(1, 400),
    d=st.integers(1, 96),
    block_n=st.sampled_from([32, 128, 256]),
)
def test_covariance_matches_ref(n, d, block_n):
    rng = np.random.default_rng(n * 1000 + d)
    y = _rand(rng, n, d)
    got = covariance(y, block_n=block_n)
    want = ref.ref_covariance(y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(**_SETTINGS)
@given(
    n=st.integers(1, 300),
    d=st.integers(2, 80),
    block_d=st.sampled_from([16, 32, 64]),
)
def test_covariance_blocked_matches_ref(n, d, block_d):
    rng = np.random.default_rng(n * 7 + d)
    y = _rand(rng, n, d)
    got = covariance_blocked_feature(y, block_n=64, block_d=block_d)
    np.testing.assert_allclose(got, ref.ref_covariance(y), rtol=1e-5, atol=1e-3)


def test_covariance_symmetry_and_psd():
    rng = np.random.default_rng(0)
    y = _rand(rng, 256, 48)
    c = np.asarray(covariance(y))
    np.testing.assert_allclose(c, c.T, rtol=1e-6, atol=1e-4)
    eigs = np.linalg.eigvalsh(c)
    assert eigs.min() > -1e-3  # PSD up to accumulation noise


def test_covariance_bf16_input_accumulates_f32():
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.normal(size=(128, 32))).astype(jnp.bfloat16)
    got = covariance(y)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, ref.ref_covariance(y), rtol=2e-2, atol=1e-1)


# ------------------------------------------------------------------ lowrank

@settings(**_SETTINGS)
@given(
    n=st.integers(1, 200),
    d1=st.integers(1, 64),
    d2=st.integers(1, 96),
    r=st.integers(1, 32),
)
def test_lowrank_matches_ref(n, d1, d2, r):
    rng = np.random.default_rng(n + d1 * 31 + d2 * 7 + r)
    x = _rand(rng, n, d1)
    w2 = _rand(rng, r, d1)
    w1 = _rand(rng, d2, r)
    got = lowrank_matmul(x, w2, w1)
    want = ref.ref_lowrank_matmul(x, w2, w1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_lowrank_equals_dense_composition():
    """Factored layer must equal the dense layer with W = W1 @ W2."""
    rng = np.random.default_rng(3)
    x, w2, w1 = _rand(rng, 64, 24), _rand(rng, 8, 24), _rand(rng, 40, 8)
    dense = x @ (w1 @ w2).T
    np.testing.assert_allclose(lowrank_matmul(x, w2, w1), dense, rtol=1e-4, atol=1e-3)


def test_lowrank_shape_mismatch_raises():
    rng = np.random.default_rng(4)
    with pytest.raises(AssertionError):
        lowrank_matmul(_rand(rng, 8, 4), _rand(rng, 2, 5), _rand(rng, 6, 2))


# ---------------------------------------------------------------- attention

@settings(**_SETTINGS)
@given(
    t=st.sampled_from([16, 32, 64, 128, 192]),
    hd=st.sampled_from([8, 16, 32]),
    block_q=st.sampled_from([16, 32, 64]),
    block_k=st.sampled_from([16, 32, 64]),
)
def test_attention_matches_ref(t, hd, block_q, block_k):
    rng = np.random.default_rng(t + hd)
    q, k, v = (_rand(rng, t, hd) for _ in range(3))
    got = causal_attention(q, k, v, block_q=block_q, block_k=block_k)
    want = ref.ref_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_is_causal():
    """Perturbing future keys/values must not change earlier outputs."""
    rng = np.random.default_rng(5)
    t, hd = 64, 16
    q, k, v = (_rand(rng, t, hd) for _ in range(3))
    base = np.asarray(causal_attention(q, k, v))
    k2 = k.at[t // 2:].set(999.0)
    v2 = v.at[t // 2:].set(-999.0)
    pert = np.asarray(causal_attention(q, k2, v2))
    np.testing.assert_allclose(base[: t // 2], pert[: t // 2], rtol=1e-5, atol=1e-5)


def test_attention_first_row_is_v0():
    """Position 0 attends only to itself -> output row 0 == v[0]."""
    rng = np.random.default_rng(6)
    q, k, v = (_rand(rng, 32, 8) for _ in range(3))
    out = np.asarray(causal_attention(q, k, v))
    np.testing.assert_allclose(out[0], np.asarray(v)[0], rtol=1e-5, atol=1e-5)


def test_multihead_matches_per_head():
    rng = np.random.default_rng(7)
    h, t, hd = 4, 64, 16
    q, k, v = (_rand(rng, h, t, hd) for _ in range(3))
    got = np.asarray(multihead_causal_attention(q, k, v))
    for i in range(h):
        np.testing.assert_allclose(
            got[i], ref.ref_attention(q[i], k[i], v[i]), rtol=1e-4, atol=1e-4
        )


# ------------------------------------------------------------------ rmsnorm

@settings(**_SETTINGS)
@given(n=st.integers(1, 300), d=st.integers(1, 128))
def test_rmsnorm_matches_ref(n, d):
    rng = np.random.default_rng(n * 13 + d)
    x = _rand(rng, n, d)
    g = _rand(rng, d)
    np.testing.assert_allclose(rmsnorm(x, g), ref.ref_rmsnorm(x, g), rtol=1e-5, atol=1e-5)


def test_rmsnorm_unit_rms():
    """With unit gain the output rows have RMS ≈ 1."""
    rng = np.random.default_rng(8)
    x = _rand(rng, 64, 96) * 7.0
    out = np.asarray(rmsnorm(x, jnp.ones((96,), jnp.float32)))
    rms = np.sqrt((out ** 2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_rmsnorm_scale_invariance():
    """RMSNorm(c·x) == RMSNorm(x) for c > 0 (up to eps)."""
    rng = np.random.default_rng(9)
    x = _rand(rng, 16, 64)
    g = _rand(rng, 64)
    a = np.asarray(rmsnorm(x, g))
    b = np.asarray(rmsnorm(x * 100.0, g))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_covariance_default_blocks_nonmultiple_shape():
    """Default (tuned) block_n=512 on a shape that is not a multiple."""
    rng = np.random.default_rng(42)
    y = jnp.asarray(rng.normal(size=(700, 40)).astype(np.float32))
    np.testing.assert_allclose(covariance(y), ref.ref_covariance(y), rtol=1e-5, atol=1e-3)


def test_lowrank_default_blocks_large_n():
    rng = np.random.default_rng(43)
    x = jnp.asarray(rng.normal(size=(1030, 24)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(size=(8, 24)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    np.testing.assert_allclose(
        lowrank_matmul(x, w2, w1), ref.ref_lowrank_matmul(x, w2, w1), rtol=1e-4, atol=1e-3
    )
