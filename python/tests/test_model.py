"""L2 correctness: MiniLLaMA forward/train invariants + flat-arg plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, paramschema
from compile.config import PAD, ModelConfig


@pytest.fixture(scope="module")
def cfg():
    # Tiny config so the jnp path stays fast under pytest.
    return ModelConfig(
        vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=48,
        train_batch=2, train_seq=16, eval_batch=2, eval_seq=16,
    )


@pytest.fixture(scope="module")
def params(cfg):
    return model.init_params(cfg, seed=0)


def _tokens(cfg, rng, b=None, t=None):
    b = b or cfg.eval_batch
    t = t or cfg.eval_seq
    return jnp.asarray(rng.integers(0, 60, size=(b, t)).astype(np.int32))


# ------------------------------------------------------------------- schema

def test_param_schema_roundtrip(cfg, params):
    flat = paramschema.flatten(cfg, params)
    tree = paramschema.unflatten(cfg, flat)
    flat2 = paramschema.flatten(cfg, tree)
    assert len(flat) == len(paramschema.param_names(cfg)) == 2 + 9 * cfg.n_layers
    for a, b in zip(flat, flat2):
        np.testing.assert_array_equal(a, b)


def test_param_shapes_match_schema(cfg, params):
    flat = paramschema.flatten(cfg, params)
    for name, t in zip(paramschema.param_names(cfg), flat):
        assert tuple(t.shape) == paramschema.param_shape(cfg, name), name


def test_maskable_names_are_the_7_matrices(cfg):
    names = paramschema.maskable_names(cfg)
    assert len(names) == 7 * cfg.n_layers
    assert all(paramschema.param_shape(cfg, n).__len__() == 2 for n in names)


# ------------------------------------------------------------------ forward

def test_pallas_and_jnp_paths_agree(cfg, params):
    rng = np.random.default_rng(0)
    tokens = _tokens(cfg, rng)
    a = model.model_forward(cfg, params, tokens, pallas=True)
    b = model.model_forward(cfg, params, tokens, pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_forward_is_causal(cfg, params):
    """Changing a future token must not change earlier logits."""
    rng = np.random.default_rng(1)
    tokens = _tokens(cfg, rng)
    logits = np.asarray(model.model_forward(cfg, params, tokens, pallas=False))
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % 60)
    logits2 = np.asarray(model.model_forward(cfg, params, tokens2, pallas=False))
    np.testing.assert_allclose(logits[:, :-1], logits2[:, :-1], rtol=1e-4, atol=1e-4)


def test_flat_forward_matches_tree(cfg, params):
    rng = np.random.default_rng(2)
    tokens = _tokens(cfg, rng)
    flat = paramschema.flatten(cfg, params)
    (logits_flat,) = model.forward_logits_flat(cfg, *flat, tokens)
    logits_tree = model.model_forward(cfg, params, tokens, pallas=True)
    np.testing.assert_allclose(logits_flat, logits_tree, rtol=1e-6, atol=1e-6)


def test_block_capture_consistency(cfg, params):
    """Captured Y must equal X @ W^T for each decomposable matrix, and the
    streamed block chain must equal the monolithic forward."""
    rng = np.random.default_rng(3)
    tokens = _tokens(cfg, rng)
    h = params["embed"][tokens]
    cos, sin = model.rope_tables(cfg, tokens.shape[1])
    for blk in params["blocks"]:
        flat_blk = [blk[f] for f in paramschema.BLOCK_FIELDS]
        outs = model.block_capture_flat(cfg, *flat_blk, h)
        h_out, caps = outs[0], dict(zip(model.CAPTURE_NAMES, outs[1:]))
        np.testing.assert_allclose(
            caps["y_q"], caps["x_attn"] @ blk["wq"].T, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            caps["y_o"], caps["x_o"] @ blk["wo"].T, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            caps["y_gate"], caps["x_ffn"] @ blk["w_gate"].T, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            caps["y_down"], caps["x_down"] @ blk["w_down"].T, rtol=1e-5, atol=1e-5)
        ref_h = model.block_forward(cfg, blk, h, cos, sin, pallas=True)
        np.testing.assert_allclose(h_out, ref_h, rtol=1e-6, atol=1e-6)
        h = h_out
    # chain end == full forward pre-head
    hn = model._norm(cfg, h, params["final_norm"], pallas=True)
    logits = hn @ params["embed"].T
    full = model.model_forward(cfg, params, tokens, pallas=True)
    np.testing.assert_allclose(logits, full, rtol=2e-5, atol=2e-5)


def test_score_fwd_matches_manual(cfg, params):
    rng = np.random.default_rng(4)
    tokens = _tokens(cfg, rng)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
    flat = paramschema.flatten(cfg, params)
    s, c = model.score_fwd_flat(cfg, *flat, tokens, targets, mask)
    logits = model.model_forward(cfg, params, tokens, pallas=True)
    lp = model.token_logprobs(logits, targets) * mask
    np.testing.assert_allclose(s, lp.sum(axis=-1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c, mask.sum(axis=-1))


def test_head_score_matches_score_fwd(cfg, params):
    rng = np.random.default_rng(5)
    tokens = _tokens(cfg, rng)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32)
    flat = paramschema.flatten(cfg, params)
    s_ref, c_ref = model.score_fwd_flat(cfg, *flat, tokens, targets, mask)
    # stream: embed -> blocks -> head
    h = model.embed_fwd_flat(cfg, params["embed"], tokens)[0]
    for blk in params["blocks"]:
        flat_blk = [blk[f] for f in paramschema.BLOCK_FIELDS]
        h = model.block_fwd_flat(cfg, *flat_blk, h)[0]
    s, c = model.head_score_flat(cfg, params["final_norm"], params["embed"], h, targets, mask)
    np.testing.assert_allclose(s, s_ref, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(c, c_ref)


# ------------------------------------------------------------------- train

def test_train_step_reduces_loss(cfg, params):
    """A few steps on a fixed batch must reduce loss (sanity of grads+AdamW)."""
    rng = np.random.default_rng(6)
    tokens = _tokens(cfg, rng, cfg.train_batch, cfg.train_seq)
    targets = jnp.roll(tokens, -1, axis=1)
    names = paramschema.param_names(cfg)
    flat = paramschema.flatten(cfg, params)
    m = [jnp.zeros_like(t) for t in flat]
    v = [jnp.zeros_like(t) for t in flat]
    losses = []
    step_fn = jax.jit(lambda *a: model.train_step_flat(cfg, *a))
    for i in range(5):
        outs = step_fn(*flat, *m, *v,
                       jnp.float32(i + 1), jnp.float32(1e-3), tokens, targets)
        n = len(names)
        flat, m, v = list(outs[:n]), list(outs[n:2 * n]), list(outs[2 * n:3 * n])
        losses.append(float(outs[-1]))
    assert losses[-1] < losses[0], losses


def test_train_step_ignores_pad(cfg, params):
    """Loss must not depend on PAD-target positions."""
    rng = np.random.default_rng(7)
    tokens = _tokens(cfg, rng, cfg.train_batch, cfg.train_seq)
    targets = jnp.roll(tokens, -1, axis=1).at[:, -4:].set(PAD)
    l1 = model._loss_fn(cfg, params, tokens, targets)
    # garbage in the masked positions -> same loss
    t2 = targets.at[:, -4:].set(PAD)
    l2 = model._loss_fn(cfg, params, tokens, t2)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_train_step_masked_preserves_zeros(cfg, params):
    rng = np.random.default_rng(8)
    tokens = _tokens(cfg, rng, cfg.train_batch, cfg.train_seq)
    targets = jnp.roll(tokens, -1, axis=1)
    names = paramschema.param_names(cfg)
    maskable = paramschema.maskable_names(cfg)
    flat = paramschema.flatten(cfg, params)
    # zero the first 8 output channels of every maskable matrix
    masks = []
    flat_masked = []
    by_name = dict(zip(names, flat))
    for nm in maskable:
        w = by_name[nm]
        mask = jnp.ones_like(w).at[:8, :].set(0.0)
        masks.append(mask)
        by_name[nm] = w * mask
    flat_masked = [by_name[nm] for nm in names]
    m = [jnp.zeros_like(t) for t in flat_masked]
    v = [jnp.zeros_like(t) for t in flat_masked]
    outs = model.train_step_masked_flat(
        cfg, *flat_masked, *masks, *m, *v,
        jnp.float32(1), jnp.float32(1e-3), tokens, targets)
    new_flat = outs[: len(names)]
    for nm, t in zip(names, new_flat):
        if nm in maskable:
            np.testing.assert_array_equal(np.asarray(t)[:8, :], 0.0)


# --------------------------------------------------------------------- rope

def test_rope_preserves_norm(cfg):
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.head_dim)).astype(np.float32))
    cos, sin = model.rope_tables(cfg, 8)
    y = model.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_is_identity(cfg):
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.normal(size=(1, 4, cfg.head_dim)).astype(np.float32))
    cos, sin = model.rope_tables(cfg, 4)
    y = model.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(y)[0, 0], np.asarray(x)[0, 0], rtol=1e-6)


def test_rope_relative_dot_products(cfg):
    """RoPE dot products depend only on relative distance."""
    hd = cfg.head_dim
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 16, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 16, hd)).astype(np.float32))
    cos, sin = model.rope_tables(cfg, 16)
    # broadcast same q/k content at all positions
    qc = jnp.broadcast_to(q[:, :1], q.shape)
    kc = jnp.broadcast_to(k[:, :1], k.shape)
    qr = np.asarray(model.apply_rope(qc, cos, sin))[0]
    kr = np.asarray(model.apply_rope(kc, cos, sin))[0]
    d1 = float(qr[3] @ kr[1])   # distance 2
    d2 = float(qr[10] @ kr[8])  # distance 2
    np.testing.assert_allclose(d1, d2, rtol=1e-4)
