"""tensorio round-trip + AOT manifest/rank-math checks (incl. paper values)."""

import json
import os

import numpy as np
import pytest

from compile import aot, paramschema, tensorio
from compile.config import ModelConfig, llama7b, mini


# ----------------------------------------------------------------- tensorio

def test_rtz_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.normal(size=(3, 5)).astype(np.float32),
        "b.c": rng.integers(-10, 10, size=(7,)).astype(np.int32),
        "scalarish": rng.normal(size=(1,)).astype(np.float64),
        "bytes": rng.integers(0, 255, size=(4, 4)).astype(np.uint8),
    }
    p = str(tmp_path / "x.rtz")
    tensorio.save(p, tensors)
    loaded = tensorio.load(p)
    assert set(loaded) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(loaded[k], tensors[k])
        assert loaded[k].dtype == tensors[k].dtype


def test_rtz_empty_and_bad_magic(tmp_path):
    p = str(tmp_path / "e.rtz")
    tensorio.save(p, {})
    assert tensorio.load(p) == {}
    with open(p, "wb") as f:
        f.write(b"NOPE")
    with pytest.raises(ValueError):
        tensorio.load(p)


# ---------------------------------------------------------------- rank math

def test_rank_formula_reproduces_paper_values():
    """Paper §2.1, LLaMA-7B: attention 4096×4096 and FFN 4096×11008.

    Published ranks: attn {1228, 954, 675}, ffn {1791, 1373, 985} for
    module budgets {0.60, 0.46, 0.33}. All match r = ⌊b·d1·d2/(d1+d2)⌋
    except attn@0.46 where the paper reports 954 (≙ b=0.466) instead of
    942 — a rounding/reporting anomaly we document rather than replicate.
    """
    assert aot.rank_for_budget(4096, 4096, 0.60) == 1228
    assert aot.rank_for_budget(4096, 4096, 0.33) == 675
    assert aot.rank_for_budget(11008, 4096, 0.60) == 1791
    assert aot.rank_for_budget(11008, 4096, 0.46) == 1373
    assert aot.rank_for_budget(11008, 4096, 0.33) == 985
    # the anomaly: formula gives 942, paper prints 954
    assert aot.rank_for_budget(4096, 4096, 0.46) == 942
    assert abs(954 * (4096 + 4096) / (4096 * 4096) - 0.466) < 1e-3


def test_rank_budget_actually_compresses():
    for b in (0.9, 0.6, 0.46, 0.33, 0.1):
        for d1, d2 in ((128, 128), (344, 128), (4096, 11008)):
            r = aot.rank_for_budget(d1, d2, b)
            assert r * (d1 + d2) <= b * d1 * d2


def test_llama7b_param_count():
    cfg = llama7b()
    # 6.7B total per the paper's Table 1 (tied-head accounting).
    assert abs(cfg.n_params() - 6.7e9) / 6.7e9 < 0.05


def test_decoder_fraction_dominates():
    """Paper: decoder modules hold >96% of LLaMA-7B parameters."""
    cfg = llama7b()
    per_block = 4 * cfg.d_model ** 2 + 3 * cfg.d_model * cfg.d_ff + 2 * cfg.d_model
    frac = cfg.n_layers * per_block / cfg.n_params()
    assert frac > 0.96


# ----------------------------------------------------------------- manifest

def test_entry_specs_are_consistent():
    cfg = ModelConfig(
        vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=48,
        train_batch=2, train_seq=16, eval_batch=2, eval_seq=16,
    )
    entries = aot.build_entries(cfg)
    n = len(paramschema.param_names(cfg))
    k = len(paramschema.maskable_names(cfg))
    assert len(entries["forward_logits"]["args"]) == n + 1
    assert len(entries["score_fwd"]["args"]) == n + 3
    assert len(entries["train_step"]["args"]) == 3 * n + 4
    assert len(entries["train_step_masked"]["args"]) == 3 * n + k + 4
    assert len(entries["train_step"]["outputs"]) == 3 * n + 1
    assert len(entries["block_capture"]["outputs"]) == 12
    # arg names in the manifest match the schema order
    names = [a["name"] for a in entries["forward_logits"]["args"][:n]]
    assert names == paramschema.param_names(cfg)


@pytest.mark.slow
def test_full_export_smoke(tmp_path):
    """End-to-end export of a tiny config: every HLO file + manifest + init."""
    cfg = ModelConfig(
        vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=48,
        train_batch=2, train_seq=16, eval_batch=2, eval_seq=16,
    )
    out = str(tmp_path / "artifacts")
    aot.export(cfg, out)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    for name, ent in manifest["entries"].items():
        p = os.path.join(out, ent["file"])
        assert os.path.exists(p), name
        head = open(p).read(200)
        assert "HloModule" in head, name
    params = tensorio.load(os.path.join(out, "init.rtz"))
    assert set(params) == set(manifest["param_names"])
    for nm, arr in params.items():
        assert list(arr.shape) == list(paramschema.param_shape(cfg, nm))
