"""Build-time compile package for LLM-ROM (L1 kernels + L2 model + AOT)."""
