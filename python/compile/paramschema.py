"""Canonical flat parameter ordering, shared with the Rust side.

HLO entry points take parameters as a *flat positional argument list* (so
the Rust runtime can swap weights without recompiling). This module defines
the one true ordering; ``aot.py`` embeds it in ``manifest.json`` and
``rust/src/model/schema.rs`` mirrors the same generation rule, with a test
asserting both agree against the manifest.

Order: ``embed``, then for each block ``i``:
``attn_norm, wq, wk, wv, wo, ffn_norm, w_gate, w_up, w_down``, then
``final_norm``.  Maskable (decomposable / prunable) tensors are exactly the
7 two-dimensional weights per block.
"""

from __future__ import annotations

from typing import Iterator

from .config import ModelConfig

BLOCK_FIELDS = ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down")
MASKABLE_FIELDS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def param_names(cfg: ModelConfig) -> list[str]:
    def gen() -> Iterator[str]:
        yield "embed"
        for i in range(cfg.n_layers):
            for f in BLOCK_FIELDS:
                yield f"blocks.{i}.{f}"
        yield "final_norm"

    return list(gen())


def param_shape(cfg: ModelConfig, name: str) -> tuple[int, ...]:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    if name == "embed":
        return (v, d)
    if name == "final_norm":
        return (d,)
    field = name.split(".")[-1]
    return {
        "attn_norm": (d,),
        "ffn_norm": (d,),
        "wq": (d, d),
        "wk": (d, d),
        "wv": (d, d),
        "wo": (d, d),
        "w_gate": (f, d),
        "w_up": (f, d),
        "w_down": (d, f),
    }[field]


def maskable_names(cfg: ModelConfig) -> list[str]:
    """The 7·L decomposable weight matrices, in param order."""
    return [n for n in param_names(cfg) if n.split(".")[-1] in MASKABLE_FIELDS]


def flatten(cfg: ModelConfig, tree: dict) -> list:
    """Nested param dict -> flat list in canonical order."""
    out = []
    for name in param_names(cfg):
        node = tree
        for part in name.split("."):
            node = node[int(part)] if part.isdigit() else node[part]
        out.append(node)
    return out


def unflatten(cfg: ModelConfig, flat: list) -> dict:
    """Flat list in canonical order -> nested param dict."""
    it = iter(flat)
    tree: dict = {"embed": next(it), "blocks": []}
    for _ in range(cfg.n_layers):
        blk = {f: next(it) for f in BLOCK_FIELDS}
        tree["blocks"].append(blk)
    tree["final_norm"] = next(it)
    try:
        next(it)
    except StopIteration:
        return tree
    raise ValueError("flat param list longer than schema")
