"""Model / export configuration shared by the L2 model and the AOT exporter.

The same dimensions are mirrored on the Rust side via ``manifest.json``
(written by :mod:`aot`), so this file is the single Python source of truth.

The default ``mini`` config is a faithful scale-down of LLaMA-7B: identical
block structure (RMSNorm → MHA(+RoPE) → residual → RMSNorm → SwiGLU →
residual; 7 decomposable weight matrices per module), with dimensions sized
for a 1-core CI box. ``llama7b()`` shows that the real config is expressible.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

# Byte-level tokenizer special ids (bytes occupy 0..255).
BOS = 256
EOS = 257
PAD = 258
SEP = 259
VOCAB_USED = 260


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + canonical AOT shapes."""

    vocab: int = 320          # embedding rows (VOCAB_USED padded up for tiling)
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 8
    d_ff: int = 344           # ≈ 2.69 × d_model, LLaMA-7B's 11008/4096 ratio
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # Canonical AOT batch shapes (HLO is static-shape; Rust chunks to these).
    train_batch: int = 16
    train_seq: int = 64
    eval_batch: int = 32
    eval_seq: int = 128
    # AdamW hyperparameters baked into the train-step graph (lr is an input).
    adam_beta1: float = 0.9
    adam_beta2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.01

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (tied LM head)."""
        per_block = 4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff + 2 * self.d_model
        return self.vocab * self.d_model + self.n_layers * per_block + self.d_model

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(obj: dict[str, Any]) -> "ModelConfig":
        return ModelConfig(**obj)

    @staticmethod
    def from_file(path: str) -> "ModelConfig":
        with open(path) as f:
            return ModelConfig.from_json(json.load(f))


def mini() -> ModelConfig:
    """Default reproduction config (~1.8 M params, 8 modules × 7 matrices)."""
    return ModelConfig()


def llama7b() -> ModelConfig:
    """The paper's target, for budget-math tests (never instantiated)."""
    return ModelConfig(
        vocab=32000, d_model=4096, n_heads=32, n_layers=32, d_ff=11008,
        train_batch=1, train_seq=2048, eval_batch=1, eval_seq=2048,
    )
