"""Pallas kernel: causal fused attention (flash-style online softmax).

The model-forward hot-spot for MiniLLaMA (L2). One head per call; vmapped
over heads and batch in model.py.

TPU mapping (DESIGN.md §Hardware-Adaptation): CUDA flash-attention assigns a
threadblock per Q tile and streams K/V tiles through shared memory; here the
grid's leading axis is the Q row-block and the kernel *scans* K/V key-blocks
with ``jax.lax.fori_loop``, keeping the running max ``m``, normalizer ``l``
and accumulator ``acc`` in VMEM/registers. Causality lets us skip key blocks
strictly above the diagonal by bounding the loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_len: int, scale: float):
    qi = pl.program_id(0)
    blk_q = q_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale  # (blk_q, hd)

    m0 = jnp.full((blk_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc0 = jnp.zeros((blk_q, v_ref.shape[-1]), jnp.float32)

    q_start = qi * blk_q
    # Causal: key block j is needed only while j*block_k <= last query row.
    num_k = (q_start + blk_q + block_k - 1) // block_k
    num_k = min(num_k, (seq_len + block_k - 1) // block_k) if isinstance(num_k, int) else num_k

    def body(j, carry):
        m, l, acc = carry
        k_blk = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)  # (blk_q, blk_k)

        # Causal mask within the tile: query row q_start+a attends to key
        # col j*block_k+b iff col <= row.
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= rows, s, _NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_blk, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    k_hi = jnp.minimum((q_start + blk_q + block_k - 1) // block_k, pl.cdiv(seq_len, block_k))
    m, l, acc = jax.lax.fori_loop(0, k_hi, body, (m0, l0, acc0))
    o_ref[...] = acc / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = 64,
    block_k: int = 64,
) -> jnp.ndarray:
    """Single-head causal attention, (t, hd) -> (t, hd), f32 output.

    ``t`` must be a multiple of ``block_q`` and ``block_k`` is clamped to
    ``t`` (model.py pads sequences to the block size).
    """
    t, hd = q.shape
    blk_q = min(block_q, t)
    blk_k = min(block_k, t)
    scale = 1.0 / float(hd) ** 0.5
    grid = (pl.cdiv(t, blk_q),)
    kernel = functools.partial(_attn_kernel, block_k=blk_k, seq_len=t, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q, hd), lambda i: (i, 0)),
            # Full K/V visible to every Q block; the kernel streams tiles
            # out of them with pl.load (VMEM-resident at MiniLLaMA sizes).
            pl.BlockSpec((t, hd), lambda i: (0, 0)),
            pl.BlockSpec((t, hd), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk_q, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, hd), jnp.float32),
        interpret=True,
    )(q, k, v)


def multihead_causal_attention(q, k, v, *, block_q: int = 64, block_k: int = 64):
    """(h, t, hd) -> (h, t, hd): vmap the single-head kernel over heads."""
    fn = functools.partial(causal_attention, block_q=block_q, block_k=block_k)
    return jax.vmap(fn)(q, k, v)
