"""Pure-jnp reference oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the mathematical specification of the matching
kernel in :mod:`covariance`, :mod:`lowrank`, :mod:`attention` and
:mod:`rmsnorm`.  The pytest suite (``python/tests/test_kernels.py``) sweeps
shapes/dtypes with hypothesis and asserts ``allclose`` between kernel and
oracle; the kernels are only trusted through these oracles.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_covariance(y: jnp.ndarray) -> jnp.ndarray:
    """Gram/covariance matrix of row-major samples.

    ``y``: (n, d) activation matrix (n samples of d features).
    Returns ``y^T y`` in f32 — the symmetric (d, d) matrix whose
    eigendecomposition yields the ROM principal components. Normalization by
    ``n`` is left to the caller (it does not change the eigenvectors).
    """
    y32 = y.astype(jnp.float32)
    return y32.T @ y32


def ref_lowrank_matmul(x: jnp.ndarray, w2: jnp.ndarray, w1: jnp.ndarray) -> jnp.ndarray:
    """Factored (ROM) linear layer: ``x @ w2^T @ w1^T``.

    ``x``: (n, d1) inputs; ``w2``: (r, d1) = V_r W; ``w1``: (d2, r) = V_r^T.
    Equivalent to the dense layer ``x @ (w1 w2)^T`` but with
    ``r (d1 + d2)`` MACs per sample instead of ``d1 d2``.
    """
    t = x.astype(jnp.float32) @ w2.astype(jnp.float32).T
    return t @ w1.astype(jnp.float32).T


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True) -> jnp.ndarray:
    """Scaled dot-product attention over one head.

    ``q, k, v``: (t, hd). Causal masking by default (decoder-only model).
    """
    t, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return probs @ v.astype(jnp.float32)


def ref_rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm: ``x / rms(x) * gain`` rowwise over the last axis."""
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return x32 * (1.0 / jnp.sqrt(ms + eps)) * gain.astype(jnp.float32)


def ref_swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray) -> jnp.ndarray:
    """LLaMA FFN: ``(silu(x W_g^T) * (x W_u^T)) W_d^T``."""
    x32 = x.astype(jnp.float32)
    g = x32 @ w_gate.astype(jnp.float32).T
    u = x32 @ w_up.astype(jnp.float32).T
    act = g * (1.0 / (1.0 + jnp.exp(-g))) * u
    return act @ w_down.astype(jnp.float32).T
