"""L1 Pallas kernels for LLM-ROM (all interpret=True — CPU PJRT target).

- :mod:`covariance` — streaming Gram matrix ``Y^T Y`` (ROM pass hot-spot)
- :mod:`lowrank` — fused factored linear ``x W2^T W1^T`` (inference hot-spot)
- :mod:`attention` — causal flash-style attention (model fwd hot-spot)
- :mod:`rmsnorm` — fused RMSNorm
- :mod:`ref` — pure-jnp oracles for all of the above
"""

from .attention import causal_attention, multihead_causal_attention
from .covariance import covariance, covariance_blocked_feature
from .lowrank import lowrank_matmul
from .rmsnorm import rmsnorm

__all__ = [
    "causal_attention",
    "multihead_causal_attention",
    "covariance",
    "covariance_blocked_feature",
    "lowrank_matmul",
    "rmsnorm",
]
