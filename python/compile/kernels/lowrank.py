"""Pallas kernel: fused factored (ROM) linear layer ``x @ W2^T @ W1^T``.

The compressed-model inference hot-spot. After ROM re-parameterization a
dense layer ``W ∈ R^{d2×d1}`` becomes ``W1 ∈ R^{d2×r}``, ``W2 ∈ R^{r×d1}``
(paper §2). A naive execution materializes the intermediate ``(n, r)`` in
HBM; this kernel keeps it in VMEM and fuses both matmuls per row-block.

TPU mapping: grid over row-blocks of ``x``; per step the ``(blk_n, d1)``
input panel, both factors, and the ``(blk_n, r)`` intermediate are
VMEM-resident, and both contractions are MXU ``jnp.dot`` calls. ``r`` is
chosen by the budget allocator precisely so the factors fit on-chip — this
is the TPU translation of the paper's "two smaller linear layers".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lowrank_kernel(x_ref, w2_ref, w1_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w2 = w2_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    t = jnp.dot(x, w2.T, preferred_element_type=jnp.float32)  # (blk_n, r)
    o_ref[...] = jnp.dot(t, w1.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def lowrank_matmul(
    x: jnp.ndarray, w2: jnp.ndarray, w1: jnp.ndarray, *, block_n: int = 512
) -> jnp.ndarray:
    """Fused ``(x @ w2^T) @ w1^T``.

    ``x``: (n, d1); ``w2``: (r, d1) = V_r W; ``w1``: (d2, r) = V_r^T.
    Returns (n, d2) f32. Row-blocked; factors broadcast to every grid step.
    """
    n, d1 = x.shape
    r, d1b = w2.shape
    d2, rb = w1.shape
    assert d1 == d1b and r == rb, f"shape mismatch: x{x.shape} w2{w2.shape} w1{w1.shape}"
    blk = min(block_n, n)
    grid = (pl.cdiv(n, blk),)
    return pl.pallas_call(
        _lowrank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d1), lambda i: (i, 0)),
            pl.BlockSpec((r, d1), lambda i: (0, 0)),
            pl.BlockSpec((d2, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, d2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d2), jnp.float32),
        interpret=True,
    )(x, w2, w1)
