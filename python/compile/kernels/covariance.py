"""Pallas kernel: streaming Gram/covariance accumulation ``C = Y^T Y``.

This is the ROM-pass compute hot-spot (paper §2): for every linear layer the
calibration activations ``Y ∈ R^{n×d}`` are reduced to the symmetric
covariance ``C ∈ R^{d×d}`` whose eigenvectors are the principal components.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks row-panels of
``Y``; each step loads one ``(blk_n, d)`` panel into VMEM and performs a
rank-``blk_n`` MXU update ``C += Y_p^T Y_p`` into a VMEM-resident ``(d, d)``
accumulator. This is the classic SYRK panel schedule — what a CUDA
implementation would do with threadblock tiles in shared memory, expressed
here with a BlockSpec over the sample axis.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cov_kernel(y_ref, o_ref, *, n: int):
    """One grid step: accumulate the Gram update of one row panel."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    panel = y_ref[...].astype(jnp.float32)
    # Mask rows past the true sample count: pallas pads the trailing panel
    # with undefined values (NaN under interpret=True), which must not
    # reach the Gram sum.
    blk = panel.shape[0]
    rows = step * blk + jax.lax.broadcasted_iota(jnp.int32, panel.shape, 0)
    panel = jnp.where(rows < n, panel, 0.0)
    # MXU-shaped rank-k update: (d, blk_n) @ (blk_n, d).
    o_ref[...] += jnp.dot(panel.T, panel, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def covariance(y: jnp.ndarray, *, block_n: int = 512) -> jnp.ndarray:
    """Compute ``y^T y`` (f32) with a row-panel Pallas kernel.

    ``y``: (n, d); ``n`` need not be a multiple of ``block_n`` — Pallas pads
    the trailing panel with zeros, which contribute nothing to the Gram sum.
    """
    n, d = y.shape
    blk = min(block_n, n)
    grid = (pl.cdiv(n, blk),)
    return pl.pallas_call(
        functools.partial(_cov_kernel, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((blk, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(y)


def covariance_blocked_feature(y: jnp.ndarray, *, block_n: int = 128, block_d: int = 256) -> jnp.ndarray:
    """Feature-tiled variant for ``d`` too large for one VMEM tile.

    2-D grid: (row panel, feature-column tile j, feature-row tile i).  Each
    step computes the (i, j) output tile's contribution from one row panel.
    Used when ``d × d`` f32 exceeds the VMEM accumulator budget (~16 MB).
    """
    n, d = y.shape
    blk_n = min(block_n, n)
    blk_d = min(block_d, d)
    grid = (pl.cdiv(d, blk_d), pl.cdiv(d, blk_d), pl.cdiv(n, blk_n))

    def kernel(yi_ref, yj_ref, o_ref):
        step = pl.program_id(2)

        @pl.when(step == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        a = yi_ref[...].astype(jnp.float32)
        b = yj_ref[...].astype(jnp.float32)
        rows_a = step * blk_n + jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
        a = jnp.where(rows_a < n, a, 0.0)
        b = jnp.where(rows_a < n, b, 0.0)
        # Feature-axis padding (d % blk_d != 0) also arrives as NaN.
        cols_a = pl.program_id(0) * blk_d + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        cols_b = pl.program_id(1) * blk_d + jax.lax.broadcasted_iota(jnp.int32, b.shape, 1)
        a = jnp.where(cols_a < d, a, 0.0)
        b = jnp.where(cols_b < d, b, 0.0)
        o_ref[...] += jnp.dot(a.T, b, preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, blk_d), lambda i, j, s: (s, i)),
            pl.BlockSpec((blk_n, blk_d), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((blk_d, blk_d), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(y, y)
