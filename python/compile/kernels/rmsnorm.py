"""Pallas kernel: fused RMSNorm (normalize + gain in one VMEM pass).

Small but ubiquitous — runs twice per decoder block. Fusing avoids a
round-trip of the (n, d) activation through HBM between the reduction and
the scale. Grid over row blocks; the full feature axis lives in one tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * g


@functools.partial(jax.jit, static_argnames=("block_n", "eps"))
def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, *, eps: float = 1e-5, block_n: int = 512) -> jnp.ndarray:
    """Rowwise RMSNorm of (n, d) by (d,) gain, f32 output."""
    n, d = x.shape
    blk = min(block_n, n)
    grid = (pl.cdiv(n, blk),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(x, gain)
