"""L2: MiniLLaMA — the JAX model whose latent features LLM-ROM compresses.

Faithful scale-down of LLaMA (Touvron et al., 2023): decoder-only, RMSNorm
pre-norm, rotary position embeddings, SwiGLU FFN, tied LM head. Each decoder
module contains exactly the paper's 7 decomposable weight matrices
(wq, wk, wv, wo, w_gate, w_up, w_down).

Two execution paths:

- **eval / calibration path** (``forward_logits``, ``score_fwd``,
  ``block_capture``) — uses the L1 Pallas kernels (attention, rmsnorm);
  this is what the Rust runtime executes on the request path.
- **train path** (``train_step``, ``train_step_masked``) — pure-jnp
  compute (autodiff through interpret-mode Pallas is unsupported); AdamW
  with masked-gradient support for the pruning baseline's recovery
  fine-tune.

All public entry points operate on the *flat* parameter list defined by
:mod:`paramschema`, so the Rust side can marshal arguments positionally.
Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text once, and Python never runs at serving/compression time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import paramschema
from .config import PAD, ModelConfig
from .kernels import multihead_causal_attention, rmsnorm as pallas_rmsnorm
from .kernels.ref import ref_rmsnorm


# ---------------------------------------------------------------------------
# Positional encoding
# ---------------------------------------------------------------------------

def rope_tables(cfg: ModelConfig, seq: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(seq, hd/2) cos/sin tables for rotary embeddings."""
    hd = cfg.head_dim
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    pos = jnp.arange(seq, dtype=jnp.float32)
    angles = pos[:, None] * inv_freq[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs of channels. ``x``: (..., seq, hd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x1 * sin + x2 * cos
    return jnp.stack([out1, out2], axis=-1).reshape(x.shape)


# ---------------------------------------------------------------------------
# Shared building blocks
# ---------------------------------------------------------------------------

def _silu(x):
    return x * jax.nn.sigmoid(x)


def _norm(cfg: ModelConfig, x: jnp.ndarray, gain: jnp.ndarray, *, pallas: bool) -> jnp.ndarray:
    """RMSNorm over the last axis of (B, T, D)."""
    b, t, d = x.shape
    if pallas:
        return pallas_rmsnorm(x.reshape(b * t, d), gain, eps=cfg.norm_eps).reshape(b, t, d)
    return ref_rmsnorm(x, gain, eps=cfg.norm_eps)


def _jnp_attention(q, k, v):
    """Pure-jnp causal MHA for the differentiable train path.

    q,k,v: (B, H, T, hd) -> (B, H, T, hd).
    """
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    t = q.shape[2]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def _attention(cfg: ModelConfig, q, k, v, *, pallas: bool):
    """Dispatch (B, H, T, hd) attention to the Pallas kernel or jnp ref."""
    if not pallas:
        return _jnp_attention(q, k, v)
    return jax.vmap(multihead_causal_attention)(q, k, v)


def _split_heads(cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, h, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)


def block_forward(
    cfg: ModelConfig,
    blk: dict,
    h: jnp.ndarray,
    cos: jnp.ndarray,
    sin: jnp.ndarray,
    *,
    pallas: bool,
    capture: bool = False,
):
    """One decoder module.

    With ``capture=True`` additionally returns, for each of the 7
    decomposable matrices, its calibration input X and output Y — the raw
    material of the ROM pass (paper §2) and of the Wanda-style pruning
    importance. Shapes: X/Y over (B, T, ·).
    """
    x_attn = _norm(cfg, h, blk["attn_norm"], pallas=pallas)
    y_q = x_attn @ blk["wq"].T
    y_k = x_attn @ blk["wk"].T
    y_v = x_attn @ blk["wv"].T
    q = apply_rope(_split_heads(cfg, y_q), cos, sin)
    k = apply_rope(_split_heads(cfg, y_k), cos, sin)
    v = _split_heads(cfg, y_v)
    x_o = _merge_heads(cfg, _attention(cfg, q, k, v, pallas=pallas))
    y_o = x_o @ blk["wo"].T
    h = h + y_o

    x_ffn = _norm(cfg, h, blk["ffn_norm"], pallas=pallas)
    y_gate = x_ffn @ blk["w_gate"].T
    y_up = x_ffn @ blk["w_up"].T
    x_down = _silu(y_gate) * y_up
    y_down = x_down @ blk["w_down"].T
    h = h + y_down

    if not capture:
        return h
    captures = {
        "x_attn": x_attn, "x_o": x_o, "x_ffn": x_ffn, "x_down": x_down,
        "y_q": y_q, "y_k": y_k, "y_v": y_v, "y_o": y_o,
        "y_gate": y_gate, "y_up": y_up, "y_down": y_down,
    }
    return h, captures


def model_forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray, *, pallas: bool) -> jnp.ndarray:
    """Full forward: (B, T) int32 tokens -> (B, T, V) f32 logits."""
    h = params["embed"][tokens]
    cos, sin = rope_tables(cfg, tokens.shape[1])
    for blk in params["blocks"]:
        h = block_forward(cfg, blk, h, cos, sin, pallas=pallas)
    h = _norm(cfg, h, params["final_norm"], pallas=pallas)
    return h @ params["embed"].T  # tied LM head


def token_logprobs(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Per-position log p(target) from (B, T, V) logits."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return picked - logz


# ---------------------------------------------------------------------------
# Flat-argument entry points (what aot.py lowers)
# ---------------------------------------------------------------------------

CAPTURE_NAMES = (
    "x_attn", "x_o", "x_ffn", "x_down",
    "y_q", "y_k", "y_v", "y_o", "y_gate", "y_up", "y_down",
)


def forward_logits_flat(cfg: ModelConfig, *args):
    """args = flat params ++ [tokens (B,T) i32] -> (logits,)"""
    n = len(paramschema.param_names(cfg))
    params = paramschema.unflatten(cfg, list(args[:n]))
    tokens = args[n]
    return (model_forward(cfg, params, tokens, pallas=True),)


def score_fwd_flat(cfg: ModelConfig, *args):
    """Length-normalizable span scoring (LLaMA zero-shot protocol).

    args = flat params ++ [tokens (B,T) i32, targets (B,T) i32,
    mask (B,T) f32]. Returns per-sequence (sum log p, token count) over the
    masked span. The Rust evaluator turns these into multiple-choice
    predictions and perplexity.
    """
    n = len(paramschema.param_names(cfg))
    params = paramschema.unflatten(cfg, list(args[:n]))
    tokens, targets, mask = args[n], args[n + 1], args[n + 2]
    logits = model_forward(cfg, params, tokens, pallas=True)
    lp = token_logprobs(logits, targets) * mask
    return lp.sum(axis=-1), mask.sum(axis=-1)


def embed_fwd_flat(cfg: ModelConfig, embed: jnp.ndarray, tokens: jnp.ndarray):
    """Layerwise streaming stage 0: tokens -> hidden states."""
    return (embed[tokens],)


def block_capture_flat(cfg: ModelConfig, *args):
    """One decoder module with ROM captures.

    args = 9 block params (schema order) ++ [h (B,T,D)].
    Returns (h_out,) ++ captures in CAPTURE_NAMES order.
    """
    blk = dict(zip(paramschema.BLOCK_FIELDS, args[:9]))
    h = args[9]
    cos, sin = rope_tables(cfg, h.shape[1])
    h_out, cap = block_forward(cfg, blk, h, cos, sin, pallas=True, capture=True)
    return (h_out,) + tuple(cap[k] for k in CAPTURE_NAMES)


def block_fwd_flat(cfg: ModelConfig, *args):
    """One decoder module without captures (cheap streaming)."""
    blk = dict(zip(paramschema.BLOCK_FIELDS, args[:9]))
    h = args[9]
    cos, sin = rope_tables(cfg, h.shape[1])
    return (block_forward(cfg, blk, h, cos, sin, pallas=True),)


def head_score_flat(cfg: ModelConfig, *args):
    """Layerwise streaming final stage: hidden states -> span scores.

    args = [final_norm (D,), embed (V,D), h (B,T,D), targets (B,T) i32,
    mask (B,T) f32] -> per-sequence (sum log p, count).
    """
    final_norm, embed, h, targets, mask = args
    hn = _norm(cfg, h, final_norm, pallas=True)
    logits = hn @ embed.T
    lp = token_logprobs(logits, targets) * mask
    return lp.sum(axis=-1), mask.sum(axis=-1)


# ---------------------------------------------------------------------------
# Training (pure-jnp path, AdamW)
# ---------------------------------------------------------------------------

def _loss_fn(cfg: ModelConfig, params: dict, tokens: jnp.ndarray, targets: jnp.ndarray):
    """Mean next-token NLL, ignoring PAD targets."""
    logits = model_forward(cfg, params, tokens, pallas=False)
    lp = token_logprobs(logits, targets)
    mask = (targets != PAD).astype(jnp.float32)
    return -(lp * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _adamw_update(cfg: ModelConfig, p, g, m, v, step, lr):
    """One AdamW step for a single tensor (decay only on 2-D weights)."""
    b1, b2, eps = cfg.adam_beta1, cfg.adam_beta2, cfg.adam_eps
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1 ** step)
    vhat = v / (1.0 - b2 ** step)
    upd = mhat / (jnp.sqrt(vhat) + eps)
    if p.ndim == 2:
        upd = upd + cfg.weight_decay * p
    return p - lr * upd, m, v


def train_step_flat(cfg: ModelConfig, *args):
    """One AdamW step.

    args = flat params ++ flat m ++ flat v ++ [step f32 scalar, lr f32
    scalar, tokens (B,T) i32, targets (B,T) i32].
    Returns new params ++ new m ++ new v ++ (loss,). ``step`` is 1-based
    (bias correction).
    """
    names = paramschema.param_names(cfg)
    n = len(names)
    flat_p, flat_m, flat_v = list(args[:n]), list(args[n:2 * n]), list(args[2 * n:3 * n])
    step, lr, tokens, targets = args[3 * n], args[3 * n + 1], args[3 * n + 2], args[3 * n + 3]

    params = paramschema.unflatten(cfg, flat_p)
    loss, grads = jax.value_and_grad(lambda p: _loss_fn(cfg, p, tokens, targets))(params)
    flat_g = paramschema.flatten(cfg, grads)

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        p2, m2, v2 = _adamw_update(cfg, p, g, m, v, step, lr)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)


def train_step_masked_flat(cfg: ModelConfig, *args):
    """AdamW step that preserves structured-pruning masks.

    args = flat params ++ flat masks (one f32 mask per maskable matrix,
    schema order) ++ flat m ++ flat v ++ [step, lr, tokens, targets].
    Masks multiply both the gradients and the updated weights, so pruned
    channels stay exactly zero through the recovery fine-tune
    (LLM-Pruner's finetuned rows in Table 1).
    """
    names = paramschema.param_names(cfg)
    maskable = paramschema.maskable_names(cfg)
    n, k = len(names), len(maskable)
    flat_p = list(args[:n])
    flat_masks = list(args[n:n + k])
    flat_m = list(args[n + k:2 * n + k])
    flat_v = list(args[2 * n + k:3 * n + k])
    step, lr, tokens, targets = (
        args[3 * n + k], args[3 * n + k + 1], args[3 * n + k + 2], args[3 * n + k + 3]
    )

    mask_by_name = dict(zip(maskable, flat_masks))
    params = paramschema.unflatten(cfg, flat_p)
    loss, grads = jax.value_and_grad(lambda p: _loss_fn(cfg, p, tokens, targets))(params)
    flat_g = paramschema.flatten(cfg, grads)

    new_p, new_m, new_v = [], [], []
    for name, p, g, m, v in zip(names, flat_p, flat_g, flat_m, flat_v):
        mask = mask_by_name.get(name)
        if mask is not None:
            g = g * mask
        p2, m2, v2 = _adamw_update(cfg, p, g, m, v, step, lr)
        if mask is not None:
            p2 = p2 * mask
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """LLaMA-style init: N(0, 0.02) matrices, unit norms."""
    key = jax.random.PRNGKey(seed)

    def dense(key, shape):
        return (0.02 * jax.random.normal(key, shape)).astype(jnp.float32)

    keys = jax.random.split(key, cfg.n_layers + 1)
    params: dict = {"embed": dense(keys[0], (cfg.vocab, cfg.d_model)), "blocks": []}
    for i in range(cfg.n_layers):
        bk = jax.random.split(keys[i + 1], 7)
        d, f = cfg.d_model, cfg.d_ff
        params["blocks"].append({
            "attn_norm": jnp.ones((d,), jnp.float32),
            "wq": dense(bk[0], (d, d)),
            "wk": dense(bk[1], (d, d)),
            "wv": dense(bk[2], (d, d)),
            "wo": dense(bk[3], (d, d)),
            "ffn_norm": jnp.ones((d,), jnp.float32),
            "w_gate": dense(bk[4], (f, d)),
            "w_up": dense(bk[5], (f, d)),
            "w_down": dense(bk[6], (d, f)),
        })
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params
