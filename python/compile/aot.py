"""AOT exporter: lower every L2 entry point to HLO text + manifest.

This is the single build-time bridge between the Python world (L1/L2) and
the Rust runtime (L3). It writes into ``artifacts/``:

- ``<entry>.hlo.txt``  — HLO *text* for each entry point (text, not a
  serialized ``HloModuleProto``: jax ≥ 0.5 emits 64-bit instruction ids
  that xla_extension 0.5.1 rejects; the text parser reassigns ids — see
  /opt/xla-example/README.md).
- ``manifest.json``    — model config, tokenizer specials, canonical
  shapes, and the exact positional argument/output spec of every entry
  point (the Rust marshaller follows this, never guesses).
- ``init.rtz``         — freshly initialized parameters in the shared
  ``.rtz`` container.

Weights are *arguments* of every graph (never baked constants), so the Rust
side can train, prune, and ROM-compress without recompilation.

Usage: ``python -m compile.aot --out-dir ../artifacts [--config cfg.json]``
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, paramschema, tensorio
from .config import BOS, EOS, PAD, SEP, VOCAB_USED, ModelConfig, mini
from .kernels import covariance as cov_kernel, lowrank_matmul

# Preset per-module budgets from the paper §2.1 (90%/80%/50% global budgets
# on LLaMA-7B map to compressing the last 8/12/24 modules at these rates).
MODULE_BUDGETS = {"b60": 0.60, "b46": 0.46, "b33": 0.33}


def rank_for_budget(d_out: int, d_in: int, budget: float) -> int:
    """Paper §2.1: factored pair r(d1+d2) params vs dense d1·d2."""
    return int(budget * d_out * d_in / (d_out + d_in))


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _arg(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _param_args(cfg: ModelConfig, prefix: str = "") -> list[dict]:
    return [_arg(prefix + n, paramschema.param_shape(cfg, n)) for n in paramschema.param_names(cfg)]


def _to_specs(args: list[dict]):
    dt = {"f32": jnp.float32, "i32": jnp.int32}
    return [_spec(a["shape"], dt[a["dtype"]]) for a in args]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_entries(cfg: ModelConfig) -> dict[str, dict]:
    """Entry-point registry: fn + positional arg/output specs."""
    n_p = paramschema.param_names(cfg)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    eb, es = cfg.eval_batch, cfg.eval_seq
    tb, ts = cfg.train_batch, cfg.train_seq
    ncal = eb * es

    entries: dict[str, dict] = {}

    entries["forward_logits"] = {
        "fn": functools.partial(model.forward_logits_flat, cfg),
        "args": _param_args(cfg) + [_arg("tokens", (eb, es), "i32")],
        "outputs": [_arg("logits", (eb, es, v))],
    }
    entries["score_fwd"] = {
        "fn": functools.partial(model.score_fwd_flat, cfg),
        "args": _param_args(cfg)
        + [_arg("tokens", (eb, es), "i32"), _arg("targets", (eb, es), "i32"), _arg("mask", (eb, es))],
        "outputs": [_arg("sum_logprob", (eb,)), _arg("count", (eb,))],
    }
    entries["embed_fwd"] = {
        "fn": functools.partial(model.embed_fwd_flat, cfg),
        "args": [_arg("embed", (v, d)), _arg("tokens", (eb, es), "i32")],
        "outputs": [_arg("h", (eb, es, d))],
    }
    blk_args = [_arg(fld, paramschema.param_shape(cfg, f"blocks.0.{fld}")) for fld in paramschema.BLOCK_FIELDS]
    cap_shapes = {
        "x_attn": (eb, es, d), "x_o": (eb, es, d), "x_ffn": (eb, es, d), "x_down": (eb, es, f),
        "y_q": (eb, es, d), "y_k": (eb, es, d), "y_v": (eb, es, d), "y_o": (eb, es, d),
        "y_gate": (eb, es, f), "y_up": (eb, es, f), "y_down": (eb, es, d),
    }
    entries["block_capture"] = {
        "fn": functools.partial(model.block_capture_flat, cfg),
        "args": blk_args + [_arg("h", (eb, es, d))],
        "outputs": [_arg("h_out", (eb, es, d))]
        + [_arg(k, cap_shapes[k]) for k in model.CAPTURE_NAMES],
    }
    entries["block_fwd"] = {
        "fn": functools.partial(model.block_fwd_flat, cfg),
        "args": blk_args + [_arg("h", (eb, es, d))],
        "outputs": [_arg("h_out", (eb, es, d))],
    }
    entries["head_score"] = {
        "fn": functools.partial(model.head_score_flat, cfg),
        "args": [
            _arg("final_norm", (d,)), _arg("embed", (v, d)), _arg("h", (eb, es, d)),
            _arg("targets", (eb, es), "i32"), _arg("mask", (eb, es)),
        ],
        "outputs": [_arg("sum_logprob", (eb,)), _arg("count", (eb,))],
    }

    train_io = _param_args(cfg)
    opt_m = _param_args(cfg, "m.")
    opt_v = _param_args(cfg, "v.")
    tail = [
        _arg("step", ()), _arg("lr", ()),
        _arg("tokens", (tb, ts), "i32"), _arg("targets", (tb, ts), "i32"),
    ]
    entries["train_step"] = {
        "fn": functools.partial(model.train_step_flat, cfg),
        "args": train_io + opt_m + opt_v + tail,
        "outputs": _param_args(cfg) + opt_m + opt_v + [_arg("loss", ())],
    }
    mask_args = [
        _arg("mask." + nm, paramschema.param_shape(cfg, nm)) for nm in paramschema.maskable_names(cfg)
    ]
    entries["train_step_masked"] = {
        "fn": functools.partial(model.train_step_masked_flat, cfg),
        "args": train_io + mask_args + opt_m + opt_v + tail,
        "outputs": _param_args(cfg) + opt_m + opt_v + [_arg("loss", ())],
    }

    # L1 kernels exported standalone: ROM covariance accumulation (used by
    # the Rust ROM pass) and the factored-linear inference kernel at the
    # paper's preset module budgets (used by the perf benches).
    for dim, tag in ((d, "d"), (f, "ff")):
        entries[f"covariance_{tag}"] = {
            "fn": lambda y, _dim=dim: (cov_kernel(y),),
            "args": [_arg("y", (ncal, dim))],
            "outputs": [_arg("cov", (dim, dim))],
        }
    for key, b in MODULE_BUDGETS.items():
        r_attn = rank_for_budget(d, d, b)
        r_ffn = rank_for_budget(f, d, b)
        entries[f"lowrank_attn_{key}"] = {
            "fn": lambda x, w2, w1: (lowrank_matmul(x, w2, w1),),
            "args": [_arg("x", (ncal, d)), _arg("w2", (r_attn, d)), _arg("w1", (d, r_attn))],
            "outputs": [_arg("y", (ncal, d))],
        }
        entries[f"lowrank_ffn_{key}"] = {
            "fn": lambda x, w2, w1: (lowrank_matmul(x, w2, w1),),
            "args": [_arg("x", (ncal, d)), _arg("w2", (r_ffn, d)), _arg("w1", (f, r_ffn))],
            "outputs": [_arg("y", (ncal, f))],
        }
        entries[f"dense_attn_{key}"] = {
            # Dense counterpart for the factored-vs-dense bench.
            "fn": lambda x, w: (x @ w.T,),
            "args": [_arg("x", (ncal, d)), _arg("w", (d, d))],
            "outputs": [_arg("y", (ncal, d))],
        }
    return entries


def export(cfg: ModelConfig, out_dir: str, *, seed: int = 0, skip_unchanged: bool = True) -> None:
    os.makedirs(out_dir, exist_ok=True)
    entries = build_entries(cfg)

    manifest = {
        "format_version": 1,
        "model_config": cfg.to_json(),
        "tokenizer": {"bos": BOS, "eos": EOS, "pad": PAD, "sep": SEP, "vocab_used": VOCAB_USED},
        "param_names": paramschema.param_names(cfg),
        "maskable_names": paramschema.maskable_names(cfg),
        "capture_names": list(model.CAPTURE_NAMES),
        "module_budgets": MODULE_BUDGETS,
        "entries": {},
    }

    for name, ent in entries.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(ent["fn"]).lower(*_to_specs(ent["args"]))
        text = to_hlo_text(lowered)
        with open(path, "w") as fh:
            fh.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "args": ent["args"],
            "outputs": ent["outputs"],
        }
        print(f"  lowered {name}: {len(ent['args'])} args -> {len(ent['outputs'])} outputs, {len(text)//1024} KiB")

    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)

    params = model.init_params(cfg, seed=seed)
    flat = paramschema.flatten(cfg, params)
    tensors = {n: np.asarray(t) for n, t in zip(paramschema.param_names(cfg), flat)}
    tensorio.save(os.path.join(out_dir, "init.rtz"), tensors)
    print(f"  wrote init.rtz ({sum(t.size for t in tensors.values())} params) + manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", default=None, help="path to a ModelConfig json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = ModelConfig.from_file(args.config) if args.config else mini()
    print(f"exporting MiniLLaMA ({cfg.n_params():,} params) to {args.out_dir}")
    export(cfg, args.out_dir, seed=args.seed)


if __name__ == "__main__":
    main()
