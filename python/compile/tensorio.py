"""``.rtz`` named-tensor container — Python side (mirrored in Rust).

A deliberately tiny, dependency-free binary format used to move weights
between the build-time Python world and the runtime Rust world:

    magic  b"RTZ1"
    u32    tensor count (LE)
    repeat:
        u16   name length, then UTF-8 name
        u8    dtype  (0 = f32, 1 = i32, 2 = f64, 3 = u8)
        u8    ndim
        u64×n dims (LE)
        raw   row-major LE data

No alignment, no compression — files are small (≤ tens of MB) and both
readers stream.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"RTZ1"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.float64, 3: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.float64): 2, np.dtype(np.uint8): 3}


def save(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<Q", dim))
            f.write(arr.astype(arr.dtype, copy=False).tobytes())


def load(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            dtype = np.dtype(_DTYPES[code])
            n = int(np.prod(dims)) if dims else 1
            data = f.read(n * dtype.itemsize)
            out[name] = np.frombuffer(data, dtype=dtype).reshape(dims).copy()
    return out
