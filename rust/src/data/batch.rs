//! Batch packing: corpus -> LM training batches, task instances ->
//! multiple-choice scoring batches, calibration-set builders (the knobs of
//! the paper's Tables 2-4).
//!
//! HLO graphs are static-shape, so everything packs to the canonical
//! `(eval_batch, eval_seq)` / `(train_batch, train_seq)` shapes from the
//! manifest and pads with PAD; per-row valid lengths ride along so the ROM
//! pass can drop padded rows before covariance accumulation.

use anyhow::{bail, Result};

use crate::util::Rng;

use super::tasks::{McInstance, Split, Task, TaskKind, ALL_TASKS};
use super::tokenizer::{Tokenizer, PAD};
use super::world::World;

/// One LM training batch (flattened row-major `(batch, seq)`).
#[derive(Debug, Clone)]
pub struct LmBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Pack text into `(batch, seq)` next-token batches.
///
/// Windows are sampled at random offsets (seeded), giving shuffled epochs
/// over the corpus. `tokens[t]` predicts `targets[t]`.
pub fn pack_lm_batches(
    text: &str,
    batch: usize,
    seq: usize,
    n_batches: usize,
    seed: u64,
) -> Vec<LmBatch> {
    let tk = Tokenizer::new();
    let ids = tk.encode(text);
    assert!(ids.len() > seq + 1, "corpus shorter than one window");
    let mut rng = Rng::new(seed ^ 0xBA7C4);
    (0..n_batches)
        .map(|_| {
            let mut tokens = Vec::with_capacity(batch * seq);
            let mut targets = Vec::with_capacity(batch * seq);
            for _ in 0..batch {
                let start = rng.below(ids.len() - seq - 1);
                tokens.extend_from_slice(&ids[start..start + seq]);
                targets.extend_from_slice(&ids[start + 1..start + seq + 1]);
            }
            LmBatch { tokens, targets, batch, seq }
        })
        .collect()
}

/// Row metadata in a scoring batch: which instance/choice it encodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct McRow {
    pub instance: usize,
    pub choice: usize,
}

/// One multiple-choice scoring batch at canonical `(batch, seq)`.
///
/// `mask[t] = 1` exactly on the positions whose *target* byte belongs to
/// the choice span, implementing LLaMA's completion scoring. Rows beyond
/// the real instances are PAD rows with zero mask (their scores are
/// ignored via `rows`).
#[derive(Debug, Clone)]
pub struct McBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub rows: Vec<McRow>,
    pub batch: usize,
    pub seq: usize,
}

/// Encode `(instance, choice)` pairs into fixed-shape scoring batches.
pub fn encode_mc_batches(
    instances: &[McInstance],
    batch: usize,
    seq: usize,
) -> Result<Vec<McBatch>> {
    let tk = Tokenizer::new();
    let mut rows: Vec<(McRow, Vec<i32>, Vec<i32>, Vec<f32>)> = Vec::new();
    for (ii, inst) in instances.iter().enumerate() {
        for ci in 0..inst.choices.len() {
            let full = inst.full_text(ci);
            let bytes = tk.encode(&full);
            if bytes.len() + 1 > seq {
                bail!(
                    "instance {ii} choice {ci} needs {} tokens > seq {seq}: `{full}`",
                    bytes.len() + 1
                );
            }
            // tokens = BOS ++ bytes, padded; targets[t] = bytes[t]
            let tokens = tk.encode_fixed(&full, seq);
            let mut targets = vec![PAD; seq];
            let mut mask = vec![0.0f32; seq];
            let choice_start = inst.prompt.len() + 1; // skip the separating space
            for (t, &b) in bytes.iter().enumerate() {
                targets[t] = b;
                if t >= choice_start {
                    mask[t] = 1.0;
                }
            }
            rows.push((McRow { instance: ii, choice: ci }, tokens, targets, mask));
        }
    }

    let mut out = Vec::new();
    for chunk in rows.chunks(batch) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        let mut mask = Vec::with_capacity(batch * seq);
        let mut meta = Vec::with_capacity(chunk.len());
        for (row, tk_row, tg_row, m_row) in chunk {
            meta.push(*row);
            tokens.extend_from_slice(tk_row);
            targets.extend_from_slice(tg_row);
            mask.extend_from_slice(m_row);
        }
        // pad to full batch with PAD rows (mask 0 -> ignored)
        for _ in chunk.len()..batch {
            tokens.extend(std::iter::repeat(PAD).take(seq));
            targets.extend(std::iter::repeat(PAD).take(seq));
            mask.extend(std::iter::repeat(0.0f32).take(seq));
        }
        out.push(McBatch { tokens, targets, mask, rows: meta, batch, seq });
    }
    Ok(out)
}

/// Which distribution calibration activations come from (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CalibSource {
    /// Equal mix of all six task distributions (paper's "Combination").
    Combination,
    /// A single task's prompts (paper's "ARC-c" row).
    SingleTask(TaskKind),
    /// Generic narrative text (paper's "BookCorpus" row).
    Corpus,
}

impl CalibSource {
    pub fn name(&self) -> String {
        match self {
            CalibSource::Combination => "combination".into(),
            CalibSource::SingleTask(k) => k.name().to_string(),
            CalibSource::Corpus => "corpus".into(),
        }
    }
}

/// Calibration batch: `(batch, seq)` tokens + per-row valid lengths.
///
/// `seq_used ≤ seq` implements the paper's sequence-length ablation
/// (Table 3): rows carry at most `seq_used` real tokens, the remainder is
/// PAD, and `valid[row]` tells the ROM pass how many leading positions of
/// that row are real activations.
#[derive(Debug, Clone)]
pub struct CalibBatch {
    pub tokens: Vec<i32>,
    pub valid: Vec<usize>,
    pub batch: usize,
    pub seq: usize,
}

/// Build a calibration set of `total_rows` rows at canonical shape
/// `(batch, seq)`, with real content limited to `seq_used` tokens per row
/// (batch-size and seq-length are the Table 2/3 knobs).
pub fn build_calibration(
    world: &World,
    source: CalibSource,
    total_rows: usize,
    batch: usize,
    seq: usize,
    seq_used: usize,
    seed: u64,
) -> Vec<CalibBatch> {
    assert!(seq_used >= 8 && seq_used <= seq, "seq_used {seq_used} out of range");
    let tk = Tokenizer::new();
    let mut texts: Vec<String> = Vec::with_capacity(total_rows);
    match source {
        CalibSource::Combination => {
            // equal share per task, calib split (paper §3.3)
            let per = total_rows.div_ceil(ALL_TASKS.len());
            for kind in ALL_TASKS {
                let task = Task::new(world, kind);
                for inst in task.generate(Split::Calib, per, seed) {
                    texts.push(inst.full_text(inst.gold));
                }
            }
            let mut rng = Rng::new(seed ^ 0xCA11B);
            rng.shuffle(&mut texts[..]);
            texts.truncate(total_rows);
        }
        CalibSource::SingleTask(kind) => {
            let task = Task::new(world, kind);
            for inst in task.generate(Split::Calib, total_rows, seed) {
                texts.push(inst.full_text(inst.gold));
            }
        }
        CalibSource::Corpus => {
            // generic narrative windows
            let text = super::corpus::render_corpus(world, seed ^ 0xB00C, total_rows * seq_used * 2 + 4096, 1);
            let mut rng = Rng::new(seed ^ 0xB00C2);
            for _ in 0..total_rows {
                let start = rng.below(text.len() - seq_used - 1);
                // cut at char boundary (ascii corpus, safe) and pack
                texts.push(text[start..start + seq_used - 1].to_string());
            }
        }
    }

    let mut batches = Vec::new();
    for chunk in texts.chunks(batch) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut valid = Vec::with_capacity(batch);
        for t in chunk {
            let mut row = tk.encode_fixed(t, seq);
            // enforce the seq_used budget: blank everything beyond it
            for x in row.iter_mut().skip(seq_used) {
                *x = PAD;
            }
            let vlen = row.iter().take_while(|&&x| x != PAD).count().min(seq_used);
            tokens.extend_from_slice(&row);
            valid.push(vlen);
        }
        for _ in chunk.len()..batch {
            tokens.extend(std::iter::repeat(PAD).take(seq));
            valid.push(0);
        }
        batches.push(CalibBatch { tokens, valid, batch, seq });
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::default_world(42)
    }

    #[test]
    fn lm_batches_shift_by_one() {
        let w = world();
        let text = super::super::corpus::render_corpus(&w, 0, 20_000, 1);
        let bs = pack_lm_batches(&text, 4, 32, 3, 0);
        assert_eq!(bs.len(), 3);
        for b in &bs {
            assert_eq!(b.tokens.len(), 4 * 32);
            for row in 0..4 {
                for t in 0..31 {
                    assert_eq!(b.tokens[row * 32 + t + 1], b.targets[row * 32 + t]);
                }
            }
        }
    }

    #[test]
    fn mc_mask_covers_choice_only() {
        let w = world();
        let task = Task::new(&w, TaskKind::BoolLike);
        let insts = task.generate(Split::Eval, 3, 0);
        let batches = encode_mc_batches(&insts, 8, 128).unwrap();
        let b = &batches[0];
        let tk = Tokenizer::new();
        for (r, row) in b.rows.iter().enumerate() {
            let inst = &insts[row.instance];
            let masked: Vec<i32> = (0..128)
                .filter(|&t| b.mask[r * 128 + t] > 0.0)
                .map(|t| b.targets[r * 128 + t])
                .collect();
            let text = tk.decode(&masked);
            assert_eq!(text, inst.choices[row.choice], "row {r}");
        }
    }

    #[test]
    fn mc_batches_pad_to_full() {
        let w = world();
        let task = Task::new(&w, TaskKind::QaEasy); // 4 choices
        let insts = task.generate(Split::Eval, 3, 0); // 12 rows
        let batches = encode_mc_batches(&insts, 8, 128).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].rows.len(), 8);
        assert_eq!(batches[1].rows.len(), 4);
        assert_eq!(batches[1].tokens.len(), 8 * 128); // padded
    }

    #[test]
    fn calibration_sources_build() {
        let w = world();
        for source in [
            CalibSource::Combination,
            CalibSource::SingleTask(TaskKind::QaHard),
            CalibSource::Corpus,
        ] {
            let bs = build_calibration(&w, source, 20, 8, 128, 128, 1);
            assert_eq!(bs.len(), 3, "{source:?}");
            let rows: usize = bs.iter().map(|b| b.valid.iter().filter(|&&v| v > 0).count()).sum();
            assert_eq!(rows, 20, "{source:?}");
        }
    }

    #[test]
    fn seq_used_limits_valid_lengths() {
        let w = world();
        let bs = build_calibration(&w, CalibSource::Combination, 16, 8, 128, 32, 2);
        for b in &bs {
            for (row, &v) in b.valid.iter().enumerate() {
                assert!(v <= 32);
                // tokens beyond seq_used are PAD
                for t in 32..128 {
                    assert_eq!(b.tokens[row * 128 + t], PAD);
                }
            }
        }
    }

    #[test]
    fn calibration_is_deterministic() {
        let w = world();
        let a = build_calibration(&w, CalibSource::Combination, 16, 8, 128, 64, 5);
        let b = build_calibration(&w, CalibSource::Combination, 16, 8, 128, 64, 5);
        assert_eq!(a[0].tokens, b[0].tokens);
    }
}
