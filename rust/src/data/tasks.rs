//! The six SynthSense zero-shot tasks — analogs of the paper's benchmark
//! suite (BoolQ, PIQA, HellaSwag, WinoGrande, ARC-e, ARC-c).
//!
//! Every task emits [`McInstance`]s: a prompt, N choices, one gold index.
//! Scoring follows LLaMA's protocol (length-normalized sequence
//! log-likelihood over the choice span, implemented in `crate::eval`).
//! Instances are drawn from split-disjoint streams: `Split::Calib` and
//! `Split::Eval` use different RNG streams and (where applicable) different
//! entity subsets, mirroring the paper's "no data leakage" constraint.

use crate::util::Rng;

use super::world::{World, COLORS, MATERIALS, USES};

/// Task identifiers, ordered as in the paper's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// yes/no fact verification (BoolQ analog)
    BoolLike,
    /// physical affordance, 2 choices (PIQA analog)
    PhysLike,
    /// contextual continuation, 4 choices (HellaSwag analog)
    ContLike,
    /// give-event coreference, 2 choices (WinoGrande analog)
    CorefLike,
    /// single-hop attribute QA, 4 choices (ARC-easy analog)
    QaEasy,
    /// two-hop attribute QA, 4 choices (ARC-challenge analog)
    QaHard,
}

pub const ALL_TASKS: [TaskKind; 6] = [
    TaskKind::BoolLike,
    TaskKind::PhysLike,
    TaskKind::ContLike,
    TaskKind::CorefLike,
    TaskKind::QaEasy,
    TaskKind::QaHard,
];

impl TaskKind {
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::BoolLike => "synth-boolq",
            TaskKind::PhysLike => "synth-piqa",
            TaskKind::ContLike => "synth-hellaswag",
            TaskKind::CorefLike => "synth-winogrande",
            TaskKind::QaEasy => "synth-arc-e",
            TaskKind::QaHard => "synth-arc-c",
        }
    }

    /// Paper column this task stands in for.
    pub fn paper_name(self) -> &'static str {
        match self {
            TaskKind::BoolLike => "BoolQ",
            TaskKind::PhysLike => "PIQA",
            TaskKind::ContLike => "HellaSwag",
            TaskKind::CorefLike => "WinoGrande",
            TaskKind::QaEasy => "ARC-e",
            TaskKind::QaHard => "ARC-c",
        }
    }

    pub fn n_choices(self) -> usize {
        match self {
            TaskKind::BoolLike | TaskKind::PhysLike | TaskKind::CorefLike => 2,
            TaskKind::ContLike | TaskKind::QaEasy | TaskKind::QaHard => 4,
        }
    }
}

/// Instance stream. All three are pairwise-disjoint RNG streams:
/// `Train` instances are rendered into the LM pretraining corpus (the
/// analog of benchmark train splits / QA text in web pretraining data),
/// `Calib` feeds the ROM covariance pass, `Eval` is never seen before
/// evaluation. In a small synthetic world some prompt-level collisions
/// across streams are unavoidable (the instance space is finite); the
/// streams are disjoint by construction, which is the property the
/// paper's "no data leakage" setup needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Calib,
    Eval,
}

/// One multiple-choice instance.
#[derive(Debug, Clone)]
pub struct McInstance {
    pub task: TaskKind,
    pub prompt: String,
    pub choices: Vec<String>,
    pub gold: usize,
}

impl McInstance {
    /// Full text of choice `i` (prompt ++ choice), as scored by the model.
    pub fn full_text(&self, i: usize) -> String {
        format!("{} {}", self.prompt, self.choices[i])
    }
}

/// Task generator over a world.
pub struct Task<'w> {
    world: &'w World,
    kind: TaskKind,
}

impl<'w> Task<'w> {
    pub fn new(world: &'w World, kind: TaskKind) -> Self {
        Task { world, kind }
    }

    /// Generate `count` instances for `split`. Streams for the two splits
    /// are disjoint by construction (independent RNG forks).
    pub fn generate(&self, split: Split, count: usize, seed: u64) -> Vec<McInstance> {
        let tag = match split {
            Split::Train => 0x33,
            Split::Calib => 0x11,
            Split::Eval => 0x22,
        };
        let mut rng = Rng::new(seed ^ (tag as u64) << 32 ^ self.kind as u64);
        (0..count).map(|_| self.instance(&mut rng)).collect()
    }

    fn instance(&self, rng: &mut Rng) -> McInstance {
        match self.kind {
            TaskKind::BoolLike => self.bool_like(rng),
            TaskKind::PhysLike => self.phys_like(rng),
            TaskKind::ContLike => self.cont_like(rng),
            TaskKind::CorefLike => self.coref_like(rng),
            TaskKind::QaEasy => self.qa_easy(rng),
            TaskKind::QaHard => self.qa_hard(rng),
        }
    }

    fn bool_like(&self, rng: &mut Rng) -> McInstance {
        let w = self.world;
        let p = rng.below(w.n_people());
        let truth = rng.chance(0.5);
        let loc = if truth {
            w.person_loc[p]
        } else {
            // any wrong location
            let mut l = rng.below(w.locations.len());
            while l == w.person_loc[p] {
                l = rng.below(w.locations.len());
            }
            l
        };
        McInstance {
            task: self.kind,
            prompt: format!("question : is {} in the {} ? answer :", w.people[p], w.locations[loc]),
            choices: vec!["yes".into(), "no".into()],
            gold: if truth { 0 } else { 1 },
        }
    }

    fn phys_like(&self, rng: &mut Rng) -> McInstance {
        let w = self.world;
        let use_ = rng.below(USES.len());
        let gold_obj = w.object_for_use(use_).expect("every use has an object");
        let distractors = w.objects_without_use(use_);
        let wrong = distractors[rng.below(distractors.len())];
        let gold_pos = rng.below(2);
        let mut choices = vec![String::new(); 2];
        choices[gold_pos] = w.objects[gold_obj].name.clone();
        choices[1 - gold_pos] = w.objects[wrong].name.clone();
        McInstance {
            task: self.kind,
            prompt: format!("to {} people use the", USES[use_]),
            choices,
            gold: gold_pos,
        }
    }

    fn cont_like(&self, rng: &mut Rng) -> McInstance {
        let w = self.world;
        let p = rng.below(w.n_people());
        let friend = w.person_friend[p];
        let gold_obj = w.person_likes[p];
        let mut choice_idx = vec![gold_obj];
        while choice_idx.len() < 4 {
            let o = rng.below(w.n_objects());
            if !choice_idx.contains(&o) {
                choice_idx.push(o);
            }
        }
        rng.shuffle(&mut choice_idx[..]);
        let gold = choice_idx.iter().position(|&o| o == gold_obj).unwrap();
        McInstance {
            task: self.kind,
            prompt: format!(
                "{} is friends with {} . {} likes the",
                w.people[p], w.people[friend], w.people[p]
            ),
            choices: choice_idx.iter().map(|&o| w.objects[o].name.clone()).collect(),
            gold,
        }
    }

    fn coref_like(&self, rng: &mut Rng) -> McInstance {
        let w = self.world;
        let e = w.events[rng.below(w.events.len())];
        let obj = &w.objects[e.object].name;
        let ask_receiver = rng.chance(0.5);
        let (question, gold_person, other) = if ask_receiver {
            ("who has", e.receiver, e.giver)
        } else {
            ("who gave", e.giver, e.receiver)
        };
        let gold_pos = rng.below(2);
        let mut choices = vec![String::new(); 2];
        choices[gold_pos] = w.people[gold_person].clone();
        choices[1 - gold_pos] = w.people[other].clone();
        let tail = if ask_receiver { "now ? answer :" } else { "away ? answer :" };
        McInstance {
            task: self.kind,
            prompt: format!(
                "{} gave the {} to {} . question : {} the {} {tail}",
                w.people[e.giver], obj, w.people[e.receiver], question, obj
            ),
            choices,
            gold: gold_pos,
        }
    }

    fn qa_easy(&self, rng: &mut Rng) -> McInstance {
        let w = self.world;
        let o = rng.below(w.n_objects());
        let obj = &w.objects[o];
        // rotate among three attribute families
        let (question, gold_text, pool): (String, &str, &[&str]) = match rng.below(3) {
            0 => (
                format!("question : what is the {} made of ? answer :", obj.name),
                MATERIALS[obj.material],
                &MATERIALS,
            ),
            1 => (
                format!("question : what color is the {} ? answer :", obj.name),
                COLORS[obj.color],
                &COLORS,
            ),
            _ => (
                format!("question : what is the {} used to do ? answer :", obj.name),
                USES[obj.use_],
                &USES,
            ),
        };
        let (choices, gold) = four_choices(rng, gold_text, pool);
        McInstance { task: self.kind, prompt: question, choices, gold }
    }

    fn qa_hard(&self, rng: &mut Rng) -> McInstance {
        // two-hop: person -> liked object -> attribute
        let w = self.world;
        let p = rng.below(w.n_people());
        let obj = &w.objects[w.person_likes[p]];
        let (question, gold_text, pool): (String, &str, &[&str]) = if rng.chance(0.5) {
            (
                format!("question : what is the thing {} likes made of ? answer :", w.people[p]),
                MATERIALS[obj.material],
                &MATERIALS,
            )
        } else {
            (
                format!("question : what color is the thing {} likes ? answer :", w.people[p]),
                COLORS[obj.color],
                &COLORS,
            )
        };
        let (choices, gold) = four_choices(rng, gold_text, pool);
        McInstance { task: self.kind, prompt: question, choices, gold }
    }
}

/// Gold + 3 distinct distractors from `pool`, shuffled.
fn four_choices(rng: &mut Rng, gold_text: &str, pool: &[&str]) -> (Vec<String>, usize) {
    let mut picks: Vec<&str> = vec![gold_text];
    while picks.len() < 4 {
        let c = pool[rng.below(pool.len())];
        if !picks.contains(&c) {
            picks.push(c);
        }
    }
    rng.shuffle(&mut picks[..]);
    let gold = picks.iter().position(|&c| c == gold_text).unwrap();
    (picks.into_iter().map(String::from).collect(), gold)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::default_world(42)
    }

    #[test]
    fn all_tasks_generate_valid_instances() {
        let w = world();
        for kind in ALL_TASKS {
            let task = Task::new(&w, kind);
            let xs = task.generate(Split::Eval, 50, 1);
            assert_eq!(xs.len(), 50);
            for x in &xs {
                assert_eq!(x.choices.len(), kind.n_choices(), "{:?}", kind);
                assert!(x.gold < x.choices.len());
                // choices distinct
                let mut c = x.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), x.choices.len(), "dup choices in {:?}: {:?}", kind, x.choices);
                assert!(!x.prompt.is_empty());
            }
        }
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let w = world();
        for kind in ALL_TASKS {
            let task = Task::new(&w, kind);
            let a = task.generate(Split::Calib, 20, 1);
            let b = task.generate(Split::Eval, 20, 1);
            let same = a
                .iter()
                .zip(&b)
                .filter(|(x, y)| x.prompt == y.prompt && x.gold == y.gold)
                .count();
            assert!(same < 20, "{:?}: calib/eval streams identical", kind);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let w = world();
        let t = Task::new(&w, TaskKind::QaHard);
        let a = t.generate(Split::Eval, 10, 3);
        let b = t.generate(Split::Eval, 10, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.choices, y.choices);
            assert_eq!(x.gold, y.gold);
        }
    }

    #[test]
    fn bool_task_balanced() {
        let w = world();
        let t = Task::new(&w, TaskKind::BoolLike);
        let xs = t.generate(Split::Eval, 400, 5);
        let yes = xs.iter().filter(|x| x.gold == 0).count();
        assert!(yes > 120 && yes < 280, "yes={yes}/400");
    }

    #[test]
    fn gold_positions_unbiased() {
        // degenerate scorers should not beat chance by position
        let w = world();
        for kind in [TaskKind::PhysLike, TaskKind::QaEasy] {
            let t = Task::new(&w, kind);
            let xs = t.generate(Split::Eval, 400, 7);
            let pos0 = xs.iter().filter(|x| x.gold == 0).count() as f64 / 400.0;
            let chance = 1.0 / kind.n_choices() as f64;
            assert!((pos0 - chance).abs() < 0.1, "{:?}: pos0 {pos0}", kind);
        }
    }

    #[test]
    fn qa_hard_is_two_hop_consistent() {
        let w = world();
        let t = Task::new(&w, TaskKind::QaHard);
        for x in t.generate(Split::Eval, 30, 9) {
            // the gold choice must be the attribute of the liked object of
            // the person named in the prompt
            let person = w
                .people
                .iter()
                .position(|p| x.prompt.contains(p.as_str()))
                .expect("person in prompt");
            let obj = &w.objects[w.person_likes[person]];
            let gold = &x.choices[x.gold];
            assert!(
                gold == MATERIALS[obj.material] || gold == COLORS[obj.color],
                "gold {gold} not an attribute of {}",
                obj.name
            );
        }
    }

    #[test]
    fn full_text_concatenates() {
        let w = world();
        let t = Task::new(&w, TaskKind::BoolLike);
        let x = &t.generate(Split::Eval, 1, 0)[0];
        assert_eq!(x.full_text(0), format!("{} yes", x.prompt));
    }
}
