//! Byte-level tokenizer: ids 0..=255 are raw bytes, plus BOS/EOS/PAD/SEP.
//!
//! Matches `python/compile/config.py` (asserted against the manifest's
//! tokenizer spec at runtime). Byte-level keeps the substrate honest — no
//! vocabulary tuning can leak task structure into the model.

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;
pub const SEP: i32 = 259;
pub const VOCAB_USED: usize = 260;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Tokenizer {
        Tokenizer
    }

    /// Encode text to byte tokens (no specials).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    /// Decode, skipping special ids.
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// `BOS ++ bytes(text)`, truncated/padded to `len` with PAD.
    pub fn encode_fixed(&self, text: &str, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        out.push(BOS);
        out.extend(self.encode(text));
        out.truncate(len);
        while out.len() < len {
            out.push(PAD);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tk = Tokenizer::new();
        let text = "the ball is red .";
        assert_eq!(tk.decode(&tk.encode(text)), text);
    }

    #[test]
    fn encode_fixed_pads_and_truncates() {
        let tk = Tokenizer::new();
        let v = tk.encode_fixed("ab", 6);
        assert_eq!(v, vec![BOS, b'a' as i32, b'b' as i32, PAD, PAD, PAD]);
        let w = tk.encode_fixed("abcdefgh", 4);
        assert_eq!(w.len(), 4);
        assert_eq!(w[0], BOS);
        assert_eq!(w[3], b'c' as i32);
    }

    #[test]
    fn specials_skipped_in_decode() {
        let tk = Tokenizer::new();
        assert_eq!(tk.decode(&[BOS, b'h' as i32, b'i' as i32, PAD, EOS]), "hi");
    }

    #[test]
    fn ids_fit_used_vocab() {
        assert!(SEP < VOCAB_USED as i32);
        assert_eq!(VOCAB_USED, 260);
    }
}
