//! Data substrate: the synthetic world, its narrative corpus, the six
//! SynthSense zero-shot tasks, the byte-level tokenizer, and batch packing.
//!
//! Why synthetic (DESIGN.md §2): the paper evaluates LLaMA-7B on six
//! commonsense benchmarks we cannot ship. The substitution preserves the
//! *protocol* — a decoder LM trained on a corpus of facts, evaluated
//! zero-shot by length-normalized multiple-choice scoring on task
//! distributions that mirror the papers' difficulty spread, with disjoint
//! calibration/eval splits.

pub mod batch;
pub mod corpus;
pub mod tasks;
pub mod tokenizer;
pub mod world;

pub use batch::{
    build_calibration, encode_mc_batches, pack_lm_batches, CalibBatch, CalibSource, LmBatch,
    McBatch, McRow,
};
pub use corpus::render_corpus;
pub use tasks::{McInstance, Split, Task, TaskKind, ALL_TASKS};
pub use tokenizer::{Tokenizer, BOS, EOS, PAD, SEP, VOCAB_USED};
pub use world::World;
