//! Deterministic synthetic world: entities, attributes, relations, events.
//!
//! The world is the ground truth behind both the training corpus and the
//! six SynthSense tasks. All structure flows from one seed, so every
//! experiment regenerates identically.

use crate::util::Rng;

/// Fixed attribute vocabularies (small, regular, byte-tokenizer friendly).
pub const MATERIALS: [&str; 8] =
    ["wood", "metal", "glass", "rubber", "stone", "cloth", "paper", "clay"];
pub const COLORS: [&str; 8] = ["red", "blue", "green", "black", "white", "brown", "grey", "pink"];
pub const USES: [&str; 8] = [
    "carry water", "cut bread", "dig soil", "light a fire",
    "sweep dust", "catch fish", "open doors", "write notes",
];
pub const SIZES: [&str; 2] = ["small", "big"];

/// One physical object and its attributes.
#[derive(Debug, Clone)]
pub struct Object {
    pub name: String,
    pub material: usize,
    pub color: usize,
    pub use_: usize,
    pub size: usize,
}

/// A give-event: `giver` gave `object` to `receiver`.
#[derive(Debug, Clone, Copy)]
pub struct GiveEvent {
    pub giver: usize,
    pub object: usize,
    pub receiver: usize,
}

/// The complete world state.
#[derive(Debug, Clone)]
pub struct World {
    pub seed: u64,
    pub people: Vec<String>,
    pub objects: Vec<Object>,
    pub locations: Vec<String>,
    /// person -> location index
    pub person_loc: Vec<usize>,
    /// person -> liked object index
    pub person_likes: Vec<usize>,
    /// person -> friend (person index, != self)
    pub person_friend: Vec<usize>,
    pub events: Vec<GiveEvent>,
}

fn make_names(rng: &mut Rng, count: usize, syllables: usize) -> Vec<String> {
    const C: &[u8] = b"bdfgklmnprstvz";
    const V: &[u8] = b"aeiou";
    let mut out: Vec<String> = Vec::with_capacity(count);
    while out.len() < count {
        let mut name = String::new();
        for _ in 0..syllables {
            name.push(C[rng.below(C.len())] as char);
            name.push(V[rng.below(V.len())] as char);
        }
        name.push(C[rng.below(C.len())] as char);
        if !out.contains(&name) {
            out.push(name);
        }
    }
    out
}

impl World {
    /// Generate a world with `n_people` people, `n_objects` objects and
    /// `n_locations` locations.
    pub fn generate(seed: u64, n_people: usize, n_objects: usize, n_locations: usize) -> World {
        assert!(n_people >= 2 && n_objects >= 4 && n_locations >= 2);
        let mut rng = Rng::new(seed ^ 0x5EED_0001);
        let people = make_names(&mut rng, n_people, 2);
        let object_names = make_names(&mut rng, n_objects, 1);
        let locations = make_names(&mut rng, n_locations, 2);

        let objects: Vec<Object> = object_names
            .into_iter()
            .enumerate()
            .map(|(i, name)| Object {
                name,
                // spread attributes so every material/use occurs
                material: if i < MATERIALS.len() { i } else { rng.below(MATERIALS.len()) },
                color: rng.below(COLORS.len()),
                use_: if i < USES.len() { i } else { rng.below(USES.len()) },
                size: rng.below(SIZES.len()),
            })
            .collect();

        let person_loc = (0..n_people).map(|_| rng.below(n_locations)).collect();
        let person_likes = (0..n_people).map(|_| rng.below(n_objects)).collect();
        let person_friend = (0..n_people)
            .map(|i| {
                let mut f = rng.below(n_people);
                while f == i {
                    f = rng.below(n_people);
                }
                f
            })
            .collect();

        // one give-event per person (giver i)
        let events = (0..n_people)
            .map(|giver| {
                let mut receiver = rng.below(n_people);
                while receiver == giver {
                    receiver = rng.below(n_people);
                }
                GiveEvent { giver, object: rng.below(n_objects), receiver }
            })
            .collect();

        World { seed, people, objects, locations, person_loc, person_likes, person_friend, events }
    }

    /// Default reproduction world.
    pub fn default_world(seed: u64) -> World {
        World::generate(seed, 24, 16, 8)
    }

    pub fn n_people(&self) -> usize {
        self.people.len()
    }

    pub fn n_objects(&self) -> usize {
        self.objects.len()
    }

    /// Objects that have a *different* use than `use_` (PIQA distractors).
    pub fn objects_without_use(&self, use_: usize) -> Vec<usize> {
        (0..self.objects.len()).filter(|&i| self.objects[i].use_ != use_).collect()
    }

    /// The object that serves `use_` (first match).
    pub fn object_for_use(&self, use_: usize) -> Option<usize> {
        (0..self.objects.len()).find(|&i| self.objects[i].use_ == use_)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = World::default_world(7);
        let b = World::default_world(7);
        assert_eq!(a.people, b.people);
        assert_eq!(a.person_loc, b.person_loc);
        assert_eq!(a.objects.len(), b.objects.len());
    }

    #[test]
    fn different_seeds_differ() {
        let a = World::default_world(1);
        let b = World::default_world(2);
        assert!(a.people != b.people || a.person_loc != b.person_loc);
    }

    #[test]
    fn names_unique() {
        let w = World::default_world(3);
        let mut names = w.people.clone();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), w.people.len());
    }

    #[test]
    fn friends_not_self() {
        let w = World::default_world(4);
        for (i, &f) in w.person_friend.iter().enumerate() {
            assert_ne!(i, f);
        }
    }

    #[test]
    fn every_use_has_an_object() {
        let w = World::default_world(5);
        for u in 0..USES.len() {
            assert!(w.object_for_use(u).is_some(), "use {u}");
        }
    }

    #[test]
    fn events_well_formed() {
        let w = World::default_world(6);
        assert_eq!(w.events.len(), w.n_people());
        for e in &w.events {
            assert_ne!(e.giver, e.receiver);
            assert!(e.object < w.n_objects());
        }
    }
}
