//! Narrative corpus renderer: world facts -> training text.
//!
//! Each fact is rendered through several paraphrase templates and shuffled;
//! the model must memorize the world to predict the corpus, which is what
//! makes zero-shot task evaluation meaningful. Also provides the
//! "BookCorpus" analog: generic narrative text that mentions entities but
//! not in task format (Table 4's generic calibration set).

use crate::util::Rng;

use super::world::{GiveEvent, World, COLORS, MATERIALS, SIZES, USES};

/// Render all fact sentences (each fact in every paraphrase).
pub fn fact_sentences(world: &World) -> Vec<String> {
    let mut out = Vec::new();
    for (p, name) in world.people.iter().enumerate() {
        let loc = &world.locations[world.person_loc[p]];
        let obj = &world.objects[world.person_likes[p]].name;
        let friend = &world.people[world.person_friend[p]];
        out.push(format!("{name} is in the {loc} ."));
        out.push(format!("you can find {name} in the {loc} ."));
        out.push(format!("{name} likes the {obj} ."));
        out.push(format!("the favorite thing of {name} is the {obj} ."));
        out.push(format!("{name} is friends with {friend} ."));
    }
    for o in &world.objects {
        let (name, mat, col, use_, size) = (
            &o.name,
            MATERIALS[o.material],
            COLORS[o.color],
            USES[o.use_],
            SIZES[o.size],
        );
        out.push(format!("the {name} is made of {mat} ."));
        out.push(format!("{mat} is what the {name} is made of ."));
        out.push(format!("the {name} is {col} ."));
        out.push(format!("the {name} is used to {use_} ."));
        out.push(format!("to {use_} people use the {name} ."));
        out.push(format!("the {name} is {size} ."));
    }
    for &GiveEvent { giver, object, receiver } in &world.events {
        let g = &world.people[giver];
        let o = &world.objects[object].name;
        let r = &world.people[receiver];
        out.push(format!("{g} gave the {o} to {r} ."));
        out.push(format!("now {r} has the {o} ."));
        out.push(format!("{r} got the {o} from {g} ."));
    }
    out
}

/// Filler narrative (the BookCorpus analog): grammatical, on-vocabulary,
/// but carrying no task-critical facts.
pub fn filler_sentences(world: &World, rng: &mut Rng, count: usize) -> Vec<String> {
    let verbs = ["walked to", "looked at", "talked about", "sat near", "thought about"];
    let days = ["one day", "later", "in the morning", "after that", "at night"];
    (0..count)
        .map(|_| {
            let p = rng.choose(&world.people);
            let d = rng.choose(&days);
            match rng.below(3) {
                0 => {
                    let l = rng.choose(&world.locations);
                    format!("{d} {p} {} the {l} .", rng.choose(&verbs))
                }
                1 => {
                    let o = &rng.choose(&world.objects).name;
                    format!("{d} {p} {} the {o} .", rng.choose(&verbs))
                }
                _ => {
                    let q = rng.choose(&world.people);
                    format!("{d} {p} {} {q} .", rng.choose(&verbs))
                }
            }
        })
        .collect()
}

/// Task-format demonstrations from the **train split** of every task —
/// the analog of QA text in web pretraining corpora (and of benchmark
/// train splits). Without these a 1.6M-param byte LM cannot zero-shot
/// transfer to the "question : … answer :" format at all; with them the
/// knowledge still has to come from the narrative facts. The train
/// instance stream is disjoint from calib/eval (see `tasks::Split`).
pub fn qa_sentences(world: &World, seed: u64, per_task: usize) -> Vec<String> {
    use super::tasks::{Split, Task, ALL_TASKS};
    let mut out = Vec::with_capacity(per_task * ALL_TASKS.len());
    for kind in ALL_TASKS {
        let task = Task::new(world, kind);
        for inst in task.generate(Split::Train, per_task, seed) {
            out.push(inst.full_text(inst.gold));
        }
    }
    out
}

/// Full training corpus: facts repeated + QA demonstrations + filler,
/// shuffled, concatenated. `target_chars` bounds the size; facts are
/// up-weighted (repeated `fact_repeat`×) relative to filler so attributes
/// are learned firmly.
pub fn render_corpus(world: &World, seed: u64, target_chars: usize, fact_repeat: usize) -> String {
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let facts = fact_sentences(world);
    let qa = qa_sentences(world, seed ^ 0x9A, facts.len() / 4);
    let mut sentences: Vec<String> = Vec::new();
    while sentences.iter().map(|s| s.len() + 1).sum::<usize>() < target_chars {
        for _ in 0..fact_repeat {
            sentences.extend(facts.iter().cloned());
            sentences.extend(qa.iter().cloned());
        }
        sentences.extend(filler_sentences(world, &mut rng, facts.len()));
    }
    rng.shuffle(&mut sentences);
    let mut text = String::with_capacity(target_chars + 128);
    for s in sentences {
        text.push_str(&s);
        text.push(' ');
        if text.len() >= target_chars {
            break;
        }
    }
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facts_cover_all_entities() {
        let w = World::default_world(1);
        let text = fact_sentences(&w).join(" ");
        for p in &w.people {
            assert!(text.contains(p.as_str()), "person {p}");
        }
        for o in &w.objects {
            assert!(text.contains(&o.name), "object {}", o.name);
        }
    }

    #[test]
    fn corpus_reaches_target_size() {
        let w = World::default_world(2);
        let text = render_corpus(&w, 0, 50_000, 2);
        assert!(text.len() >= 50_000);
        assert!(text.len() < 60_000);
    }

    #[test]
    fn corpus_is_deterministic() {
        let w = World::default_world(3);
        assert_eq!(render_corpus(&w, 5, 10_000, 1), render_corpus(&w, 5, 10_000, 1));
        assert_ne!(render_corpus(&w, 5, 10_000, 1), render_corpus(&w, 6, 10_000, 1));
    }

    #[test]
    fn corpus_is_ascii_lowercase() {
        let w = World::default_world(4);
        let text = render_corpus(&w, 0, 5_000, 1);
        assert!(text.is_ascii());
        assert!(!text.chars().any(|c| c.is_ascii_uppercase()));
    }

    #[test]
    fn filler_mentions_no_attribute_facts() {
        let w = World::default_world(5);
        let mut rng = Rng::new(0);
        let fillers = filler_sentences(&w, &mut rng, 200);
        for f in &fillers {
            assert!(!f.contains("made of"), "{f}");
            assert!(!f.contains("is used to"), "{f}");
        }
    }
}
