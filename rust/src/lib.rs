//! # LLM-ROM — Reduced Order Modelling of Latent Features in LLMs
//!
//! Rust coordinator (L3) of the three-layer reproduction of
//! *"Rethinking Compression: Reduced Order Modelling of Latent Features in
//! Large Language Models"* (ICLR 2024).
//!
//! The request path is pure Rust: this crate loads HLO artifacts lowered
//! once at build time from JAX/Pallas (`python/compile/`), executes them on
//! the PJRT CPU client, and implements the paper's CPU-side algorithm —
//! activation-covariance eigendecomposition, rank selection, and low-rank
//! re-parameterization — natively.
//!
//! Module map (see DESIGN.md for the full inventory):
//! - [`exec`] — the unified parallel execution core: [`exec::ExecPool`]
//!   (scoped worker pool with deterministic `parallel_for`/`parallel_map`
//!   fan-out — static chunking into pre-sized slots, bitwise-identical
//!   output for any thread count) and the global [`exec::ExecConfig`]
//!   `--threads` knob shared by the matmul kernels, the ROM pipeline,
//!   the serve engine, and the decode scheduler
//! - [`engine`] — the unified request lifecycle: one streaming inference
//!   core ([`engine::EngineCore`] / [`engine::Session`]) with a priced,
//!   bounded admission queue ([`engine::Scheduler`]: per-tier MAC token
//!   buckets, earliest-deadline-first ordering, batch preemption at
//!   token boundaries, per-tenant fairness ledger — reducing exactly to
//!   FIFO for single-tier/no-deadline/unlimited-meter configs),
//!   per-request event streams
//!   (`Admitted`/`Prefilled`/`Token`/`Finished`), cancellation and
//!   deadline eviction — the substrate both [`serve`] and [`decode`]
//!   front-ends adapt, with event order bitwise invariant to `--threads`
//! - [`linalg`] — dense matrix substrate + symmetric eigensolvers, plus
//!   [`linalg::simd`]: the serving hot path's portable SIMD microkernels
//!   (fixed-lane-order dot/axpy, cache-aware packed weight panels
//!   ([`linalg::simd::PackedWeight`]), per-row int8 quantized factors
//!   ([`linalg::simd::QuantizedWeight`]), vectorized rmsnorm, and the
//!   shared [`linalg::simd::RopeTable`] sin/cos cache) — every f32 kernel
//!   bitwise identical to its scalar oracle and to itself at any
//!   `--threads`
//! - [`tensor`] — named tensors and the `.rtz` interchange container
//! - [`runtime`] — PJRT executable loading/caching/marshalling
//! - [`model`] — MiniLLaMA schema, parameter store, MACs accounting and
//!   the [`model::macs::CostModel`] request pricer (analytic
//!   prefill/decode MACs + KV bytes, quoted before a request runs)
//! - [`data`] — synthetic world, corpus, SynthSense tasks, tokenizer
//! - [`rom`] — the paper's engine: layerwise ROM decomposition
//! - [`prune`] — structured-pruning engine (channels + heads, ± masks)
//! - [`compress`] — the unified compression API: the [`compress::Compressor`]
//!   trait, the method registry (`rom-feature`, `rom-weight-svd`,
//!   `prune-magnitude`, `prune-activation`), pluggable calibration
//!   streams, the [`compress::CompressedModel`] artifact, and
//!   [`compress::CompressionSession`] — the front door used by the CLI,
//!   tables harness, examples, and benches
//! - [`serve`] — factored-form serving: batched forward engine executing
//!   compressed layers as two skinny matmuls (`r(d1+d2)` MACs) with
//!   per-layer dense/low-rank/int8-quantized dispatch
//!   ([`serve::ExecMode::FactoredQuant`] — explicit, never a silent
//!   substitute), packed-panel kernels, a per-request scratch arena
//!   ([`serve::ServeScratch`]: zero hot-path allocation at steady state),
//!   adapting the [`engine`] core's request lifecycle, and
//!   latency/throughput/MAC accounting
//! - [`decode`] — autoregressive generation over the serve path: per-slot
//!   KV cache pool, single-token dense/factored `forward_step`, a
//!   continuous-batching scheduler over the [`engine`] core (mid-run
//!   admission, EOS/max-token/cancel/deadline eviction, round-robin
//!   fairness), seeded greedy/temperature/top-k sampling,
//!   TTFT/inter-token-latency/MAC-savings stats from the event timeline,
//!   and [`decode::SpecDecoder`] — rank-ladder speculative decoding
//!   (a low-budget artifact of the same checkpoint drafts K tokens, the
//!   high-budget verifier checks them in one chunked batched forward,
//!   caches roll back via `KvCache::truncate_to`) with greedy streams
//!   bitwise identical to verifier-only decode and exact
//!   [`model::macs::spec_report`] accounting
//! - [`daemon`] — HTTP/1.1 + SSE transport front-end: a dependency-free
//!   `std::net` server binding the [`engine`] session API to the wire
//!   (`/v1/generate`, `/v1/score`, health/readiness, admin drain) with
//!   scheduling fields (`tier`/`tenant`/`deadline_ms`) on both request
//!   envelopes, load shedding priced in metered MACs (`429` with a
//!   drain-time `Retry-After` estimate), mid-stream disconnect
//!   cancellation, and graceful drain — plus the open-loop
//!   `repro loadgen` wire-path load generator with per-tier latency
//!   percentiles, deadline hit-rate, and `--mix interactive:batch`
//! - [`obs`] — the observability plane: a deterministic, wall-clock-free
//!   flight recorder of scheduler/lifecycle events ([`obs::FlightRecorder`],
//!   JSONL export, byte-diffable across `--threads`) plus a lock-light
//!   metrics registry ([`obs::MetricsRegistry`]: counters, gauges,
//!   fixed-bound latency histograms, per-tier/per-tenant labels) rendered
//!   as Prometheus text for the daemon's `GET /metrics` — attaching either
//!   plane never perturbs scheduling or output (asserted bitwise by the
//!   self-checks)
//! - [`train`] — Rust-owned AdamW training loop over the AOT train step
//! - [`eval`] — perplexity + zero-shot multiple-choice evaluation
//! - [`coordinator`] — memory-bounded pipeline orchestration, metrics

pub mod compress;
pub mod coordinator;
pub mod daemon;
pub mod data;
pub mod decode;
pub mod engine;
pub mod eval;
pub mod exec;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod prune;
pub mod rom;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
