//! Aggregate accounting for one decode run — the generation-side analog of
//! [`crate::serve::ServeStats`], built on the same shared
//! [`RequestStats`] core ([`crate::util::stats`]).
//!
//! Beyond the core's requests/tokens/MACs/latency, the decode regime has
//! its own latency anatomy: time-to-first-token (prefill + queue wait) and
//! inter-token latency (steady-state step time), both **derived from the
//! engine core's event timestamps** (each `Prefilled`/`Token` event
//! carries the instant its token was produced) and summarized with the
//! small-sample-safe [`LatencySummary`]. The MAC side carries *two*
//! totals — what the KV-cached path executed (`core.macs`) and what a
//! cache-less server re-forwarding the growing prefix would have executed
//! — so the cache's algorithmic saving is reported next to the paper's
//! `r(d1+d2)` factorization saving.

use crate::util::{LatencySummary, RequestStats};

/// Aggregate result of one [`crate::decode::DecodeScheduler::run`].
#[derive(Debug, Clone)]
pub struct DecodeStats {
    /// The shared request-lifecycle core: requests completed, tokens
    /// *generated*, MACs executed (KV-cached regime), wall clock, and the
    /// per-request completion-latency summary.
    pub core: RequestStats,
    /// Prompt tokens consumed across all requests (prefill).
    pub prompt_tokens: usize,
    /// Analytic MACs a full-recompute decode of the same streams would
    /// have executed (the cache-less baseline).
    pub recompute_macs: u128,
    /// Time to first token per request, from run start (queue wait +
    /// prefill) — the `Prefilled` event timestamps.
    pub ttft: LatencySummary,
    /// Latency between consecutive `Token` events of a request.
    pub inter_token: LatencySummary,
    /// Peak concurrently-decoding sequences.
    pub peak_active: usize,
    /// Requests admitted after an earlier request finished — i.e. into a
    /// slot another sequence freed, the continuous-batching behavior.
    pub mid_run_admissions: usize,
    /// Decode rounds executed (each advances every active sequence by one
    /// token — or, speculatively, by one draft/verify round — the
    /// fairness unit).
    pub decode_rounds: usize,
    /// Candidate tokens proposed by the draft model (0 on plain runs).
    pub spec_drafted: usize,
    /// Drafted candidates the verifier accepted.
    pub spec_accepted: usize,
}

impl DecodeStats {
    /// Tokens generated across all requests.
    pub fn generated_tokens(&self) -> usize {
        self.core.tokens
    }

    /// Generated tokens per wall-clock second.
    pub fn tokens_per_s(&self) -> f64 {
        self.core.tokens_per_s()
    }

    /// Executed MACs amortized per generated token.
    pub fn macs_per_generated_token(&self) -> u128 {
        self.core.macs_per_token()
    }

    /// Recompute-baseline MACs amortized per generated token.
    pub fn recompute_macs_per_generated_token(&self) -> u128 {
        if self.core.tokens > 0 {
            self.recompute_macs / self.core.tokens as u128
        } else {
            0
        }
    }

    /// Fraction of drafted candidates the verifier accepted (0.0 when
    /// nothing was drafted — i.e. on non-speculative runs).
    pub fn spec_accept_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        }
    }

    /// How many times more MACs the cache-less baseline would execute.
    /// The baseline bills in `macs::report`'s full-window attention
    /// convention (see `macs::DecodeMacsReport::recompute_macs`), so the
    /// attention share of this ratio is an upper bound; weight/head MACs
    /// dominate and are billed identically on both sides.
    pub fn mac_savings(&self) -> f64 {
        if self.core.macs == 0 {
            1.0
        } else {
            self.recompute_macs as f64 / self.core.macs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(generated: usize, macs: u128, recompute: u128, wall: f64) -> DecodeStats {
        DecodeStats {
            core: RequestStats {
                requests: 1,
                tokens: generated,
                macs,
                wall_s: wall,
                latency: LatencySummary::default(),
            },
            prompt_tokens: 4,
            recompute_macs: recompute,
            ttft: LatencySummary::default(),
            inter_token: LatencySummary::default(),
            peak_active: 1,
            mid_run_admissions: 0,
            decode_rounds: generated,
            spec_drafted: 0,
            spec_accepted: 0,
        }
    }

    #[test]
    fn derived_rates() {
        let s = stats(10, 1_000, 4_000, 2.0);
        assert_eq!(s.generated_tokens(), 10);
        assert_eq!(s.tokens_per_s(), 5.0);
        assert_eq!(s.macs_per_generated_token(), 100);
        assert_eq!(s.recompute_macs_per_generated_token(), 400);
        assert_eq!(s.mac_savings(), 4.0);
        assert_eq!(s.spec_accept_rate(), 0.0, "no drafting, rate is defined as 0");
        let mut spec = stats(10, 1_000, 4_000, 2.0);
        spec.spec_drafted = 8;
        spec.spec_accepted = 6;
        assert_eq!(spec.spec_accept_rate(), 0.75);
    }

    #[test]
    fn degenerate_runs_are_well_defined() {
        let s = stats(0, 0, 0, 0.0);
        assert_eq!(s.tokens_per_s(), 0.0);
        assert_eq!(s.macs_per_generated_token(), 0);
        assert_eq!(s.recompute_macs_per_generated_token(), 0);
        assert_eq!(s.mac_savings(), 1.0);
    }
}
