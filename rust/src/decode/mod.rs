//! Autoregressive decode subsystem — KV-cached incremental generation with
//! continuous batching over the factored serve path.
//!
//! The paper's serving claim (`r(d1+d2)` instead of `d1·d2` MACs per
//! token) pays off at scale only when tokens are *generated*
//! incrementally, not re-forwarded from scratch. This module is that
//! generation engine, layered on [`crate::serve`] and — since the request
//! lifecycle moved into the shared streaming core — on [`crate::engine`]:
//!
//! - [`KvCache`] / [`KvCachePool`] — preallocated per-layer K/V blocks per
//!   sequence slot, keyed off [`crate::model::ModelConfig`]; the substrate
//!   of [`crate::serve::ServeModel::forward_step`], the single-token
//!   incremental forward that applies the shared rope/causal-attention
//!   helpers in both dense and factored [`crate::serve::ExecMode`].
//! - [`DecodeScheduler`] — the batch front door over the engine core's
//!   continuous-batching lifecycle: FIFO admission into free slots
//!   (including *mid-run*, as finished sequences are evicted on
//!   EOS/max-tokens/cancel/deadline) and round-robin decode rounds so no
//!   request starves. Streaming callers open
//!   [`DecodeScheduler::session`] and drain per-token events instead.
//! - [`Sampling`] — greedy / temperature / top-k next-token selection,
//!   seeded through [`crate::util::Rng`] per request for reproducibility.
//! - [`SpecDecoder`] / [`spec_round`] — speculative decoding over a
//!   draft/verifier artifact pair of the same checkpoint: the low-budget
//!   draft proposes `k` greedy tokens, the verifier scores all of them in
//!   one chunked forward, and both KV caches roll back on rejection via
//!   [`KvCache::truncate_to`]. The speculative greedy stream is *bitwise
//!   identical* to the verifier-only stream; only the wall-clock (and the
//!   acceptance counters) change.
//! - [`DecodeStats`] — the shared [`crate::util::RequestStats`] core plus
//!   time-to-first-token and inter-token latency summaries (derived from
//!   the event timeline) and executed-vs-recompute MAC accounting that
//!   matches [`crate::model::macs::decode_report`] exactly.
//!
//! `repro generate` (incl. `--stream` and the fully-offline
//! `--self-check`s) and `repro bench-decode` drive this module;
//! [`run_recompute`] is the cache-less baseline those commands compare
//! against.

pub mod kv;
pub mod sampler;
pub mod scheduler;
pub mod spec;
pub mod stats;

use std::time::Instant;

use anyhow::Result;

use crate::data::Tokenizer;
use crate::model::ModelConfig;
use crate::serve::ServeModel;
use crate::util::{LatencySummary, RequestStats};

pub use kv::{kv_slot_bytes, KvCache, KvCachePool};
pub use sampler::Sampling;
pub use scheduler::{
    DecodeConfig, DecodeScheduler, Event, EventKind, FinishReason, GenRequest, GenResult,
    StreamControl,
};
pub use spec::{spec_round, SpecDecoder, SpecRoundOutcome, SpecState, SpecStream};
pub use stats::DecodeStats;

/// Deterministic synthetic generation workload: `n` requests of
/// `prompt_len` random in-vocab tokens — a [`GenRequest`] view over the
/// one shared stream generator [`crate::engine::synth_token_streams`]
/// (same token streams as [`crate::serve::synth_requests`] at the same
/// seed).
pub fn synth_gen_requests(
    cfg: &ModelConfig,
    n: usize,
    prompt_len: usize,
    seed: u64,
) -> Vec<GenRequest> {
    crate::engine::synth_token_streams(cfg, n, prompt_len, seed)
        .into_iter()
        .enumerate()
        .map(|(id, prompt)| GenRequest { id, prompt, max_new: None, deadline_s: None })
        .collect()
}

/// The cache-less baseline: decode every request sequentially by
/// re-forwarding the growing prefix from scratch for each token. Uses the
/// same per-request RNG streams and stopping rules as
/// [`DecodeScheduler::run`], so at equal seeds the token streams are
/// directly comparable (identical under greedy sampling). Returns results
/// in request id order plus aggregate stats — the "dense-recompute" row of
/// `repro bench-decode`.
pub fn run_recompute(
    model: &ServeModel,
    requests: &[GenRequest],
    config: &DecodeConfig,
) -> Result<(Vec<GenResult>, DecodeStats)> {
    let vocab = model.config().vocab;
    let tokenizer = Tokenizer::new();
    // the baseline decodes sequentially; its growing-prefix forwards still
    // row-shard over the same thread budget (intra-op only)
    let pool = config.exec.pool();
    let t0 = Instant::now();
    let mut results: Vec<GenResult> = Vec::with_capacity(requests.len());
    let mut ttfts: Vec<f64> = Vec::new();
    let mut itls: Vec<f64> = Vec::new();
    let prompt_tokens: usize = requests.iter().map(|r| r.prompt.len()).sum();

    for (order, req) in requests.iter().enumerate() {
        anyhow::ensure!(!req.prompt.is_empty(), "request {}: empty prompt", req.id);
        let max_new = req.max_new.unwrap_or(config.max_new).max(1);
        let mut rng = scheduler::request_rng(config.seed, req.id);
        let mut seq = req.prompt.clone();
        let mut tokens: Vec<i32> = Vec::with_capacity(max_new);
        let mut macs: u128 = 0;
        let mut finish = FinishReason::MaxTokens;
        let (mut ttft_s, mut last_s) = (0.0f64, 0.0f64);
        loop {
            let (logits, m) = model.forward_logits_pooled(&seq, &pool)?;
            macs += m;
            let next = config.sampling.sample(&logits[(seq.len() - 1) * vocab..], &mut rng);
            let now = t0.elapsed().as_secs_f64();
            if tokens.is_empty() {
                ttft_s = now;
                ttfts.push(now);
            } else {
                itls.push(now - last_s);
            }
            last_s = now;
            tokens.push(next);
            if Some(next) == config.eos {
                finish = FinishReason::Eos;
                break;
            }
            if tokens.len() >= max_new {
                break;
            }
            seq.push(next);
        }
        let text = tokenizer.decode(&tokens);
        results.push(GenResult {
            id: req.id,
            admitted: Some(order),
            prompt_len: req.prompt.len(),
            tokens,
            text,
            finish,
            ttft_s,
            latency_s: last_s,
            macs,
            // the recompute path *is* its own baseline
            recompute_macs: macs,
        });
    }

    let wall_s = t0.elapsed().as_secs_f64();
    results.sort_by_key(|r| r.id);
    let generated: usize = results.iter().map(|r| r.tokens.len()).sum();
    let total_macs: u128 = results.iter().map(|r| r.macs).sum();
    let stats = DecodeStats {
        core: RequestStats {
            requests: results.len(),
            tokens: generated,
            macs: total_macs,
            wall_s,
            latency: LatencySummary::from_unsorted(
                results.iter().map(|r| r.latency_s).collect(),
            ),
        },
        prompt_tokens,
        recompute_macs: total_macs,
        ttft: LatencySummary::from_unsorted(ttfts),
        inter_token: LatencySummary::from_unsorted(itls),
        peak_active: usize::from(!results.is_empty()),
        mid_run_admissions: 0,
        decode_rounds: generated.saturating_sub(results.len()),
    };
    Ok((results, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::macs::{self, CompressionAccounting};
    use crate::serve::{demo_artifact, demo_config, ExecMode};

    #[test]
    fn synth_gen_requests_are_deterministic_and_in_vocab() {
        let cfg = demo_config();
        let a = synth_gen_requests(&cfg, 4, 9, 3);
        let b = synth_gen_requests(&cfg, 4, 9, 3);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.prompt.len(), 9);
            assert!(x.max_new.is_none());
            assert!(x.deadline_s.is_none());
            assert!(x.prompt.iter().all(|&t| (t as usize) < cfg.vocab));
        }
        // identical streams to the serve-side helper: one shared generator
        let s = crate::serve::synth_requests(&cfg, 4, 9, 3);
        for (g, r) in a.iter().zip(&s) {
            assert_eq!(g.prompt, r.tokens);
        }
    }

    #[test]
    fn kv_decode_matches_recompute_streams_and_analytic_macs() {
        // the subsystem's central invariant, in both execution modes:
        // identical greedy token streams, and executed MACs equal to the
        // analytic cached-decode accounting
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 67).unwrap();
        let reqs = synth_gen_requests(&cfg, 4, 7, 13);
        let config = DecodeConfig {
            slots: 2,
            capacity: 32,
            max_new: 8,
            sampling: Sampling::Greedy,
            seed: 13,
            eos: None,
            ..DecodeConfig::default()
        };
        for mode in [ExecMode::Dense, ExecMode::Factored] {
            let model = ServeModel::from_artifact(&cm, mode).unwrap();
            let acc = match mode {
                ExecMode::Dense => CompressionAccounting::dense(),
                ExecMode::Factored => cm.accounting.clone(),
            };
            let (kv, kv_stats) = DecodeScheduler::new(&model, config).run(reqs.clone()).unwrap();
            let (rc, rc_stats) = run_recompute(&model, &reqs, &config).unwrap();
            assert_eq!(kv.len(), rc.len());
            for (a, b) in kv.iter().zip(&rc) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.tokens, b.tokens, "{}: KV stream diverged", mode.name());
                assert_eq!(a.finish, b.finish);
                assert_eq!(a.text, b.text, "{}: decoded text diverged", mode.name());
                let rep = macs::decode_report(&cfg, &acc, a.prompt_len, a.tokens.len());
                assert_eq!(a.macs, rep.cached_macs(), "{}: executed != analytic", mode.name());
                assert_eq!(a.recompute_macs, rep.recompute_macs);
                assert_eq!(b.macs, rep.recompute_macs, "recompute executed != analytic");
            }
            assert_eq!(kv_stats.recompute_macs, rc_stats.core.macs);
            assert!(
                kv_stats.core.macs < rc_stats.core.macs,
                "{}: cache must save MACs",
                mode.name()
            );
        }
    }

    #[test]
    fn factored_kv_beats_dense_recompute_on_macs() {
        // the acceptance bar of `repro bench-decode`
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 71).unwrap();
        let reqs = synth_gen_requests(&cfg, 3, 6, 5);
        let config = DecodeConfig { slots: 2, capacity: 24, max_new: 6, ..Default::default() };
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let dense = ServeModel::from_artifact(&cm, ExecMode::Dense).unwrap();
        let (_, kv) = DecodeScheduler::new(&fact, config).run(reqs.clone()).unwrap();
        let (_, rc) = run_recompute(&dense, &reqs, &config).unwrap();
        assert!(
            kv.macs_per_generated_token() < rc.macs_per_generated_token(),
            "factored-KV {} vs dense-recompute {}",
            kv.macs_per_generated_token(),
            rc.macs_per_generated_token()
        );
    }
}
