//! Per-sequence KV cache and the slot pool behind the decode scheduler.
//!
//! A [`KvCache`] preallocates one `capacity × d_model` K block and V block
//! per transformer layer (keyed off [`ModelConfig`]), so appending a
//! token's keys/values during incremental decoding is a bounded
//! `memcpy` — no reallocation on the token path. A [`KvCachePool`] owns a
//! fixed number of cache slots; the continuous-batching scheduler acquires
//! an *owned* cache at request admission (so active sequences can step on
//! worker threads without aliasing the pool) and releases (resets) it on
//! eviction — steady-state serving allocates nothing per request. Pool
//! construction can be capped ([`KvCachePool::with_cap`]): a requested
//! footprint beyond the cap is a proper `Err` before any slot is
//! allocated, not a later panic.

use anyhow::{ensure, Result};

use crate::model::ModelConfig;

/// Preallocated per-layer K/V blocks for one decoding sequence.
///
/// Rows are row-major `(t, d_model)`, rotary embeddings already applied —
/// exactly what the shared `causal_attention` helper consumes.
/// `pos` counts the tokens written so far; writes land at explicit
/// positions during a chunked forward and `advance` moves the cursor once
/// per consumed chunk.
#[derive(Debug, Clone)]
pub struct KvCache {
    d: usize,
    capacity: usize,
    pos: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// Preallocate blocks for `capacity` tokens of `cfg`'s geometry.
    pub fn new(cfg: &ModelConfig, capacity: usize) -> KvCache {
        KvCache {
            d: cfg.d_model,
            capacity,
            pos: 0,
            k: vec![vec![0.0; capacity * cfg.d_model]; cfg.n_layers],
            v: vec![vec![0.0; capacity * cfg.d_model]; cfg.n_layers],
        }
    }

    pub fn layers(&self) -> usize {
        self.k.len()
    }

    /// Row width (`d_model` of the owning config).
    pub fn width(&self) -> usize {
        self.d
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Tokens consumed so far (the next token decodes at this position).
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.capacity - self.pos
    }

    /// Preallocated footprint of this cache in bytes.
    pub fn bytes(&self) -> usize {
        2 * self.k.len() * self.capacity * self.d * std::mem::size_of::<f32>()
    }

    /// Forget the sequence (keeps the allocation — slot reuse).
    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// Roll the cursor back to `pos` — the speculative-decoding rejection
    /// path. Rows past `pos` become stale and are overwritten by the next
    /// write; truncating to the current position is a no-op, truncating
    /// *past* the position (a rewind to tokens never consumed) is a
    /// proper `Err`.
    pub fn truncate_to(&mut self, pos: usize) -> Result<()> {
        ensure!(
            pos <= self.pos,
            "KV truncate_to({pos}) past the cursor (pos {}): cannot roll forward",
            self.pos
        );
        self.pos = pos;
        Ok(())
    }

    /// Copy `rows·d` K and V values into `layer`'s blocks at row `at`.
    pub(crate) fn write(&mut self, layer: usize, at: usize, k_rows: &[f32], v_rows: &[f32]) {
        debug_assert_eq!(k_rows.len(), v_rows.len());
        debug_assert_eq!(k_rows.len() % self.d, 0);
        debug_assert!(at * self.d + k_rows.len() <= self.capacity * self.d, "KV write past capacity");
        let start = at * self.d;
        self.k[layer][start..start + k_rows.len()].copy_from_slice(k_rows);
        self.v[layer][start..start + v_rows.len()].copy_from_slice(v_rows);
    }

    /// The first `rows` K and V rows of `layer` — the attention window.
    pub(crate) fn view(&self, layer: usize, rows: usize) -> (&[f32], &[f32]) {
        (&self.k[layer][..rows * self.d], &self.v[layer][..rows * self.d])
    }

    /// Advance the cursor after a chunk of `seq` tokens was written to
    /// every layer.
    pub(crate) fn advance(&mut self, seq: usize) {
        debug_assert!(self.pos + seq <= self.capacity);
        self.pos += seq;
    }
}

/// Preallocated per-slot footprint of a pool over `cfg`/`capacity`, in
/// bytes (computable before any allocation — the cap guard's currency).
pub fn kv_slot_bytes(cfg: &ModelConfig, capacity: usize) -> usize {
    2 * cfg.n_layers * capacity * cfg.d_model * std::mem::size_of::<f32>()
}

/// A fixed set of [`KvCache`] slots, handed out by value.
pub struct KvCachePool {
    free: Vec<KvCache>,
    slots: usize,
    per_slot_bytes: usize,
}

impl KvCachePool {
    /// An uncapped pool (never fails).
    pub fn new(cfg: &ModelConfig, slots: usize, capacity: usize) -> KvCachePool {
        Self::with_cap(cfg, slots, capacity, None).expect("uncapped pool")
    }

    /// A pool whose preallocated footprint must stay within `max_bytes`
    /// (when given). The guard runs *before* the slots are allocated, so
    /// an over-budget request is a clean `Err` — not an OOM or a
    /// slot-exhaustion panic later.
    pub fn with_cap(
        cfg: &ModelConfig,
        slots: usize,
        capacity: usize,
        max_bytes: Option<usize>,
    ) -> Result<KvCachePool> {
        Ok(Self::with_cap_dual(cfg, slots, capacity, false, max_bytes)?.0)
    }

    /// [`KvCachePool::with_cap`] for speculative decoding: when
    /// `speculative`, a second (draft-model) cache family of identical
    /// geometry is allocated alongside the verifier's, and the footprint
    /// guard bills *both* families against `max_bytes` before either is
    /// allocated — the draft cache is real memory, so `--kv-cap-mb` must
    /// see it.
    pub fn with_cap_dual(
        cfg: &ModelConfig,
        slots: usize,
        capacity: usize,
        speculative: bool,
        max_bytes: Option<usize>,
    ) -> Result<(KvCachePool, Option<KvCachePool>)> {
        let per_slot_bytes = kv_slot_bytes(cfg, capacity);
        let families = if speculative { 2 } else { 1 };
        if let Some(cap) = max_bytes {
            let need = families * slots * per_slot_bytes;
            ensure!(
                need <= cap,
                "KV cache pool over budget: {families} cache famil{} × {slots} slots × \
                 {per_slot_bytes} bytes/slot = {need} bytes > cap {cap} (lower --slots, \
                 shorten the capacity, or raise the cap)",
                if families == 1 { "y" } else { "ies (verifier + speculative draft)" }
            );
        }
        let build = || KvCachePool {
            free: (0..slots).map(|_| KvCache::new(cfg, capacity)).collect(),
            slots,
            per_slot_bytes,
        };
        Ok((build(), speculative.then(build)))
    }

    pub fn n_slots(&self) -> usize {
        self.slots
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Claim a free cache, if any. Ownership moves to the caller (the
    /// scheduler's active sequence) until [`KvCachePool::release`].
    pub fn acquire(&mut self) -> Option<KvCache> {
        self.free.pop()
    }

    /// Return a cache to the pool, resetting its sequence.
    pub fn release(&mut self, mut cache: KvCache) {
        debug_assert!(self.free.len() < self.slots, "released more caches than the pool owns");
        cache.reset();
        self.free.push(cache);
    }

    /// Preallocated footprint of the whole pool in bytes (including
    /// caches currently out with active sequences).
    pub fn footprint_bytes(&self) -> usize {
        self.slots * self.per_slot_bytes
    }

    /// Back-compat alias of [`KvCachePool::footprint_bytes`].
    pub fn bytes(&self) -> usize {
        self.footprint_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig { vocab: 32, d_model: 8, n_heads: 2, n_layers: 3, d_ff: 12, ..ModelConfig::mini() }
    }

    #[test]
    fn cache_geometry_follows_config() {
        let c = KvCache::new(&cfg(), 10);
        assert_eq!(c.layers(), 3);
        assert_eq!(c.width(), 8);
        assert_eq!(c.capacity(), 10);
        assert_eq!(c.pos(), 0);
        assert_eq!(c.remaining(), 10);
        assert_eq!(c.bytes(), 2 * 3 * 10 * 8 * 4);
    }

    #[test]
    fn write_view_advance_round_trip() {
        let mut c = KvCache::new(&cfg(), 4);
        let k: Vec<f32> = (0..16).map(|i| i as f32).collect(); // 2 rows of 8
        let v: Vec<f32> = (0..16).map(|i| -(i as f32)).collect();
        c.write(1, 0, &k, &v);
        c.advance(2);
        assert_eq!(c.pos(), 2);
        assert_eq!(c.remaining(), 2);
        let (kc, vc) = c.view(1, 2);
        assert_eq!(kc, &k[..]);
        assert_eq!(vc, &v[..]);
        // appending a third row lands after the first two
        c.write(1, 2, &k[..8], &v[..8]);
        c.advance(1);
        let (kc, _) = c.view(1, 3);
        assert_eq!(&kc[16..], &k[..8]);
        // untouched layers stay zeroed
        let (k0, v0) = c.view(0, 3);
        assert!(k0.iter().all(|&x| x == 0.0) && v0.iter().all(|&x| x == 0.0));
        c.reset();
        assert_eq!(c.pos(), 0);
    }

    #[test]
    fn pool_acquire_release_cycles() {
        let mut p = KvCachePool::new(&cfg(), 2, 6);
        assert_eq!(p.n_slots(), 2);
        assert_eq!(p.n_free(), 2);
        let mut a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert_eq!(b.capacity(), 6);
        assert!(p.acquire().is_none(), "pool exhausted");
        a.advance(3);
        assert_eq!(a.pos(), 3);
        p.release(a);
        assert_eq!(p.n_free(), 1);
        let c = p.acquire().unwrap();
        assert_eq!(c.pos(), 0, "release resets the sequence");
        assert_eq!(p.footprint_bytes(), 2 * (2 * 3 * 6 * 8 * 4));
        assert_eq!(p.bytes(), p.footprint_bytes(), "footprint counts caches out on loan too");
        assert_eq!(kv_slot_bytes(&cfg(), 6), 2 * 3 * 6 * 8 * 4);
    }

    #[test]
    fn truncate_to_rolls_back_but_never_forward() {
        let mut c = KvCache::new(&cfg(), 6);
        let rows: Vec<f32> = (0..24).map(|i| i as f32).collect(); // 3 rows of 8
        c.write(0, 0, &rows, &rows);
        c.advance(3);
        assert_eq!(c.pos(), 3);
        // to the current position: a no-op
        c.truncate_to(3).unwrap();
        assert_eq!(c.pos(), 3);
        // mid-sequence rollback (the speculative rejection path); the
        // surviving rows are untouched
        c.truncate_to(1).unwrap();
        assert_eq!(c.pos(), 1);
        assert_eq!(c.remaining(), 5);
        let (k, _) = c.view(0, 1);
        assert_eq!(k, &rows[..8]);
        // to zero: equivalent to reset
        c.truncate_to(0).unwrap();
        assert_eq!(c.pos(), 0);
        // past the cursor: rejected, cursor unchanged
        let e = c.truncate_to(1).unwrap_err();
        assert!(e.to_string().contains("past the cursor"), "{e}");
        assert_eq!(c.pos(), 0);
    }

    #[test]
    fn dual_family_cap_bills_draft_caches_too() {
        let cfg = cfg();
        let per_slot = kv_slot_bytes(&cfg, 6);
        // one family fits under the cap…
        let (pool, none) = KvCachePool::with_cap_dual(&cfg, 2, 6, false, Some(2 * per_slot))
            .unwrap();
        assert!(none.is_none());
        assert_eq!(pool.footprint_bytes(), 2 * per_slot);
        // …but the same cap must reject verifier + draft before allocating
        let e = KvCachePool::with_cap_dual(&cfg, 2, 6, true, Some(2 * per_slot)).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("over budget"), "{msg}");
        assert!(msg.contains("verifier + speculative draft"), "{msg}");
        assert!(msg.contains(&format!("{}", 4 * per_slot)), "{msg}");
        // doubling the cap admits both families, each fully provisioned
        let (ver, draft) =
            KvCachePool::with_cap_dual(&cfg, 2, 6, true, Some(4 * per_slot)).unwrap();
        let draft = draft.expect("speculative mode carries a draft family");
        assert_eq!(ver.footprint_bytes() + draft.footprint_bytes(), 4 * per_slot);
        assert_eq!(draft.n_slots(), 2);
    }

    #[test]
    fn capacity_cap_is_enforced_before_allocation() {
        let cfg = cfg();
        let per_slot = kv_slot_bytes(&cfg, 6);
        // exactly at the cap: fine
        let p = KvCachePool::with_cap(&cfg, 2, 6, Some(2 * per_slot)).unwrap();
        assert_eq!(p.footprint_bytes(), 2 * per_slot);
        // one byte under: a proper Err naming the shortfall
        let e = KvCachePool::with_cap(&cfg, 2, 6, Some(2 * per_slot - 1)).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("over budget"), "{msg}");
        assert!(msg.contains(&format!("{}", 2 * per_slot)), "{msg}");
        // no cap: anything goes
        assert!(KvCachePool::with_cap(&cfg, 64, 6, None).is_ok());
    }
}
