//! Seeded next-token sampling: greedy, temperature, top-k.
//!
//! Every draw flows through [`crate::util::Rng`], so generation is
//! reproducible run-to-run given the same seed — the scheduler derives one
//! independent stream per request, which also makes token streams
//! invariant to slot assignment and admission timing.

use anyhow::{bail, Result};

use crate::util::Rng;

/// Next-token selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Argmax of the logits (ties break toward the highest token id, like
    /// the reference model's sampler).
    Greedy,
    /// Softmax at the given temperature over the full vocabulary.
    Temperature(f32),
    /// Softmax at `temperature` restricted to the `k` highest logits
    /// (`temperature <= 0` degenerates to greedy).
    TopK { k: usize, temperature: f32 },
}

impl Sampling {
    /// Build a policy from the CLI's `--temp` / `--top-k` flags:
    /// `top_k > 0` restricts to the top-k set; `temperature <= 0` is
    /// greedy.
    pub fn from_flags(temperature: f32, top_k: usize) -> Sampling {
        if top_k > 0 {
            Sampling::TopK { k: top_k, temperature }
        } else if temperature > 0.0 {
            Sampling::Temperature(temperature)
        } else {
            Sampling::Greedy
        }
    }

    pub fn parse(temperature: f32, top_k: usize) -> Result<Sampling> {
        if temperature < 0.0 {
            bail!("--temp must be >= 0 (got {temperature})");
        }
        Ok(Sampling::from_flags(temperature, top_k))
    }

    pub fn label(&self) -> String {
        match *self {
            Sampling::Greedy => "greedy".to_string(),
            Sampling::Temperature(t) => format!("temp {t}"),
            Sampling::TopK { k, temperature } => format!("top-{k} @ temp {temperature}"),
        }
    }

    /// Draw the next token id from one row of logits.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        debug_assert!(!logits.is_empty());
        match *self {
            Sampling::Greedy => argmax(logits),
            Sampling::Temperature(t) => {
                if t <= 0.0 {
                    argmax(logits)
                } else {
                    let all: Vec<usize> = (0..logits.len()).collect();
                    draw_softmax(logits, &all, t, rng)
                }
            }
            Sampling::TopK { k, temperature } => {
                if k == 0 || k >= logits.len() {
                    // degenerate top-k: plain temperature sampling
                    return Sampling::from_flags(temperature, 0).sample(logits, rng);
                }
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                // logit descending, ties toward the highest id (same
                // tie-break as greedy argmax)
                idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(b.cmp(&a)));
                idx.truncate(k);
                if temperature <= 0.0 {
                    idx[0] as i32
                } else {
                    draw_softmax(logits, &idx, temperature, rng)
                }
            }
        }
    }
}

/// Argmax over logits; of equal maxima the highest index wins (matches the
/// reference model's greedy tie-break).
fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .expect("non-empty logits")
}

/// Sample from softmax(logits[subset] / temperature), f64 accumulation.
fn draw_softmax(logits: &[f32], subset: &[usize], temperature: f32, rng: &mut Rng) -> i32 {
    let max = subset.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let probs: Vec<f64> =
        subset.iter().map(|&i| (((logits[i] - max) / temperature) as f64).exp()).collect();
    let z: f64 = probs.iter().sum();
    let mut r = rng.f64() * z;
    for (p, &i) in probs.iter().zip(subset) {
        r -= p;
        if r <= 0.0 {
            return i as i32;
        }
    }
    subset[subset.len() - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing_picks_the_right_policy() {
        assert_eq!(Sampling::from_flags(0.0, 0), Sampling::Greedy);
        assert_eq!(Sampling::from_flags(0.7, 0), Sampling::Temperature(0.7));
        assert_eq!(Sampling::from_flags(0.7, 5), Sampling::TopK { k: 5, temperature: 0.7 });
        assert!(Sampling::parse(-0.1, 0).is_err());
        assert_eq!(Sampling::parse(0.0, 3).unwrap(), Sampling::TopK { k: 3, temperature: 0.0 });
        assert!(Sampling::Greedy.label().contains("greedy"));
        assert!(Sampling::TopK { k: 4, temperature: 0.5 }.label().contains("top-4"));
    }

    #[test]
    fn greedy_is_deterministic_and_breaks_ties_high() {
        let mut rng = Rng::new(0);
        let logits = [0.0f32, 3.0, 3.0, -1.0];
        for _ in 0..10 {
            assert_eq!(Sampling::Greedy.sample(&logits, &mut rng), 2);
        }
        assert_eq!(Sampling::Temperature(0.0).sample(&logits, &mut rng), 2);
        assert_eq!(
            Sampling::TopK { k: 2, temperature: 0.0 }.sample(&logits, &mut rng),
            2,
            "zero-temperature top-k is greedy, same high-id tie-break"
        );
    }

    #[test]
    fn temperature_respects_support() {
        let mut logits = vec![-1e9f32; 10];
        logits[3] = 0.0;
        logits[7] = 0.0;
        let mut rng = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..200 {
            let s = Sampling::Temperature(1.0).sample(&logits, &mut rng) as usize;
            assert!(s == 3 || s == 7, "impossible token {s}");
            seen[s] = true;
        }
        assert!(seen[3] && seen[7], "both supported tokens should appear");
    }

    #[test]
    fn top_k_only_emits_the_top_set() {
        let logits: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect(); // 11 is best
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let s = Sampling::TopK { k: 3, temperature: 1.5 }.sample(&logits, &mut rng);
            assert!((9..=11).contains(&s), "token {s} outside the top-3");
        }
        // k >= vocab degenerates to plain temperature sampling
        let s = Sampling::TopK { k: 100, temperature: 0.0 }.sample(&logits, &mut rng);
        assert_eq!(s, 11);
    }

    #[test]
    fn seeded_draws_are_reproducible() {
        let logits: Vec<f32> = (0..20).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
        let pol = Sampling::TopK { k: 5, temperature: 0.9 };
        let run = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| pol.sample(&logits, &mut rng)).collect()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43), "different seeds should diverge somewhere");
    }
}
