//! Speculative decoding on the rank ladder: draft with a cheap artifact,
//! verify with the full one.
//!
//! The compression sweep produces a *family* of artifacts of the same
//! checkpoint at different §2 energy budgets. A low-budget artifact costs
//! only `r_draft(d1+d2)` MACs/token, which makes it a free draft model
//! for paper-native speculative decoding — no second network, exactly
//! the deployment-accelerator framing of LORD (arXiv:2309.14021) and the
//! small-drafts-large pairing of Lillama (arXiv:2412.16719). Decode is
//! sequential; speculative verification turns K sequential verifier
//! steps into **one** chunked-prefill batched forward
//! ([`ServeModel::forward_cached_scratch`] over K+1 positions), so the
//! verifier's per-position head and attention work amortizes across the
//! chunk while the cheap model absorbs the sequential dependency.
//!
//! ## The round ([`spec_round`])
//!
//! With `g` tokens generated, canonical verifier position
//! `C = prompt + g - 1`, and `last` the newest token:
//!
//! 1. **Draft**: catch the draft KV cache up to the canonical stream
//!    (it lags by the bonus token after a fully accepted round), then
//!    greedily draft `k_eff = min(spec_k, max_new - g - 1)` candidates
//!    `d1..dk` one step at a time on the cheap model. The clamp keeps
//!    every transient cache position `<= prompt + max_new - 1`, so the
//!    speculative path needs **no capacity headroom** over plain decode.
//! 2. **Verify**: one chunked forward of `[last, d1, .., dk]` on the
//!    verifier scores all `k_eff + 1` positions at once; row `j` is the
//!    verifier's greedy choice after consuming the chunk prefix
//!    `..=j` — exactly the token verifier-only decode would emit at
//!    stream index `g + j`.
//! 3. **Commit**: accept the longest prefix with `d_{j+1} == v_j`, then
//!    append the verifier's own next token (the *bonus*) — always
//!    `accepted + 1 ∈ 1..=k_eff+1` tokens, so a round never stalls.
//! 4. **Rollback**: both caches roll back to the new canonical position
//!    via [`KvCache::truncate_to`]; rejected positions stay billed
//!    (that waste is the price of speculation and is accounted
//!    explicitly by [`crate::model::macs::spec_report`]).
//!
//! ## Contracts
//!
//! - **Bitwise identity**: every emitted token is a verifier argmax over
//!   a prefix identical to what verifier-only greedy decode would have
//!   consumed, and the chunked forward computes per-position arithmetic
//!   identical to single-step decode — so the speculative stream equals
//!   the verifier-only greedy stream *bitwise*, for any `spec_k` and any
//!   `--threads` (asserted by `prop_speculative_equals_verifier_greedy`
//!   and `repro generate --self-check --speculative`).
//! - **Exact MAC accounting**: executed MACs (draft prefill + draft
//!   steps + verify chunks, rollback waste included) equal the analytic
//!   [`crate::model::macs::spec_report`] over the `(drafted, accepted)`
//!   round trace, exactly — not approximately.
//! - **Greedy only**: non-greedy sampling depends on a per-request RNG
//!   stream that a draft model cannot reproduce, so those requests
//!   deterministically fall back to the plain decode path (the engine
//!   never builds spec state for them).

use anyhow::{ensure, Result};

use crate::compress::CompressedModel;
use crate::exec::{ExecConfig, ExecPool};
use crate::model::macs::SpecRound;
use crate::serve::{ExecMode, ServeModel, ServeScratch};
use crate::util::Rng;

use super::kv::KvCache;
use super::sampler::Sampling;

/// Greedy argmax over row `row` of the `(rows, vocab)` logits a chunked
/// forward leaves in scratch. Routed through [`Sampling::Greedy`] (which
/// ignores the rng) so the tie-break — highest id wins — is *the same
/// code path* as plain decode: that identity is what makes the
/// speculative stream bitwise equal to the verifier-only one.
fn argmax_row(logits: &[f32], row: usize, vocab: usize) -> i32 {
    Sampling::Greedy.sample(&logits[row * vocab..(row + 1) * vocab], &mut Rng::new(0))
}

/// Per-lane speculative state: the draft model's KV cache and scratch
/// arena plus a reusable chunk buffer. Preallocated at admission so
/// steady-state speculative rounds allocate nothing.
pub struct SpecState {
    draft_cache: KvCache,
    draft_scratch: ServeScratch,
    /// Reusable token buffer for the catch-up and verify chunks
    /// (capacity `spec_k + 2` covers both).
    chunk: Vec<i32>,
    round_drafted: usize,
    round_accepted: usize,
    round_emitted: usize,
}

impl SpecState {
    pub fn new(draft_cache: KvCache, draft_scratch: ServeScratch, spec_k: usize) -> SpecState {
        SpecState {
            draft_cache,
            draft_scratch,
            chunk: Vec::with_capacity(spec_k + 2),
            round_drafted: 0,
            round_accepted: 0,
            round_emitted: 0,
        }
    }

    /// Prefill the draft cache with the prompt (the draft model's share
    /// of lane prefill). Returns the MACs executed.
    pub fn prefill(&mut self, draft: &ServeModel, prompt: &[i32], pool: &ExecPool) -> Result<u128> {
        draft.forward_prefill_scratch(prompt, &mut self.draft_cache, pool, &mut self.draft_scratch)
    }

    /// Candidates drafted in the most recent round (0 for a degenerate
    /// verify-only round at the token-budget boundary).
    pub fn round_drafted(&self) -> usize {
        self.round_drafted
    }

    /// Candidates the verifier accepted in the most recent round.
    pub fn round_accepted(&self) -> usize {
        self.round_accepted
    }

    /// Tokens appended to the stream in the most recent round (after EOS
    /// truncation) — always >= 1.
    pub fn round_emitted(&self) -> usize {
        self.round_emitted
    }

    /// Release the draft cache back to its pool at lane retirement.
    pub fn into_cache(self) -> KvCache {
        self.draft_cache
    }
}

/// What one speculative round executed and emitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecRoundOutcome {
    /// Candidates drafted (`k_eff`, the clamped `spec_k`).
    pub drafted: usize,
    /// Longest drafted prefix matching the verifier's greedy choices.
    pub accepted: usize,
    /// Tokens appended to the stream (accepted + bonus, truncated at the
    /// first EOS) — always >= 1.
    pub emitted: usize,
    /// The emitted tokens ended at an EOS.
    pub hit_eos: bool,
    /// MACs executed this round (draft catch-up + draft steps + the
    /// verify chunk, rejected positions included).
    pub macs: u128,
}

/// One speculative round: draft `k_eff` tokens on the cheap model,
/// verify them all in one chunked verifier forward, commit the accepted
/// prefix plus the verifier's bonus token, and roll both caches back.
/// Appends the emitted tokens to `tokens`. The caller owns the stop
/// decision (`hit_eos` / token budget), mirroring the plain decode path.
#[allow(clippy::too_many_arguments)]
pub fn spec_round(
    verifier: &ServeModel,
    draft: &ServeModel,
    prompt_len: usize,
    max_new: usize,
    spec_k: usize,
    eos: Option<i32>,
    tokens: &mut Vec<i32>,
    cache: &mut KvCache,
    state: &mut SpecState,
    scratch: &mut ServeScratch,
    pool: &ExecPool,
) -> Result<SpecRoundOutcome> {
    let g = tokens.len();
    debug_assert!(g >= 1 && g < max_new, "spec rounds run on live lanes only");
    let vocab = verifier.config().vocab;
    // clamp so the verify chunk never scores past the verifier-only
    // stream length: no capacity headroom needed over plain decode
    let k_eff = spec_k.min(max_new - g - 1);
    let last = tokens[g - 1];
    let mut macs = 0u128;

    // ---- draft phase ----
    state.chunk.clear();
    if k_eff > 0 {
        // catch-up: feed the canonical tokens the draft cache has not
        // consumed yet (one token in steady state, two after a fully
        // accepted round — the bonus token plus the new last)
        debug_assert!(state.draft_cache.pos() >= prompt_len, "draft cache is prefilled");
        let start = state.draft_cache.pos() - prompt_len;
        state.chunk.extend_from_slice(&tokens[start..g]);
        let rows = state.chunk.len();
        macs += draft.forward_cached_scratch(
            &state.chunk,
            &mut state.draft_cache,
            pool,
            &mut state.draft_scratch,
        )?;
        let d1 = argmax_row(&state.draft_scratch.logits, rows - 1, vocab);
        // the verify chunk doubles as the candidate list: [last, d1..dk]
        state.chunk.clear();
        state.chunk.push(last);
        state.chunk.push(d1);
        for _ in 1..k_eff {
            let prev = *state.chunk.last().expect("chunk holds the previous candidate");
            macs += draft.forward_step_scratch(
                prev,
                &mut state.draft_cache,
                pool,
                &mut state.draft_scratch,
            )?;
            state.chunk.push(argmax_row(&state.draft_scratch.logits, 0, vocab));
        }
    } else {
        // degenerate round at the token-budget boundary: verify-only
        state.chunk.push(last);
    }

    // ---- verify phase: one chunked-prefill batched forward scores all
    // k_eff candidates plus the bonus position on the verifier ----
    let drafted = k_eff;
    macs += verifier.forward_cached_scratch(&state.chunk, cache, pool, scratch)?;
    let mut accepted = 0;
    while accepted < drafted {
        if argmax_row(&scratch.logits, accepted, vocab) != state.chunk[accepted + 1] {
            break;
        }
        accepted += 1;
    }
    let bonus = argmax_row(&scratch.logits, accepted, vocab);

    // ---- rollback: both caches back to the new canonical position;
    // the rejected verifier positions stay billed (speculation waste) ----
    let c = cache.pos() - (drafted + 1);
    cache.truncate_to(c + accepted + 1)?;
    if drafted > 0 && accepted < drafted {
        // on a full accept the draft cache is already exactly one token
        // behind the new canonical stream; the next catch-up absorbs it
        state.draft_cache.truncate_to(c + accepted + 1)?;
    }

    // ---- commit: accepted prefix + bonus, truncated at the first EOS
    // (the emitted tokens are verifier-greedy by construction, so this
    // stops exactly where verifier-only decode would) ----
    let mut emitted = 0;
    let mut hit_eos = false;
    for j in 0..=accepted {
        let tok = if j < accepted { state.chunk[j + 1] } else { bonus };
        tokens.push(tok);
        emitted += 1;
        if Some(tok) == eos {
            hit_eos = true;
            break;
        }
    }
    state.round_drafted = drafted;
    state.round_accepted = accepted;
    state.round_emitted = emitted;
    Ok(SpecRoundOutcome { drafted, accepted, emitted, hit_eos, macs })
}

/// One finished speculative generation with its full round trace — the
/// reference implementation the engine path is asserted against, and the
/// input [`crate::model::macs::spec_report`] replays analytically.
#[derive(Debug, Clone)]
pub struct SpecStream {
    /// Generated tokens (terminating EOS included when present) —
    /// bitwise identical to the verifier-only greedy stream.
    pub tokens: Vec<i32>,
    /// Per-round `(drafted, accepted)` trace, in execution order.
    pub rounds: Vec<SpecRound>,
    /// MACs executed: both prefills + every draft step + every verify
    /// chunk, rollback waste included. Equals
    /// `decode_report(verifier).prefill_macs + spec_report(..).spec_macs()`
    /// exactly.
    pub macs: u128,
}

impl SpecStream {
    /// Total candidates drafted across rounds.
    pub fn drafted(&self) -> usize {
        self.rounds.iter().map(|r| r.drafted).sum()
    }

    /// Total drafted candidates the verifier accepted.
    pub fn accepted(&self) -> usize {
        self.rounds.iter().map(|r| r.accepted).sum()
    }

    /// `accepted / drafted` (0 when nothing was drafted).
    pub fn accept_rate(&self) -> f64 {
        let drafted = self.drafted();
        if drafted == 0 {
            0.0
        } else {
            self.accepted() as f64 / drafted as f64
        }
    }
}

/// Single-sequence speculative greedy decoder over a (draft, verifier)
/// artifact pair of the same checkpoint — the standalone face of the
/// engine's speculative lane path, used by the self-checks, the decode
/// bench, and the property tests as the per-request reference.
pub struct SpecDecoder {
    verifier: ServeModel,
    draft: ServeModel,
    spec_k: usize,
}

impl SpecDecoder {
    /// Pair two loaded models. The models must share a [`ModelConfig`]
    /// (two budgets of the same checkpoint, not two checkpoints) — the
    /// artifact-level compatibility check is
    /// [`CompressedModel::check_spec_draft`].
    ///
    /// [`ModelConfig`]: crate::model::ModelConfig
    pub fn new(verifier: ServeModel, draft: ServeModel, spec_k: usize) -> Result<SpecDecoder> {
        ensure!(spec_k > 0, "speculative decoding needs --spec-k >= 1 (got {spec_k})");
        ensure!(
            verifier.config() == draft.config(),
            "draft and verifier models are from different checkpoint families \
             (configs differ); speculative decoding pairs two budgets of one checkpoint"
        );
        Ok(SpecDecoder { verifier, draft, spec_k })
    }

    /// Load a (verifier, draft) artifact pair, enforcing the
    /// compatibility contract (same config/tokenizer, draft no more
    /// expensive than the verifier) before any weights are packed.
    pub fn from_artifacts(
        verifier: &CompressedModel,
        draft: &CompressedModel,
        mode: ExecMode,
        spec_k: usize,
    ) -> Result<SpecDecoder> {
        verifier.check_spec_draft(draft)?;
        let v = ServeModel::from_artifact(verifier, mode)?;
        let d = ServeModel::from_artifact(draft, mode)?;
        SpecDecoder::new(v, d, spec_k)
    }

    pub fn verifier(&self) -> &ServeModel {
        &self.verifier
    }

    pub fn draft(&self) -> &ServeModel {
        &self.draft
    }

    pub fn spec_k(&self) -> usize {
        self.spec_k
    }

    /// Generate up to `max_new` tokens greedily, drafting on the cheap
    /// model and verifying in chunked verifier forwards. The returned
    /// stream is bitwise identical to verifier-only greedy decode.
    pub fn generate(
        &self,
        prompt: &[i32],
        max_new: usize,
        eos: Option<i32>,
        exec: ExecConfig,
    ) -> Result<SpecStream> {
        ensure!(!prompt.is_empty(), "speculative generate: empty prompt");
        let max_new = max_new.max(1);
        let capacity = prompt.len() + max_new;
        let vocab = self.verifier.config().vocab;
        let pool = ExecPool::new(exec.resolve().max(1));
        let mut cache = KvCache::new(self.verifier.config(), capacity);
        let mut scratch = self.verifier.scratch(capacity);
        let mut state = SpecState::new(
            KvCache::new(self.draft.config(), capacity),
            self.draft.scratch(capacity),
            self.spec_k,
        );
        let mut tokens: Vec<i32> = Vec::with_capacity(max_new);
        let mut macs =
            self.verifier.forward_prefill_scratch(prompt, &mut cache, &pool, &mut scratch)?;
        macs += state.prefill(&self.draft, prompt, &pool)?;
        let first = argmax_row(&scratch.logits, 0, vocab);
        tokens.push(first);
        let mut rounds = Vec::new();
        if Some(first) != eos {
            while tokens.len() < max_new {
                let out = spec_round(
                    &self.verifier,
                    &self.draft,
                    prompt.len(),
                    max_new,
                    self.spec_k,
                    eos,
                    &mut tokens,
                    &mut cache,
                    &mut state,
                    &mut scratch,
                    &pool,
                )?;
                rounds.push(SpecRound { drafted: out.drafted, accepted: out.accepted });
                macs += out.macs;
                if out.hit_eos {
                    break;
                }
            }
        }
        Ok(SpecStream { tokens, rounds, macs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::{DecodeConfig, DecodeScheduler, GenRequest};
    use crate::model::macs::{decode_report, spec_report};
    use crate::serve::{demo_artifact, demo_config};

    fn pair(spec_k: usize) -> (CompressedModel, CompressedModel, SpecDecoder) {
        let cfg = demo_config();
        let verifier = demo_artifact(&cfg, 0.8, 0x51EC).unwrap();
        let draft = demo_artifact(&cfg, 0.35, 0x51EC).unwrap();
        let dec = SpecDecoder::from_artifacts(&verifier, &draft, ExecMode::Factored, spec_k).unwrap();
        (verifier, draft, dec)
    }

    fn verifier_only(verifier: &CompressedModel, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let model = ServeModel::from_artifact(verifier, ExecMode::Factored).unwrap();
        let config = DecodeConfig {
            slots: 1,
            capacity: prompt.len() + max_new,
            max_new,
            eos: None,
            ..DecodeConfig::default()
        };
        let reqs =
            vec![GenRequest { id: 0, prompt: prompt.to_vec(), max_new: None, deadline_s: None }];
        let (results, _) = DecodeScheduler::new(&model, config).run(reqs).unwrap();
        results.into_iter().next().unwrap().tokens
    }

    #[test]
    fn speculative_stream_is_bitwise_verifier_greedy() {
        let cfg = demo_config();
        let prompt = crate::engine::synth_token_streams(&cfg, 1, 9, 0xB00).remove(0);
        let max_new = 14;
        let (verifier_cm, draft_cm, _) = pair(3);
        let reference = verifier_only(&verifier_cm, &prompt, max_new);
        assert_eq!(reference.len(), max_new);
        for spec_k in [1usize, 2, 3, 4, 9] {
            let dec =
                SpecDecoder::from_artifacts(&verifier_cm, &draft_cm, ExecMode::Factored, spec_k)
                    .unwrap();
            let stream = dec.generate(&prompt, max_new, None, ExecConfig::default()).unwrap();
            assert_eq!(
                stream.tokens, reference,
                "spec_k {spec_k}: speculative stream diverged from verifier-only greedy"
            );
            // every round emits accepted + 1 tokens (no EOS here)
            let emitted: usize = 1 + stream.rounds.iter().map(|r| r.accepted + 1).sum::<usize>();
            assert_eq!(emitted, max_new);
        }
    }

    #[test]
    fn executed_macs_equal_the_analytic_spec_accounting() {
        let cfg = demo_config();
        let prompt = crate::engine::synth_token_streams(&cfg, 1, 7, 0xACC).remove(0);
        let max_new = 11;
        for spec_k in [1usize, 3, 6] {
            let (verifier_cm, draft_cm, dec) = {
                let (v, d, _) = pair(spec_k);
                let dec =
                    SpecDecoder::from_artifacts(&v, &d, ExecMode::Factored, spec_k).unwrap();
                (v, d, dec)
            };
            let stream = dec.generate(&prompt, max_new, None, ExecConfig::default()).unwrap();
            let analytic = spec_report(
                &cfg,
                &draft_cm.accounting,
                &verifier_cm.accounting,
                prompt.len(),
                &stream.rounds,
            );
            let verifier_prefill =
                decode_report(&cfg, &verifier_cm.accounting, prompt.len(), 1).prefill_macs;
            assert_eq!(
                stream.macs,
                verifier_prefill + analytic.spec_macs(),
                "spec_k {spec_k}: executed MACs != analytic draft+verify accounting"
            );
            assert_eq!(analytic.generated, stream.tokens.len());
            assert!(
                analytic.spec_macs() > analytic.draft_prefill_macs,
                "rounds executed work beyond the draft prefill"
            );
        }
    }

    #[test]
    fn eos_stops_the_stream_exactly_where_verifier_only_does() {
        let cfg = demo_config();
        let prompt = crate::engine::synth_token_streams(&cfg, 1, 8, 0xE05).remove(0);
        let max_new = 12;
        let (verifier_cm, _, dec) = pair(4);
        let reference = verifier_only(&verifier_cm, &prompt, max_new);
        // declare a mid-stream token EOS and re-run both paths with it
        let eos = reference[5];
        let cut = reference.iter().position(|&t| t == eos).unwrap();
        let stream = dec.generate(&prompt, max_new, Some(eos), ExecConfig::default()).unwrap();
        assert_eq!(stream.tokens, reference[..=cut], "EOS truncation diverged");
        assert_eq!(*stream.tokens.last().unwrap(), eos, "the EOS token itself is kept");
    }

    #[test]
    fn mismatched_pairs_are_rejected_up_front() {
        let cfg = demo_config();
        let verifier = demo_artifact(&cfg, 0.8, 0x51EC).unwrap();
        let draft = demo_artifact(&cfg, 0.35, 0x51EC).unwrap();
        // swapped: the "draft" costs more than the "verifier"
        let err = SpecDecoder::from_artifacts(&draft, &verifier, ExecMode::Factored, 2).unwrap_err();
        assert!(err.to_string().contains("swap"), "{err}");
        // different checkpoint family: different config
        let other_cfg = crate::model::ModelConfig { d_ff: cfg.d_ff + 16, ..cfg.clone() };
        let other = demo_artifact(&other_cfg, 0.35, 0x51EC).unwrap();
        let err =
            SpecDecoder::from_artifacts(&verifier, &other, ExecMode::Factored, 2).unwrap_err();
        assert!(err.to_string().contains("different checkpoint"), "{err}");
        // spec_k 0 is not a speculative decoder
        let v = ServeModel::from_artifact(&verifier, ExecMode::Factored).unwrap();
        let d = ServeModel::from_artifact(&draft, ExecMode::Factored).unwrap();
        assert!(SpecDecoder::new(v, d, 0).is_err());
    }

    #[test]
    fn streams_are_thread_count_invariant() {
        let cfg = demo_config();
        let prompt = crate::engine::synth_token_streams(&cfg, 1, 6, 0x7123).remove(0);
        let (_, _, dec) = pair(3);
        let run = |threads: usize| {
            let s = dec.generate(&prompt, 10, None, ExecConfig::with_threads(threads)).unwrap();
            (s.tokens, s.rounds, s.macs)
        };
        let serial = run(1);
        for threads in [2usize, 8] {
            assert_eq!(run(threads), serial, "--threads {threads} moved the speculative stream");
        }
    }
}
