//! Continuous-batching decode scheduler — now a thin adapter over the
//! shared streaming core ([`crate::engine`]).
//!
//! The scheduling semantics are unchanged from the original
//! implementation (they are the engine core's contract): requests wait in
//! a priced admission queue that reduces exactly to a FIFO for this
//! front door (no tiers, unlimited meter — deadlines, when declared,
//! admit earliest-deadline-first), free KV slots admit the queue head,
//! prompts prefill with a last-position LM head and sample their first token
//! (time-to-first-token), and active sequences advance one token per
//! *decode round* in admission order so no request starves. Sequences
//! finishing (EOS, token budget — or now a [`Session::cancel`] or a
//! per-request deadline) are evicted, their slots released, and the queue
//! drains into the freed slots *mid-run*
//! ([`DecodeStats::mid_run_admissions`]).
//!
//! What this file owns is only the *batch front door*: [`GenRequest`] /
//! [`GenResult`] and the [`DecodeScheduler::run`] signature every caller,
//! bench, and self-check already uses. `run` validates the whole batch
//! up-front (a bad request fails before any compute), feeds the session
//! under queue backpressure, and projects [`FinishedRequest`]s and
//! [`CoreStats`] back into decode vocabulary. Streaming callers drive
//! [`crate::engine::Session`] directly and receive the same token
//! streams, bitwise, in event form.
//!
//! Determinism: each request samples from its own [`crate::util::Rng`]
//! stream derived from `seed ^ id`, so token streams are identical
//! run-to-run and independent of slot assignment, admission timing, the
//! slot count — and, because every parallel kernel is bitwise stable, the
//! thread count.

use anyhow::Result;

use crate::engine::{
    CoreStats, EngineConfig, EngineCore, FinishedRequest, InferenceRequest, Session,
};
use crate::exec::ExecConfig;
use crate::serve::ServeModel;
use crate::util::RequestStats;

use super::sampler::Sampling;
use super::stats::DecodeStats;

pub use crate::engine::{Event, EventKind, FinishReason, StreamControl};
pub(crate) use crate::engine::request_rng;

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: usize,
    /// Prompt token ids (non-empty, in-vocab).
    pub prompt: Vec<i32>,
    /// Per-request generation cap; `None` uses [`DecodeConfig::max_new`].
    pub max_new: Option<usize>,
    /// Optional wall-clock budget (seconds from run start); an unfinished
    /// request is evicted with [`FinishReason::Deadline`] on expiry.
    pub deadline_s: Option<f64>,
}

/// One finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: usize,
    /// Admission sequence number (0-based): the order the scheduler
    /// granted slots, which for the FIFO queue equals submission order.
    /// `None` when the request was cancelled straight from the queue,
    /// before it ever took a slot.
    pub admitted: Option<usize>,
    pub prompt_len: usize,
    /// Generated tokens (terminating EOS included when present).
    pub tokens: Vec<i32>,
    /// `tokens` decoded through the byte-level tokenizer (specials
    /// skipped) — what `repro generate` prints.
    pub text: String,
    pub finish: FinishReason,
    /// Run start → first token (queue wait + prefill).
    pub ttft_s: f64,
    /// Run start → last token.
    pub latency_s: f64,
    /// MACs executed for this request (KV-cached regime).
    pub macs: u128,
    /// Analytic MACs a full-recompute decode of the same stream would
    /// execute (sum of from-scratch forwards over the growing prefix).
    pub recompute_macs: u128,
}

impl GenResult {
    pub(crate) fn from_finished(f: FinishedRequest) -> GenResult {
        GenResult {
            id: f.id,
            admitted: f.admitted,
            prompt_len: f.prompt_len,
            tokens: f.tokens,
            text: f.text,
            finish: f.reason,
            ttft_s: f.ttft_s,
            latency_s: f.latency_s,
            macs: f.macs,
            recompute_macs: f.recompute_macs,
        }
    }
}

/// Scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct DecodeConfig {
    /// Concurrent sequences (KV cache slots).
    pub slots: usize,
    /// KV capacity per slot, in tokens. Every request must satisfy
    /// `prompt + max_new <= capacity` to be admissible.
    pub capacity: usize,
    /// Default generation cap per request.
    pub max_new: usize,
    pub sampling: Sampling,
    /// Base seed; each request derives an independent stream from it.
    pub seed: u64,
    /// Token that terminates a sequence (`None` disables EOS eviction).
    pub eos: Option<i32>,
    /// Worker-pool budget shared by sequence-level fan-out and intra-op
    /// row sharding (token streams are invariant to it).
    pub exec: ExecConfig,
    /// Cap on the KV cache pool's preallocated footprint; construction
    /// fails cleanly when `slots × per-slot bytes` exceeds it. In
    /// speculative mode the cap covers *both* cache families (verifier +
    /// draft).
    pub max_cache_bytes: Option<usize>,
    /// Draft tokens proposed per speculative round (0 disables
    /// speculation; effective only via [`DecodeScheduler::with_draft`]
    /// under greedy sampling).
    pub spec_k: usize,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig {
            slots: 4,
            capacity: 192,
            max_new: 32,
            sampling: Sampling::Greedy,
            seed: 0,
            eos: Some(crate::data::EOS),
            exec: ExecConfig::default(),
            max_cache_bytes: None,
            spec_k: 0,
        }
    }
}

impl DecodeConfig {
    /// This front-end's knobs as an [`EngineConfig`]: every free slot is
    /// admissible per step (`max_admit = 0`) and the queue is bounded by
    /// the caller-visible workload (`queue_cap`).
    pub(crate) fn engine_config(&self, queue_cap: usize) -> EngineConfig {
        EngineConfig {
            slots: self.slots.max(1),
            queue_cap: queue_cap.max(1),
            max_admit: 0,
            capacity: self.capacity,
            max_new: self.max_new,
            sampling: self.sampling,
            seed: self.seed,
            eos: self.eos,
            exec: self.exec,
            // decode's historical behavior: lane fan-out bounded only by
            // the thread budget
            lane_parallelism: 0,
            max_cache_bytes: self.max_cache_bytes,
            // unlimited meter: the batch front door keeps exact-FIFO
            // admission unless a caller opts into tiers via the session
            interactive_macs_per_round: 0,
            batch_macs_per_round: 0,
            max_queued_macs: 0,
            spec_k: self.spec_k,
        }
    }
}

/// Project the core's aggregate stats into decode vocabulary.
pub(crate) fn decode_stats(cs: CoreStats) -> DecodeStats {
    DecodeStats {
        core: RequestStats {
            requests: cs.requests,
            tokens: cs.generated_tokens,
            macs: cs.macs,
            wall_s: cs.wall_s,
            latency: cs.latency,
        },
        prompt_tokens: cs.prompt_tokens,
        recompute_macs: cs.recompute_macs,
        ttft: cs.ttft,
        inter_token: cs.inter_token,
        peak_active: cs.peak_active,
        mid_run_admissions: cs.mid_run_admissions,
        decode_rounds: cs.decode_rounds,
        spec_drafted: cs.spec_drafted,
        spec_accepted: cs.spec_accepted,
    }
}

/// KV-cached autoregressive generation over one loaded [`ServeModel`],
/// optionally speculating with a low-budget draft model of the same
/// checkpoint ([`DecodeScheduler::with_draft`]).
pub struct DecodeScheduler<'m> {
    model: &'m ServeModel,
    draft: Option<&'m ServeModel>,
    config: DecodeConfig,
}

impl<'m> DecodeScheduler<'m> {
    pub fn new(model: &'m ServeModel, config: DecodeConfig) -> DecodeScheduler<'m> {
        DecodeScheduler { model, draft: None, config }
    }

    /// A scheduler that drafts `config.spec_k` candidate tokens per round
    /// on `draft` and verifies them in one chunked forward on `model`.
    /// Greedy streams are bitwise identical to [`DecodeScheduler::new`];
    /// non-greedy sampling falls back to plain decode deterministically.
    /// Fails when the pair is inconsistent (different checkpoint family,
    /// or `spec_k == 0` with a draft bound) — the same validation
    /// [`EngineCore::with_draft`] applies, surfaced before any compute.
    pub fn with_draft(
        model: &'m ServeModel,
        draft: &'m ServeModel,
        config: DecodeConfig,
    ) -> Result<DecodeScheduler<'m>> {
        // validate the pair eagerly with a throwaway core so misuse fails
        // at construction, not at the first run
        EngineCore::with_draft(model, draft, config.engine_config(1))?;
        Ok(DecodeScheduler { model, draft: Some(draft), config })
    }

    pub fn model(&self) -> &ServeModel {
        self.model
    }

    pub fn config(&self) -> &DecodeConfig {
        &self.config
    }

    /// The engine core this front door drives: draft-bound when
    /// speculative, plain otherwise.
    fn core(&self, ecfg: EngineConfig) -> Result<EngineCore<'m>> {
        match self.draft {
            Some(draft) => EngineCore::with_draft(self.model, draft, ecfg),
            None => Ok(EngineCore::new(self.model, ecfg)),
        }
    }

    /// An event-driven session over this scheduler's model and knobs —
    /// the streaming face of the same lifecycle `run` drives in batch.
    pub fn session(&self, queue_cap: usize) -> Session<'m> {
        self.core(self.config.engine_config(queue_cap))
            .expect("pair validated at construction")
            .session()
    }

    /// Validate a batch up-front with the core's own rules (so a bad
    /// request or duplicate id fails before any compute is spent — the
    /// session would catch each only at its own submission, after earlier
    /// requests were already served) and convert it for the engine.
    fn prepare(
        &self,
        requests: Vec<GenRequest>,
    ) -> Result<(EngineConfig, Vec<InferenceRequest>)> {
        let ecfg = self.config.engine_config(requests.len());
        let reqs: Vec<InferenceRequest> = requests.into_iter().map(Into::into).collect();
        ecfg.validate_batch(&reqs)?;
        Ok((ecfg, reqs))
    }

    /// Drive every request to completion. Results are returned in request
    /// id order with the run's aggregate stats. This is the no-event fast
    /// path: no per-token event or text is materialized.
    pub fn run(&self, requests: Vec<GenRequest>) -> Result<(Vec<GenResult>, DecodeStats)> {
        let (ecfg, reqs) = self.prepare(requests)?;
        let (finished, cs) = self.core(ecfg)?.run(reqs)?;
        let results = finished.into_iter().map(GenResult::from_finished).collect();
        Ok((results, decode_stats(cs)))
    }

    /// The streaming face of [`DecodeScheduler::run`]: identical
    /// scheduling, token streams, and stats, but every lifecycle step is
    /// surfaced to `on_event` as it happens — `Admitted`,
    /// `Prefilled{ttft}`, `Token{id, text}` (one per generated token, in
    /// deterministic order), `Finished{reason}`. Returning
    /// [`StreamControl::Cancel`] evicts that event's request at the next
    /// token boundary (finish reason `Cancelled`, partial stream kept,
    /// slot recycled to the queue). The concatenated `Token` payloads per
    /// request are byte-identical to the batch `run()` result — asserted
    /// by `repro generate --stream --self-check`.
    pub fn run_streaming<F>(
        &self,
        requests: Vec<GenRequest>,
        on_event: F,
    ) -> Result<(Vec<GenResult>, DecodeStats)>
    where
        F: FnMut(&Event) -> StreamControl,
    {
        let (ecfg, reqs) = self.prepare(requests)?;
        let (finished, cs) = self.core(ecfg)?.run_streaming(reqs, on_event)?;
        let results = finished.into_iter().map(GenResult::from_finished).collect();
        Ok((results, decode_stats(cs)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{demo_artifact, demo_config, ExecMode, ServeModel};

    fn model(mode: ExecMode, seed: u64) -> ServeModel {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, seed).unwrap();
        ServeModel::from_artifact(&cm, mode).unwrap()
    }

    fn config() -> DecodeConfig {
        DecodeConfig {
            slots: 2,
            capacity: 32,
            max_new: 6,
            sampling: Sampling::Greedy,
            seed: 7,
            eos: None,
            ..DecodeConfig::default()
        }
    }

    fn requests(n: usize, prompt_len: usize) -> Vec<GenRequest> {
        super::super::synth_gen_requests(&demo_config(), n, prompt_len, 11)
    }

    #[test]
    fn completes_every_request_in_fifo_admission_order() {
        let m = model(ExecMode::Factored, 41);
        let sched = DecodeScheduler::new(&m, config());
        let (results, stats) = sched.run(requests(5, 8)).unwrap();
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i, "results sorted by id");
            assert_eq!(r.admitted, Some(i), "FIFO admission: no request overtakes an earlier one");
            assert_eq!(r.prompt_len, 8);
            assert_eq!(r.tokens.len(), 6, "greedy runs to the token budget");
            assert_eq!(r.finish, FinishReason::MaxTokens);
            assert!(r.tokens.iter().all(|&t| (t as usize) < demo_config().vocab));
            assert!(r.ttft_s >= 0.0 && r.ttft_s <= r.latency_s);
            assert!(r.macs > 0 && r.recompute_macs > r.macs);
            assert_eq!(r.text, crate::data::Tokenizer::new().decode(&r.tokens));
        }
        assert_eq!(stats.core.requests, 5);
        assert_eq!(stats.prompt_tokens, 5 * 8);
        assert_eq!(stats.core.tokens, 5 * 6);
        assert_eq!(stats.peak_active, 2, "2 slots cap concurrency");
        assert!(stats.mid_run_admissions >= 3, "5 requests through 2 slots admit mid-run");
        assert!(stats.mac_savings() > 1.0);
        assert_eq!(stats.ttft.n, 5);
        assert_eq!(stats.inter_token.n, 5 * 5, "max_new-1 steps per request");
        assert_eq!(stats.core.latency.n, 5, "per-request completion latencies");
    }

    #[test]
    fn token_streams_are_slot_count_invariant() {
        let m = model(ExecMode::Factored, 43);
        let runs: Vec<Vec<Vec<i32>>> = [1usize, 2, 4]
            .iter()
            .map(|&slots| {
                let sched = DecodeScheduler::new(&m, DecodeConfig { slots, ..config() });
                let (results, _) = sched.run(requests(5, 6)).unwrap();
                results.into_iter().map(|r| r.tokens).collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1], "1 vs 2 slots");
        assert_eq!(runs[0], runs[2], "1 vs 4 slots");
    }

    #[test]
    fn token_streams_and_macs_are_thread_count_invariant() {
        let m = model(ExecMode::Factored, 97);
        let run = |threads: usize| {
            let cfg = DecodeConfig { exec: ExecConfig::with_threads(threads), ..config() };
            let (results, _) = DecodeScheduler::new(&m, cfg).run(requests(5, 7)).unwrap();
            results.into_iter().map(|r| (r.id, r.tokens, r.macs, r.recompute_macs)).collect::<Vec<_>>()
        };
        let serial = run(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(run(threads), serial, "--threads {threads} changed the streams");
        }
    }

    #[test]
    fn cache_cap_rejects_oversized_pools_cleanly() {
        use crate::decode::kv_slot_bytes;
        let m = model(ExecMode::Factored, 101);
        let per_slot = kv_slot_bytes(m.config(), config().capacity);
        let tight = DecodeConfig { max_cache_bytes: Some(2 * per_slot - 1), ..config() };
        let err = DecodeScheduler::new(&m, tight).run(requests(2, 4)).unwrap_err();
        assert!(err.to_string().contains("over budget"), "{err}");
        let roomy = DecodeConfig { max_cache_bytes: Some(2 * per_slot), ..config() };
        let (results, _) = DecodeScheduler::new(&m, roomy).run(requests(2, 4)).unwrap();
        assert_eq!(results.len(), 2, "a pool exactly at the cap still serves");
    }

    #[test]
    fn seeded_sampling_is_reproducible_and_seed_sensitive() {
        let m = model(ExecMode::Dense, 47);
        let run = |seed: u64| {
            let cfg = DecodeConfig {
                sampling: Sampling::TopK { k: 8, temperature: 0.9 },
                seed,
                ..config()
            };
            let (results, _) = DecodeScheduler::new(&m, cfg).run(requests(3, 6)).unwrap();
            results.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5), "same seed, same streams");
        assert_ne!(run(5), run(6), "different seed should move some stream");
    }

    #[test]
    fn eos_evicts_early() {
        let m = model(ExecMode::Factored, 53);
        // discover what greedy generates, then declare its second token EOS
        let sched = DecodeScheduler::new(&m, config());
        let (base, _) = sched.run(requests(1, 5)).unwrap();
        let eos_tok = base[0].tokens[1];
        let cfg_eos = DecodeConfig { eos: Some(eos_tok), ..config() };
        let (results, _) = DecodeScheduler::new(&m, cfg_eos).run(requests(1, 5)).unwrap();
        assert_eq!(results[0].finish, FinishReason::Eos);
        assert_eq!(results[0].tokens.len(), 2, "stops at the EOS token, inclusive");
        assert_eq!(results[0].tokens[1], eos_tok);
    }

    #[test]
    fn per_request_max_new_overrides_config() {
        let m = model(ExecMode::Factored, 59);
        let mut reqs = requests(3, 4);
        reqs[0].max_new = Some(1);
        reqs[2].max_new = Some(3);
        let (results, _) = DecodeScheduler::new(&m, config()).run(reqs).unwrap();
        assert_eq!(results[0].tokens.len(), 1, "max_new 1 finishes right after prefill");
        assert_eq!(results[1].tokens.len(), 6);
        assert_eq!(results[2].tokens.len(), 3);
    }

    #[test]
    fn per_request_deadline_is_honored_by_the_batch_path() {
        let m = model(ExecMode::Factored, 63);
        let mut reqs = requests(3, 4);
        // expires right after prefill: keeps its first token, steps no more
        reqs[1].deadline_s = Some(1e-9);
        let (results, _) = DecodeScheduler::new(&m, config()).run(reqs).unwrap();
        assert_eq!(results[0].finish, FinishReason::MaxTokens);
        assert_eq!(results[1].finish, FinishReason::Deadline);
        assert_eq!(results[1].tokens.len(), 1);
        assert_eq!(results[2].finish, FinishReason::MaxTokens);
        assert_eq!(results[2].tokens.len(), 6);
    }

    #[test]
    fn speculative_run_is_bitwise_identical_and_counts_acceptance() {
        let cfg = demo_config();
        let verifier_cm = demo_artifact(&cfg, 0.8, 0x51EC).unwrap();
        let draft_cm = demo_artifact(&cfg, 0.35, 0x51EC).unwrap();
        let verifier = ServeModel::from_artifact(&verifier_cm, ExecMode::Factored).unwrap();
        let draft = ServeModel::from_artifact(&draft_cm, ExecMode::Factored).unwrap();
        let (base, base_stats) =
            DecodeScheduler::new(&verifier, config()).run(requests(4, 6)).unwrap();
        let spec_cfg = DecodeConfig { spec_k: 3, ..config() };
        let sched = DecodeScheduler::with_draft(&verifier, &draft, spec_cfg).unwrap();
        let (results, stats) = sched.run(requests(4, 6)).unwrap();
        for (a, b) in base.iter().zip(&results) {
            assert_eq!(a.tokens, b.tokens, "speculative stream diverged on request {}", a.id);
            assert_eq!(a.finish, b.finish);
            assert_eq!(a.text, b.text);
        }
        assert_eq!(base_stats.spec_drafted, 0);
        assert!(stats.spec_drafted > 0, "draft model never ran");
        assert!(stats.spec_accepted <= stats.spec_drafted);
        assert!(stats.spec_accept_rate() > 0.0, "same-checkpoint pair should agree sometimes");
        // non-greedy sampling must deterministically fall back to plain
        // decode: same streams as a draft-less scheduler, nothing drafted
        let sampled = DecodeConfig {
            sampling: Sampling::TopK { k: 8, temperature: 0.9 },
            spec_k: 3,
            ..config()
        };
        let spec = DecodeScheduler::with_draft(&verifier, &draft, sampled).unwrap();
        let (a, a_stats) = spec.run(requests(3, 6)).unwrap();
        let plain = DecodeScheduler::new(&verifier, DecodeConfig { spec_k: 0, ..sampled });
        let (b, _) = plain.run(requests(3, 6)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "sampled fallback diverged");
        }
        assert_eq!(a_stats.spec_drafted, 0, "non-greedy runs must not draft");
    }

    #[test]
    fn invalid_requests_fail_before_compute() {
        let m = model(ExecMode::Factored, 61);
        let sched = DecodeScheduler::new(&m, config());
        let empty =
            vec![GenRequest { id: 0, prompt: Vec::new(), max_new: None, deadline_s: None }];
        assert!(sched.run(empty).is_err(), "empty prompt");
        let too_long =
            vec![GenRequest { id: 0, prompt: vec![1; 40], max_new: None, deadline_s: None }];
        assert!(sched.run(too_long).is_err(), "prompt + max_new > capacity");
        let (results, stats) = sched.run(Vec::new()).unwrap();
        assert!(results.is_empty());
        assert_eq!(stats.core.tokens, 0);
        assert_eq!(stats.ttft.n, 0);
    }
}
