//! Continuous-batching decode scheduler: prefill/decode phase split,
//! mid-run admission, EOS/max-token eviction, round-robin fairness.
//!
//! The scheduler owns a [`KvCachePool`] of `slots` preallocated caches.
//! Requests wait in a FIFO; whenever a slot is free the head of the queue
//! is admitted — its prompt is prefilled through the cache (the LM head
//! sliced to the final position, the only row the sampler reads) and its
//! first token sampled (time-to-first-token). Active sequences then
//! advance in *decode rounds*: every round steps each active sequence by
//! exactly one token, in admission order, so no request can starve while
//! another streams ahead. Sequences finishing (EOS or their token budget)
//! are evicted at the end of the round, their slots released, and the
//! queue drains into the freed slots *mid-run* — the continuous-batching
//! behavior, observable as [`DecodeStats::mid_run_admissions`].
//!
//! Parallelism ([`DecodeConfig::exec`]): prefills of a freshly admitted
//! batch and the per-sequence steps of a decode round fan out over the
//! shared [`ExecPool`] (each active sequence owns its cache, so steps are
//! embarrassingly parallel); leftover thread budget goes to row-sharded
//! matmuls inside each forward, so request-level and intra-op parallelism
//! split one knob and can't oversubscribe.
//!
//! Determinism: each request samples from its own [`Rng`] stream derived
//! from `seed ^ id`, so token streams are identical run-to-run and
//! independent of slot assignment, admission timing, the slot count —
//! and, because every parallel kernel is bitwise stable, the thread count.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::exec::{ExecConfig, ExecPool};
use crate::serve::ServeModel;
use crate::util::{LatencySummary, Rng};

use super::kv::{KvCache, KvCachePool};
use super::sampler::Sampling;
use super::stats::DecodeStats;

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: usize,
    /// Prompt token ids (non-empty, in-vocab).
    pub prompt: Vec<i32>,
    /// Per-request generation cap; `None` uses [`DecodeConfig::max_new`].
    pub max_new: Option<usize>,
}

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The configured end-of-sequence token was sampled (it is included as
    /// the last generated token).
    Eos,
    /// The request's token budget was reached.
    MaxTokens,
}

impl FinishReason {
    pub fn name(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max-tokens",
        }
    }
}

/// One finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: usize,
    /// Admission sequence number (0-based): the order the scheduler
    /// granted slots, which for the FIFO queue equals submission order.
    pub admitted: usize,
    pub prompt_len: usize,
    /// Generated tokens (terminating EOS included when present).
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Run start → first token (queue wait + prefill).
    pub ttft_s: f64,
    /// Run start → last token.
    pub latency_s: f64,
    /// MACs executed for this request (KV-cached regime).
    pub macs: u128,
    /// Analytic MACs a full-recompute decode of the same stream would
    /// execute (sum of from-scratch forwards over the growing prefix).
    pub recompute_macs: u128,
}

/// Scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct DecodeConfig {
    /// Concurrent sequences (KV cache slots).
    pub slots: usize,
    /// KV capacity per slot, in tokens. Every request must satisfy
    /// `prompt + max_new <= capacity` to be admissible.
    pub capacity: usize,
    /// Default generation cap per request.
    pub max_new: usize,
    pub sampling: Sampling,
    /// Base seed; each request derives an independent stream from it.
    pub seed: u64,
    /// Token that terminates a sequence (`None` disables EOS eviction).
    pub eos: Option<i32>,
    /// Worker-pool budget shared by sequence-level fan-out and intra-op
    /// row sharding (token streams are invariant to it).
    pub exec: ExecConfig,
    /// Cap on the KV cache pool's preallocated footprint; construction
    /// fails cleanly when `slots × per-slot bytes` exceeds it.
    pub max_cache_bytes: Option<usize>,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig {
            slots: 4,
            capacity: 192,
            max_new: 32,
            sampling: Sampling::Greedy,
            seed: 0,
            eos: Some(crate::data::EOS),
            exec: ExecConfig::default(),
            max_cache_bytes: None,
        }
    }
}

/// The per-request RNG stream: independent of scheduling, stable across
/// slot counts — shared with the recompute baseline so both paths draw
/// identical samples.
pub(crate) fn request_rng(seed: u64, id: usize) -> Rng {
    Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xD0DE))
}

/// A sequence occupying a slot. Owns its KV cache for the duration of the
/// run, so decode rounds can step every active sequence on worker threads
/// without aliasing the pool.
struct Active {
    id: usize,
    admitted: usize,
    prompt: Vec<i32>,
    max_new: usize,
    tokens: Vec<i32>,
    cache: KvCache,
    rng: Rng,
    macs: u128,
    recompute_macs: u128,
    ttft_s: f64,
    last_s: f64,
    /// Inter-token latency of this sequence's step in the current round.
    itl_s: f64,
    done: Option<FinishReason>,
}

impl Active {
    /// Apply the stopping rules after `token` was appended.
    fn note_stop(&mut self, eos: Option<i32>, token: i32) {
        if Some(token) == eos {
            self.done = Some(FinishReason::Eos);
        } else if self.tokens.len() >= self.max_new {
            self.done = Some(FinishReason::MaxTokens);
        }
    }
}

/// KV-cached autoregressive generation over one loaded [`ServeModel`].
pub struct DecodeScheduler<'m> {
    model: &'m ServeModel,
    config: DecodeConfig,
}

impl<'m> DecodeScheduler<'m> {
    pub fn new(model: &'m ServeModel, config: DecodeConfig) -> DecodeScheduler<'m> {
        DecodeScheduler { model, config }
    }

    pub fn model(&self) -> &ServeModel {
        self.model
    }

    pub fn config(&self) -> &DecodeConfig {
        &self.config
    }

    /// Drive every request to completion. Results are returned in request
    /// id order with the run's aggregate stats.
    pub fn run(&self, requests: Vec<GenRequest>) -> Result<(Vec<GenResult>, DecodeStats)> {
        let cfg = self.model.config();
        let slots = self.config.slots.max(1);
        let n = requests.len();
        let prompt_tokens: usize = requests.iter().map(|r| r.prompt.len()).sum();

        // validate everything up-front so a bad request fails before any
        // compute is spent
        for r in &requests {
            ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
            let max_new = r.max_new.unwrap_or(self.config.max_new).max(1);
            ensure!(
                r.prompt.len() + max_new <= self.config.capacity,
                "request {}: prompt {} + max_new {max_new} exceeds KV capacity {}",
                r.id,
                r.prompt.len(),
                self.config.capacity
            );
        }

        let t0 = Instant::now();
        let mut pool =
            KvCachePool::with_cap(cfg, slots, self.config.capacity, self.config.max_cache_bytes)?;
        let threads = self.config.exec.resolve().max(1);
        let sampling = self.config.sampling;
        let eos = self.config.eos;
        let mut pending: VecDeque<GenRequest> = requests.into();
        let mut active: Vec<Active> = Vec::new();
        let mut results: Vec<GenResult> = Vec::with_capacity(n);
        let mut ttfts: Vec<f64> = Vec::with_capacity(n);
        let mut itls: Vec<f64> = Vec::new();
        let (mut admitted_count, mut mid_run) = (0usize, 0usize);
        let (mut peak_active, mut rounds) = (0usize, 0usize);

        loop {
            // ---- admission: drain the queue into free slots ----
            let mut fresh: Vec<Active> = Vec::new();
            while active.len() + fresh.len() < slots {
                let Some(req) = pending.pop_front() else { break };
                let max_new = req.max_new.unwrap_or(self.config.max_new).max(1);
                let cache = pool.acquire().expect("free cache under the active-count bound");
                let admitted = admitted_count;
                admitted_count += 1;
                // continuous batching: an admission after any eviction means
                // this request entered a slot another sequence freed mid-run
                if !results.is_empty() {
                    mid_run += 1;
                }
                let rng = request_rng(self.config.seed, req.id);
                fresh.push(Active {
                    id: req.id,
                    admitted,
                    prompt: req.prompt,
                    max_new,
                    tokens: Vec::new(),
                    cache,
                    rng,
                    macs: 0,
                    recompute_macs: 0,
                    ttft_s: 0.0,
                    last_s: 0.0,
                    itl_s: 0.0,
                    done: None,
                });
            }
            if !fresh.is_empty() {
                // prefill phase: the freshly admitted prompts fan out over
                // the pool (each owns its cache); leftover thread budget
                // row-shards the matmuls inside each prefill
                let n_par = threads.min(fresh.len()).max(1);
                let outer = ExecPool::new(n_par);
                let intra = ExecPool::new(threads).split(n_par);
                outer.try_parallel_for(&mut fresh, |_, a| -> Result<()> {
                    let (logits, macs) =
                        self.model.forward_prefill(&a.prompt, &mut a.cache, &intra)?;
                    let first = sampling.sample(&logits, &mut a.rng);
                    let now = t0.elapsed().as_secs_f64();
                    a.macs = macs;
                    a.recompute_macs = self.model.macs_for(a.prompt.len());
                    a.ttft_s = now;
                    a.last_s = now;
                    a.tokens.push(first);
                    a.note_stop(eos, first);
                    Ok(())
                })?;
                for a in fresh {
                    ttfts.push(a.ttft_s);
                    active.push(a);
                    peak_active = peak_active.max(active.len());
                }
            }
            evict(&mut active, &mut pool, &mut results);
            if active.is_empty() {
                if pending.is_empty() {
                    break;
                }
                continue; // every admission finished instantly; admit more
            }

            // ---- one decode round: each active sequence advances a token,
            // all sequences stepping concurrently on the pool ----
            rounds += 1;
            let n_par = threads.min(active.len()).max(1);
            let outer = ExecPool::new(n_par);
            let intra = ExecPool::new(threads).split(n_par);
            outer.try_parallel_for(&mut active, |_, a| -> Result<()> {
                let last_tok = *a.tokens.last().expect("active sequences hold >= 1 token");
                let (logits, m) =
                    self.model.forward_step_pooled(last_tok, &mut a.cache, &intra)?;
                a.macs += m;
                a.recompute_macs += self.model.macs_for(a.prompt.len() + a.tokens.len());
                let next = sampling.sample(&logits, &mut a.rng);
                let now = t0.elapsed().as_secs_f64();
                a.itl_s = now - a.last_s;
                a.last_s = now;
                a.tokens.push(next);
                a.note_stop(eos, next);
                Ok(())
            })?;
            for a in &active {
                itls.push(a.itl_s);
            }
            evict(&mut active, &mut pool, &mut results);
        }

        let wall_s = t0.elapsed().as_secs_f64();
        results.sort_by_key(|r| r.id);
        let stats = DecodeStats {
            requests: results.len(),
            prompt_tokens,
            generated_tokens: results.iter().map(|r| r.tokens.len()).sum(),
            wall_s,
            macs: results.iter().map(|r| r.macs).sum(),
            recompute_macs: results.iter().map(|r| r.recompute_macs).sum(),
            ttft: LatencySummary::from_unsorted(ttfts),
            inter_token: LatencySummary::from_unsorted(itls),
            peak_active,
            mid_run_admissions: mid_run,
            decode_rounds: rounds,
        };
        Ok((results, stats))
    }
}

/// Move finished sequences out of the active set, releasing their caches.
fn evict(active: &mut Vec<Active>, pool: &mut KvCachePool, results: &mut Vec<GenResult>) {
    let mut i = 0;
    while i < active.len() {
        if let Some(finish) = active[i].done {
            let a = active.remove(i);
            pool.release(a.cache);
            results.push(GenResult {
                id: a.id,
                admitted: a.admitted,
                prompt_len: a.prompt.len(),
                tokens: a.tokens,
                finish,
                ttft_s: a.ttft_s,
                latency_s: a.last_s,
                macs: a.macs,
                recompute_macs: a.recompute_macs,
            });
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{demo_artifact, demo_config, ExecMode, ServeModel};

    fn model(mode: ExecMode, seed: u64) -> ServeModel {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, seed).unwrap();
        ServeModel::from_artifact(&cm, mode).unwrap()
    }

    fn config() -> DecodeConfig {
        DecodeConfig {
            slots: 2,
            capacity: 32,
            max_new: 6,
            sampling: Sampling::Greedy,
            seed: 7,
            eos: None,
            ..DecodeConfig::default()
        }
    }

    fn requests(n: usize, prompt_len: usize) -> Vec<GenRequest> {
        super::super::synth_gen_requests(&demo_config(), n, prompt_len, 11)
    }

    #[test]
    fn completes_every_request_in_fifo_admission_order() {
        let m = model(ExecMode::Factored, 41);
        let sched = DecodeScheduler::new(&m, config());
        let (results, stats) = sched.run(requests(5, 8)).unwrap();
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i, "results sorted by id");
            assert_eq!(r.admitted, i, "FIFO admission: no request overtakes an earlier one");
            assert_eq!(r.prompt_len, 8);
            assert_eq!(r.tokens.len(), 6, "greedy runs to the token budget");
            assert_eq!(r.finish, FinishReason::MaxTokens);
            assert!(r.tokens.iter().all(|&t| (t as usize) < demo_config().vocab));
            assert!(r.ttft_s >= 0.0 && r.ttft_s <= r.latency_s);
            assert!(r.macs > 0 && r.recompute_macs > r.macs);
        }
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.prompt_tokens, 5 * 8);
        assert_eq!(stats.generated_tokens, 5 * 6);
        assert_eq!(stats.peak_active, 2, "2 slots cap concurrency");
        assert!(stats.mid_run_admissions >= 3, "5 requests through 2 slots admit mid-run");
        assert!(stats.mac_savings() > 1.0);
        assert_eq!(stats.ttft.n, 5);
        assert_eq!(stats.inter_token.n, 5 * 5, "max_new-1 steps per request");
    }

    #[test]
    fn token_streams_are_slot_count_invariant() {
        let m = model(ExecMode::Factored, 43);
        let runs: Vec<Vec<Vec<i32>>> = [1usize, 2, 4]
            .iter()
            .map(|&slots| {
                let sched = DecodeScheduler::new(&m, DecodeConfig { slots, ..config() });
                let (results, _) = sched.run(requests(5, 6)).unwrap();
                results.into_iter().map(|r| r.tokens).collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1], "1 vs 2 slots");
        assert_eq!(runs[0], runs[2], "1 vs 4 slots");
    }

    #[test]
    fn token_streams_and_macs_are_thread_count_invariant() {
        let m = model(ExecMode::Factored, 97);
        let run = |threads: usize| {
            let cfg = DecodeConfig { exec: ExecConfig::with_threads(threads), ..config() };
            let (results, _) = DecodeScheduler::new(&m, cfg).run(requests(5, 7)).unwrap();
            results.into_iter().map(|r| (r.id, r.tokens, r.macs, r.recompute_macs)).collect::<Vec<_>>()
        };
        let serial = run(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(run(threads), serial, "--threads {threads} changed the streams");
        }
    }

    #[test]
    fn cache_cap_rejects_oversized_pools_cleanly() {
        use crate::decode::kv_slot_bytes;
        let m = model(ExecMode::Factored, 101);
        let per_slot = kv_slot_bytes(m.config(), config().capacity);
        let tight = DecodeConfig { max_cache_bytes: Some(2 * per_slot - 1), ..config() };
        let err = DecodeScheduler::new(&m, tight).run(requests(2, 4)).unwrap_err();
        assert!(err.to_string().contains("over budget"), "{err}");
        let roomy = DecodeConfig { max_cache_bytes: Some(2 * per_slot), ..config() };
        let (results, _) = DecodeScheduler::new(&m, roomy).run(requests(2, 4)).unwrap();
        assert_eq!(results.len(), 2, "a pool exactly at the cap still serves");
    }

    #[test]
    fn seeded_sampling_is_reproducible_and_seed_sensitive() {
        let m = model(ExecMode::Dense, 47);
        let run = |seed: u64| {
            let cfg = DecodeConfig {
                sampling: Sampling::TopK { k: 8, temperature: 0.9 },
                seed,
                ..config()
            };
            let (results, _) = DecodeScheduler::new(&m, cfg).run(requests(3, 6)).unwrap();
            results.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5), "same seed, same streams");
        assert_ne!(run(5), run(6), "different seed should move some stream");
    }

    #[test]
    fn eos_evicts_early() {
        let m = model(ExecMode::Factored, 53);
        // discover what greedy generates, then declare its second token EOS
        let sched = DecodeScheduler::new(&m, config());
        let (base, _) = sched.run(requests(1, 5)).unwrap();
        let eos_tok = base[0].tokens[1];
        let cfg_eos = DecodeConfig { eos: Some(eos_tok), ..config() };
        let (results, _) = DecodeScheduler::new(&m, cfg_eos).run(requests(1, 5)).unwrap();
        assert_eq!(results[0].finish, FinishReason::Eos);
        assert_eq!(results[0].tokens.len(), 2, "stops at the EOS token, inclusive");
        assert_eq!(results[0].tokens[1], eos_tok);
    }

    #[test]
    fn per_request_max_new_overrides_config() {
        let m = model(ExecMode::Factored, 59);
        let mut reqs = requests(3, 4);
        reqs[0].max_new = Some(1);
        reqs[2].max_new = Some(3);
        let (results, _) = DecodeScheduler::new(&m, config()).run(reqs).unwrap();
        assert_eq!(results[0].tokens.len(), 1, "max_new 1 finishes right after prefill");
        assert_eq!(results[1].tokens.len(), 6);
        assert_eq!(results[2].tokens.len(), 3);
    }

    #[test]
    fn invalid_requests_fail_before_compute() {
        let m = model(ExecMode::Factored, 61);
        let sched = DecodeScheduler::new(&m, config());
        let empty = vec![GenRequest { id: 0, prompt: Vec::new(), max_new: None }];
        assert!(sched.run(empty).is_err(), "empty prompt");
        let too_long = vec![GenRequest { id: 0, prompt: vec![1; 40], max_new: None }];
        assert!(sched.run(too_long).is_err(), "prompt + max_new > capacity");
        let (results, stats) = sched.run(Vec::new()).unwrap();
        assert!(results.is_empty());
        assert_eq!(stats.generated_tokens, 0);
        assert_eq!(stats.ttft.n, 0);
    }
}
