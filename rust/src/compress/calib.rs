//! Pluggable calibration sources for the unified compression pipeline.
//!
//! A [`CalibrationStream`] abstracts *where* calibration rows come from
//! (task combination, a single task, generic corpus, a pre-built slice)
//! behind a chunked iterator: consumers pull canonical-shape
//! [`CalibBatch`]es one fixed-size chunk at a time, so the memory held for
//! calibration *activations* stays bounded by one chunk regardless of the
//! configured row count (token batches themselves are KB-sized). Streams
//! are rewindable — [`CalibrationStream::reset`] restarts the same
//! deterministic row sequence, which lets one stream feed a multi-method
//! sweep.

use crate::data::{build_calibration, CalibBatch, CalibSource, World};

/// A rewindable, chunked source of calibration batches.
pub trait CalibrationStream {
    /// Human-readable source label (recorded in provenance).
    fn label(&self) -> String;

    /// Next chunk of batches; `None` once the stream is exhausted.
    fn next_chunk(&mut self) -> Option<Vec<CalibBatch>>;

    /// Rewind to the start of the (deterministic) sequence.
    fn reset(&mut self);

    /// Configured number of calibration rows (provenance bookkeeping).
    fn rows_hint(&self) -> usize;

    /// Configured per-row sequence length (provenance bookkeeping).
    fn seq_hint(&self) -> usize;
}

/// Batches per chunk yielded by the built-in streams.
const CHUNK_BATCHES: usize = 4;

/// Drain a stream into a batch list, optionally stopping once `max_rows`
/// real (non-PAD) rows have been gathered. The ROM pipeline keeps the
/// *token* batches resident (small) while streaming activations chunkwise,
/// so materializing here does not break the fixed-memory argument.
///
/// The cap is exact: if the final batch straddles it, the excess rows of
/// that batch are marked invalid (`valid = 0`), so consumers calibrate on
/// precisely `max_rows` rows — what the provenance records — rather than
/// overshooting by up to a full chunk.
pub fn collect_rows(stream: &mut dyn CalibrationStream, max_rows: Option<usize>) -> Vec<CalibBatch> {
    stream.reset();
    let mut out = Vec::new();
    let mut rows = 0usize;
    while let Some(chunk) = stream.next_chunk() {
        for mut b in chunk {
            match max_rows {
                None => {
                    rows += b.valid.iter().filter(|&&v| v > 0).count();
                    out.push(b);
                }
                Some(cap) => {
                    let remaining = cap - rows;
                    let mut kept = 0usize;
                    for v in b.valid.iter_mut() {
                        if *v > 0 {
                            if kept < remaining {
                                kept += 1;
                            } else {
                                *v = 0; // truncate to the cap: pad row
                            }
                        }
                    }
                    rows += kept;
                    out.push(b);
                    if rows >= cap {
                        return out;
                    }
                }
            }
        }
    }
    out
}

/// Calibration drawn from the synthetic world's task/corpus distributions
/// — the stream form of [`build_calibration`], built lazily on first pull.
pub struct WorldStream<'w> {
    world: &'w World,
    source: CalibSource,
    rows: usize,
    batch: usize,
    seq: usize,
    seq_used: usize,
    seed: u64,
    built: Option<Vec<CalibBatch>>,
    cursor: usize,
}

impl<'w> WorldStream<'w> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        world: &'w World,
        source: CalibSource,
        rows: usize,
        batch: usize,
        seq: usize,
        seq_used: usize,
        seed: u64,
    ) -> WorldStream<'w> {
        WorldStream { world, source, rows, batch, seq, seq_used, seed, built: None, cursor: 0 }
    }
}

impl CalibrationStream for WorldStream<'_> {
    fn label(&self) -> String {
        self.source.name()
    }

    fn next_chunk(&mut self) -> Option<Vec<CalibBatch>> {
        if self.built.is_none() {
            self.built = Some(build_calibration(
                self.world,
                self.source,
                self.rows,
                self.batch,
                self.seq,
                self.seq_used,
                self.seed,
            ));
        }
        let all = self.built.as_ref().unwrap();
        if self.cursor >= all.len() {
            return None;
        }
        let end = (self.cursor + CHUNK_BATCHES).min(all.len());
        let chunk = all[self.cursor..end].to_vec();
        self.cursor = end;
        Some(chunk)
    }

    fn reset(&mut self) {
        self.cursor = 0;
    }

    fn rows_hint(&self) -> usize {
        self.rows
    }

    fn seq_hint(&self) -> usize {
        self.seq_used
    }
}

/// A pre-built batch list as a stream (table sweeps, tests, benches).
pub struct VecStream {
    label: String,
    batches: Vec<CalibBatch>,
    cursor: usize,
}

impl VecStream {
    pub fn new(label: impl Into<String>, batches: Vec<CalibBatch>) -> VecStream {
        VecStream { label: label.into(), batches, cursor: 0 }
    }
}

impl CalibrationStream for VecStream {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn next_chunk(&mut self) -> Option<Vec<CalibBatch>> {
        if self.cursor >= self.batches.len() {
            return None;
        }
        let end = (self.cursor + CHUNK_BATCHES).min(self.batches.len());
        let chunk = self.batches[self.cursor..end].to_vec();
        self.cursor = end;
        Some(chunk)
    }

    fn reset(&mut self) {
        self.cursor = 0;
    }

    fn rows_hint(&self) -> usize {
        self.batches.iter().map(|b| b.valid.iter().filter(|&&v| v > 0).count()).sum()
    }

    fn seq_hint(&self) -> usize {
        // the *used* sequence length, not the padded canonical `b.seq`:
        // rows carry at most `seq_used` valid tokens, so the longest
        // valid run is the configured length (mirrors WorldStream)
        self.batches.iter().flat_map(|b| b.valid.iter().copied()).max().unwrap_or(0)
    }
}

/// The empty stream — for data-free methods (weight-space SVD, magnitude
/// pruning) and for offline sessions.
#[derive(Default)]
pub struct EmptyStream;

impl CalibrationStream for EmptyStream {
    fn label(&self) -> String {
        "none".to_string()
    }

    fn next_chunk(&mut self) -> Option<Vec<CalibBatch>> {
        None
    }

    fn reset(&mut self) {}

    fn rows_hint(&self) -> usize {
        0
    }

    fn seq_hint(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_batch(valid: &[usize], seq: usize) -> CalibBatch {
        CalibBatch {
            tokens: vec![0; valid.len() * seq],
            valid: valid.to_vec(),
            batch: valid.len(),
            seq,
        }
    }

    #[test]
    fn vec_stream_chunks_and_rewinds() {
        let batches: Vec<CalibBatch> = (0..6).map(|_| mk_batch(&[3, 3], 8)).collect();
        let mut s = VecStream::new("six", batches);
        let mut n = 0;
        while let Some(c) = s.next_chunk() {
            assert!(c.len() <= CHUNK_BATCHES);
            n += c.len();
        }
        assert_eq!(n, 6);
        assert!(s.next_chunk().is_none());
        s.reset();
        assert_eq!(s.next_chunk().unwrap().len(), CHUNK_BATCHES);
        assert_eq!(s.rows_hint(), 12);
        // seq_hint reports the used length (max valid run), not b.seq
        assert_eq!(s.seq_hint(), 3);
    }

    #[test]
    fn collect_rows_caps_at_max() {
        // 4 valid rows per batch (a row = one calibration sequence)
        let batches: Vec<CalibBatch> = (0..5).map(|_| mk_batch(&[2, 2, 2, 2], 8)).collect();
        let mut s = VecStream::new("cap", batches);
        let got = collect_rows(&mut s, Some(10));
        // rows accumulate 4, 8, 12 — the cap is reached inside batch 3
        assert_eq!(got.len(), 3);
        // invariant: exactly `cap` valid rows survive — the final batch's
        // two excess rows are truncated to padding, so calibration sees
        // what the provenance records
        let valid: usize =
            got.iter().map(|b| b.valid.iter().filter(|&&v| v > 0).count()).sum();
        assert_eq!(valid, 10);
        assert_eq!(got[2].valid, vec![2, 2, 0, 0]);
        let uncapped = collect_rows(&mut s, None);
        assert_eq!(uncapped.len(), 5);
        let all: usize =
            uncapped.iter().map(|b| b.valid.iter().filter(|&&v| v > 0).count()).sum();
        assert_eq!(all, 20);
    }

    #[test]
    fn world_stream_matches_build_calibration() {
        let world = World::default_world(7);
        let direct = build_calibration(&world, CalibSource::Combination, 20, 8, 128, 64, 9);
        let mut s = WorldStream::new(&world, CalibSource::Combination, 20, 8, 128, 64, 9);
        let streamed = collect_rows(&mut s, None);
        assert_eq!(direct.len(), streamed.len());
        for (a, b) in direct.iter().zip(&streamed) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.valid, b.valid);
        }
        assert_eq!(s.label(), "combination");
        assert_eq!(s.rows_hint(), 20);
        assert_eq!(s.seq_hint(), 64);
    }

    #[test]
    fn empty_stream_is_empty() {
        let mut s = EmptyStream;
        assert!(s.next_chunk().is_none());
        assert_eq!(collect_rows(&mut s, None).len(), 0);
        assert_eq!(s.label(), "none");
    }
}
