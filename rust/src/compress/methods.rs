//! The built-in [`Compressor`] implementations: the paper's feature-space
//! ROM, its weight-space SVD ablation, and the two structured-pruning
//! baselines. Each is a thin adapter from the shared [`CompressCtx`] onto
//! the corresponding engine (`rom::pipeline`, `prune`), normalizing every
//! result into a [`CompressedModel`]. The ROM adapters carry the
//! low-rank factors of every decomposed matrix into the artifact (via
//! [`CompressedModel::from_rom`]), which is what the factored-form
//! serving engine ([`crate::serve`]) executes; pruning artifacts carry
//! none and always serve dense.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::prune::{Importance, Pruner};
use crate::rom::pipeline::{compress_weight_space, DecompositionSpace, RomConfig, RomPipeline};

use super::artifact::CompressedModel;
use super::calib::collect_rows;
use super::{CompressCtx, Compressor};

/// Activation-aware pruning scores converge with far fewer rows than ROM
/// covariances need; cap the capture work (mirrors the previous
/// `prune_at` behavior).
const PRUNE_MAX_CALIB_ROWS: usize = 128;

/// Paper §2: feature-space ROM (covariance of calibration outputs).
pub struct RomFeature {
    /// §2 error propagation — calibrate each layer against the already
    /// compressed prefix. `false` is the published ablation.
    pub propagate_errors: bool,
}

impl Default for RomFeature {
    fn default() -> Self {
        RomFeature { propagate_errors: true }
    }
}

impl Compressor for RomFeature {
    fn name(&self) -> &str {
        "rom-feature"
    }

    fn needs_runtime(&self) -> bool {
        true
    }

    fn compress(&self, ctx: &mut CompressCtx<'_>) -> Result<CompressedModel> {
        let rt = ctx
            .runtime
            .context("`rom-feature` needs a PJRT runtime for activation capture")?;
        let batches = collect_rows(ctx.calib, None);
        let rcfg = RomConfig {
            schedule: ctx.schedule,
            pallas_covariance: ctx.pallas_covariance,
            propagate_errors: self.propagate_errors,
            space: DecompositionSpace::Feature,
            exec: ctx.exec,
            ..RomConfig::default()
        };
        let rom = RomPipeline::new(rt).compress(ctx.params, &batches, &rcfg)?;
        Ok(CompressedModel::from_rom(rom, ctx.provenance(self.name())))
    }
}

/// Ablation baseline: data-free truncated SVD of W (eigendecomposition of
/// W·Wᵀ) with the same ranks/schedule as ROM. Needs no runtime and no
/// calibration data.
#[derive(Default)]
pub struct RomWeightSvd;

impl Compressor for RomWeightSvd {
    fn name(&self) -> &str {
        "rom-weight-svd"
    }

    fn compress(&self, ctx: &mut CompressCtx<'_>) -> Result<CompressedModel> {
        let rcfg = RomConfig {
            schedule: ctx.schedule,
            space: DecompositionSpace::Weight,
            exec: ctx.exec,
            ..RomConfig::default()
        };
        let rom = compress_weight_space(&ctx.cfg, ctx.params, &rcfg)?;
        Ok(CompressedModel::from_rom(rom, data_free_provenance(ctx, self.name())))
    }
}

/// Provenance for a method that consumed no calibration data — records
/// `none`/0 regardless of what stream the session happened to carry.
fn data_free_provenance(ctx: &CompressCtx<'_>, method: &str) -> crate::compress::Provenance {
    let mut prov = ctx.provenance(method);
    prov.calib_label = "none".to_string();
    prov.calib_rows = 0;
    prov.calib_seq = 0;
    prov
}

/// LLM-Pruner-style structured pruning (whole FFN channels + attention
/// heads), with either importance criterion.
pub struct PruneStructured {
    pub importance: Importance,
}

impl Compressor for PruneStructured {
    fn name(&self) -> &str {
        match self.importance {
            Importance::Magnitude => "prune-magnitude",
            Importance::ActivationAware => "prune-activation",
        }
    }

    fn needs_runtime(&self) -> bool {
        self.importance == Importance::ActivationAware
    }

    fn compress(&self, ctx: &mut CompressCtx<'_>) -> Result<CompressedModel> {
        let t0 = Instant::now();
        let (pruner, batches) = match self.importance {
            Importance::Magnitude => (Pruner::offline(ctx.cfg.clone()), Vec::new()),
            Importance::ActivationAware => {
                let rt = ctx
                    .runtime
                    .context("`prune-activation` needs a PJRT runtime for activation capture")?;
                let batches = collect_rows(ctx.calib, Some(PRUNE_MAX_CALIB_ROWS));
                (Pruner::new(rt), batches)
            }
        };
        // provenance records what was actually consumed, not what the
        // stream was configured to offer (the row cap above may bite)
        let provenance = match self.importance {
            Importance::Magnitude => data_free_provenance(ctx, self.name()),
            Importance::ActivationAware => {
                let consumed: usize =
                    batches.iter().map(|b| b.valid.iter().filter(|&&v| v > 0).count()).sum();
                let mut prov = ctx.provenance(self.name());
                prov.calib_rows = prov.calib_rows.min(consumed);
                prov
            }
        };
        let pruned = pruner.prune(ctx.params, &batches, ctx.schedule, self.importance)?;
        Ok(CompressedModel::from_pruned(
            &ctx.cfg,
            pruned,
            provenance,
            t0.elapsed().as_secs_f64(),
        ))
    }
}
