//! [`CompressionSession`] — the one front door for running any registered
//! (or hand-built) [`Compressor`] against a model.
//!
//! A session binds the execution environment (an optional PJRT runtime +
//! the model config) and the shared knobs, then runs methods by registry
//! name or as trait objects. Sessions without a runtime (`offline`) can
//! still run every data-free method — and *any* method at budget 1.0,
//! which is short-circuited to the identity artifact before the method is
//! consulted.

use anyhow::{bail, Result};

use crate::exec::ExecConfig;
use crate::model::{ModelConfig, ParamStore};
use crate::rom::budget::{paper_preset, ModuleSchedule};
use crate::runtime::Runtime;

use super::artifact::{CompressedModel, Provenance};
use super::calib::CalibrationStream;
use super::registry::resolve;
use super::{CompressCtx, Compressor};

/// Execution environment + knobs for a sequence of compression runs.
pub struct CompressionSession<'rt> {
    runtime: Option<&'rt Runtime>,
    cfg: ModelConfig,
    pallas_covariance: bool,
    exec: ExecConfig,
}

impl<'rt> CompressionSession<'rt> {
    /// Session over a live PJRT runtime (all methods available).
    pub fn new(runtime: &'rt Runtime) -> CompressionSession<'rt> {
        let cfg = ModelConfig::from_manifest(&runtime.manifest().model_config);
        CompressionSession {
            runtime: Some(runtime),
            cfg,
            pallas_covariance: true,
            exec: ExecConfig::default(),
        }
    }

    /// Runtime-free session: data-free methods only (plus the budget-1.0
    /// identity path for every method).
    pub fn offline(cfg: ModelConfig) -> CompressionSession<'static> {
        CompressionSession {
            runtime: None,
            cfg,
            pallas_covariance: false,
            exec: ExecConfig::default(),
        }
    }

    /// Toggle the Pallas Gram kernel for covariance accumulation.
    pub fn with_pallas_covariance(mut self, on: bool) -> Self {
        self.pallas_covariance = on;
        self
    }

    /// Set the worker-pool budget for this session's runs (the `--threads`
    /// knob). Compression output is bitwise identical for any value; this
    /// only changes wall-clock.
    pub fn with_exec(mut self, exec: ExecConfig) -> Self {
        self.exec = exec;
        self
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// Run a compressor under an explicit module schedule.
    pub fn run(
        &self,
        compressor: &dyn Compressor,
        params: &ParamStore,
        schedule: ModuleSchedule,
        global_budget: f64,
        calib: &mut dyn CalibrationStream,
    ) -> Result<CompressedModel> {
        // Budget 1.0 (or an empty schedule) compresses nothing: return the
        // identity artifact without touching the method or the runtime.
        if schedule.start_block >= self.cfg.n_layers || schedule.module_budget >= 1.0 - 1e-12 {
            let provenance = Provenance {
                method: compressor.name().to_string(),
                global_budget,
                schedule,
                calib_label: calib.label(),
                calib_rows: calib.rows_hint(),
                calib_seq: calib.seq_hint(),
            };
            return Ok(CompressedModel::identity(params.clone(), provenance));
        }
        if compressor.needs_runtime() && self.runtime.is_none() {
            bail!(
                "method `{}` needs a PJRT runtime (offline session); \
                 data-free alternatives: rom-weight-svd, prune-magnitude",
                compressor.name()
            );
        }
        let mut ctx = CompressCtx {
            runtime: self.runtime,
            cfg: self.cfg.clone(),
            params,
            calib,
            schedule,
            global_budget,
            pallas_covariance: self.pallas_covariance,
            exec: self.exec,
        };
        compressor.compress(&mut ctx)
    }

    /// Run a registered method under an explicit schedule.
    pub fn compress(
        &self,
        method: &str,
        params: &ParamStore,
        schedule: ModuleSchedule,
        calib: &mut dyn CalibrationStream,
    ) -> Result<CompressedModel> {
        let c = resolve(method)?;
        let global = schedule.global_budget(&self.cfg);
        self.run(c.as_ref(), params, schedule, global, calib)
    }

    /// Run a registered method at a global budget, using the paper's
    /// preset schedule family.
    pub fn compress_at(
        &self,
        method: &str,
        params: &ParamStore,
        global_budget: f64,
        calib: &mut dyn CalibrationStream,
    ) -> Result<CompressedModel> {
        let c = resolve(method)?;
        let schedule = if global_budget >= 1.0 - 1e-12 {
            ModuleSchedule { start_block: self.cfg.n_layers, module_budget: 1.0 }
        } else {
            paper_preset(&self.cfg, global_budget)
        };
        self.run(c.as_ref(), params, schedule, global_budget, calib)
    }

    /// Run several registered methods at one budget over the same
    /// (rewindable) calibration stream, handing each artifact to `visit`
    /// as it completes — the engine behind `repro sweep`. Visiting (and
    /// dropping) artifacts one at a time keeps peak memory at one
    /// compressed model regardless of how many methods are swept.
    pub fn sweep_with(
        &self,
        methods: &[String],
        params: &ParamStore,
        global_budget: f64,
        calib: &mut dyn CalibrationStream,
        mut visit: impl FnMut(&str, CompressedModel) -> Result<()>,
    ) -> Result<()> {
        for m in methods {
            let cm = self.compress_at(m, params, global_budget, &mut *calib)?;
            visit(m.as_str(), cm)?;
        }
        Ok(())
    }

    /// [`CompressionSession::sweep_with`], collecting every artifact
    /// (memory scales with the method count — prefer `sweep_with` when
    /// artifacts can be consumed one at a time).
    pub fn sweep(
        &self,
        methods: &[String],
        params: &ParamStore,
        global_budget: f64,
        calib: &mut dyn CalibrationStream,
    ) -> Result<Vec<CompressedModel>> {
        let mut out = Vec::with_capacity(methods.len());
        self.sweep_with(methods, params, global_budget, calib, |_, cm| {
            out.push(cm);
            Ok(())
        })?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::calib::EmptyStream;
    use crate::compress::registry::METHODS;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() }
    }

    #[test]
    fn budget_one_is_identity_for_every_method_offline() {
        let cfg = tiny_cfg();
        let session = CompressionSession::offline(cfg.clone());
        let params = ParamStore::zeros(&cfg);
        for method in METHODS {
            let mut calib = EmptyStream;
            let cm = session.compress_at(method, &params, 1.0, &mut calib).unwrap();
            assert_eq!(cm.provenance.method, *method);
            assert!(cm.accounting.layers.is_empty(), "{method}");
            assert!(cm.params.distance(&params).unwrap() < 1e-12, "{method}");
        }
    }

    #[test]
    fn runtime_needing_methods_rejected_offline() {
        let cfg = tiny_cfg();
        let session = CompressionSession::offline(cfg.clone());
        let params = ParamStore::zeros(&cfg);
        for method in ["rom-feature", "prune-activation"] {
            let mut calib = EmptyStream;
            let err = session.compress_at(method, &params, 0.8, &mut calib).unwrap_err();
            assert!(err.to_string().contains("runtime"), "{method}: {err}");
        }
    }

    #[test]
    fn unknown_method_rejected() {
        let session = CompressionSession::offline(tiny_cfg());
        let params = ParamStore::zeros(&tiny_cfg());
        let mut calib = EmptyStream;
        assert!(session.compress_at("nope", &params, 0.8, &mut calib).is_err());
    }
}
