//! The unified compression artifact: one result type for every method.
//!
//! A [`CompressedModel`] bundles the compressed parameters with the
//! accounting view (Table 1's #Params/#MACs columns), per-layer timings
//! (the §4 cost evidence), the low-rank factors of every decomposed
//! matrix, and provenance metadata describing exactly how it was produced.
//! The whole artifact serializes to a single `.rtz` container: the
//! parameters under their schema names, one reserved `__compress_meta__`
//! tensor holding the metadata as JSON, and — for ROM artifacts — the
//! factors as `⟨name⟩.__w1__` / `⟨name⟩.__w2__` f64 sidecar entries, so
//! the factored form survives serialization losslessly and the serving
//! engine ([`crate::serve`]) can execute it directly. Compressed
//! checkpoints stay loadable by every existing `.rtz` consumer (the
//! [`crate::model::ParamStore`] loader skips `__`-marked entries).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Matrix;
use crate::model::macs::{self, CompressionAccounting, LayerCompression, MacsReport};
use crate::model::{ModelConfig, ParamStore};
use crate::prune::PrunedModel;
use crate::rom::budget::ModuleSchedule;
use crate::rom::decompose::RomFactors;
use crate::rom::pipeline::{LayerTiming, RomModel};
use crate::tensor::{load_rtz, save_rtz, Tensor, TensorMap};
use crate::util::json::Json;

/// Reserved `.rtz` entry carrying the compression metadata.
pub const META_KEY: &str = "__compress_meta__";

/// Sidecar suffixes under which the factors of a decomposed matrix are
/// stored in the `.rtz` (`blocks.3.wq.__w1__` holds `W1` of `blocks.3.wq`).
pub const W1_SUFFIX: &str = ".__w1__";
pub const W2_SUFFIX: &str = ".__w2__";

/// How a [`CompressedModel`] was produced.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// Registry name of the method (`rom-feature`, `prune-magnitude`, …).
    pub method: String,
    /// Requested global parameter budget (fraction of dense).
    pub global_budget: f64,
    /// The module schedule that realized it.
    pub schedule: ModuleSchedule,
    /// Calibration source label (`combination`, `corpus`, `none`, …).
    pub calib_label: String,
    /// Calibration rows / per-row sequence length the stream advertised.
    pub calib_rows: usize,
    pub calib_seq: usize,
}

/// Kept channel/head index sets of a structured-pruning artifact —
/// serialized with the model so masks can be rebuilt on load.
#[derive(Debug, Clone, PartialEq)]
pub struct KeptSets {
    /// block -> kept FFN channel indices (ascending).
    pub ffn: BTreeMap<usize, Vec<usize>>,
    /// block -> kept attention head indices (ascending).
    pub heads: BTreeMap<usize, Vec<usize>>,
}

/// Unified result of any [`super::Compressor`].
#[derive(Debug)]
pub struct CompressedModel {
    /// Compressed parameters at dense schema shapes (runnable through the
    /// unmodified HLO graphs and the reference model).
    pub params: ParamStore,
    /// Analytic #Params/#MACs state of every touched matrix.
    pub accounting: CompressionAccounting,
    /// Low-rank factors of every decomposed matrix (empty for pruning and
    /// identity artifacts). Serialized as `⟨name⟩.__w1__`/`⟨name⟩.__w2__`
    /// sidecar entries so the factored form survives `.rtz` round-trips —
    /// the substrate of factored-form serving.
    pub factors: BTreeMap<String, RomFactors>,
    /// Per-matrix (ROM) or per-module (pruning) wall-clock records.
    pub timings: Vec<LayerTiming>,
    /// How this artifact was produced.
    pub provenance: Provenance,
    /// Peak bytes held in calibration captures (0 for data-free methods).
    pub peak_capture_bytes: usize,
    /// Kept channel/head sets, present only for structured pruning;
    /// serialized in the metadata so [`CompressedModel::load`] can
    /// rebuild the masks.
    pub kept: Option<KeptSets>,
    /// Pruning masks (1 = kept), present only for structured pruning.
    /// Not serialized directly — rebuilt from [`CompressedModel::kept`]
    /// on load, so masked fine-tuning works on loaded artifacts too.
    pub masks: Option<Vec<Tensor>>,
}

impl CompressedModel {
    /// A no-op artifact: budget ≥ 1.0 means "compress nothing".
    pub fn identity(params: ParamStore, provenance: Provenance) -> CompressedModel {
        CompressedModel {
            params,
            accounting: CompressionAccounting::dense(),
            factors: BTreeMap::new(),
            timings: Vec::new(),
            provenance,
            peak_capture_bytes: 0,
            kept: None,
            masks: None,
        }
    }

    /// Wrap a ROM pipeline result, carrying the factored form along.
    pub fn from_rom(rom: RomModel, provenance: Provenance) -> CompressedModel {
        let accounting = rom.accounting();
        CompressedModel {
            params: rom.params,
            accounting,
            factors: rom.factors,
            timings: rom.timings,
            provenance,
            peak_capture_bytes: rom.peak_capture_bytes,
            kept: None,
            masks: None,
        }
    }

    /// Wrap a structured-pruning result; `elapsed_s` is the whole pass,
    /// amortized into one timing record per pruned module.
    pub fn from_pruned(
        cfg: &ModelConfig,
        pruned: PrunedModel,
        provenance: Provenance,
        elapsed_s: f64,
    ) -> CompressedModel {
        let accounting = pruned.accounting(cfg);
        let blocks: Vec<usize> = pruned.kept_ffn.keys().copied().collect();
        let per = if blocks.is_empty() { 0.0 } else { elapsed_s / blocks.len() as f64 };
        let timings = blocks
            .iter()
            .map(|b| LayerTiming {
                name: format!("blocks.{b}"),
                covariance_s: 0.0,
                decompose_s: per,
            })
            .collect();
        let kept = KeptSets { ffn: pruned.kept_ffn.clone(), heads: pruned.kept_heads.clone() };
        CompressedModel {
            params: pruned.params,
            accounting,
            factors: BTreeMap::new(),
            timings,
            provenance,
            peak_capture_bytes: 0,
            kept: Some(kept),
            masks: Some(pruned.masks),
        }
    }

    /// Total compression wall time across recorded layers.
    pub fn total_seconds(&self) -> f64 {
        self.timings.iter().map(|t| t.total_s()).sum()
    }

    pub fn mean_seconds_per_layer(&self) -> f64 {
        if self.timings.is_empty() {
            0.0
        } else {
            self.total_seconds() / self.timings.len() as f64
        }
    }

    /// #Params/#MACs under this artifact's accounting.
    pub fn macs_report(&self, cfg: &ModelConfig, tokens: usize) -> MacsReport {
        macs::report(cfg, &self.accounting, tokens)
    }

    /// Speculative-decoding compatibility: can `draft` serve as the cheap
    /// draft model for this (verifier) artifact? Both must come from the
    /// same checkpoint geometry and tokenizer — an identical
    /// [`ModelConfig`] (vocab, d_model, heads, layers, d_ff, rope/norm
    /// constants), which is exactly what two points on the same rank
    /// ladder share; the *ranks* are what may (and should) differ. The
    /// draft must not cost more MACs per token than the verifier —
    /// otherwise the pair is swapped and speculation is a strict loss.
    pub fn check_spec_draft(&self, draft: &CompressedModel) -> Result<()> {
        let (vc, dc) = (self.params.config(), draft.params.config());
        anyhow::ensure!(
            vc == dc,
            "speculative draft artifact is from a different checkpoint family: verifier \
             config (vocab {}, d {}, heads {}, L {}, ff {}) != draft config (vocab {}, d {}, \
             heads {}, L {}, ff {}) — draft and verifier must be two budgets of the same \
             checkpoint",
            vc.vocab,
            vc.d_model,
            vc.n_heads,
            vc.n_layers,
            vc.d_ff,
            dc.vocab,
            dc.d_model,
            dc.n_heads,
            dc.n_layers,
            dc.d_ff
        );
        let unit = |cm: &CompressedModel| cm.macs_report(vc, 1).macs;
        let (v_unit, d_unit) = (unit(self), unit(draft));
        anyhow::ensure!(
            d_unit <= v_unit,
            "speculative draft artifact (method {}, budget {:.2}, {d_unit} MACs/token) costs \
             more than the verifier (method {}, budget {:.2}, {v_unit} MACs/token) — swap \
             --ckpt and --draft",
            draft.provenance.method,
            draft.provenance.global_budget,
            self.provenance.method,
            self.provenance.global_budget
        );
        Ok(())
    }

    /// Serialize params + accounting + factors + timings + provenance to
    /// `.rtz`. Factors are written as f64 sidecar tensors, so the
    /// round-trip back to [`RomFactors`] is bit-exact.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut map = TensorMap::new();
        for name in self.params.names() {
            map.insert(name.clone(), self.params.get(name)?.clone());
        }
        for (name, f) in &self.factors {
            map.insert(format!("{name}{W1_SUFFIX}"), matrix_to_f64_tensor(&f.w1));
            map.insert(format!("{name}{W2_SUFFIX}"), matrix_to_f64_tensor(&f.w2));
        }
        let meta = self.meta_json().to_string().into_bytes();
        map.insert(META_KEY.to_string(), Tensor::U8 { shape: vec![meta.len()], data: meta });
        save_rtz(path, &map)
    }

    /// Load an artifact written by [`CompressedModel::save`].
    pub fn load(cfg: &ModelConfig, path: impl AsRef<Path>) -> Result<CompressedModel> {
        let mut map = load_rtz(&path)
            .with_context(|| format!("load compressed model {}", path.as_ref().display()))?;
        let meta = match map.remove(META_KEY) {
            Some(Tensor::U8 { data, .. }) => {
                Json::parse(std::str::from_utf8(&data).context("metadata utf8")?)
                    .context("parse compression metadata")?
            }
            Some(_) => bail!("`{META_KEY}` entry has wrong dtype"),
            None => bail!(
                "{}: no `{META_KEY}` entry — a plain checkpoint, not a compressed artifact \
                 (load it with ParamStore::load instead)",
                path.as_ref().display()
            ),
        };
        // pull the factor sidecars out before the params are validated
        let sidecar_keys: Vec<String> =
            map.keys().filter(|k| k.contains(".__")).cloned().collect();
        let mut sidecars = TensorMap::new();
        for k in sidecar_keys {
            if let Some(t) = map.remove(&k) {
                sidecars.insert(k, t);
            }
        }
        let params = ParamStore::from_map(cfg, map)?;
        Self::from_parts(params, &meta, &sidecars)
    }

    fn from_parts(params: ParamStore, meta: &Json, sidecars: &TensorMap) -> Result<CompressedModel> {
        let version = meta.get("format")?.as_usize()?;
        if version != 1 {
            bail!("unsupported compression metadata format {version}");
        }
        let p = meta.get("provenance")?;
        let provenance = Provenance {
            method: p.get("method")?.as_str()?.to_string(),
            global_budget: p.get("global_budget")?.as_f64()?,
            schedule: ModuleSchedule {
                start_block: p.get("start_block")?.as_usize()?,
                module_budget: p.get("module_budget")?.as_f64()?,
            },
            calib_label: p.get("calib_label")?.as_str()?.to_string(),
            calib_rows: p.get("calib_rows")?.as_usize()?,
            calib_seq: p.get("calib_seq")?.as_usize()?,
        };
        let mut accounting = CompressionAccounting::dense();
        for (name, entry) in meta.get("accounting")?.as_obj()? {
            accounting.set(name, layer_compression_from_json(entry)?);
        }
        let timings = meta
            .get("timings")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(LayerTiming {
                    name: t.get("name")?.as_str()?.to_string(),
                    covariance_s: t.get("covariance_s")?.as_f64()?,
                    decompose_s: t.get("decompose_s")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // rebuild the factored form: rank/energy from the metadata, the
        // W1/W2 payloads from their sidecar tensors
        let mut factors = BTreeMap::new();
        if let Some(fmeta) = meta.opt("factors") {
            for (name, entry) in fmeta.as_obj()? {
                let rank = entry.get("rank")?.as_usize()?;
                let energy = entry.get("energy")?.as_f64()?;
                let w1 = matrix_from_tensor(
                    sidecars
                        .get(&format!("{name}{W1_SUFFIX}"))
                        .with_context(|| format!("artifact missing factor `{name}{W1_SUFFIX}`"))?,
                )?;
                let w2 = matrix_from_tensor(
                    sidecars
                        .get(&format!("{name}{W2_SUFFIX}"))
                        .with_context(|| format!("artifact missing factor `{name}{W2_SUFFIX}`"))?,
                )?;
                // the factored pair must exactly tile the dense parameter:
                // W1 (d_out×r) · W2 (r×d_in) — reject truncated/corrupt
                // sidecars at load time, not deep inside a later matmul
                let wshape = params.get(name)?.shape().to_vec();
                if wshape.len() != 2
                    || w1.cols() != rank
                    || w2.rows() != rank
                    || w1.rows() != wshape[0]
                    || w2.cols() != wshape[1]
                {
                    bail!(
                        "factor `{name}`: shapes {}x{} / {}x{} inconsistent with rank {rank} \
                         and layer shape {wshape:?}",
                        w1.rows(),
                        w1.cols(),
                        w2.rows(),
                        w2.cols()
                    );
                }
                factors.insert(name.clone(), RomFactors { w1, w2, rank, energy });
            }
        }
        let kept = match meta.opt("kept") {
            Some(k) => Some(KeptSets {
                ffn: kept_map_from_json(k.get("ffn")?)?,
                heads: kept_map_from_json(k.get("heads")?)?,
            }),
            None => None,
        };
        // rebuild the pruning masks so masked fine-tune works on loaded
        // artifacts exactly as on freshly compressed ones
        let masks = kept
            .as_ref()
            .map(|k| crate::prune::build_masks(params.config(), &k.ffn, &k.heads));
        Ok(CompressedModel {
            params,
            accounting,
            factors,
            timings,
            provenance,
            peak_capture_bytes: meta.get("peak_capture_bytes")?.as_usize()?,
            kept,
            masks,
        })
    }

    fn meta_json(&self) -> Json {
        let p = &self.provenance;
        let provenance = Json::Obj(
            [
                ("method".to_string(), Json::Str(p.method.clone())),
                ("global_budget".to_string(), Json::Num(p.global_budget)),
                ("start_block".to_string(), Json::Num(p.schedule.start_block as f64)),
                ("module_budget".to_string(), Json::Num(p.schedule.module_budget)),
                ("calib_label".to_string(), Json::Str(p.calib_label.clone())),
                ("calib_rows".to_string(), Json::Num(p.calib_rows as f64)),
                ("calib_seq".to_string(), Json::Num(p.calib_seq as f64)),
            ]
            .into_iter()
            .collect(),
        );
        let accounting = Json::Obj(
            self.accounting
                .layers
                .iter()
                .map(|(name, c)| (name.clone(), layer_compression_to_json(*c)))
                .collect(),
        );
        let timings = Json::Arr(
            self.timings
                .iter()
                .map(|t| {
                    Json::Obj(
                        [
                            ("name".to_string(), Json::Str(t.name.clone())),
                            ("covariance_s".to_string(), Json::Num(t.covariance_s)),
                            ("decompose_s".to_string(), Json::Num(t.decompose_s)),
                        ]
                        .into_iter()
                        .collect(),
                    )
                })
                .collect(),
        );
        let mut top: std::collections::BTreeMap<String, Json> = [
            ("format".to_string(), Json::Num(1.0)),
            ("provenance".to_string(), provenance),
            ("accounting".to_string(), accounting),
            ("timings".to_string(), timings),
            ("peak_capture_bytes".to_string(), Json::Num(self.peak_capture_bytes as f64)),
        ]
        .into_iter()
        .collect();
        if !self.factors.is_empty() {
            top.insert(
                "factors".to_string(),
                Json::Obj(
                    self.factors
                        .iter()
                        .map(|(name, f)| {
                            (
                                name.clone(),
                                Json::Obj(
                                    [
                                        ("rank".to_string(), Json::Num(f.rank as f64)),
                                        ("energy".to_string(), Json::Num(f.energy)),
                                    ]
                                    .into_iter()
                                    .collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            );
        }
        if let Some(kept) = &self.kept {
            top.insert(
                "kept".to_string(),
                Json::Obj(
                    [
                        ("ffn".to_string(), kept_map_to_json(&kept.ffn)),
                        ("heads".to_string(), kept_map_to_json(&kept.heads)),
                    ]
                    .into_iter()
                    .collect(),
                ),
            );
        }
        Json::Obj(top)
    }
}

/// Factor payloads are stored at full f64 precision — [`RomFactors`]
/// matrices are f64, and rounding through f32 would break the lossless
/// round-trip guarantee the serving engine's self-check relies on.
fn matrix_to_f64_tensor(m: &Matrix) -> Tensor {
    Tensor::F64 { shape: vec![m.rows(), m.cols()], data: m.data().to_vec() }
}

fn matrix_from_tensor(t: &Tensor) -> Result<Matrix> {
    match t {
        Tensor::F64 { shape, data } if shape.len() == 2 => {
            Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
        }
        Tensor::F32 { shape, data } if shape.len() == 2 => {
            Ok(Matrix::from_f32(shape[0], shape[1], data))
        }
        other => bail!("factor tensor: expected rank-2 f64/f32, got {:?} {:?}", other.dtype(), other.shape()),
    }
}

fn kept_map_to_json(m: &BTreeMap<usize, Vec<usize>>) -> Json {
    Json::Obj(
        m.iter()
            .map(|(block, idxs)| {
                (block.to_string(), Json::Arr(idxs.iter().map(|&i| Json::Num(i as f64)).collect()))
            })
            .collect(),
    )
}

fn kept_map_from_json(j: &Json) -> Result<BTreeMap<usize, Vec<usize>>> {
    j.as_obj()?
        .iter()
        .map(|(block, idxs)| {
            let b: usize = block.parse().map_err(|_| anyhow::anyhow!("bad block key `{block}`"))?;
            Ok((b, idxs.usize_vec()?))
        })
        .collect()
}

fn layer_compression_to_json(c: LayerCompression) -> Json {
    let (kind, value) = match c {
        LayerCompression::Dense => ("dense", 0),
        LayerCompression::LowRank { rank } => ("low_rank", rank),
        LayerCompression::PrunedOut { kept_out } => ("pruned_out", kept_out),
        LayerCompression::PrunedIn { kept_in } => ("pruned_in", kept_in),
    };
    Json::Obj(
        [
            ("kind".to_string(), Json::Str(kind.to_string())),
            ("n".to_string(), Json::Num(value as f64)),
        ]
        .into_iter()
        .collect(),
    )
}

fn layer_compression_from_json(j: &Json) -> Result<LayerCompression> {
    let n = j.get("n")?.as_usize()?;
    Ok(match j.get("kind")?.as_str()? {
        "dense" => LayerCompression::Dense,
        "low_rank" => LayerCompression::LowRank { rank: n },
        "pruned_out" => LayerCompression::PrunedOut { kept_out: n },
        "pruned_in" => LayerCompression::PrunedIn { kept_in: n },
        other => bail!("unknown layer compression kind `{other}`"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_compression_json_roundtrip() {
        for c in [
            LayerCompression::Dense,
            LayerCompression::LowRank { rank: 17 },
            LayerCompression::PrunedOut { kept_out: 5 },
            LayerCompression::PrunedIn { kept_in: 9 },
        ] {
            let j = layer_compression_to_json(c);
            assert_eq!(layer_compression_from_json(&j).unwrap(), c);
        }
        assert!(layer_compression_from_json(&Json::parse(r#"{"kind":"x","n":1}"#).unwrap())
            .is_err());
    }

    #[test]
    fn meta_json_roundtrips_through_text() {
        let cfg = ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() };
        let mut accounting = CompressionAccounting::dense();
        accounting.set("blocks.1.wq", LayerCompression::LowRank { rank: 3 });
        let cm = CompressedModel {
            params: ParamStore::zeros(&cfg),
            accounting,
            factors: BTreeMap::new(),
            timings: vec![LayerTiming { name: "blocks.1.wq".into(), covariance_s: 0.25, decompose_s: 0.75 }],
            provenance: Provenance {
                method: "rom-feature".into(),
                global_budget: 0.8,
                schedule: ModuleSchedule { start_block: 1, module_budget: 0.46 },
                calib_label: "combination".into(),
                calib_rows: 32,
                calib_seq: 128,
            },
            peak_capture_bytes: 12345,
            kept: None,
            masks: None,
        };
        let text = cm.meta_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        let back =
            CompressedModel::from_parts(ParamStore::zeros(&cfg), &parsed, &TensorMap::new())
                .unwrap();
        assert_eq!(back.provenance, cm.provenance);
        assert_eq!(back.accounting.layers, cm.accounting.layers);
        assert_eq!(back.timings.len(), 1);
        assert_eq!(back.peak_capture_bytes, 12345);
        assert!(back.kept.is_none() && back.masks.is_none());
        assert!((back.total_seconds() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn factors_roundtrip_rtz_losslessly() {
        use crate::util::Rng;
        let cfg = ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() };
        let mut rng = Rng::new(7);
        let (rank, d) = (3usize, 8usize);
        let w1 = Matrix::from_fn(d, rank, |_, _| rng.normal());
        let w2 = Matrix::from_fn(rank, d, |_, _| rng.normal());
        let mut factors = BTreeMap::new();
        factors.insert(
            "blocks.1.wq".to_string(),
            RomFactors { w1: w1.clone(), w2: w2.clone(), rank, energy: 0.937_251 },
        );
        let mut accounting = CompressionAccounting::dense();
        accounting.set("blocks.1.wq", LayerCompression::LowRank { rank });
        let cm = CompressedModel {
            params: ParamStore::zeros(&cfg),
            accounting,
            factors,
            timings: Vec::new(),
            provenance: Provenance {
                method: "rom-feature".into(),
                global_budget: 0.8,
                schedule: ModuleSchedule { start_block: 1, module_budget: 0.46 },
                calib_label: "combination".into(),
                calib_rows: 32,
                calib_seq: 128,
            },
            peak_capture_bytes: 0,
            kept: None,
            masks: None,
        };
        let dir = std::env::temp_dir().join(format!("factors_rtz_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("factored.rtz");
        cm.save(&path).unwrap();
        // the artifact stays loadable as a plain (dense) checkpoint
        assert!(ParamStore::load(&cfg, &path).is_ok());
        let back = CompressedModel::load(&cfg, &path).unwrap();
        let f = &back.factors["blocks.1.wq"];
        assert_eq!(f.rank, rank);
        assert_eq!(f.energy, 0.937_251); // bit-exact through JSON
        assert_eq!(f.w1.data(), w1.data()); // bit-exact through f64 sidecars
        assert_eq!(f.w2.data(), w2.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_factor_sidecar_rejected_on_load() {
        let cfg = ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() };
        let mut factors = BTreeMap::new();
        // w2 truncated to 7 columns for an 8-wide layer
        factors.insert(
            "blocks.1.wq".to_string(),
            RomFactors { w1: Matrix::zeros(8, 3), w2: Matrix::zeros(3, 7), rank: 3, energy: 1.0 },
        );
        let cm = CompressedModel {
            params: ParamStore::zeros(&cfg),
            accounting: CompressionAccounting::dense(),
            factors,
            timings: Vec::new(),
            provenance: Provenance {
                method: "rom-feature".into(),
                global_budget: 0.8,
                schedule: ModuleSchedule { start_block: 1, module_budget: 0.46 },
                calib_label: "none".into(),
                calib_rows: 0,
                calib_seq: 0,
            },
            peak_capture_bytes: 0,
            kept: None,
            masks: None,
        };
        let dir = std::env::temp_dir().join(format!("bad_factor_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.rtz");
        cm.save(&path).unwrap();
        let err = CompressedModel::load(&cfg, &path).unwrap_err();
        assert!(err.to_string().contains("inconsistent"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kept_sets_roundtrip_and_rebuild_masks() {
        let cfg = ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() };
        let mut ffn = BTreeMap::new();
        ffn.insert(1usize, vec![0, 3, 5]);
        let mut heads = BTreeMap::new();
        heads.insert(1usize, vec![1]);
        let kept = KeptSets { ffn, heads };
        let cm = CompressedModel {
            params: ParamStore::zeros(&cfg),
            accounting: CompressionAccounting::dense(),
            factors: BTreeMap::new(),
            timings: Vec::new(),
            provenance: Provenance {
                method: "prune-magnitude".into(),
                global_budget: 0.8,
                schedule: ModuleSchedule { start_block: 1, module_budget: 0.46 },
                calib_label: "none".into(),
                calib_rows: 0,
                calib_seq: 0,
            },
            peak_capture_bytes: 0,
            kept: Some(kept.clone()),
            masks: Some(crate::prune::build_masks(&cfg, &kept.ffn, &kept.heads)),
        };
        let parsed = Json::parse(&cm.meta_json().to_string()).unwrap();
        let back =
            CompressedModel::from_parts(ParamStore::zeros(&cfg), &parsed, &TensorMap::new())
                .unwrap();
        assert_eq!(back.kept, cm.kept);
        // masks are rebuilt from the kept sets, identical to the originals
        let (a, b) = (cm.masks.as_ref().unwrap(), back.masks.as_ref().unwrap());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x, y);
        }
    }
}
