//! The unified compression artifact: one result type for every method.
//!
//! A [`CompressedModel`] bundles the compressed parameters with the
//! accounting view (Table 1's #Params/#MACs columns), per-layer timings
//! (the §4 cost evidence), and provenance metadata describing exactly how
//! it was produced. The whole artifact serializes to a single `.rtz`
//! container: the parameters under their schema names plus one reserved
//! `__compress_meta__` tensor holding the metadata as JSON, so compressed
//! checkpoints stay loadable by every existing `.rtz` consumer (the
//! [`crate::model::ParamStore`] loader skips `__`-prefixed entries).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::macs::{self, CompressionAccounting, LayerCompression, MacsReport};
use crate::model::{ModelConfig, ParamStore};
use crate::prune::PrunedModel;
use crate::rom::budget::ModuleSchedule;
use crate::rom::pipeline::{LayerTiming, RomModel};
use crate::tensor::{load_rtz, save_rtz, Tensor, TensorMap};
use crate::util::json::Json;

/// Reserved `.rtz` entry carrying the compression metadata.
pub const META_KEY: &str = "__compress_meta__";

/// How a [`CompressedModel`] was produced.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// Registry name of the method (`rom-feature`, `prune-magnitude`, …).
    pub method: String,
    /// Requested global parameter budget (fraction of dense).
    pub global_budget: f64,
    /// The module schedule that realized it.
    pub schedule: ModuleSchedule,
    /// Calibration source label (`combination`, `corpus`, `none`, …).
    pub calib_label: String,
    /// Calibration rows / per-row sequence length the stream advertised.
    pub calib_rows: usize,
    pub calib_seq: usize,
}

/// Kept channel/head index sets of a structured-pruning artifact —
/// serialized with the model so masks can be rebuilt on load.
#[derive(Debug, Clone, PartialEq)]
pub struct KeptSets {
    /// block -> kept FFN channel indices (ascending).
    pub ffn: BTreeMap<usize, Vec<usize>>,
    /// block -> kept attention head indices (ascending).
    pub heads: BTreeMap<usize, Vec<usize>>,
}

/// Unified result of any [`super::Compressor`].
#[derive(Debug)]
pub struct CompressedModel {
    /// Compressed parameters at dense schema shapes (runnable through the
    /// unmodified HLO graphs and the reference model).
    pub params: ParamStore,
    /// Analytic #Params/#MACs state of every touched matrix.
    pub accounting: CompressionAccounting,
    /// Per-matrix (ROM) or per-module (pruning) wall-clock records.
    pub timings: Vec<LayerTiming>,
    /// How this artifact was produced.
    pub provenance: Provenance,
    /// Peak bytes held in calibration captures (0 for data-free methods).
    pub peak_capture_bytes: usize,
    /// Kept channel/head sets, present only for structured pruning;
    /// serialized in the metadata so [`CompressedModel::load`] can
    /// rebuild the masks.
    pub kept: Option<KeptSets>,
    /// Pruning masks (1 = kept), present only for structured pruning.
    /// Not serialized directly — rebuilt from [`CompressedModel::kept`]
    /// on load, so masked fine-tuning works on loaded artifacts too.
    pub masks: Option<Vec<Tensor>>,
}

impl CompressedModel {
    /// A no-op artifact: budget ≥ 1.0 means "compress nothing".
    pub fn identity(params: ParamStore, provenance: Provenance) -> CompressedModel {
        CompressedModel {
            params,
            accounting: CompressionAccounting::dense(),
            timings: Vec::new(),
            provenance,
            peak_capture_bytes: 0,
            kept: None,
            masks: None,
        }
    }

    /// Wrap a ROM pipeline result.
    pub fn from_rom(rom: RomModel, provenance: Provenance) -> CompressedModel {
        let accounting = rom.accounting();
        CompressedModel {
            params: rom.params,
            accounting,
            timings: rom.timings,
            provenance,
            peak_capture_bytes: rom.peak_capture_bytes,
            kept: None,
            masks: None,
        }
    }

    /// Wrap a structured-pruning result; `elapsed_s` is the whole pass,
    /// amortized into one timing record per pruned module.
    pub fn from_pruned(
        cfg: &ModelConfig,
        pruned: PrunedModel,
        provenance: Provenance,
        elapsed_s: f64,
    ) -> CompressedModel {
        let accounting = pruned.accounting(cfg);
        let blocks: Vec<usize> = pruned.kept_ffn.keys().copied().collect();
        let per = if blocks.is_empty() { 0.0 } else { elapsed_s / blocks.len() as f64 };
        let timings = blocks
            .iter()
            .map(|b| LayerTiming {
                name: format!("blocks.{b}"),
                covariance_s: 0.0,
                decompose_s: per,
            })
            .collect();
        let kept = KeptSets { ffn: pruned.kept_ffn.clone(), heads: pruned.kept_heads.clone() };
        CompressedModel {
            params: pruned.params,
            accounting,
            timings,
            provenance,
            peak_capture_bytes: 0,
            kept: Some(kept),
            masks: Some(pruned.masks),
        }
    }

    /// Total compression wall time across recorded layers.
    pub fn total_seconds(&self) -> f64 {
        self.timings.iter().map(|t| t.total_s()).sum()
    }

    pub fn mean_seconds_per_layer(&self) -> f64 {
        if self.timings.is_empty() {
            0.0
        } else {
            self.total_seconds() / self.timings.len() as f64
        }
    }

    /// #Params/#MACs under this artifact's accounting.
    pub fn macs_report(&self, cfg: &ModelConfig, tokens: usize) -> MacsReport {
        macs::report(cfg, &self.accounting, tokens)
    }

    /// Serialize params + accounting + timings + provenance to `.rtz`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut map = TensorMap::new();
        for name in self.params.names() {
            map.insert(name.clone(), self.params.get(name)?.clone());
        }
        let meta = self.meta_json().to_string().into_bytes();
        map.insert(META_KEY.to_string(), Tensor::U8 { shape: vec![meta.len()], data: meta });
        save_rtz(path, &map)
    }

    /// Load an artifact written by [`CompressedModel::save`].
    pub fn load(cfg: &ModelConfig, path: impl AsRef<Path>) -> Result<CompressedModel> {
        let mut map = load_rtz(&path)
            .with_context(|| format!("load compressed model {}", path.as_ref().display()))?;
        let meta = match map.remove(META_KEY) {
            Some(Tensor::U8 { data, .. }) => {
                Json::parse(std::str::from_utf8(&data).context("metadata utf8")?)
                    .context("parse compression metadata")?
            }
            Some(_) => bail!("`{META_KEY}` entry has wrong dtype"),
            None => bail!(
                "{}: no `{META_KEY}` entry — a plain checkpoint, not a compressed artifact \
                 (load it with ParamStore::load instead)",
                path.as_ref().display()
            ),
        };
        let params = ParamStore::from_map(cfg, map)?;
        Self::from_parts(params, &meta)
    }

    fn from_parts(params: ParamStore, meta: &Json) -> Result<CompressedModel> {
        let version = meta.get("format")?.as_usize()?;
        if version != 1 {
            bail!("unsupported compression metadata format {version}");
        }
        let p = meta.get("provenance")?;
        let provenance = Provenance {
            method: p.get("method")?.as_str()?.to_string(),
            global_budget: p.get("global_budget")?.as_f64()?,
            schedule: ModuleSchedule {
                start_block: p.get("start_block")?.as_usize()?,
                module_budget: p.get("module_budget")?.as_f64()?,
            },
            calib_label: p.get("calib_label")?.as_str()?.to_string(),
            calib_rows: p.get("calib_rows")?.as_usize()?,
            calib_seq: p.get("calib_seq")?.as_usize()?,
        };
        let mut accounting = CompressionAccounting::dense();
        for (name, entry) in meta.get("accounting")?.as_obj()? {
            accounting.set(name, layer_compression_from_json(entry)?);
        }
        let timings = meta
            .get("timings")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(LayerTiming {
                    name: t.get("name")?.as_str()?.to_string(),
                    covariance_s: t.get("covariance_s")?.as_f64()?,
                    decompose_s: t.get("decompose_s")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let kept = match meta.opt("kept") {
            Some(k) => Some(KeptSets {
                ffn: kept_map_from_json(k.get("ffn")?)?,
                heads: kept_map_from_json(k.get("heads")?)?,
            }),
            None => None,
        };
        // rebuild the pruning masks so masked fine-tune works on loaded
        // artifacts exactly as on freshly compressed ones
        let masks = kept
            .as_ref()
            .map(|k| crate::prune::build_masks(params.config(), &k.ffn, &k.heads));
        Ok(CompressedModel {
            params,
            accounting,
            timings,
            provenance,
            peak_capture_bytes: meta.get("peak_capture_bytes")?.as_usize()?,
            kept,
            masks,
        })
    }

    fn meta_json(&self) -> Json {
        let p = &self.provenance;
        let provenance = Json::Obj(
            [
                ("method".to_string(), Json::Str(p.method.clone())),
                ("global_budget".to_string(), Json::Num(p.global_budget)),
                ("start_block".to_string(), Json::Num(p.schedule.start_block as f64)),
                ("module_budget".to_string(), Json::Num(p.schedule.module_budget)),
                ("calib_label".to_string(), Json::Str(p.calib_label.clone())),
                ("calib_rows".to_string(), Json::Num(p.calib_rows as f64)),
                ("calib_seq".to_string(), Json::Num(p.calib_seq as f64)),
            ]
            .into_iter()
            .collect(),
        );
        let accounting = Json::Obj(
            self.accounting
                .layers
                .iter()
                .map(|(name, c)| (name.clone(), layer_compression_to_json(*c)))
                .collect(),
        );
        let timings = Json::Arr(
            self.timings
                .iter()
                .map(|t| {
                    Json::Obj(
                        [
                            ("name".to_string(), Json::Str(t.name.clone())),
                            ("covariance_s".to_string(), Json::Num(t.covariance_s)),
                            ("decompose_s".to_string(), Json::Num(t.decompose_s)),
                        ]
                        .into_iter()
                        .collect(),
                    )
                })
                .collect(),
        );
        let mut top: std::collections::BTreeMap<String, Json> = [
            ("format".to_string(), Json::Num(1.0)),
            ("provenance".to_string(), provenance),
            ("accounting".to_string(), accounting),
            ("timings".to_string(), timings),
            ("peak_capture_bytes".to_string(), Json::Num(self.peak_capture_bytes as f64)),
        ]
        .into_iter()
        .collect();
        if let Some(kept) = &self.kept {
            top.insert(
                "kept".to_string(),
                Json::Obj(
                    [
                        ("ffn".to_string(), kept_map_to_json(&kept.ffn)),
                        ("heads".to_string(), kept_map_to_json(&kept.heads)),
                    ]
                    .into_iter()
                    .collect(),
                ),
            );
        }
        Json::Obj(top)
    }
}

fn kept_map_to_json(m: &BTreeMap<usize, Vec<usize>>) -> Json {
    Json::Obj(
        m.iter()
            .map(|(block, idxs)| {
                (block.to_string(), Json::Arr(idxs.iter().map(|&i| Json::Num(i as f64)).collect()))
            })
            .collect(),
    )
}

fn kept_map_from_json(j: &Json) -> Result<BTreeMap<usize, Vec<usize>>> {
    j.as_obj()?
        .iter()
        .map(|(block, idxs)| {
            let b: usize = block.parse().map_err(|_| anyhow::anyhow!("bad block key `{block}`"))?;
            Ok((b, idxs.usize_vec()?))
        })
        .collect()
}

fn layer_compression_to_json(c: LayerCompression) -> Json {
    let (kind, value) = match c {
        LayerCompression::Dense => ("dense", 0),
        LayerCompression::LowRank { rank } => ("low_rank", rank),
        LayerCompression::PrunedOut { kept_out } => ("pruned_out", kept_out),
        LayerCompression::PrunedIn { kept_in } => ("pruned_in", kept_in),
    };
    Json::Obj(
        [
            ("kind".to_string(), Json::Str(kind.to_string())),
            ("n".to_string(), Json::Num(value as f64)),
        ]
        .into_iter()
        .collect(),
    )
}

fn layer_compression_from_json(j: &Json) -> Result<LayerCompression> {
    let n = j.get("n")?.as_usize()?;
    Ok(match j.get("kind")?.as_str()? {
        "dense" => LayerCompression::Dense,
        "low_rank" => LayerCompression::LowRank { rank: n },
        "pruned_out" => LayerCompression::PrunedOut { kept_out: n },
        "pruned_in" => LayerCompression::PrunedIn { kept_in: n },
        other => bail!("unknown layer compression kind `{other}`"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_compression_json_roundtrip() {
        for c in [
            LayerCompression::Dense,
            LayerCompression::LowRank { rank: 17 },
            LayerCompression::PrunedOut { kept_out: 5 },
            LayerCompression::PrunedIn { kept_in: 9 },
        ] {
            let j = layer_compression_to_json(c);
            assert_eq!(layer_compression_from_json(&j).unwrap(), c);
        }
        assert!(layer_compression_from_json(&Json::parse(r#"{"kind":"x","n":1}"#).unwrap())
            .is_err());
    }

    #[test]
    fn meta_json_roundtrips_through_text() {
        let cfg = ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() };
        let mut accounting = CompressionAccounting::dense();
        accounting.set("blocks.1.wq", LayerCompression::LowRank { rank: 3 });
        let cm = CompressedModel {
            params: ParamStore::zeros(&cfg),
            accounting,
            timings: vec![LayerTiming { name: "blocks.1.wq".into(), covariance_s: 0.25, decompose_s: 0.75 }],
            provenance: Provenance {
                method: "rom-feature".into(),
                global_budget: 0.8,
                schedule: ModuleSchedule { start_block: 1, module_budget: 0.46 },
                calib_label: "combination".into(),
                calib_rows: 32,
                calib_seq: 128,
            },
            peak_capture_bytes: 12345,
            kept: None,
            masks: None,
        };
        let text = cm.meta_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        let back = CompressedModel::from_parts(ParamStore::zeros(&cfg), &parsed).unwrap();
        assert_eq!(back.provenance, cm.provenance);
        assert_eq!(back.accounting.layers, cm.accounting.layers);
        assert_eq!(back.timings.len(), 1);
        assert_eq!(back.peak_capture_bytes, 12345);
        assert!(back.kept.is_none() && back.masks.is_none());
        assert!((back.total_seconds() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kept_sets_roundtrip_and_rebuild_masks() {
        let cfg = ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() };
        let mut ffn = BTreeMap::new();
        ffn.insert(1usize, vec![0, 3, 5]);
        let mut heads = BTreeMap::new();
        heads.insert(1usize, vec![1]);
        let kept = KeptSets { ffn, heads };
        let cm = CompressedModel {
            params: ParamStore::zeros(&cfg),
            accounting: CompressionAccounting::dense(),
            timings: Vec::new(),
            provenance: Provenance {
                method: "prune-magnitude".into(),
                global_budget: 0.8,
                schedule: ModuleSchedule { start_block: 1, module_budget: 0.46 },
                calib_label: "none".into(),
                calib_rows: 0,
                calib_seq: 0,
            },
            peak_capture_bytes: 0,
            kept: Some(kept.clone()),
            masks: Some(crate::prune::build_masks(&cfg, &kept.ffn, &kept.heads)),
        };
        let parsed = Json::parse(&cm.meta_json().to_string()).unwrap();
        let back = CompressedModel::from_parts(ParamStore::zeros(&cfg), &parsed).unwrap();
        assert_eq!(back.kept, cm.kept);
        // masks are rebuilt from the kept sets, identical to the originals
        let (a, b) = (cm.masks.as_ref().unwrap(), back.masks.as_ref().unwrap());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x, y);
        }
    }
}
