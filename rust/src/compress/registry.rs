//! Method registry: compression methods resolvable by name.

use anyhow::Result;

use crate::prune::Importance;

use super::methods::{PruneStructured, RomFeature, RomWeightSvd};
use super::Compressor;

/// Names of every registered method, in comparison order.
pub const METHODS: &[&str] =
    &["rom-feature", "rom-weight-svd", "prune-magnitude", "prune-activation"];

/// Resolve a method by registry name.
pub fn resolve(name: &str) -> Result<Box<dyn Compressor>> {
    Ok(match name {
        "rom-feature" => Box::new(RomFeature::default()),
        "rom-weight-svd" => Box::new(RomWeightSvd),
        "prune-magnitude" => Box::new(PruneStructured { importance: Importance::Magnitude }),
        "prune-activation" => {
            Box::new(PruneStructured { importance: Importance::ActivationAware })
        }
        other => anyhow::bail!(
            "unknown compression method `{other}` (registered: {})",
            METHODS.join(", ")
        ),
    })
}

/// All registered methods, in [`METHODS`] order.
pub fn all() -> Vec<Box<dyn Compressor>> {
    METHODS.iter().map(|m| resolve(m).expect("registered method resolves")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_name_resolves_to_itself() {
        for name in METHODS {
            let c = resolve(name).unwrap();
            assert_eq!(c.name(), *name);
        }
        assert_eq!(all().len(), METHODS.len());
    }

    #[test]
    fn unknown_name_lists_registry() {
        let err = resolve("svd-9000").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("svd-9000"));
        assert!(msg.contains("rom-feature"));
    }

    #[test]
    fn runtime_requirements_declared() {
        assert!(resolve("rom-feature").unwrap().needs_runtime());
        assert!(resolve("prune-activation").unwrap().needs_runtime());
        assert!(!resolve("rom-weight-svd").unwrap().needs_runtime());
        assert!(!resolve("prune-magnitude").unwrap().needs_runtime());
    }
}
