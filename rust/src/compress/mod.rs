//! Unified compression API — one pluggable pipeline for every method.
//!
//! The paper's central comparison (feature-space ROM vs weight-space SVD
//! vs structured pruning) runs through a single abstraction here:
//!
//! - [`Compressor`] — the method trait: `name()` + `compress(&mut ctx)`.
//! - [`CompressCtx`] — everything a method may need: optional PJRT
//!   runtime, model config, source parameters, a pluggable
//!   [`CalibrationStream`], the module schedule and global budget.
//! - [`CompressedModel`] — the unified artifact (params + accounting +
//!   timings + provenance), serializable to `.rtz`.
//! - the registry ([`METHODS`], [`resolve`]) — method lookup by name:
//!   `rom-feature`, `rom-weight-svd`, `prune-magnitude`,
//!   `prune-activation`.
//! - [`CompressionSession`] — binds an environment and runs methods by
//!   name or as trait objects; the CLI, tables harness, examples, and
//!   benches all go through it.
//!
//! Adding a method: implement [`Compressor`] (set `needs_runtime` if it
//! captures activations), register a name in [`registry::resolve`], and
//! every consumer — `repro compress`, `repro sweep`, the tables harness,
//! the benches — picks it up with no further plumbing.

pub mod artifact;
pub mod calib;
pub mod methods;
pub mod registry;
pub mod session;

use anyhow::Result;

use crate::exec::ExecConfig;
use crate::model::{ModelConfig, ParamStore};
use crate::rom::budget::ModuleSchedule;
use crate::runtime::Runtime;

pub use artifact::{CompressedModel, KeptSets, Provenance, META_KEY};
pub use calib::{collect_rows, CalibrationStream, EmptyStream, VecStream, WorldStream};
pub use registry::{all, resolve, METHODS};
pub use session::CompressionSession;

/// Shared context handed to every [`Compressor::compress`] call.
pub struct CompressCtx<'a> {
    /// Live PJRT runtime, when the session has one. Methods that capture
    /// activations require it; data-free methods ignore it.
    pub runtime: Option<&'a Runtime>,
    pub cfg: ModelConfig,
    /// Source parameters (never mutated; methods clone what they change).
    pub params: &'a ParamStore,
    /// Pluggable calibration source (drain with [`collect_rows`]).
    pub calib: &'a mut dyn CalibrationStream,
    /// Which modules to compress and how hard.
    pub schedule: ModuleSchedule,
    /// The requested global parameter budget (provenance).
    pub global_budget: f64,
    /// Use the Pallas Gram kernel for covariance accumulation.
    pub pallas_covariance: bool,
    /// Worker-pool budget (the global `--threads` knob). Methods that
    /// parallelize must stay bitwise deterministic across thread counts.
    pub exec: ExecConfig,
}

impl CompressCtx<'_> {
    /// Provenance record for the current run.
    pub fn provenance(&self, method: &str) -> Provenance {
        Provenance {
            method: method.to_string(),
            global_budget: self.global_budget,
            schedule: self.schedule,
            calib_label: self.calib.label(),
            calib_rows: self.calib.rows_hint(),
            calib_seq: self.calib.seq_hint(),
        }
    }
}

/// A compression method, pluggable by name through the registry.
pub trait Compressor {
    /// Registry name (`rom-feature`, `prune-magnitude`, …).
    fn name(&self) -> &str;

    /// Whether the method captures activations through the PJRT runtime.
    fn needs_runtime(&self) -> bool {
        false
    }

    /// Run the method over `ctx`, producing the unified artifact.
    fn compress(&self, ctx: &mut CompressCtx<'_>) -> Result<CompressedModel>;
}
