//! Named-tensor substrate: in-memory [`Tensor`] + the `.rtz` container
//! shared with the build-time Python world (`python/compile/tensorio.py`).

pub mod rtz;
pub mod tensor;

pub use rtz::{load_rtz, save_rtz};
pub use tensor::{DType, Tensor, TensorMap};
