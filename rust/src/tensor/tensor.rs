//! Minimal dense tensor with just enough dtype coverage for the pipeline
//! (f32 weights/activations, i32 tokens; f64/u8 for bookkeeping).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::linalg::Matrix;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    F64,
    U8,
}

impl DType {
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::F64 => 2,
            DType::U8 => 3,
        }
    }

    pub fn from_code(code: u8) -> Result<Self> {
        Ok(match code {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::F64,
            3 => DType::U8,
            _ => bail!("unknown dtype code {code}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
            DType::U8 => 1,
        }
    }
}

/// Dense row-major tensor. Data lives in one of the typed vectors.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    F64 { shape: Vec<usize>, data: Vec<f64> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
}

/// Named tensor collection (checkpoints, calibration captures, …).
pub type TensorMap = BTreeMap<String, Tensor>;

impl Tensor {
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
            Tensor::F64 { .. } => DType::F64,
            Tensor::U8 { .. } => DType::U8,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. }
            | Tensor::I32 { shape, .. }
            | Tensor::F64 { shape, .. }
            | Tensor::U8 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    /// View a rank-2 f32 tensor as an f64 [`Matrix`].
    pub fn to_matrix(&self) -> Result<Matrix> {
        let shape = self.shape();
        if shape.len() != 2 {
            bail!("to_matrix: rank {} tensor", shape.len());
        }
        Ok(Matrix::from_f32(shape[0], shape[1], self.as_f32()?))
    }

    /// Rank-2 f32 tensor from a [`Matrix`].
    pub fn from_matrix(m: &Matrix) -> Tensor {
        Tensor::from_f32(&[m.rows(), m.cols()], m.to_f32())
    }

    /// Flatten leading axes: (a, b, …, d) -> (a·b·…, d). Used to turn
    /// (B, T, d) activation captures into (N, d) sample matrices.
    pub fn flatten_to_2d(&self) -> Result<Tensor> {
        let shape = self.shape();
        if shape.is_empty() {
            bail!("flatten_to_2d: scalar");
        }
        let d = *shape.last().unwrap();
        let n: usize = shape[..shape.len() - 1].iter().product();
        Ok(match self {
            Tensor::F32 { data, .. } => Tensor::F32 { shape: vec![n, d], data: data.clone() },
            Tensor::I32 { data, .. } => Tensor::I32 { shape: vec![n, d], data: data.clone() },
            Tensor::F64 { data, .. } => Tensor::F64 { shape: vec![n, d], data: data.clone() },
            Tensor::U8 { data, .. } => Tensor::U8 { shape: vec![n, d], data: data.clone() },
        })
    }

    /// Keep only the first `n` rows of a rank-2 tensor (used to drop
    /// padded calibration rows before covariance accumulation).
    pub fn truncate_rows(&self, n: usize) -> Result<Tensor> {
        let shape = self.shape();
        if shape.len() != 2 {
            bail!("truncate_rows: rank {} tensor", shape.len());
        }
        let (rows, cols) = (shape[0], shape[1]);
        if n > rows {
            bail!("truncate_rows: {n} > {rows}");
        }
        Ok(match self {
            Tensor::F32 { data, .. } => Tensor::from_f32(&[n, cols], data[..n * cols].to_vec()),
            Tensor::I32 { data, .. } => Tensor::from_i32(&[n, cols], data[..n * cols].to_vec()),
            _ => bail!("truncate_rows: unsupported dtype"),
        })
    }

    /// Raw little-endian bytes (for `.rtz` serialization).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        match self {
            Tensor::F32 { data, .. } => data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Tensor::I32 { data, .. } => data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Tensor::F64 { data, .. } => data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Tensor::U8 { data, .. } => data.clone(),
        }
    }

    pub fn from_le_bytes(dtype: DType, shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * dtype.size() {
            bail!("byte length {} != {} elems of {:?}", bytes.len(), n, dtype);
        }
        Ok(match dtype {
            DType::F32 => Tensor::F32 {
                shape,
                data: bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            },
            DType::I32 => Tensor::I32 {
                shape,
                data: bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            },
            DType::F64 => Tensor::F64 {
                shape,
                data: bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
            },
            DType::U8 => Tensor::U8 { shape, data: bytes.to_vec() },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_len() {
        let t = Tensor::zeros_f32(&[2, 3, 4]);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    fn matrix_roundtrip() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let m = t.to_matrix().unwrap();
        assert_eq!(m[(1, 0)], 3.0);
        let t2 = Tensor::from_matrix(&m);
        assert_eq!(t, t2);
    }

    #[test]
    fn flatten_3d() {
        let t = Tensor::from_f32(&[2, 3, 4], (0..24).map(|x| x as f32).collect());
        let f = t.flatten_to_2d().unwrap();
        assert_eq!(f.shape(), &[6, 4]);
        assert_eq!(f.as_f32().unwrap()[23], 23.0);
    }

    #[test]
    fn truncate_rows_drops_tail() {
        let t = Tensor::from_f32(&[4, 2], (0..8).map(|x| x as f32).collect());
        let tr = t.truncate_rows(2).unwrap();
        assert_eq!(tr.shape(), &[2, 2]);
        assert_eq!(tr.as_f32().unwrap(), &[0.0, 1.0, 2.0, 3.0]);
        assert!(t.truncate_rows(9).is_err());
    }

    #[test]
    fn le_bytes_roundtrip() {
        let t = Tensor::from_i32(&[3], vec![-1, 0, 65536]);
        let b = t.to_le_bytes();
        let t2 = Tensor::from_le_bytes(DType::I32, vec![3], &b).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::from_i32(&[1], vec![1]);
        assert!(t.as_f32().is_err());
        assert!(t.to_matrix().is_err());
    }
}
