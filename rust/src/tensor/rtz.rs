//! `.rtz` container reader/writer — byte-compatible with
//! `python/compile/tensorio.py` (see that file for the format spec).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{DType, Tensor, TensorMap};

const MAGIC: &[u8; 4] = b"RTZ1";

pub fn save_rtz(path: impl AsRef<Path>, tensors: &TensorMap) -> Result<()> {
    let f = File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        if nb.len() > u16::MAX as usize {
            bail!("tensor name too long: {name}");
        }
        w.write_all(&(nb.len() as u16).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&[t.dtype().code(), t.shape().len() as u8])?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        w.write_all(&t.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

pub fn load_rtz(path: impl AsRef<Path>) -> Result<TensorMap> {
    let f = File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic {:?}", path.as_ref().display(), magic);
    }
    let mut buf4 = [0u8; 4];
    r.read_exact(&mut buf4)?;
    let count = u32::from_le_bytes(buf4);

    let mut out = TensorMap::new();
    for _ in 0..count {
        let mut buf2 = [0u8; 2];
        r.read_exact(&mut buf2)?;
        let nlen = u16::from_le_bytes(buf2) as usize;
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name utf8")?;

        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let dtype = DType::from_code(hdr[0])?;
        let ndim = hdr[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b8 = [0u8; 8];
            r.read_exact(&mut b8)?;
            shape.push(u64::from_le_bytes(b8) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0u8; n * dtype.size()];
        r.read_exact(&mut data)?;
        out.insert(name, Tensor::from_le_bytes(dtype, shape, &data)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("rtz_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.rtz");

        let mut m = TensorMap::new();
        m.insert("w".into(), Tensor::from_f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 7.0, -9.25]));
        m.insert("tokens".into(), Tensor::from_i32(&[4], vec![1, 2, 3, 258]));
        m.insert("scalar".into(), Tensor::scalar_f32(42.0));
        save_rtz(&path, &m).unwrap();
        let loaded = load_rtz(&path).unwrap();
        assert_eq!(loaded, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_python_written_file() {
        // artifacts/init.rtz is produced by python tensorio; only run when
        // artifacts exist (make artifacts).
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/init.rtz");
        if !path.exists() {
            eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
            return;
        }
        let map = load_rtz(&path).unwrap();
        assert!(map.contains_key("embed"));
        assert!(map.contains_key("final_norm"));
        let embed = &map["embed"];
        assert_eq!(embed.shape().len(), 2);
        assert_eq!(embed.dtype(), DType::F32);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join(format!("rtz_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.rtz");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load_rtz(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
