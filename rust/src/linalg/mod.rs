//! Dense linear-algebra substrate (pure Rust, CPU-only).
//!
//! The paper's selling point is that the whole ROM pass runs on a CPU with
//! no GPU and no deep-learning framework; this module is that substrate:
//! a row-major `f64` matrix type, cache-blocked matmul, and two symmetric
//! eigensolvers (Householder tridiagonalization + implicit-shift QL as the
//! production path, cyclic Jacobi as the cross-check oracle). The [`simd`]
//! submodule adds the serving-path microkernel layer: fixed-lane-order
//! vectorized dot/axpy/rmsnorm, cache-aware packed weights, per-row int8
//! quantized weights, and the shared rope table — all deterministic and
//! thread-invariant by construction.

pub mod eigen;
pub mod jacobi;
pub mod matrix;
pub mod matmul;
pub mod simd;
pub mod svd;

pub use eigen::{eigh, EigenDecomposition};
pub use jacobi::eigh_jacobi;
pub use matrix::Matrix;
pub use matmul::{
    matmul, matmul_f32, matmul_transb_blocked_f32, matmul_transb_blocked_into, matmul_transb_f32,
    par_matmul, par_matmul_f32, par_matmul_transb_blocked_f32, par_matmul_transb_blocked_into,
};
pub use simd::{
    axpy_f32, dot_f32, dot_f32_ref, matmul_transb_packed_into, matmul_transb_quant_into,
    mean_square, par_matmul_transb_packed, par_matmul_transb_packed_into,
    par_matmul_transb_quant_into, rmsnorm as rmsnorm_rows, PackedWeight, QuantizedWeight,
    RopeTable, LANES, PANEL_ROWS,
};
pub use svd::{svd, Svd};
