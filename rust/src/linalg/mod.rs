//! Dense linear-algebra substrate (pure Rust, CPU-only).
//!
//! The paper's selling point is that the whole ROM pass runs on a CPU with
//! no GPU and no deep-learning framework; this module is that substrate:
//! a row-major `f64` matrix type, cache-blocked matmul, and two symmetric
//! eigensolvers (Householder tridiagonalization + implicit-shift QL as the
//! production path, cyclic Jacobi as the cross-check oracle).

pub mod eigen;
pub mod jacobi;
pub mod matrix;
pub mod matmul;
pub mod svd;

pub use eigen::{eigh, EigenDecomposition};
pub use jacobi::eigh_jacobi;
pub use matrix::Matrix;
pub use matmul::{
    matmul, matmul_f32, matmul_transb_blocked_f32, matmul_transb_f32, par_matmul, par_matmul_f32,
    par_matmul_transb_blocked_f32,
};
pub use svd::{svd, Svd};
