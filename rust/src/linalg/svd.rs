//! Thin SVD built on the symmetric eigensolver: `A = U Σ Vᵀ` via the
//! eigendecomposition of the smaller Gram matrix.
//!
//! Used by the weight-space ablation and the analysis tooling (effective
//! rank / spectra of calibration covariances in EXPERIMENTS.md §Perf).

use anyhow::Result;

use super::eigen::eigh;
use super::matmul::matmul;
use super::matrix::Matrix;

/// Thin singular value decomposition.
#[derive(Debug, Clone)]
pub struct Svd {
    /// (m, k) left singular vectors (columns), k = min(m, n).
    pub u: Matrix,
    /// Singular values, descending, length k.
    pub sigma: Vec<f64>,
    /// (k, n) right singular vectors (rows).
    pub vt: Matrix,
}

impl Svd {
    /// Rank-r truncated reconstruction.
    pub fn truncate(&self, r: usize) -> Matrix {
        let r = r.min(self.sigma.len());
        let m = self.u.rows();
        let n = self.vt.cols();
        let mut out = Matrix::zeros(m, n);
        for k in 0..r {
            let s = self.sigma[k];
            for i in 0..m {
                let us = self.u[(i, k)] * s;
                for j in 0..n {
                    out[(i, j)] += us * self.vt[(k, j)];
                }
            }
        }
        out
    }

    /// Effective rank at relative threshold `tol` (σ_i > tol·σ_0).
    pub fn effective_rank(&self, tol: f64) -> usize {
        let s0 = self.sigma.first().copied().unwrap_or(0.0);
        self.sigma.iter().filter(|&&s| s > tol * s0).count()
    }
}

/// Compute the thin SVD of `a` via the Gram matrix of the smaller side.
pub fn svd(a: &Matrix) -> Result<Svd> {
    let (m, n) = (a.rows(), a.cols());
    if m <= n {
        // A Aᵀ = U Σ² Uᵀ, then Vᵀ = Σ⁻¹ Uᵀ A
        let aat = matmul(a, &a.transpose());
        let dec = eigh(&aat)?;
        let sigma: Vec<f64> = dec.values.iter().map(|l| l.max(0.0).sqrt()).collect();
        // u columns = eigenvectors (dec rows are eigvecs)
        let u = dec.vectors.transpose(); // (m, m)
        let ut_a = matmul(&dec.vectors, a); // (m, n)
        let mut vt = Matrix::zeros(m, n);
        for k in 0..m {
            let s = sigma[k];
            if s > 1e-12 {
                for j in 0..n {
                    vt[(k, j)] = ut_a[(k, j)] / s;
                }
            }
        }
        Ok(Svd { u, sigma, vt })
    } else {
        // Aᵀ A = V Σ² Vᵀ, then U = A V Σ⁻¹
        let ata = matmul(&a.transpose(), a);
        let dec = eigh(&ata)?;
        let sigma: Vec<f64> = dec.values.iter().map(|l| l.max(0.0).sqrt()).collect();
        let vt = dec.vectors.clone(); // (n, n), rows are right singular vecs
        let av = matmul(a, &dec.vectors.transpose()); // (m, n)
        let mut u = Matrix::zeros(m, n);
        for k in 0..n {
            let s = sigma[k];
            if s > 1e-12 {
                for i in 0..m {
                    u[(i, k)] = av[(i, k)] / s;
                }
            }
        }
        Ok(Svd { u, sigma, vt })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(m, n, |_, _| rng.normal())
    }

    #[test]
    fn reconstructs_both_orientations() {
        for &(m, n) in &[(6usize, 10usize), (10, 6), (8, 8)] {
            let a = rand(m, n, (m * 31 + n) as u64);
            let s = svd(&a).unwrap();
            let rec = s.truncate(m.min(n));
            assert!(rec.sub(&a).max_abs() < 1e-8, "{m}x{n}: {}", rec.sub(&a).max_abs());
        }
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let a = rand(12, 7, 3);
        let s = svd(&a).unwrap();
        for w in s.sigma.windows(2) {
            assert!(w[0] >= w[1] - 1e-10);
        }
        assert!(s.sigma.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn truncation_error_is_tail_energy() {
        // Eckart–Young: ‖A - A_r‖_F² = Σ_{i>r} σ_i²
        let a = rand(9, 14, 4);
        let s = svd(&a).unwrap();
        for r in [1, 3, 6] {
            let err = s.truncate(r).sub(&a).frobenius_norm();
            let tail: f64 = s.sigma[r..].iter().map(|x| x * x).sum();
            assert!((err * err - tail).abs() < 1e-6, "r={r}: {} vs {}", err * err, tail);
        }
    }

    #[test]
    fn effective_rank_of_lowrank_matrix() {
        let b = rand(10, 3, 5);
        let c = rand(3, 8, 6);
        let a = matmul(&b, &c);
        let s = svd(&a).unwrap();
        // σ = √λ amplifies eigensolver noise on the zero modes
        // (λ ≈ 1e-12·scale ⇒ σ/σ₀ ≈ 1e-6), so threshold at 1e-4.
        assert_eq!(s.effective_rank(1e-4), 3);
    }

    #[test]
    fn matches_eigh_of_gram() {
        let a = rand(5, 12, 7);
        let s = svd(&a).unwrap();
        let ata = matmul(&a.transpose(), &a);
        let dec = eigh(&ata).unwrap();
        for (sv, ev) in s.sigma.iter().zip(&dec.values) {
            assert!((sv * sv - ev.max(0.0)).abs() < 1e-8);
        }
    }
}
