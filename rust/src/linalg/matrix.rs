//! Row-major dense `f64` matrix.
//!
//! Small-dimension workhorse of the ROM pass (covariances are `d×d` with
//! `d ≤ d_ff`), so clarity beats cleverness here; the blocked multiply in
//! [`super::matmul`] covers the few hot products.

use std::fmt;
use std::ops::{Index, IndexMut};

#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Build from a flat f32 slice (tensor interop).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Select rows `[0, r)` — the top-r principal components when the rows
    /// are eigenvectors sorted by descending eigenvalue.
    pub fn top_rows(&self, r: usize) -> Matrix {
        assert!(r <= self.rows);
        Matrix {
            rows: r,
            cols: self.cols,
            data: self.data[..r * self.cols].to_vec(),
        }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Symmetrize in place: `A ← (A + Aᵀ)/2` (covariance hygiene before
    /// handing to the eigensolver).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let avg = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = avg;
                self[(j, i)] = avg;
            }
        }
    }

    /// `self @ v` for a vector.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let vals: Vec<String> = (0..cols).map(|j| format!("{:9.4}", self[(i, j)])).collect();
            writeln!(f, "  [{}{}]", vals.join(", "), if self.cols > 8 { ", …" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m[(0, 0)], 0.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(2, 1)], m[(1, 2)]);
    }

    #[test]
    fn identity_matvec() {
        let id = Matrix::identity(4);
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(id.matvec(&v), v);
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let mut m = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        assert!(!m.is_symmetric(1e-12));
        m.symmetrize();
        assert!(m.is_symmetric(1e-12));
    }

    #[test]
    fn top_rows_selects_prefix() {
        let m = Matrix::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        let t = m.top_rows(2);
        assert_eq!(t.rows(), 2);
        assert_eq!(t[(1, 1)], 3.0);
    }

    #[test]
    fn f32_interop_roundtrip() {
        let data: Vec<f32> = (0..6).map(|x| x as f32 * 0.5).collect();
        let m = Matrix::from_f32(2, 3, &data);
        assert_eq!(m.to_f32(), data);
    }

    #[test]
    fn frobenius() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.frobenius_norm() - 5.0).abs() < 1e-12);
    }
}
