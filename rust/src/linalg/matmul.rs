//! Cache-blocked dense matrix multiplication.
//!
//! Hot in two places: the ROM re-parameterization (`W_eff = V_rᵀ (V_r W)`)
//! and the Rust-side covariance fallback (`YᵀY` on calibration captures).
//! The kernel is an i-k-j loop order (streaming the B rows) with L1-sized
//! blocking — no SIMD intrinsics, but the loop body autovectorizes.

use super::matrix::Matrix;

/// Block edge tuned for ~32 KiB L1 (3 × 64×64 f64 panels ≈ 96 KiB L2-ish,
/// inner panels L1-resident).
const BLOCK: usize = 64;

/// `a @ b` for f64 matrices.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} @ {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Matrix::zeros(m, n);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    for kk in k0..k1 {
                        let aik = a[(i, kk)];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.row(kk)[j0..j1];
                        let orow = &mut out.row_mut(i)[j0..j1];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += aik * bv;
                        }
                    }
                }
            }
        }
    }
    out
}

/// `a @ b` over f32 slices (row-major), f32 accumulation into f64 rows.
/// Shapes: a is (m, k), b is (k, n); returns (m, n) f32.
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..kk * n + n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += aik * bv;
                    }
                }
            }
        }
    }
    out
}

/// `a @ bᵀ` over f32 slices: a is (m, k), b is (n, k); returns (m, n).
/// This is the natural layout for `X @ Wᵀ` with row-major weights.
pub fn matmul_transb_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
    out
}

/// Cache-blocked `a @ bᵀ`: same contract as [`matmul_transb_f32`], tiled
/// over (j, k) so a `BLOCK`-wide panel of `b` rows stays L1-resident while
/// every row of `a` streams past it. This is the serving hot path: the
/// factored form applies two *skinny* weights (`n = r` or `k = r` with
/// `r ≪ d`), where the j-panel of `b` fits in cache whole and the k-tiling
/// keeps long reduction dims from thrashing it.
pub fn matmul_transb_blocked_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for j0 in (0..n).step_by(BLOCK) {
        let j1 = (j0 + BLOCK).min(n);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in 0..m {
                let arow = &a[i * k + k0..i * k + k1];
                let orow = &mut out[i * n + j0..i * n + j1];
                for (j, o) in (j0..j1).zip(orow.iter_mut()) {
                    let brow = &b[j * k + k0..j * k + k1];
                    let mut acc = 0.0f32;
                    for (x, y) in arow.iter().zip(brow) {
                        acc += x * y;
                    }
                    *o += acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (100, 33, 65), (129, 70, 10)] {
            let a = Matrix::from_fn(m, k, |_, _| rng.normal());
            let b = Matrix::from_fn(k, n, |_, _| rng.normal());
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.sub(&want).max_abs() < 1e-9, "{}x{}x{}", m, k, n);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(1);
        let a = Matrix::from_fn(17, 17, |_, _| rng.normal());
        let id = Matrix::identity(17);
        assert!(matmul(&a, &id).sub(&a).max_abs() < 1e-12);
        assert!(matmul(&id, &a).sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn f32_matches_f64() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (20, 30, 15);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let got = matmul_f32(&a, &b, m, k, n);
        let am = Matrix::from_f32(m, k, &a);
        let bm = Matrix::from_f32(k, n, &b);
        let want = matmul(&am, &bm);
        for i in 0..m {
            for j in 0..n {
                assert!((got[i * n + j] as f64 - want[(i, j)]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn blocked_transb_matches_naive_transb() {
        let mut rng = Rng::new(4);
        // shapes straddling the block edge, including skinny r-dims
        for &(m, k, n) in &[(1, 1, 1), (5, 70, 3), (3, 7, 70), (64, 64, 64), (33, 129, 65)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let got = matmul_transb_blocked_f32(&a, &b, m, k, n);
            let want = matmul_transb_f32(&a, &b, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{m}x{k}x{n}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn transb_matches_explicit_transpose() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (12, 24, 9);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let got = matmul_transb_f32(&a, &b, m, k, n);
        // transpose b explicitly
        let mut bt = vec![0.0f32; k * n];
        for j in 0..n {
            for kk in 0..k {
                bt[kk * n + j] = b[j * k + kk];
            }
        }
        let want = matmul_f32(&a, &bt, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }
}
