//! Cache-blocked dense matrix multiplication.
//!
//! Hot in two places: the ROM re-parameterization (`W_eff = V_rᵀ (V_r W)`)
//! and the Rust-side covariance fallback (`YᵀY` on calibration captures).
//! The kernel is an i-k-j loop order (streaming the B rows) with L1-sized
//! blocking — no SIMD intrinsics, but the loop body autovectorizes.
//!
//! Every kernel also has a row-sharded `par_*` twin over an
//! [`ExecPool`]: the output rows are statically partitioned across the
//! workers and each shard runs the *same* serial kernel, so — because
//! every output row is computed independently of which rows share its
//! shard — the parallel results are bitwise identical to the serial ones
//! for any thread count.

use crate::exec::ExecPool;

use super::matrix::Matrix;

/// Block edge tuned for ~32 KiB L1 (3 × 64×64 f64 panels ≈ 96 KiB L2-ish,
/// inner panels L1-resident). Shared with the packed kernels in
/// [`super::simd`], which must keep the same k-block partial-sum
/// boundaries to stay bitwise equal to the blocked kernel here.
pub(crate) const BLOCK: usize = 64;

/// Minimum multiply-accumulates (`m·k·n`) before a `par_*` kernel fans
/// out: below this, scoped-thread spawn overhead (~tens of µs) rivals the
/// matmul itself — the skinny factored matmuls stay serial and the outer
/// request/sequence-level fan-out carries the parallelism. Purely a
/// performance cutoff; results are identical either way.
pub(crate) const PAR_MIN_MACS: usize = 1 << 18;

/// The blocked f64 kernel over row-major slices: `out += a @ b` with
/// `out` pre-zeroed. Row `i` of the output depends only on row `i` of `a`
/// (k/j blocking is row-independent), which is what makes row sharding
/// exact.
fn matmul_into(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, out: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let arow = &a[i * k..(i + 1) * k];
                    let orow = &mut out[i * n + j0..i * n + j1];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * n + j0..kk * n + j1];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `a @ b` for f64 matrices.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} @ {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Matrix::zeros(m, n);
    matmul_into(a.data(), b.data(), m, k, n, out.data_mut());
    out
}

/// Row-sharded [`matmul`]: output rows are partitioned across the pool's
/// workers, each shard running the serial kernel — bitwise identical to
/// [`matmul`] for any thread count.
pub fn par_matmul(a: &Matrix, b: &Matrix, pool: &ExecPool) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {}x{} @ {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if pool.threads() <= 1 || m <= 1 || n == 0 || m * k * n < PAR_MIN_MACS {
        return matmul(a, b);
    }
    let mut out = Matrix::zeros(m, n);
    pool.parallel_chunks(out.data_mut(), n, |row0, chunk| {
        let rows = chunk.len() / n;
        matmul_into(&a.data()[row0 * k..(row0 + rows) * k], b.data(), rows, k, n, chunk);
    });
    out
}

/// The blocked f32 kernel over row-major slices (`out` pre-zeroed).
fn matmul_f32_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..kk * n + n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += aik * bv;
                    }
                }
            }
        }
    }
}

/// `a @ b` over f32 slices (row-major), f32 accumulation.
/// Shapes: a is (m, k), b is (k, n); returns (m, n) f32.
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    matmul_f32_into(a, b, m, k, n, &mut out);
    out
}

/// Row-sharded [`matmul_f32`] — bitwise identical for any thread count.
pub fn par_matmul_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &ExecPool,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    if pool.threads() <= 1 || m <= 1 || n == 0 || m * k * n < PAR_MIN_MACS {
        return matmul_f32(a, b, m, k, n);
    }
    let mut out = vec![0.0f32; m * n];
    pool.parallel_chunks(&mut out, n, |row0, chunk| {
        let rows = chunk.len() / n;
        matmul_f32_into(&a[row0 * k..(row0 + rows) * k], b, rows, k, n, chunk);
    });
    out
}

/// `a @ bᵀ` over f32 slices: a is (m, k), b is (n, k); returns (m, n).
/// This is the natural layout for `X @ Wᵀ` with row-major weights.
pub fn matmul_transb_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
    out
}

/// The blocked transposed-B f32 kernel over row-major slices (`out`
/// pre-zeroed). Output row `i` depends only on input row `i` — the basis
/// of the row-sharded serving kernel. The inner dot is the vectorized
/// fixed-lane-order [`super::simd::dot_f32`]; because `BLOCK` is a
/// multiple of [`super::simd::LANES`], every k-block starts lane
/// assignment at lane 0, which is what lets the packed kernel
/// ([`super::simd::matmul_transb_packed_into`]) reproduce this kernel's
/// results bit for bit.
pub fn matmul_transb_blocked_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for j0 in (0..n).step_by(BLOCK) {
        let j1 = (j0 + BLOCK).min(n);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in 0..m {
                let arow = &a[i * k + k0..i * k + k1];
                let orow = &mut out[i * n + j0..i * n + j1];
                for (j, o) in (j0..j1).zip(orow.iter_mut()) {
                    let brow = &b[j * k + k0..j * k + k1];
                    *o += super::simd::dot_f32(arow, brow);
                }
            }
        }
    }
}

/// Cache-blocked `a @ bᵀ`: same contract as [`matmul_transb_f32`], tiled
/// over (j, k) so a `BLOCK`-wide panel of `b` rows stays L1-resident while
/// every row of `a` streams past it. This is the serving hot path: the
/// factored form applies two *skinny* weights (`n = r` or `k = r` with
/// `r ≪ d`), where the j-panel of `b` fits in cache whole and the k-tiling
/// keeps long reduction dims from thrashing it.
pub fn matmul_transb_blocked_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    matmul_transb_blocked_into(a, b, m, k, n, &mut out);
    out
}

/// Row-sharded [`matmul_transb_blocked_f32`]: the output rows of
/// `y = x·Wᵀ` are statically partitioned across the pool's workers (each
/// shard running the serial blocked kernel on its row range), so batched
/// prefill and serve forwards scale with cores while staying bitwise
/// identical to the serial kernel for any thread count — including the
/// degenerate single-row decode step, which simply runs serial.
pub fn par_matmul_transb_blocked_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &ExecPool,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    if pool.threads() <= 1 || m <= 1 || n == 0 || m * k * n < PAR_MIN_MACS {
        return matmul_transb_blocked_f32(a, b, m, k, n);
    }
    let mut out = vec![0.0f32; m * n];
    par_matmul_transb_blocked_into(a, b, m, k, n, pool, &mut out);
    out
}

/// Row-sharded [`matmul_transb_blocked_into`] over a caller-provided
/// pre-zeroed `out` — the allocation-free form the serving scratch arena
/// uses. Bitwise identical to the serial kernel for any thread count.
pub fn par_matmul_transb_blocked_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &ExecPool,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    if pool.threads() <= 1 || m <= 1 || n == 0 || m * k * n < PAR_MIN_MACS {
        return matmul_transb_blocked_into(a, b, m, k, n, out);
    }
    pool.parallel_chunks(out, n, |row0, chunk| {
        let rows = chunk.len() / n;
        matmul_transb_blocked_into(&a[row0 * k..(row0 + rows) * k], b, rows, k, n, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (100, 33, 65), (129, 70, 10)] {
            let a = Matrix::from_fn(m, k, |_, _| rng.normal());
            let b = Matrix::from_fn(k, n, |_, _| rng.normal());
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.sub(&want).max_abs() < 1e-9, "{}x{}x{}", m, k, n);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(1);
        let a = Matrix::from_fn(17, 17, |_, _| rng.normal());
        let id = Matrix::identity(17);
        assert!(matmul(&a, &id).sub(&a).max_abs() < 1e-12);
        assert!(matmul(&id, &a).sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn f32_matches_f64() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (20, 30, 15);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let got = matmul_f32(&a, &b, m, k, n);
        let am = Matrix::from_f32(m, k, &a);
        let bm = Matrix::from_f32(k, n, &b);
        let want = matmul(&am, &bm);
        for i in 0..m {
            for j in 0..n {
                assert!((got[i * n + j] as f64 - want[(i, j)]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn blocked_transb_matches_naive_transb() {
        let mut rng = Rng::new(4);
        // shapes straddling the block edge, including skinny r-dims
        for &(m, k, n) in &[(1, 1, 1), (5, 70, 3), (3, 7, 70), (64, 64, 64), (33, 129, 65)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let got = matmul_transb_blocked_f32(&a, &b, m, k, n);
            let want = matmul_transb_f32(&a, &b, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{m}x{k}x{n}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn par_kernels_match_serial_bitwise_for_any_thread_count() {
        let mut rng = Rng::new(7);
        // shapes on both sides of PAR_MIN_MACS: the small ones exercise
        // the serial fallback, (96,64,64) and (129,70,40) genuinely shard
        for &(m, k, n) in &[
            (1usize, 3usize, 4usize),
            (5, 70, 3),
            (33, 17, 65),
            (129, 40, 10),
            (96, 64, 64),
            (129, 70, 40),
        ] {
            let af: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let bf: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let a64 = Matrix::from_f32(m, k, &af);
            let b64 = Matrix::from_f32(k, n, &bf);
            let want_f32 = matmul_f32(&af, &bf, m, k, n);
            let want_tb = matmul_transb_blocked_f32(&af, &bt, m, k, n);
            let want_f64 = matmul(&a64, &b64);
            for threads in [1usize, 2, 3, 8] {
                let pool = ExecPool::new(threads);
                assert_eq!(par_matmul_f32(&af, &bf, m, k, n, &pool), want_f32, "{m}x{k}x{n} t{threads}");
                assert_eq!(
                    par_matmul_transb_blocked_f32(&af, &bt, m, k, n, &pool),
                    want_tb,
                    "{m}x{k}x{n} t{threads}"
                );
                assert_eq!(
                    par_matmul(&a64, &b64, &pool).data(),
                    want_f64.data(),
                    "{m}x{k}x{n} t{threads}"
                );
            }
        }
    }

    #[test]
    fn transb_matches_explicit_transpose() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (12, 24, 9);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let got = matmul_transb_f32(&a, &b, m, k, n);
        // transpose b explicitly
        let mut bt = vec![0.0f32; k * n];
        for j in 0..n {
            for kk in 0..k {
                bt[kk * n + j] = b[j * k + kk];
            }
        }
        let want = matmul_f32(&a, &bt, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }
}
