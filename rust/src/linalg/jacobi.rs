//! Cyclic Jacobi eigensolver — the slow, independently-derived oracle used
//! to cross-check [`super::eigen::eigh`]. O(n³) per sweep, unconditionally
//! convergent on symmetric matrices.

use anyhow::{bail, Result};

use super::eigen::EigenDecomposition;
use super::matrix::Matrix;

/// Eigendecomposition by cyclic Jacobi rotations. Same contract as
/// [`super::eigen::eigh`]: eigenpairs sorted by descending eigenvalue,
/// eigenvectors as rows.
pub fn eigh_jacobi(a: &Matrix) -> Result<EigenDecomposition> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    if n == 0 {
        return Ok(EigenDecomposition { values: vec![], vectors: Matrix::zeros(0, 0) });
    }
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Matrix::identity(n);
    let scale = m.max_abs().max(1e-300);

    for _sweep in 0..100 {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-11 * scale * n as f64 {
            return Ok(sorted(m, v));
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rows/cols p and q of A
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp - s * akq;
                    m[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk - s * aqk;
                    m[(q, k)] = s * apk + c * aqk;
                }
                // accumulate rotation into V (columns are eigenvectors)
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    bail!("jacobi: no convergence after 100 sweeps")
}

fn sorted(m: Matrix, v: Matrix) -> EigenDecomposition {
    let n = m.rows();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[(j, j)].total_cmp(&m[(i, i)]));
    let values: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (row, &src) in order.iter().enumerate() {
        for i in 0..n {
            vectors[(row, i)] = v[(i, src)];
        }
    }
    EigenDecomposition { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::eigh;
    use crate::linalg::matmul;
    use crate::util::Rng;

    #[test]
    fn agrees_with_ql_on_random_matrices() {
        for &n in &[2, 5, 17, 48] {
            let mut rng = Rng::new(n as u64);
            let mut a = Matrix::from_fn(n, n, |_, _| rng.normal());
            a.symmetrize();
            let jd = eigh_jacobi(&a).unwrap();
            let qd = eigh(&a).unwrap();
            for (x, y) in jd.values.iter().zip(&qd.values) {
                assert!((x - y).abs() < 1e-7 * (1.0 + a.max_abs()), "{x} vs {y}");
            }
            // eigenvectors agree up to sign
            for k in 0..n {
                let dot: f64 = jd.vectors.row(k).iter().zip(qd.vectors.row(k)).map(|(a, b)| a * b).sum();
                assert!(dot.abs() > 1.0 - 1e-5 || (jd.values[k] - jd.values.get(k + 1).copied().unwrap_or(f64::NEG_INFINITY)).abs() < 1e-6,
                    "vector {k} mismatch: |dot|={}", dot.abs());
            }
        }
    }

    #[test]
    fn agrees_on_gram_matrices() {
        let mut rng = Rng::new(99);
        let y = Matrix::from_fn(50, 20, |_, _| rng.normal());
        let a = matmul(&y.transpose(), &y);
        let jd = eigh_jacobi(&a).unwrap();
        let qd = eigh(&a).unwrap();
        for (x, y) in jd.values.iter().zip(&qd.values) {
            assert!((x - y).abs() < 1e-6 * (1.0 + a.max_abs()));
        }
    }

    #[test]
    fn identity_has_unit_eigenvalues() {
        let dec = eigh_jacobi(&Matrix::identity(6)).unwrap();
        for v in &dec.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }
}
