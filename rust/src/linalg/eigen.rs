//! Symmetric eigendecomposition: Householder tridiagonalization (`tred2`)
//! followed by implicit-shift QL iteration (`tqli`).
//!
//! This is the paper's §2 eigendecomposition of the activation covariance,
//! implemented natively so the ROM pass needs no GPU, no BLAS/LAPACK and no
//! Python at runtime. The classic EISPACK-lineage algorithms are used;
//! [`super::jacobi`] provides an independent oracle the tests cross-check
//! against.

use anyhow::{bail, Result};

use super::matrix::Matrix;

/// Result of [`eigh`]: eigenpairs sorted by **descending** eigenvalue
/// (ROM keeps the top-r — descending is the natural order here).
#[derive(Debug, Clone)]
pub struct EigenDecomposition {
    /// Eigenvalues, descending.
    pub values: Vec<f64>,
    /// Row `i` is the unit eigenvector of `values[i]` — i.e. the matrix is
    /// `Vᵀ` in the paper's notation: `principal_components.top_rows(r)` is
    /// exactly `V_r ∈ R^{r×d}`.
    pub vectors: Matrix,
}

impl EigenDecomposition {
    /// Reconstruct `A = Vᵀ Λ V` (for tests / reconstruction error).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.values.len();
        let mut out = Matrix::zeros(n, n);
        for k in 0..n {
            let lam = self.values[k];
            let v = self.vectors.row(k);
            for i in 0..n {
                let li = lam * v[i];
                for j in 0..n {
                    out[(i, j)] += li * v[j];
                }
            }
        }
        out
    }
}

/// Eigendecomposition of a symmetric matrix.
///
/// The input is symmetrized defensively (covariance accumulation can leave
/// ~1e-7 asymmetry). Fails if the input carries non-finite entries (e.g. a
/// covariance poisoned by overflowing activations — QL would spin or the
/// sort would be meaningless on NaN) or if QL does not converge in 50
/// sweeps per eigenvalue, which for real covariance matrices does not
/// happen.
pub fn eigh(a: &Matrix) -> Result<EigenDecomposition> {
    assert_eq!(a.rows(), a.cols(), "eigh: square matrix required");
    let n = a.rows();
    if n == 0 {
        return Ok(EigenDecomposition { values: vec![], vectors: Matrix::zeros(0, 0) });
    }
    if let Some(bad) = a.data().iter().find(|x| !x.is_finite()) {
        bail!("eigh: input contains non-finite entry {bad} (overflowing covariance?)");
    }
    let mut q = a.clone();
    q.symmetrize();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut q, &mut d, &mut e);
    tqli(&mut d, &mut e, &mut q)?;

    // q columns are eigenvectors; sort descending and emit rows.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[j].total_cmp(&d[i]));
    let values: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (row, &src) in order.iter().enumerate() {
        for i in 0..n {
            vectors[(row, i)] = q[(i, src)];
        }
    }
    Ok(EigenDecomposition { values, vectors })
}

/// Householder reduction of a real symmetric matrix to tridiagonal form.
///
/// On exit `a` holds the orthogonal transformation matrix `Q` (columns),
/// `d` the diagonal and `e[1..]` the sub-diagonal. 0-indexed port of the
/// EISPACK/NR `tred2`.
fn tred2(a: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = a.rows();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += a[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = a[(i, l)];
            } else {
                for k in 0..=l {
                    a[(i, k)] /= scale;
                    h += a[(i, k)] * a[(i, k)];
                }
                let mut f = a[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    a[(j, i)] = a[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a[(j, k)] * a[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += a[(k, j)] * a[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * a[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = a[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let delta = f * e[k] + g * a[(i, k)];
                        a[(j, k)] -= delta;
                    }
                }
            }
        } else {
            e[i] = a[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += a[(i, k)] * a[(k, j)];
                }
                for k in 0..i {
                    let delta = g * a[(k, i)];
                    a[(k, j)] -= delta;
                }
            }
        }
        d[i] = a[(i, i)];
        a[(i, i)] = 1.0;
        for j in 0..i {
            a[(j, i)] = 0.0;
            a[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL on a tridiagonal matrix, accumulating eigenvectors
/// into `z` (which enters as the `tred2` transformation).
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut Matrix) -> Result<()> {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find the boundary of the unreduced block
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                bail!("tqli: no convergence for eigenvalue {l} after 50 iterations");
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.abs().copysign(if g >= 0.0 { 1.0 } else { -1.0 }));
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate the rotation into the eigenvector matrix
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::util::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::from_fn(n, n, |_, _| rng.normal());
        m.symmetrize();
        m
    }

    fn random_covariance(n: usize, samples: usize, seed: u64) -> Matrix {
        // Gram matrix of random samples — what ROM actually decomposes.
        let mut rng = Rng::new(seed);
        let y = Matrix::from_fn(samples, n, |_, _| rng.normal());
        matmul(&y.transpose(), &y)
    }

    fn check_eigen(a: &Matrix, tol: f64) {
        let n = a.rows();
        let dec = eigh(a).unwrap();
        // A v = λ v for every pair
        for k in 0..n {
            let v = dec.vectors.row(k).to_vec();
            let av = a.matvec(&v);
            for i in 0..n {
                assert!(
                    (av[i] - dec.values[k] * v[i]).abs() < tol * (1.0 + a.max_abs()),
                    "eigenpair {k}: residual {} vs tol", (av[i] - dec.values[k] * v[i]).abs()
                );
            }
        }
        // descending order
        for w in dec.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
        // orthonormal rows
        for i in 0..n {
            for j in i..n {
                let dot: f64 = dec.vectors.row(i).iter().zip(dec.vectors.row(j)).map(|(a, b)| a * b).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-8, "orthonormality ({i},{j}): {dot}");
            }
        }
        // reconstruction
        let rec = dec.reconstruct();
        assert!(rec.sub(a).max_abs() < tol * 10.0 * (1.0 + a.max_abs()), "reconstruction");
    }

    #[test]
    fn diagonal_matrix() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [3.0, -1.0, 7.0, 0.5].iter().enumerate() {
            a[(i, i)] = *v;
        }
        let dec = eigh(&a).unwrap();
        assert!((dec.values[0] - 7.0).abs() < 1e-12);
        assert!((dec.values[3] - -1.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let dec = eigh(&a).unwrap();
        assert!((dec.values[0] - 3.0).abs() < 1e-12);
        assert!((dec.values[1] - 1.0).abs() < 1e-12);
        // eigenvector of 3 is (1,1)/√2 up to sign
        let v = dec.vectors.row(0);
        assert!((v[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
        assert!((v[0] - v[1]).abs() < 1e-10);
    }

    #[test]
    fn random_symmetric_sizes() {
        for &n in &[1, 2, 3, 5, 16, 33, 64] {
            check_eigen(&random_symmetric(n, n as u64), 1e-8);
        }
    }

    #[test]
    fn covariance_matrices_are_psd() {
        for &n in &[8, 32, 96] {
            let a = random_covariance(n, 4 * n, n as u64 + 100);
            let dec = eigh(&a).unwrap();
            assert!(dec.values.iter().all(|&l| l > -1e-6), "PSD violated");
            check_eigen(&a, 1e-7);
        }
    }

    #[test]
    fn rank_deficient_covariance() {
        // fewer samples than dims -> exactly (n - samples) zero eigenvalues
        let n = 24;
        let samples = 10;
        let a = random_covariance(n, samples, 7);
        let dec = eigh(&a).unwrap();
        let zeros = dec.values.iter().filter(|&&l| l.abs() < 1e-6).count();
        assert_eq!(zeros, n - samples);
    }

    #[test]
    fn trace_equals_eigenvalue_sum() {
        let a = random_symmetric(40, 11);
        let dec = eigh(&a).unwrap();
        let trace: f64 = (0..40).map(|i| a[(i, i)]).sum();
        let sum: f64 = dec.values.iter().sum();
        assert!((trace - sum).abs() < 1e-8);
    }

    #[test]
    fn non_finite_input_is_a_clean_error() {
        // a NaN/Inf sneaking into the covariance must surface as Err, not
        // as a panic in the descending sort
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut a = random_symmetric(6, 42);
            a[(2, 4)] = bad;
            a[(4, 2)] = bad;
            let err = eigh(&a).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{bad}: {err}");
        }
    }

    #[test]
    fn repeated_eigenvalues() {
        // 2·I plus rank-1: eigenvalues {2+n, 2, 2, …}
        let n = 10;
        let mut a = Matrix::identity(n).scale(2.0);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] += 1.0;
            }
        }
        let dec = eigh(&a).unwrap();
        assert!((dec.values[0] - (2.0 + n as f64)).abs() < 1e-9);
        for k in 1..n {
            assert!((dec.values[k] - 2.0).abs() < 1e-9);
        }
        check_eigen(&a, 1e-8);
    }
}
