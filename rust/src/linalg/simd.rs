//! Portable SIMD-style microkernels, cache-aware weight packing, an int8
//! quantized weight form, and the shared rope table — the compute layer of
//! the serving hot path (PR 9).
//!
//! Nothing here uses `std::simd` or intrinsics: every kernel is written as
//! fixed-width lane loops ([`LANES`]-wide f32, [`MS_LANES`]-wide f64) over
//! `chunks_exact`, which the compiler autovectorizes into packed mul/adds
//! while the crate stays portable and dependency-free.
//!
//! # Determinism contract (the PR-4 bar)
//!
//! Every kernel reduces in one **fixed lane order**: element `i` of a
//! reduction accumulates into lane `i % LANES`, tail elements fold into
//! their lane positions, and the lane accumulators collapse through one
//! fixed reduction tree ([`reduce_lanes`]). Consequences, each asserted by
//! tests here and in `tests/proptests.rs`:
//!
//! - results are bit-for-bit reproducible and — because the `par_*` twins
//!   row-shard over the same serial kernels — identical for any
//!   `--threads`;
//! - [`dot_f32`] is bitwise equal to its scalar lane-order emulation
//!   [`dot_f32_ref`] on every input, so "vectorized" is a pure layout
//!   transform, not a numerics change;
//! - the packed kernel is bitwise equal to the unpacked blocked kernel:
//!   [`PackedWeight`] panels pad with zeros, and a lane accumulator can
//!   never be `-0.0` (it starts at `+0.0` and IEEE-754 round-to-nearest
//!   addition of `±0.0` or of cancelling values yields `+0.0`), so
//!   `acc + x·0.0 == acc` bitwise and padding is a no-op.
//!
//! The int8 kernels ([`QuantizedWeight`]) share the lane discipline — they
//! are just as deterministic and thread-invariant — but approximate the
//! f32 weights by construction: consumers hold them to a **stated
//! tolerance** of the f32 factored path (`repro serve --self-check`),
//! never to bitwise equality, and the mode that uses them
//! (`serve::ExecMode::FactoredQuant`) is only ever selected explicitly.

use std::sync::RwLock;

use crate::exec::ExecPool;

use super::matmul::{BLOCK, PAR_MIN_MACS};

/// f32 lane width of the dot/axpy/matmul kernels (8 × f32 = one 256-bit
/// register; narrower ISAs split the lane array into two 128-bit halves
/// without changing results — the lane *order* is what's fixed).
pub const LANES: usize = 8;

/// f64 lane width of the mean-square reduction in [`rmsnorm`].
pub const MS_LANES: usize = 4;

/// Rows per [`PackedWeight`] panel (one output-register strip).
pub const PANEL_ROWS: usize = 4;

/// Collapse the 8 f32 lane accumulators through the fixed reduction tree.
#[inline(always)]
fn reduce_lanes(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// 8-lane dot product in the fixed lane-reduction order. Bitwise equal to
/// [`dot_f32_ref`] on every input (the tail of a `chunks_exact` main loop
/// starts at a multiple of `LANES`, so tail element `l` lands in lane `l`
/// exactly as `i % LANES` assigns it).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ac = a.chunks_exact(LANES);
    let bc = b.chunks_exact(LANES);
    let (ar, br) = (ac.remainder(), bc.remainder());
    for (ax, bx) in ac.zip(bc) {
        for l in 0..LANES {
            acc[l] += ax[l] * bx[l];
        }
    }
    for (l, (x, y)) in ar.iter().zip(br).enumerate() {
        acc[l] += x * y;
    }
    reduce_lanes(acc)
}

/// Scalar emulation of [`dot_f32`]'s exact lane order — the oracle the
/// bitwise proptests pin the vectorized kernel against.
pub fn dot_f32_ref(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        acc[i % LANES] += x * y;
    }
    reduce_lanes(acc)
}

/// `y += alpha·x`, 8-wide unrolled. Purely elementwise — no cross-element
/// reduction — so unrolling cannot reorder anything: bitwise equal to the
/// naive loop by construction.
#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let split = x.len() - x.len() % LANES;
    let (xm, xr) = x.split_at(split);
    let (ym, yr) = y.split_at_mut(split);
    for (yx, xx) in ym.chunks_exact_mut(LANES).zip(xm.chunks_exact(LANES)) {
        for l in 0..LANES {
            yx[l] += alpha * xx[l];
        }
    }
    for (yv, &xv) in yr.iter_mut().zip(xr) {
        *yv += alpha * xv;
    }
}

/// 4-lane f64 mean of squares with the fixed reduction
/// `((l0+l1)+(l2+l3)) / n`.
#[inline]
pub fn mean_square(row: &[f32]) -> f64 {
    let mut acc = [0.0f64; MS_LANES];
    let rc = row.chunks_exact(MS_LANES);
    let rem = rc.remainder();
    for chunk in rc {
        for l in 0..MS_LANES {
            let v = chunk[l] as f64;
            acc[l] += v * v;
        }
    }
    for (l, &v) in rem.iter().enumerate() {
        let v = v as f64;
        acc[l] += v * v;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) / row.len() as f64
}

/// RMSNorm over the last axis: the [`mean_square`] lane reduction in f64,
/// then the exact pre-vectorization normalize expression per element —
/// `out[j] = (x[j] as f64 · inv_rms) as f32 · gain[j]`. Deterministic and
/// row-independent (safe to row-shard).
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f64, out: &mut [f32]) {
    let d = gain.len();
    debug_assert_eq!(x.len() % d, 0);
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let inv = 1.0 / (mean_square(row) + eps).sqrt();
        for j in 0..d {
            orow[j] = (row[j] as f64 * inv) as f32 * gain[j];
        }
    }
}

// ---------------------------------------------------------------------------
// Rope table: precomputed inverse frequencies + per-position sin/cos band.

/// Cached rotary-embedding table for one `(head_dim, theta)` band.
///
/// The closed-form rope (`model::reference::apply_rope`) recomputes
/// `theta.powf(…)` and `sin_cos` for every `(position, pair)` on every
/// call; this table computes the `hd/2` inverse frequencies once at
/// construction and grows a per-position sin/cos band on demand
/// ([`RopeTable::ensure`]), shared by every forward through one
/// `ServeModel` (and by the reference model). Applying the table is
/// **bitwise identical** to the closed-form path: the cached values are
/// produced by the *same* f64 expressions, and the rotation itself is
/// elementwise per `(t, head, pair)`, so neither caching nor the changed
/// loop order can perturb a bit.
#[derive(Debug)]
pub struct RopeTable {
    hd: usize,
    /// Rotated pairs per head row (`hd / 2`).
    pairs: usize,
    /// `1 / theta^(2i/hd)` per pair — the exact `apply_rope` expression.
    inv_freq: Vec<f64>,
    /// Interleaved `(sin, cos)` per `(pos, pair)`: stride `2·pairs` per
    /// position. Grown under a write lock; steady-state forwards only
    /// take the read lock (prewarm via [`RopeTable::ensure`] to keep the
    /// hot path allocation- and contention-free).
    band: RwLock<Vec<f64>>,
}

impl RopeTable {
    pub fn new(hd: usize, theta: f64) -> RopeTable {
        let pairs = hd / 2;
        let inv_freq = (0..pairs).map(|i| 1.0 / theta.powf(2.0 * i as f64 / hd as f64)).collect();
        RopeTable { hd, pairs, inv_freq, band: RwLock::new(Vec::new()) }
    }

    pub fn head_dim(&self) -> usize {
        self.hd
    }

    /// Grow the cached band to cover absolute positions `< pos_end`.
    /// Idempotent and monotone; call once with the KV-cache capacity to
    /// prewarm, after which [`RopeTable::apply_qk`] never writes.
    pub fn ensure(&self, pos_end: usize) {
        let stride = 2 * self.pairs;
        let need = pos_end * stride;
        if need == 0 || self.band.read().expect("rope table poisoned").len() >= need {
            return;
        }
        let mut band = self.band.write().expect("rope table poisoned");
        let mut pos = band.len() / stride;
        band.reserve(need.saturating_sub(band.len()));
        while pos < pos_end {
            for &f in &self.inv_freq {
                let (sin, cos) = (pos as f64 * f).sin_cos();
                band.push(sin);
                band.push(cos);
            }
            pos += 1;
        }
    }

    /// Rotate full-width `(seq, d)` q/k buffers in place, head by head,
    /// at absolute positions `pos0..pos0+seq` — the strided,
    /// allocation-free replacement for the per-head copy loops the old
    /// `rope_qk` ran. Bitwise identical to `apply_rope` over each head
    /// slice.
    pub fn apply_qk(&self, q: &mut [f32], k: &mut [f32], seq: usize, d: usize, nh: usize, pos0: usize) {
        let hd = d / nh;
        debug_assert_eq!(hd, self.hd, "rope table built for head_dim {}, applied at {hd}", self.hd);
        let stride = 2 * self.pairs;
        if stride == 0 || seq == 0 {
            return;
        }
        self.ensure(pos0 + seq);
        let band = self.band.read().expect("rope table poisoned");
        for t in 0..seq {
            let pb = &band[(pos0 + t) * stride..(pos0 + t + 1) * stride];
            for h in 0..nh {
                let at = t * d + h * hd;
                rotate_pairs(&mut q[at..at + hd], pb);
                rotate_pairs(&mut k[at..at + hd], pb);
            }
        }
    }
}

/// Rotate one head row by its position's `(sin, cos)` band — f64
/// arithmetic, the exact `apply_rope` rotation expression.
#[inline]
fn rotate_pairs(row: &mut [f32], band: &[f64]) {
    for i in 0..row.len() / 2 {
        let (sin, cos) = (band[2 * i], band[2 * i + 1]);
        let a = row[2 * i] as f64;
        let b = row[2 * i + 1] as f64;
        row[2 * i] = (a * cos - b * sin) as f32;
        row[2 * i + 1] = (a * sin + b * cos) as f32;
    }
}

// ---------------------------------------------------------------------------
// Cache-aware weight packing.

/// Cache-aware packed `Wᵀ` layout for the blocked transposed matmul.
///
/// The unpacked kernel reads `b` rows at stride `k` — each output column
/// touches a new cache line per k-block. Packing rewrites the weight once
/// (at `ServeModel::from_artifact`) into panel-major form: panels of
/// [`PANEL_ROWS`] weight rows, each padded to a [`LANES`] multiple,
/// interleaved by lane chunk — so the packed kernel streams one
/// contiguous panel front to back per `(input row, k-block)` pass.
///
/// Padding is all zeros, which the fixed-order lane accumulators ignore
/// bitwise (see the module doc), so [`matmul_transb_packed_into`] is
/// bit-for-bit equal to the unpacked blocked kernel — asserted by tests
/// here and in `tests/proptests.rs`.
#[derive(Debug, Clone)]
pub struct PackedWeight {
    /// `ceil(n/PANEL_ROWS)` panels × `PANEL_ROWS·k_pad` values. Within a
    /// panel, chunk `c` holds lanes `c·LANES..(c+1)·LANES` of rows
    /// `0..PANEL_ROWS` back to back; panel rows past `n` are zero.
    data: Vec<f32>,
    n: usize,
    k: usize,
    k_pad: usize,
}

impl PackedWeight {
    /// Pack a row-major `(n, k)` weight (the `b` operand of `y = x·Wᵀ`).
    pub fn pack(w: &[f32], n: usize, k: usize) -> PackedWeight {
        assert_eq!(w.len(), n * k, "packed weight shape mismatch");
        let k_pad = k.div_ceil(LANES) * LANES;
        let mut data = vec![0.0f32; n.div_ceil(PANEL_ROWS) * PANEL_ROWS * k_pad];
        for j in 0..n {
            let (p, r) = (j / PANEL_ROWS, j % PANEL_ROWS);
            let row = &w[j * k..(j + 1) * k];
            let panel = &mut data[p * PANEL_ROWS * k_pad..(p + 1) * PANEL_ROWS * k_pad];
            for (c, chunk) in row.chunks(LANES).enumerate() {
                let at = (c * PANEL_ROWS + r) * LANES;
                panel[at..at + chunk.len()].copy_from_slice(chunk);
            }
        }
        PackedWeight { data, n, k, k_pad }
    }

    /// Output dim (`n` of `y = x·Wᵀ`).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Reduction dim.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Resident bytes of the packed mirror, padding included —
    /// observability only; *logical* weight bytes are accounted in
    /// `model::macs::weight_bytes`.
    pub fn resident_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Packed-panel `out += a @ wᵀ` with `out` pre-zeroed by the caller.
///
/// Same k-block partial-sum boundaries as the unpacked blocked kernel
/// (`BLOCK` is a multiple of `LANES`, so element `t`'s lane `t % LANES`
/// is preserved across block starts) and the same per-`(i, j)` left-fold
/// of k-block partials — hence bitwise identical output. Output row `i`
/// depends only on input row `i`, which keeps row sharding exact.
pub fn matmul_transb_packed_into(a: &[f32], w: &PackedWeight, m: usize, out: &mut [f32]) {
    let (k, n, k_pad) = (w.k, w.n, w.k_pad);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(BLOCK % LANES, 0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, panel) in w.data.chunks_exact(PANEL_ROWS * k_pad).enumerate() {
            let j0 = p * PANEL_ROWS;
            let live = PANEL_ROWS.min(n - j0);
            let mut tot = [0.0f32; PANEL_ROWS];
            for k0 in (0..k).step_by(BLOCK) {
                let k1 = (k0 + BLOCK).min(k);
                let full = (k1 - k0) / LANES;
                let rem = (k1 - k0) % LANES;
                let c0 = k0 / LANES;
                let mut acc = [[0.0f32; LANES]; PANEL_ROWS];
                for c in 0..full {
                    let ax = &arow[k0 + c * LANES..k0 + (c + 1) * LANES];
                    let px = &panel[(c0 + c) * PANEL_ROWS * LANES..];
                    for r in 0..PANEL_ROWS {
                        for l in 0..LANES {
                            acc[r][l] += ax[l] * px[r * LANES + l];
                        }
                    }
                }
                if rem > 0 {
                    // Masked a-side tail (the input row really ends at k;
                    // the panel's zero padding would be a bitwise no-op,
                    // but reading `a` past its end would not be).
                    let ax = &arow[k0 + full * LANES..k1];
                    let px = &panel[(c0 + full) * PANEL_ROWS * LANES..];
                    for r in 0..PANEL_ROWS {
                        for (l, &x) in ax.iter().enumerate() {
                            acc[r][l] += x * px[r * LANES + l];
                        }
                    }
                }
                for r in 0..PANEL_ROWS {
                    tot[r] += reduce_lanes(acc[r]);
                }
            }
            for r in 0..live {
                orow[j0 + r] += tot[r];
            }
        }
    }
}

/// Row-sharded [`matmul_transb_packed_into`] over a pre-zeroed `out` —
/// bitwise identical to the serial kernel for any thread count (same
/// fan-out guard as the other `par_*` kernels).
pub fn par_matmul_transb_packed_into(
    a: &[f32],
    w: &PackedWeight,
    m: usize,
    pool: &ExecPool,
    out: &mut [f32],
) {
    let (k, n) = (w.k, w.n);
    assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    if pool.threads() <= 1 || m <= 1 || n == 0 || m * k * n < PAR_MIN_MACS {
        return matmul_transb_packed_into(a, w, m, out);
    }
    pool.parallel_chunks(out, n, |row0, chunk| {
        let rows = chunk.len() / n;
        matmul_transb_packed_into(&a[row0 * k..(row0 + rows) * k], w, rows, chunk);
    });
}

/// Allocating convenience wrapper over [`par_matmul_transb_packed_into`].
pub fn par_matmul_transb_packed(a: &[f32], w: &PackedWeight, m: usize, pool: &ExecPool) -> Vec<f32> {
    let mut out = vec![0.0f32; m * w.n];
    par_matmul_transb_packed_into(a, w, m, pool, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Int8 per-row symmetric quantization.

/// Per-row symmetric int8 quantization of a row-major `(n, k)` weight.
///
/// Row `j` stores `q = round(w / scale_j)` clamped to `[-127, 127]` with
/// `scale_j = max|row_j| / 127` in f32 (an all-zero row gets scale `1.0`
/// and all-zero codes). Rows are padded to a [`LANES`] multiple with zero
/// codes. 4× smaller than f32 and sequentially streamed — the byte side
/// of the accounting lives in `model::macs::weight_bytes`.
#[derive(Debug, Clone)]
pub struct QuantizedWeight {
    q: Vec<i8>,
    /// One f32 dequantization scale per output row.
    scales: Vec<f32>,
    n: usize,
    k: usize,
    k_pad: usize,
}

impl QuantizedWeight {
    pub fn quantize(w: &[f32], n: usize, k: usize) -> QuantizedWeight {
        assert_eq!(w.len(), n * k, "quantized weight shape mismatch");
        let k_pad = k.div_ceil(LANES) * LANES;
        let mut q = vec![0i8; n * k_pad];
        let mut scales = Vec::with_capacity(n);
        for j in 0..n {
            let row = &w[j * k..(j + 1) * k];
            let maxabs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
            for (t, &v) in row.iter().enumerate() {
                q[j * k_pad + t] = (v / scale).round().clamp(-127.0, 127.0) as i8;
            }
            scales.push(scale);
        }
        QuantizedWeight { q, scales, n, k, k_pad }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical payload bytes: one int8 code per weight plus one f32 scale
    /// per row (lane padding excluded — a layout artifact, not payload).
    pub fn logical_bytes(&self) -> u128 {
        (self.n * self.k) as u128 + 4 * self.n as u128
    }

    /// Worst-case absolute quantization error of row `j` per unit of
    /// input magnitude: half a code, i.e. `scale_j / 2`.
    pub fn row_scale(&self, j: usize) -> f32 {
        self.scales[j]
    }
}

/// `out += (a @ qᵀ)·diag(scales)` over a quantized weight (`out`
/// pre-zeroed): per output, one full-k 8-lane f32 pass over the int8
/// codes (`x · (q as f32)`, fixed lane order, single [`reduce_lanes`] —
/// the quantized path is tolerance-checked against f32, never
/// bitwise-matched, so it skips the k-blocked partial sums), then one
/// multiply by the row scale. Row `i` of `out` depends only on row `i`
/// of `a`, so row sharding stays exact.
pub fn matmul_transb_quant_into(a: &[f32], w: &QuantizedWeight, m: usize, out: &mut [f32]) {
    let (k, n, k_pad) = (w.k, w.n, w.k_pad);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let qrow = &w.q[j * k_pad..j * k_pad + k];
            let mut acc = [0.0f32; LANES];
            let ac = arow.chunks_exact(LANES);
            let qc = qrow.chunks_exact(LANES);
            let (ar, qr) = (ac.remainder(), qc.remainder());
            for (ax, qx) in ac.zip(qc) {
                for l in 0..LANES {
                    acc[l] += ax[l] * qx[l] as f32;
                }
            }
            for (l, (&x, &qv)) in ar.iter().zip(qr).enumerate() {
                acc[l] += x * qv as f32;
            }
            *o += w.scales[j] * reduce_lanes(acc);
        }
    }
}

/// Row-sharded [`matmul_transb_quant_into`] over a pre-zeroed `out` —
/// bitwise identical to the serial quant kernel for any thread count.
pub fn par_matmul_transb_quant_into(
    a: &[f32],
    w: &QuantizedWeight,
    m: usize,
    pool: &ExecPool,
    out: &mut [f32],
) {
    let (k, n) = (w.k, w.n);
    assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    if pool.threads() <= 1 || m <= 1 || n == 0 || m * k * n < PAR_MIN_MACS {
        return matmul_transb_quant_into(a, w, m, out);
    }
    pool.parallel_chunks(out, n, |row0, chunk| {
        let rows = chunk.len() / n;
        matmul_transb_quant_into(&a[row0 * k..(row0 + rows) * k], w, rows, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_transb_blocked_f32, matmul_transb_f32};
    use crate::model::reference::apply_rope;
    use crate::util::Rng;

    /// Shapes straddling the lane width and the block edge.
    const DIMS: &[usize] = &[1, 2, 3, 4, 5, 6, 7, 8, 9, 63, 64, 65, 129];

    fn randv(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn dot_is_bitwise_equal_to_lane_order_reference() {
        let mut rng = Rng::new(0x51);
        for &len in DIMS {
            let a = randv(&mut rng, len);
            let b = randv(&mut rng, len);
            let got = dot_f32(&a, &b);
            let want = dot_f32_ref(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {len}: {got} vs {want}");
        }
        assert_eq!(dot_f32(&[], &[]).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn axpy_is_bitwise_equal_to_naive() {
        let mut rng = Rng::new(0x52);
        for &len in DIMS {
            let x = randv(&mut rng, len);
            let mut y = randv(&mut rng, len);
            let mut want = y.clone();
            let alpha = rng.normal() as f32;
            axpy_f32(alpha, &x, &mut y);
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += alpha * xv;
            }
            assert_eq!(y, want, "len {len}");
        }
    }

    #[test]
    fn rmsnorm_matches_sequential_reference_closely() {
        // The lane reduction legitimately reassociates the f64 mean of
        // squares, so this is a tolerance check (the *bitwise* bar applies
        // to same-kernel comparisons, e.g. across thread counts).
        let mut rng = Rng::new(0x53);
        for &d in DIMS {
            let rows = 3;
            let x = randv(&mut rng, rows * d);
            let gain = randv(&mut rng, d);
            let mut got = vec![0.0f32; rows * d];
            rmsnorm(&x, &gain, 1e-5, &mut got);
            for (row, orow) in x.chunks_exact(d).zip(got.chunks_exact(d)) {
                let ms: f64 =
                    row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
                let inv = 1.0 / (ms + 1e-5).sqrt();
                for j in 0..d {
                    let want = (row[j] as f64 * inv) as f32 * gain[j];
                    assert!((orow[j] - want).abs() <= 1e-6, "d {d}: {} vs {want}", orow[j]);
                }
            }
        }
    }

    #[test]
    fn mean_square_matches_lane_order_emulation_bitwise() {
        let mut rng = Rng::new(0x54);
        for &len in DIMS {
            let row = randv(&mut rng, len);
            let mut acc = [0.0f64; MS_LANES];
            for (i, &v) in row.iter().enumerate() {
                let v = v as f64;
                acc[i % MS_LANES] += v * v;
            }
            let want = ((acc[0] + acc[1]) + (acc[2] + acc[3])) / len as f64;
            assert_eq!(mean_square(&row).to_bits(), want.to_bits(), "len {len}");
        }
    }

    #[test]
    fn rope_table_is_bitwise_equal_to_apply_rope() {
        let mut rng = Rng::new(0x55);
        for &(seq, hd, nh, pos0) in
            &[(1usize, 4usize, 2usize, 0usize), (5, 8, 1, 0), (7, 6, 3, 11), (4, 2, 4, 63)]
        {
            let d = hd * nh;
            let theta = 10000.0;
            let table = RopeTable::new(hd, theta);
            let mut q = randv(&mut rng, seq * d);
            let mut k = randv(&mut rng, seq * d);
            // closed-form oracle over explicit per-head copies
            let (mut q_want, mut k_want) = (q.clone(), k.clone());
            for h in 0..nh {
                for buf in [&mut q_want, &mut k_want] {
                    let mut head = vec![0.0f32; seq * hd];
                    for t in 0..seq {
                        head[t * hd..(t + 1) * hd]
                            .copy_from_slice(&buf[t * d + h * hd..t * d + (h + 1) * hd]);
                    }
                    apply_rope(&mut head, seq, hd, pos0, theta);
                    for t in 0..seq {
                        buf[t * d + h * hd..t * d + (h + 1) * hd]
                            .copy_from_slice(&head[t * hd..(t + 1) * hd]);
                    }
                }
            }
            table.apply_qk(&mut q, &mut k, seq, d, nh, pos0);
            assert_eq!(q, q_want, "q: seq {seq} hd {hd} nh {nh} pos0 {pos0}");
            assert_eq!(k, k_want, "k: seq {seq} hd {hd} nh {nh} pos0 {pos0}");
        }
    }

    #[test]
    fn rope_table_grows_incrementally_and_identically() {
        let table = RopeTable::new(8, 10000.0);
        let mut rng = Rng::new(0x56);
        let (seq, d, nh) = (3usize, 8usize, 1usize);
        let mut a_q = randv(&mut rng, seq * d);
        let mut a_k = randv(&mut rng, seq * d);
        let (mut b_q, mut b_k) = (a_q.clone(), a_k.clone());
        // one table grown step by step, a fresh one prewarmed whole
        table.ensure(1);
        table.apply_qk(&mut a_q, &mut a_k, seq, d, nh, 40);
        let fresh = RopeTable::new(8, 10000.0);
        fresh.ensure(64);
        fresh.apply_qk(&mut b_q, &mut b_k, seq, d, nh, 40);
        assert_eq!(a_q, b_q);
        assert_eq!(a_k, b_k);
    }

    #[test]
    fn packed_matmul_is_bitwise_equal_to_blocked() {
        let mut rng = Rng::new(0x57);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (2, 3, 5),
            (1, 8, 4),
            (3, 9, 2),
            (5, 63, 3),
            (4, 64, 7),
            (2, 65, 9),
            (3, 129, 6),
            (9, 70, 63),
            (2, 40, 129),
        ] {
            let a = randv(&mut rng, m * k);
            let w = randv(&mut rng, n * k);
            let packed = PackedWeight::pack(&w, n, k);
            let mut got = vec![0.0f32; m * n];
            matmul_transb_packed_into(&a, &packed, m, &mut got);
            let want = matmul_transb_blocked_f32(&a, &w, m, k, n);
            assert_eq!(got, want, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn par_packed_and_quant_match_serial_bitwise_for_any_thread_count() {
        let mut rng = Rng::new(0x58);
        for &(m, k, n) in &[(1usize, 3usize, 4usize), (33, 17, 65), (96, 64, 64), (129, 70, 40)] {
            let a = randv(&mut rng, m * k);
            let w = randv(&mut rng, n * k);
            let packed = PackedWeight::pack(&w, n, k);
            let quant = QuantizedWeight::quantize(&w, n, k);
            let mut want_p = vec![0.0f32; m * n];
            matmul_transb_packed_into(&a, &packed, m, &mut want_p);
            let mut want_q = vec![0.0f32; m * n];
            matmul_transb_quant_into(&a, &quant, m, &mut want_q);
            for threads in [1usize, 2, 3, 8] {
                let pool = ExecPool::new(threads);
                let mut got_p = vec![0.0f32; m * n];
                par_matmul_transb_packed_into(&a, &packed, m, &pool, &mut got_p);
                assert_eq!(got_p, want_p, "packed {m}x{k}x{n} t{threads}");
                let mut got_q = vec![0.0f32; m * n];
                par_matmul_transb_quant_into(&a, &quant, m, &pool, &mut got_q);
                assert_eq!(got_q, want_q, "quant {m}x{k}x{n} t{threads}");
            }
        }
    }

    #[test]
    fn quantized_matmul_stays_within_the_stated_tolerance() {
        let mut rng = Rng::new(0x59);
        for &(m, k, n) in &[(2usize, 16usize, 8usize), (3, 65, 9), (4, 129, 31)] {
            let a = randv(&mut rng, m * k);
            let w = randv(&mut rng, n * k);
            let quant = QuantizedWeight::quantize(&w, n, k);
            let mut got = vec![0.0f32; m * n];
            matmul_transb_quant_into(&a, &quant, m, &mut got);
            let want = matmul_transb_f32(&a, &w, m, k, n);
            // per-row error bound: k · (scale/2) · max|x| plus f32 slack
            for i in 0..m {
                let xmax = a[i * k..(i + 1) * k].iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
                for j in 0..n {
                    let bound = (k as f32) * (quant.row_scale(j) * 0.5) * xmax + 1e-4;
                    let err = (got[i * n + j] - want[i * n + j]).abs();
                    assert!(err <= bound, "{m}x{k}x{n} ({i},{j}): err {err} bound {bound}");
                }
            }
        }
    }

    #[test]
    fn quantization_handles_zero_rows_and_clamps() {
        let w = vec![0.0f32; 2 * 4];
        let q = QuantizedWeight::quantize(&w, 2, 4);
        let a = vec![1.0f32, -2.0, 3.0, -4.0];
        let mut out = vec![0.0f32; 2];
        matmul_transb_quant_into(&a, &q, 1, &mut out);
        assert_eq!(out, vec![0.0, 0.0], "all-zero rows quantize to exact zero output");
        assert_eq!(q.logical_bytes(), (2 * 4 + 4 * 2) as u128);
        // a row whose max is huge still round-trips codes within ±127
        let w = vec![1e30f32, -1e30, 0.5e30, 1.0];
        let q = QuantizedWeight::quantize(&w, 1, 4);
        let mut out = vec![0.0f32; 1];
        matmul_transb_quant_into(&[1.0, 1.0, 1.0, 0.0], &q, 1, &mut out);
        assert!(out[0].is_finite());
    }

    #[test]
    fn packed_resident_bytes_cover_padding() {
        let w = vec![1.0f32; 5 * 9]; // n=5 → 2 panels of 4, k=9 → k_pad=16
        let p = PackedWeight::pack(&w, 5, 9);
        assert_eq!(p.n(), 5);
        assert_eq!(p.k(), 9);
        assert_eq!(p.resident_bytes(), 2 * PANEL_ROWS * 16 * 4);
    }
}
