//! Rust-owned training loop: executes the AOT `train_step` /
//! `train_step_masked` HLO graphs (AdamW, pure-jnp autodiff path) with the
//! coordinator controlling the schedule. Python never runs here — the
//! gradients were baked into the graph at build time.

use anyhow::{bail, Context, Result};

use crate::data::LmBatch;
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Learning-rate schedule: linear warmup then cosine decay.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub peak: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn lr_at(&self, step: usize) -> f32 {
        if step < self.warmup_steps {
            return self.peak * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let t = (step - self.warmup_steps) as f32
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f32;
        let t = t.min(1.0);
        self.min_lr
            + 0.5 * (self.peak - self.min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// Stateful trainer over the AOT train step.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    cfg: ModelConfig,
    pub params: ParamStore,
    m: ParamStore,
    v: ParamStore,
    step: usize,
    /// Structured-pruning masks (name -> mask tensor) when fine-tuning a
    /// pruned model; triggers the `train_step_masked` graph.
    masks: Option<Vec<Tensor>>,
    pub losses: Vec<f32>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, params: ParamStore) -> Trainer<'rt> {
        let cfg = ModelConfig::from_manifest(&runtime.manifest().model_config);
        let m = ParamStore::zeros(&cfg);
        let v = ParamStore::zeros(&cfg);
        Trainer { runtime, cfg, params, m, v, step: 0, masks: None, losses: Vec::new() }
    }

    /// Enable mask-preserving fine-tuning. `masks` must be one f32 tensor
    /// per maskable matrix, in schema order.
    pub fn with_masks(mut self, masks: Vec<Tensor>) -> Result<Self> {
        let want = self.runtime.manifest().maskable_names.len();
        if masks.len() != want {
            bail!("{} masks given, schema has {want}", masks.len());
        }
        self.masks = Some(masks);
        Ok(self)
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// One optimizer step; returns the batch loss.
    pub fn step(&mut self, batch: &LmBatch, lr: f32) -> Result<f32> {
        let (tb, ts) = (self.cfg.train_batch, self.cfg.train_seq);
        if batch.batch != tb || batch.seq != ts {
            bail!("train batch {}x{} != canonical {tb}x{ts}", batch.batch, batch.seq);
        }
        self.step += 1;
        let step_t = Tensor::scalar_f32(self.step as f32);
        let lr_t = Tensor::scalar_f32(lr);
        let tokens = Tensor::from_i32(&[tb, ts], batch.tokens.clone());
        let targets = Tensor::from_i32(&[tb, ts], batch.targets.clone());

        let mut args: Vec<&Tensor> = Vec::new();
        args.extend(self.params.flat());
        if let Some(masks) = &self.masks {
            args.extend(masks.iter());
        }
        args.extend(self.m.flat());
        args.extend(self.v.flat());
        args.push(&step_t);
        args.push(&lr_t);
        args.push(&tokens);
        args.push(&targets);

        let entry = if self.masks.is_some() { "train_step_masked" } else { "train_step" };
        let mut outs = self.runtime.execute(entry, &args).context("train step")?;

        let loss = outs
            .pop()
            .and_then(|t| t.as_f32().ok().map(|x| x[0]))
            .context("loss output")?;
        let n = self.params.names().len();
        if outs.len() != 3 * n {
            bail!("train step returned {} tensors, want {}", outs.len(), 3 * n);
        }
        let v_new = outs.split_off(2 * n);
        let m_new = outs.split_off(n);
        self.params.set_flat(outs)?;
        self.m.set_flat(m_new)?;
        self.v.set_flat(v_new)?;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Train over a batch list with a schedule; returns final mean loss of
    /// the last `tail` steps.
    pub fn run(
        &mut self,
        batches: &[LmBatch],
        sched: &LrSchedule,
        log_every: usize,
        mut log: impl FnMut(usize, f32, f32),
    ) -> Result<f32> {
        for (i, b) in batches.iter().enumerate() {
            let lr = sched.lr_at(i);
            let loss = self.step(b, lr)?;
            if log_every > 0 && (i % log_every == 0 || i + 1 == batches.len()) {
                log(i, loss, lr);
            }
        }
        let tail = self.losses.len().min(10);
        Ok(self.losses[self.losses.len() - tail..].iter().sum::<f32>() / tail as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let s = LrSchedule { peak: 1e-3, warmup_steps: 10, total_steps: 110, min_lr: 1e-5 };
        assert!(s.lr_at(0) < s.lr_at(5));
        assert!((s.lr_at(9) - 1e-3).abs() < 1e-9);
        assert!(s.lr_at(50) < s.lr_at(10));
        assert!(s.lr_at(109) >= s.min_lr * 0.99);
        assert!(s.lr_at(1000) >= s.min_lr * 0.99); // clamped past the end
    }
}
