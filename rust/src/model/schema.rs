//! Rust mirror of `python/compile/paramschema.py` — the canonical flat
//! parameter ordering. A test asserts this generation rule agrees with the
//! ordering recorded in `manifest.json`, so the two sides cannot drift.

use super::config::ModelConfig;

/// Per-block parameter fields, in canonical order.
pub const BLOCK_FIELDS: [&str; 9] = [
    "attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down",
];

/// The paper's 7 decomposable (and prunable) matrices per module.
pub const MASKABLE_FIELDS: [&str; 7] = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

/// All parameter names in canonical flat order.
pub fn param_names(cfg: &ModelConfig) -> Vec<String> {
    let mut out = Vec::with_capacity(2 + 9 * cfg.n_layers);
    out.push("embed".to_string());
    for i in 0..cfg.n_layers {
        for f in BLOCK_FIELDS {
            out.push(format!("blocks.{i}.{f}"));
        }
    }
    out.push("final_norm".to_string());
    out
}

/// The 9 parameter names of block `i`, in schema order.
pub fn block_field_names(i: usize) -> Vec<String> {
    BLOCK_FIELDS.iter().map(|f| format!("blocks.{i}.{f}")).collect()
}

/// Names of the 7·L decomposable matrices, in flat order.
pub fn maskable_names(cfg: &ModelConfig) -> Vec<String> {
    param_names(cfg)
        .into_iter()
        .filter(|n| {
            n.rsplit('.')
                .next()
                .map(|f| MASKABLE_FIELDS.contains(&f))
                .unwrap_or(false)
        })
        .collect()
}

/// Shape of a parameter by name.
pub fn param_shape(cfg: &ModelConfig, name: &str) -> Vec<usize> {
    let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
    match name {
        "embed" => vec![v, d],
        "final_norm" => vec![d],
        _ => {
            let field = name.rsplit('.').next().unwrap();
            match field {
                "attn_norm" | "ffn_norm" => vec![d],
                "wq" | "wk" | "wv" | "wo" => vec![d, d],
                "w_gate" | "w_up" => vec![f, d],
                "w_down" => vec![d, f],
                other => panic!("unknown param field {other}"),
            }
        }
    }
}

/// Block index of a block-scoped parameter name (`blocks.3.wq` -> 3).
pub fn block_index(name: &str) -> Option<usize> {
    let mut parts = name.split('.');
    if parts.next()? != "blocks" {
        return None;
    }
    parts.next()?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let cfg = ModelConfig::mini();
        assert_eq!(param_names(&cfg).len(), 2 + 9 * cfg.n_layers);
        assert_eq!(maskable_names(&cfg).len(), 7 * cfg.n_layers);
    }

    #[test]
    fn order_starts_and_ends_right() {
        let cfg = ModelConfig::mini();
        let names = param_names(&cfg);
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "blocks.0.attn_norm");
        assert_eq!(names[2], "blocks.0.wq");
        assert_eq!(names.last().unwrap(), "final_norm");
    }

    #[test]
    fn shapes() {
        let cfg = ModelConfig::mini();
        assert_eq!(param_shape(&cfg, "embed"), vec![320, 128]);
        assert_eq!(param_shape(&cfg, "blocks.3.w_gate"), vec![344, 128]);
        assert_eq!(param_shape(&cfg, "blocks.3.w_down"), vec![128, 344]);
        assert_eq!(param_shape(&cfg, "final_norm"), vec![128]);
    }

    #[test]
    fn block_index_parse() {
        assert_eq!(block_index("blocks.5.wq"), Some(5));
        assert_eq!(block_index("embed"), None);
        assert_eq!(block_index("final_norm"), None);
    }

    #[test]
    fn matches_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let m = crate::runtime::Manifest::load(&dir).unwrap();
        let cfg = ModelConfig::from_manifest(&m.model_config);
        assert_eq!(param_names(&cfg), m.param_names);
        assert_eq!(maskable_names(&cfg), m.maskable_names);
        // shapes of forward_logits args match the schema
        let fl = m.entry("forward_logits").unwrap();
        for (spec, name) in fl.args.iter().zip(&m.param_names) {
            assert_eq!(&spec.name, name);
            assert_eq!(spec.shape, param_shape(&cfg, name));
        }
    }
}
