//! In-memory parameter store: named tensors in canonical schema order,
//! checkpointable to `.rtz`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{load_rtz, save_rtz, Tensor, TensorMap};

use super::config::ModelConfig;
use super::schema;

/// Ordered parameter collection for one model instance.
///
/// Compressed models are stored *densely* here (`W_eff = W1·W2`): the HLO
/// graphs take weights as arguments with fixed shapes, so evaluation of a
/// ROM/pruned model reuses the same executables, while [`super::macs`]
/// accounts for the factored/pruned cost analytically. The low-rank factors
/// themselves live in [`crate::rom::RomModel`].
#[derive(Debug, Clone)]
pub struct ParamStore {
    cfg: ModelConfig,
    names: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Load from an `.rtz` checkpoint, validating names and shapes.
    pub fn load(cfg: &ModelConfig, path: impl AsRef<Path>) -> Result<ParamStore> {
        let map = load_rtz(&path).with_context(|| format!("load params {}", path.as_ref().display()))?;
        Self::from_map(cfg, map)
    }

    pub fn from_map(cfg: &ModelConfig, mut map: TensorMap) -> Result<ParamStore> {
        // `__`-prefixed names and `__`-prefixed *segments* are reserved
        // metadata — the compression provenance (`__compress_meta__`) and
        // the per-layer ROM factors (`blocks.N.wq.__w1__`/`.__w2__`)
        // written by `compress::CompressedModel::save`. They are not
        // parameters; any `.rtz` consumer is free to skip them.
        map.retain(|k, _| !k.starts_with("__") && !k.contains(".__"));
        let names = schema::param_names(cfg);
        for name in &names {
            let t = map
                .get(name)
                .with_context(|| format!("checkpoint missing parameter `{name}`"))?;
            let want = schema::param_shape(cfg, name);
            if t.shape() != want.as_slice() {
                bail!("param `{name}`: shape {:?}, schema wants {:?}", t.shape(), want);
            }
        }
        if map.len() != names.len() {
            bail!("checkpoint has {} tensors, schema has {}", map.len(), names.len());
        }
        Ok(ParamStore { cfg: cfg.clone(), names, map })
    }

    /// All-zeros store with the schema's shapes (optimizer state init).
    pub fn zeros(cfg: &ModelConfig) -> ParamStore {
        let names = schema::param_names(cfg);
        let map = names
            .iter()
            .map(|n| (n.clone(), Tensor::zeros_f32(&schema::param_shape(cfg, n))))
            .collect();
        ParamStore { cfg: cfg.clone(), names, map }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        save_rtz(path, &self.map)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("no parameter `{name}`"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        if !self.map.contains_key(name) {
            bail!("unknown parameter `{name}`");
        }
        let want = schema::param_shape(&self.cfg, name);
        if t.shape() != want.as_slice() {
            bail!("set `{name}`: shape {:?}, schema wants {:?}", t.shape(), want);
        }
        self.map.insert(name.to_string(), t);
        Ok(())
    }

    /// Replace from a flat output list in canonical order (train step).
    pub fn set_flat(&mut self, flat: Vec<Tensor>) -> Result<()> {
        if flat.len() != self.names.len() {
            bail!("set_flat: {} tensors for {} params", flat.len(), self.names.len());
        }
        for (name, t) in self.names.clone().iter().zip(flat) {
            self.set(name, t)?;
        }
        Ok(())
    }

    /// Borrow all parameters in canonical flat order (HLO marshalling).
    pub fn flat(&self) -> Vec<&Tensor> {
        self.names.iter().map(|n| &self.map[n]).collect()
    }

    /// Borrow the 9 parameters of block `i` in schema order.
    pub fn block_flat(&self, i: usize) -> Vec<&Tensor> {
        schema::block_field_names(i).iter().map(|n| &self.map[n]).collect()
    }

    /// Total scalar count (sanity vs `cfg.n_params()`).
    pub fn n_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Frobenius distance to another store (test / convergence metric).
    pub fn distance(&self, other: &ParamStore) -> Result<f64> {
        let mut acc = 0.0f64;
        for name in &self.names {
            let a = self.get(name)?.as_f32()?;
            let b = other.get(name)?.as_f32()?;
            for (x, y) in a.iter().zip(b) {
                let d = (*x - *y) as f64;
                acc += d * d;
            }
        }
        Ok(acc.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() }
    }

    #[test]
    fn zeros_matches_schema() {
        let cfg = tiny_cfg();
        let p = ParamStore::zeros(&cfg);
        assert_eq!(p.n_params(), cfg.n_params());
        assert_eq!(p.flat().len(), 2 + 9 * cfg.n_layers);
        assert_eq!(p.block_flat(1).len(), 9);
    }

    #[test]
    fn set_validates_shape() {
        let cfg = tiny_cfg();
        let mut p = ParamStore::zeros(&cfg);
        assert!(p.set("blocks.0.wq", Tensor::zeros_f32(&[8, 8])).is_ok());
        assert!(p.set("blocks.0.wq", Tensor::zeros_f32(&[4, 8])).is_err());
        assert!(p.set("not_a_param", Tensor::zeros_f32(&[8, 8])).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = tiny_cfg();
        let mut p = ParamStore::zeros(&cfg);
        p.set("final_norm", Tensor::from_f32(&[8], vec![1.0; 8])).unwrap();
        let dir = std::env::temp_dir().join(format!("params_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.rtz");
        p.save(&path).unwrap();
        let q = ParamStore::load(&cfg, &path).unwrap();
        assert_eq!(q.get("final_norm").unwrap().as_f32().unwrap(), &[1.0f32; 8][..]);
        assert!((p.distance(&q).unwrap()).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metadata_entries_are_skipped() {
        let cfg = tiny_cfg();
        let p = ParamStore::zeros(&cfg);
        let mut map: TensorMap =
            p.names().iter().map(|n| (n.clone(), p.get(n).unwrap().clone())).collect();
        map.insert("__compress_meta__".into(), Tensor::U8 { shape: vec![2], data: vec![123, 125] });
        // per-layer factor sidecars are metadata too
        map.insert("blocks.0.wq.__w1__".into(), Tensor::zeros_f32(&[8, 2]));
        map.insert("blocks.0.wq.__w2__".into(), Tensor::zeros_f32(&[2, 8]));
        let q = ParamStore::from_map(&cfg, map).unwrap();
        assert_eq!(q.n_params(), cfg.n_params());
        assert!(q.get("__compress_meta__").is_err());
        assert!(q.get("blocks.0.wq.__w1__").is_err());
    }

    #[test]
    fn missing_param_rejected_on_load() {
        let cfg = tiny_cfg();
        let p = ParamStore::zeros(&cfg);
        let mut map: TensorMap = p.names().iter().map(|n| (n.clone(), p.get(n).unwrap().clone())).collect();
        map.remove("blocks.1.wv");
        assert!(ParamStore::from_map(&cfg, map).is_err());
    }
}
