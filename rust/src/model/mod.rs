//! Model-side bookkeeping: configuration, the Rust mirror of the parameter
//! schema, the in-memory parameter store, and #Params/#MACs accounting
//! (the paper's Table 1 columns).

pub mod config;
pub mod macs;
pub mod params;
pub mod reference;
pub mod schema;

pub use config::ModelConfig;
pub use macs::{CompressionAccounting, MacsReport};
pub use params::ParamStore;
pub use reference::{DecoderState, ReferenceModel};
pub use schema::{block_field_names, maskable_names, param_names, param_shape, BLOCK_FIELDS, MASKABLE_FIELDS};
