//! Pure-Rust MiniLLaMA forward pass — an independent implementation of the
//! L2 model over the linalg substrate.
//!
//! Two jobs:
//! 1. **Cross-validation**: the integration suite runs the same weights
//!    through this implementation and through the AOT HLO graphs and
//!    asserts the logits agree — an end-to-end check on the marshalling,
//!    the manifest, and the Pallas kernels at once.
//! 2. **Decoding reference**: incremental decoding with a KV cache
//!    ([`DecoderState`]) — the minimal reference the production decode
//!    subsystem ([`crate::decode`], `repro generate`) is validated
//!    against. Production generation runs over [`crate::serve::ServeModel`]
//!    with [`crate::decode::Sampling`]; [`ReferenceModel::generate`] stays
//!    as the simplest self-contained decode loop.

use anyhow::Result;

use crate::linalg::matmul_transb_f32;
use crate::linalg::simd::{axpy_f32, RopeTable};

use super::config::ModelConfig;
use super::params::ParamStore;

/// RMSNorm over the last axis (matches `kernels/rmsnorm.py`). Shared with
/// the factored-form serving engine ([`crate::serve`]); the implementation
/// is the vectorized lane-reduction kernel in [`crate::linalg::simd`].
pub(crate) fn rmsnorm(x: &[f32], gain: &[f32], eps: f64, out: &mut [f32]) {
    crate::linalg::simd::rmsnorm(x, gain, eps, out);
}

/// Rotary embedding for one (seq, hd) head slice at absolute positions
/// `pos0..pos0+seq` (matches `model.apply_rope`).
pub(crate) fn apply_rope(x: &mut [f32], seq: usize, hd: usize, pos0: usize, theta: f64) {
    for t in 0..seq {
        let row = &mut x[t * hd..(t + 1) * hd];
        let pos = (pos0 + t) as f64;
        for i in 0..hd / 2 {
            let freq = 1.0 / theta.powf(2.0 * i as f64 / hd as f64);
            let (sin, cos) = (pos * freq).sin_cos();
            let a = row[2 * i] as f64;
            let b = row[2 * i + 1] as f64;
            row[2 * i] = (a * cos - b * sin) as f32;
            row[2 * i + 1] = (a * sin + b * cos) as f32;
        }
    }
}

pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Apply rotary embeddings head-by-head to full-width `(seq, d)` q/k
/// buffers at absolute positions `pos0..pos0+seq`. Shared by the
/// reference forward and the serving engine so the two cannot diverge.
/// The work happens in the cached [`RopeTable`] (no per-head temporaries,
/// frequencies computed once) — bitwise identical to the [`apply_rope`]
/// closed form it replaced.
pub(crate) fn rope_qk(
    q: &mut [f32],
    k: &mut [f32],
    seq: usize,
    d: usize,
    nh: usize,
    pos0: usize,
    table: &RopeTable,
) {
    table.apply_qk(q, k, seq, d, nh, pos0);
}

/// Causal softmax attention (f64 score accumulation): `(seq, d)` queries
/// at absolute positions `pos0..pos0+seq` over K/V caches of `pos0+seq`
/// row-major rows. Returns the `(seq, d)` pre-`wo` attention output.
/// Shared by the reference forward and the serving engine.
pub(crate) fn causal_attention(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    seq: usize,
    pos0: usize,
    d: usize,
    nh: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; seq * d];
    let mut scores = vec![0.0f64; pos0 + seq];
    causal_attention_into(q, kc, vc, seq, pos0, d, nh, &mut scores, &mut out);
    out
}

/// [`causal_attention`] over caller-provided buffers — the scratch-arena
/// form: `scores` must hold `pos0 + seq` f64s, `out` arrives pre-zeroed
/// with `seq * d` f32s. The probability-weighted V accumulation runs
/// through the unrolled [`axpy_f32`] (elementwise, so bitwise identical
/// to the naive loop).
#[allow(clippy::too_many_arguments)]
pub(crate) fn causal_attention_into(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    seq: usize,
    pos0: usize,
    d: usize,
    nh: usize,
    scores: &mut [f64],
    out: &mut [f32],
) {
    let hd = d / nh;
    let total = pos0 + seq;
    let scale = 1.0 / (hd as f64).sqrt();
    debug_assert_eq!(out.len(), seq * d);
    debug_assert!(scores.len() >= total);
    for t in 0..seq {
        let t_abs = pos0 + t;
        for head in 0..nh {
            let qrow = &q[t * d + head * hd..t * d + (head + 1) * hd];
            let mut max = f64::NEG_INFINITY;
            for s in 0..=t_abs {
                let krow = &kc[s * d + head * hd..s * d + (head + 1) * hd];
                let dot: f64 =
                    qrow.iter().zip(krow).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
                scores[s] = dot * scale;
                max = max.max(scores[s]);
            }
            let mut z = 0.0f64;
            for s in 0..=t_abs {
                scores[s] = (scores[s] - max).exp();
                z += scores[s];
            }
            let orow = &mut out[t * d + head * hd..t * d + (head + 1) * hd];
            for s in 0..=t_abs {
                let p = (scores[s] / z) as f32;
                let vrow = &vc[s * d + head * hd..s * d + (head + 1) * hd];
                axpy_f32(p, vrow, orow);
            }
        }
    }
}

/// Incremental decoder state: per-block K/V caches, row-major (t, d).
pub struct DecoderState {
    k_cache: Vec<Vec<f32>>,
    v_cache: Vec<Vec<f32>>,
    /// tokens consumed so far
    pub pos: usize,
}

impl DecoderState {
    pub fn new(cfg: &ModelConfig) -> DecoderState {
        DecoderState {
            k_cache: vec![Vec::new(); cfg.n_layers],
            v_cache: vec![Vec::new(); cfg.n_layers],
            pos: 0,
        }
    }
}

/// Pure-Rust reference model bound to a parameter store.
pub struct ReferenceModel<'p> {
    cfg: ModelConfig,
    params: &'p ParamStore,
    /// Cached rope frequencies/sin-cos band shared by every forward.
    rope: RopeTable,
}

impl<'p> ReferenceModel<'p> {
    pub fn new(params: &'p ParamStore) -> ReferenceModel<'p> {
        let cfg = params.config().clone();
        let rope = RopeTable::new(cfg.head_dim(), cfg.rope_theta);
        ReferenceModel { cfg, params, rope }
    }

    fn weight(&self, name: &str) -> Result<&[f32]> {
        self.params.get(name)?.as_f32()
    }

    /// Full-sequence forward: tokens -> (seq, vocab) logits (no cache).
    pub fn forward_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut state = DecoderState::new(&self.cfg);
        self.forward_with_state(tokens, &mut state)
    }

    /// Consume `tokens` (appended after `state.pos`) and return logits for
    /// each consumed position, advancing the KV cache.
    pub fn forward_with_state(&self, tokens: &[i32], state: &mut DecoderState) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, nh) = (cfg.d_model, cfg.n_heads);
        debug_assert_eq!(cfg.head_dim() * nh, d);
        let seq = tokens.len();
        let pos0 = state.pos;

        // embed
        let embed = self.weight("embed")?;
        let mut h = vec![0.0f32; seq * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            anyhow::ensure!(tok < cfg.vocab, "token {tok} out of vocab");
            h[t * d..(t + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }

        let mut buf = vec![0.0f32; seq * d];
        for block in 0..cfg.n_layers {
            let name = |f: &str| format!("blocks.{block}.{f}");
            // ---- attention ----
            rmsnorm(&h, self.weight(&name("attn_norm"))?, cfg.norm_eps, &mut buf);
            let mut q = matmul_transb_f32(&buf, self.weight(&name("wq"))?, seq, d, d);
            let mut k = matmul_transb_f32(&buf, self.weight(&name("wk"))?, seq, d, d);
            let v = matmul_transb_f32(&buf, self.weight(&name("wv"))?, seq, d, d);
            rope_qk(&mut q, &mut k, seq, d, nh, pos0, &self.rope);
            // extend caches, then attend over them
            state.k_cache[block].extend_from_slice(&k);
            state.v_cache[block].extend_from_slice(&v);
            let attn_out = causal_attention(
                &q,
                &state.k_cache[block],
                &state.v_cache[block],
                seq,
                pos0,
                d,
                nh,
            );
            let o = matmul_transb_f32(&attn_out, self.weight(&name("wo"))?, seq, d, d);
            for (hv, ov) in h.iter_mut().zip(&o) {
                *hv += ov;
            }

            // ---- ffn ----
            rmsnorm(&h, self.weight(&name("ffn_norm"))?, cfg.norm_eps, &mut buf);
            let f = cfg.d_ff;
            let gate = matmul_transb_f32(&buf, self.weight(&name("w_gate"))?, seq, d, f);
            let up = matmul_transb_f32(&buf, self.weight(&name("w_up"))?, seq, d, f);
            let act: Vec<f32> = gate.iter().zip(&up).map(|(g, u)| silu(*g) * u).collect();
            let down = matmul_transb_f32(&act, self.weight(&name("w_down"))?, seq, f, d);
            for (hv, dv) in h.iter_mut().zip(&down) {
                *hv += dv;
            }
        }

        // head
        rmsnorm(&h, self.weight("final_norm")?, cfg.norm_eps, &mut buf);
        let logits = matmul_transb_f32(&buf, embed, seq, d, cfg.vocab);
        state.pos = pos0 + seq;
        Ok(logits)
    }

    /// Greedy / temperature sampling with KV cache.
    ///
    /// Returns the generated token ids (not including the prompt).
    pub fn generate(
        &self,
        prompt: &[i32],
        max_new: usize,
        temperature: f32,
        seed: u64,
    ) -> Result<Vec<i32>> {
        let mut state = DecoderState::new(&self.cfg);
        let mut logits = self.forward_with_state(prompt, &mut state)?;
        let v = self.cfg.vocab;
        let mut rng = crate::util::Rng::new(seed);
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let last = &logits[(logits.len() / v - 1) * v..];
            let next = sample(last, temperature, &mut rng);
            out.push(next);
            if next == crate::data::EOS {
                break;
            }
            logits = self.forward_with_state(&[next], &mut state)?;
        }
        Ok(out)
    }
}

/// Sample from logits (greedy when `temperature == 0`). Total-order
/// comparison, so NaN logits select deterministically instead of
/// panicking.
fn sample(logits: &[f32], temperature: f32, rng: &mut crate::util::Rng) -> i32 {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap();
    }
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let probs: Vec<f64> = logits.iter().map(|&x| (((x - max) / temperature) as f64).exp()).collect();
    let z: f64 = probs.iter().sum();
    let mut r = rng.f64() * z;
    for (i, p) in probs.iter().enumerate() {
        r -= p;
        if r <= 0.0 {
            return i as i32;
        }
    }
    (logits.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::schema;
    use crate::tensor::{Tensor, TensorMap};
    use crate::util::Rng;

    fn tiny_params() -> ParamStore {
        let cfg = ModelConfig {
            vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12,
            ..ModelConfig::mini()
        };
        let mut rng = Rng::new(0);
        let map: TensorMap = schema::param_names(&cfg)
            .into_iter()
            .map(|n| {
                let shape = schema::param_shape(&cfg, &n);
                let len: usize = shape.iter().product();
                let data: Vec<f32> = if shape.len() == 1 {
                    vec![1.0; len]
                } else {
                    (0..len).map(|_| rng.normal() as f32 * 0.1).collect()
                };
                (n, Tensor::from_f32(&shape, data))
            })
            .collect();
        ParamStore::from_map(&cfg, map).unwrap()
    }

    #[test]
    fn incremental_matches_full_forward() {
        let params = tiny_params();
        let model = ReferenceModel::new(&params);
        let tokens = [1i32, 5, 3, 7, 2, 9];
        let full = model.forward_logits(&tokens).unwrap();

        // feed one token at a time through the cache
        let mut state = DecoderState::new(params.config());
        let mut inc = Vec::new();
        for &t in &tokens {
            inc.extend(model.forward_with_state(&[t], &mut state).unwrap());
        }
        assert_eq!(full.len(), inc.len());
        for (a, b) in full.iter().zip(&inc) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn chunked_prefill_matches() {
        let params = tiny_params();
        let model = ReferenceModel::new(&params);
        let tokens = [4i32, 2, 11, 1, 8, 6, 3, 13];
        let full = model.forward_logits(&tokens).unwrap();
        let mut state = DecoderState::new(params.config());
        let mut inc = Vec::new();
        inc.extend(model.forward_with_state(&tokens[..3], &mut state).unwrap());
        inc.extend(model.forward_with_state(&tokens[3..], &mut state).unwrap());
        for (a, b) in full.iter().zip(&inc) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let params = tiny_params();
        let model = ReferenceModel::new(&params);
        let a = model.generate(&[1, 2, 3], 8, 0.0, 0).unwrap();
        let b = model.generate(&[1, 2, 3], 8, 0.0, 99).unwrap();
        assert_eq!(a, b);
        assert!(a.len() <= 8);
        assert!(a.iter().all(|&t| (t as usize) < params.config().vocab));
    }

    #[test]
    fn sampling_respects_distribution_support() {
        let mut rng = Rng::new(1);
        let mut logits = vec![-1e9f32; 10];
        logits[3] = 0.0;
        logits[7] = 0.0;
        for _ in 0..50 {
            let s = sample(&logits, 1.0, &mut rng);
            assert!(s == 3 || s == 7);
        }
        // greedy tie-break: max_by keeps the last of equal maxima
        assert_eq!(sample(&logits, 0.0, &mut rng), 7);
    }

    #[test]
    fn rejects_out_of_vocab_tokens() {
        let params = tiny_params();
        let model = ReferenceModel::new(&params);
        assert!(model.forward_logits(&[999]).is_err());
    }
}
