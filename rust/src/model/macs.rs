//! #Params / #MACs accounting — the cost columns of the paper's Table 1.
//!
//! The paper reports 6.7B params / 423.93G MACs for dense LLaMA-7B, which
//! corresponds to a ~64-token forward (1 MAC per weight per token plus
//! attention). We mirror that: MACs are reported for a forward over
//! `macs_tokens` tokens so compressed/dense *ratios* are directly
//! comparable with the paper's.

use std::collections::BTreeMap;

use super::config::ModelConfig;
use super::schema;

/// How a single weight matrix is executed after compression.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerCompression {
    /// Untouched dense `d_out × d_in`.
    Dense,
    /// ROM factored pair `W1 (d_out×r)`, `W2 (r×d_in)`.
    LowRank { rank: usize },
    /// Structured pruning: `kept_out` of the output channels remain (input
    /// dim unchanged — the consumer matrix accounts its own input cut).
    PrunedOut { kept_out: usize },
    /// Structured pruning on the input side (consumer of a pruned producer).
    PrunedIn { kept_in: usize },
}

/// Per-model compression state used for accounting.
#[derive(Debug, Clone, Default)]
pub struct CompressionAccounting {
    /// name -> compression of that matrix; missing names are Dense.
    pub layers: BTreeMap<String, LayerCompression>,
}

impl CompressionAccounting {
    pub fn dense() -> Self {
        Self::default()
    }

    pub fn set(&mut self, name: &str, c: LayerCompression) {
        self.layers.insert(name.to_string(), c);
    }

    fn params_of(&self, name: &str, d_out: usize, d_in: usize) -> usize {
        match self.layers.get(name).copied().unwrap_or(LayerCompression::Dense) {
            LayerCompression::Dense => d_out * d_in,
            LayerCompression::LowRank { rank } => rank * (d_out + d_in),
            LayerCompression::PrunedOut { kept_out } => kept_out * d_in,
            LayerCompression::PrunedIn { kept_in } => d_out * kept_in,
        }
    }
}

/// Cost report for one model variant.
#[derive(Debug, Clone, PartialEq)]
pub struct MacsReport {
    pub n_params: usize,
    /// Multiply-accumulates for a forward pass over `tokens` tokens.
    pub macs: u128,
    pub tokens: usize,
}

impl MacsReport {
    pub fn params_billions(&self) -> f64 {
        self.n_params as f64 / 1e9
    }

    pub fn macs_giga(&self) -> f64 {
        self.macs as f64 / 1e9
    }
}

/// The 7 decomposable matrices of a block with their (d_out, d_in).
pub fn block_matrices(cfg: &ModelConfig, block: usize) -> Vec<(String, usize, usize)> {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    schema::MASKABLE_FIELDS
        .iter()
        .map(|field| {
            let (o, i) = match *field {
                "wq" | "wk" | "wv" | "wo" => (d, d),
                "w_gate" | "w_up" => (f, d),
                "w_down" => (d, f),
                _ => unreachable!(),
            };
            (format!("blocks.{block}.{field}"), o, i)
        })
        .collect()
}

/// Compute params + MACs for a model under a compression state.
///
/// MAC model per token: every weight matrix contributes its (factored)
/// parameter count; attention adds `2·T·d_model` per block (QKᵀ and PV);
/// the tied LM head adds `vocab·d_model`; norms/rope are ignored (they are
/// <0.1%). `tokens` is the forward length (paper ≈ 64).
pub fn report(cfg: &ModelConfig, acc: &CompressionAccounting, tokens: usize) -> MacsReport {
    let d = cfg.d_model;
    let mut n_params = cfg.vocab * d + d; // embed (tied head) + final_norm
    let mut macs_per_token: u128 = (cfg.vocab * d) as u128; // head matmul

    for b in 0..cfg.n_layers {
        n_params += 2 * d; // norm gains
        for (name, o, i) in block_matrices(cfg, b) {
            let p = acc.params_of(&name, o, i);
            n_params += p;
            macs_per_token += p as u128;
        }
        // attention scores + weighted values: 2 · T · d per token
        macs_per_token += (2 * tokens * d) as u128;
    }
    MacsReport { n_params, macs: macs_per_token * tokens as u128, tokens }
}

/// MACs to decode one token at absolute position `pos` with a KV cache
/// holding the `pos` previous tokens: every weight matrix contributes its
/// (factored) parameter count once, the tied head adds `vocab·d_model`,
/// and attention adds `2·(pos+1)·d_model` per block (scores over the
/// cached keys + weighted values) — the exact causal cost, which is what
/// [`crate::serve::ServeModel::forward_step`] executes and counts.
pub fn decode_step_macs(cfg: &ModelConfig, acc: &CompressionAccounting, pos: usize) -> u128 {
    // report(·, 1) is one token attending over one key; a cached step at
    // position `pos` attends over `pos` additional keys per block.
    report(cfg, acc, 1).macs + 2 * (pos as u128) * (cfg.d_model as u128) * (cfg.n_layers as u128)
}

/// Cost report for one KV-cached generation: `prompt` prefill tokens, then
/// `generated` sampled tokens (the first comes free with the prefill's
/// last logits, the rest are single-token steps).
///
/// Prefill convention: the scheduler samples only the prompt's final
/// position, so the serving prefill (`ServeModel::forward_prefill`) slices
/// the LM-head matmul to that row — `prefill_macs` bills the `vocab·d`
/// head **once**, while every prompt position still pays its weight and
/// exact causal attention MACs.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeMacsReport {
    pub prompt: usize,
    pub generated: usize,
    /// MACs to consume the prompt through the cache.
    pub prefill_macs: u128,
    /// MACs for the `generated - 1` single-token decode steps.
    pub decode_macs: u128,
    /// Full-recompute baseline: re-forwarding the growing prefix from
    /// scratch for every generated token, in [`report`]'s convention —
    /// what a cache-less server would *bill* (and what
    /// `ServeModel::forward_logits` counts).
    ///
    /// Convention note: `report` bills attention at the paper's
    /// `2·T·d` per token (as if every token attended the full window),
    /// while the cached side bills the exact causal `2·(pos+1)·d` — so
    /// the attention share of [`DecodeMacsReport::savings`] is an upper
    /// bound. Weight and head MACs (the dominant terms) are billed
    /// identically on both sides.
    pub recompute_macs: u128,
}

impl DecodeMacsReport {
    /// Total MACs the KV-cached path executes.
    pub fn cached_macs(&self) -> u128 {
        self.prefill_macs + self.decode_macs
    }

    /// How many times more MACs the recompute baseline costs.
    pub fn savings(&self) -> f64 {
        if self.cached_macs() == 0 {
            1.0
        } else {
            self.recompute_macs as f64 / self.cached_macs() as f64
        }
    }
}

/// Analytic accounting for KV-cached generation under a compression state —
/// the decode-regime companion of [`report`], and what
/// `repro generate --self-check` asserts the decode subsystem actually
/// executed.
pub fn decode_report(
    cfg: &ModelConfig,
    acc: &CompressionAccounting,
    prompt: usize,
    generated: usize,
) -> DecodeMacsReport {
    // last-position-only prefill head: per position, a cached step minus
    // its head; plus one head for the row the scheduler actually samples
    let head = (cfg.vocab * cfg.d_model) as u128;
    let prefill_macs = (0..prompt)
        .map(|p| decode_step_macs(cfg, acc, p) - head)
        .sum::<u128>()
        + if prompt > 0 { head } else { 0 };
    let decode_macs = (0..generated.saturating_sub(1))
        .map(|k| decode_step_macs(cfg, acc, prompt + k))
        .sum();
    let recompute_macs =
        (1..=generated).map(|k| report(cfg, acc, prompt + k - 1).macs).sum();
    DecodeMacsReport { prompt, generated, prefill_macs, decode_macs, recompute_macs }
}

/// One engine round of speculative decoding, as recorded by the decoder:
/// the draft model proposed `drafted` tokens (0 on a degenerate
/// verifier-only round, e.g. the last token before `max_new`), of which
/// the verifier confirmed the first `accepted` (`accepted <= drafted`);
/// the round always also yields the verifier's own bonus token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecRound {
    pub drafted: usize,
    pub accepted: usize,
}

/// Analytic accounting for one speculative generation — the spec-decoding
/// companion of [`decode_report`], and what the speculative self-check /
/// proptests assert the engine actually executed, bit for bit.
///
/// Everything the speculative machinery runs is billed: the draft model's
/// prompt prefill, every draft step (including catch-up positions after a
/// fully-accepted round, where the draft cache lags the verifier by one
/// token), every verifier chunk position (the `drafted + 1` rows of the
/// one batched verify forward), and in particular the *rollback waste* —
/// verifier positions computed past the accepted prefix and then rolled
/// back via `KvCache::truncate_to`. The verifier's own prompt prefill is
/// billed by the ordinary [`decode_report`] prefill convention and is not
/// repeated here.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecMacsReport {
    pub prompt: usize,
    /// Tokens the rounds produced (first prefill-sampled token included).
    pub generated: usize,
    pub rounds: usize,
    pub drafted: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// Draft-model prompt prefill (last-position head, like any prefill).
    pub draft_prefill_macs: u128,
    /// Draft-model decode positions: catch-up chunks + draft steps.
    pub draft_macs: u128,
    /// Verifier chunk positions — every row of every verify forward.
    pub verify_macs: u128,
    /// The subset of `verify_macs` spent on positions past the accepted
    /// prefix and rolled back (`drafted - accepted` rows per round).
    pub wasted_macs: u128,
}

impl SpecMacsReport {
    /// Total MACs the speculative machinery executes beyond the
    /// verifier's own prompt prefill.
    pub fn spec_macs(&self) -> u128 {
        self.draft_prefill_macs + self.draft_macs + self.verify_macs
    }

    /// Fraction of drafted tokens the verifier confirmed.
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Analytic MACs of a speculative generation over `prompt` prefill tokens
/// and the per-round accept trace, under the draft and verifier
/// compression states. Mirrors the executed schedule exactly:
///
/// - round state: `g` tokens produced so far (1 after prefill), canonical
///   position `C = prompt + g - 1`, draft cursor `Cd` (starts at `prompt`
///   after the draft prefill);
/// - draft phase (when `drafted > 0`): one chunk over positions
///   `Cd..=C` (catch-up + the first proposal) then single steps through
///   position `C + drafted - 1` — every position billed at
///   [`decode_step_macs`] under the *draft* accounting;
/// - verify phase: one chunked forward over positions `C..=C + drafted`
///   (`drafted + 1` rows, the last yielding the bonus token) billed at
///   [`decode_step_macs`] under the *verifier* accounting;
/// - acceptance: `g += accepted + 1`; positions past `C + accepted`
///   were wasted; the draft cursor rolls back to `C + accepted + 1`
///   unless the round was fully accepted (then it lags by one and the
///   next round's chunk catches up).
pub fn spec_report(
    cfg: &ModelConfig,
    draft: &CompressionAccounting,
    verifier: &CompressionAccounting,
    prompt: usize,
    rounds: &[SpecRound],
) -> SpecMacsReport {
    let head = (cfg.vocab * cfg.d_model) as u128;
    let draft_prefill_macs = (0..prompt)
        .map(|p| decode_step_macs(cfg, draft, p) - head)
        .sum::<u128>()
        + if prompt > 0 { head } else { 0 };
    let (mut draft_macs, mut verify_macs, mut wasted_macs) = (0u128, 0u128, 0u128);
    let (mut drafted_total, mut accepted_total) = (0usize, 0usize);
    let mut g = 1usize; // the prefill-sampled token
    let mut cd = prompt;
    for r in rounds {
        debug_assert!(r.accepted <= r.drafted, "accepted {} > drafted {}", r.accepted, r.drafted);
        let c = prompt + g - 1;
        if r.drafted > 0 {
            draft_macs +=
                (cd..c + r.drafted).map(|p| decode_step_macs(cfg, draft, p)).sum::<u128>();
            cd = c + r.drafted;
        }
        verify_macs +=
            (c..=c + r.drafted).map(|p| decode_step_macs(cfg, verifier, p)).sum::<u128>();
        wasted_macs += (c + r.accepted + 1..=c + r.drafted)
            .map(|p| decode_step_macs(cfg, verifier, p))
            .sum::<u128>();
        if r.drafted > 0 && r.accepted < r.drafted {
            cd = c + r.accepted + 1;
        }
        drafted_total += r.drafted;
        accepted_total += r.accepted;
        g += r.accepted + 1;
    }
    SpecMacsReport {
        prompt,
        generated: g,
        rounds: rounds.len(),
        drafted: drafted_total,
        accepted: accepted_total,
        rejected: drafted_total - accepted_total,
        draft_prefill_macs,
        draft_macs,
        verify_macs,
        wasted_macs,
    }
}

/// Declared cost of one inference request, priced *before* it runs — the
/// currency of the engine's weight-metered admission (ROADMAP item 3:
/// Substrate's benchmarked-weights design transplanted to inference).
/// MAC totals are exact under the same conventions as [`decode_report`]:
/// a Generate request's `total_macs()` equals
/// `decode_report(cfg, acc, prompt, worst_new).cached_macs()` and a Score
/// request's equals `report(cfg, acc, tokens).macs`, bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestCost {
    /// MACs to consume the prompt (Score: the full forward).
    pub prefill_macs: u128,
    /// Worst-case decode MACs — every allowed token is generated, none of
    /// them EOS (0 for Score).
    pub decode_macs: u128,
    /// Peak KV-cache footprint at full length: `(prompt + worst_new)`
    /// positions × `n_layers` × K,V × `d_model` f32 (0 for Score).
    pub kv_bytes: u128,
}

impl RequestCost {
    /// The scheduler's metering unit: prefill plus worst-case decode.
    pub fn total_macs(&self) -> u128 {
        self.prefill_macs + self.decode_macs
    }
}

/// Closed-form request pricer: four integers distilled from a model config
/// and its per-token MAC unit, enough to price any request exactly.
///
/// Two construction paths produce the identical pricer: the engine builds
/// it from the unit its serve model already counts
/// (`ServeModel::macs_for(1)`), the self-checks from the compression
/// accounting table ([`CostModel::from_accounting`]) — agreement between
/// the two is exactly the "metered totals == analytic sums" bar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostModel {
    /// MACs of one single-token forward: `report(cfg, acc, 1).macs`.
    unit: u128,
    /// LM-head share of the unit: `vocab · d_model`.
    head: u128,
    /// Per cached position attended: `2 · d_model · n_layers`.
    attn: u128,
    /// KV bytes one position occupies: `n_layers · 2 · d_model · 4`.
    kv_token_bytes: u128,
}

/// Exact triangular number `0 + 1 + … + (n-1)` in u128.
fn tri(n: u128) -> u128 {
    n * n.saturating_sub(1) / 2
}

impl CostModel {
    /// Build from a config and the model's measured single-token MAC unit
    /// (must equal `report(cfg, acc, 1).macs` for the model's compression
    /// state — `ServeModel::macs_for(1)` is asserted to).
    pub fn new(cfg: &ModelConfig, unit_macs: u128) -> CostModel {
        let d = cfg.d_model as u128;
        let l = cfg.n_layers as u128;
        CostModel {
            unit: unit_macs,
            head: (cfg.vocab as u128) * d,
            attn: 2 * d * l,
            kv_token_bytes: l * 2 * d * 4,
        }
    }

    /// Build from an accounting table (the self-check / analytic path).
    pub fn from_accounting(cfg: &ModelConfig, acc: &CompressionAccounting) -> CostModel {
        CostModel::new(cfg, report(cfg, acc, 1).macs)
    }

    /// Price a scoring request over `tokens` prompt positions: the full
    /// forward, `report(cfg, acc, tokens).macs` exactly; no KV footprint.
    pub fn score(&self, tokens: usize) -> RequestCost {
        let t = tokens as u128;
        RequestCost {
            prefill_macs: t * self.unit + self.attn * t * t.saturating_sub(1),
            decode_macs: 0,
            kv_bytes: 0,
        }
    }

    /// Price a generation request at its worst case: prefill over `prompt`
    /// positions plus `worst_new` generated tokens (the first rides on the
    /// prefill logits), `decode_report(…).cached_macs()` exactly.
    pub fn generate(&self, prompt: usize, worst_new: usize) -> RequestCost {
        let p = prompt as u128;
        let g = (worst_new as u128).max(1);
        // per-position cached step minus its head, plus one head for the
        // sampled last row — the decode_report prefill convention
        let prefill_macs = if prompt == 0 {
            0
        } else {
            p * (self.unit - self.head) + self.attn * tri(p) + self.head
        };
        // steps g-1 single-token decodes at positions prompt .. prompt+g-2
        let decode_macs = (g - 1) * self.unit + self.attn * ((g - 1) * p + tri(g - 1));
        RequestCost {
            prefill_macs,
            decode_macs,
            kv_bytes: (p + g) * self.kv_token_bytes,
        }
    }

    /// Price an [`crate::engine::InferenceRequest`] before it runs.
    /// `default_max_new` is the engine's per-request cap fallback
    /// (`EngineConfig::max_new`), so the worst case matches what the
    /// engine would actually allow the request to spend.
    pub fn price(
        &self,
        req: &crate::engine::InferenceRequest,
        default_max_new: usize,
    ) -> RequestCost {
        use crate::engine::RequestKind;
        match &req.kind {
            RequestKind::Score { tokens } => self.score(tokens.len()),
            RequestKind::Generate { prompt, max_new } => {
                self.generate(prompt.len(), max_new.unwrap_or(default_max_new).max(1))
            }
        }
    }
}

/// How the serving engine stores weights in memory — the byte side of the
/// cost ledger, mirroring [`crate::serve::ExecMode`] (each mode maps to
/// exactly one store via `ExecMode::weight_store`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightStore {
    /// Everything re-densified f32: `4·d_out·d_in` bytes per matrix.
    Dense,
    /// Low-rank matrices as f32 factor pairs: `4·r·(d_out+d_in)` bytes.
    Factored,
    /// Low-rank matrices as per-row int8 factor pairs with f32 scales:
    /// `r·(d_out+d_in)` code bytes + `4·(d_out+r)` scale bytes.
    FactoredQuant,
}

/// Analytic weight-payload bytes of a served model under a compression
/// state and storage form — the accounting twin of
/// `crate::serve::ServeModel::weight_bytes` (asserted equal in the serve
/// tests and `repro serve --self-check`). Embed (tied head) and norm
/// gains are always f32; matrices without [`LayerCompression::LowRank`]
/// factors are stored dense by the serving engine regardless of store
/// (pruning artifacts ship re-densified parameters), so only factored
/// matrices change bytes across stores.
pub fn weight_bytes(cfg: &ModelConfig, acc: &CompressionAccounting, store: WeightStore) -> u128 {
    let d = cfg.d_model as u128;
    let mut bytes = 4 * (cfg.vocab as u128) * d + 4 * d; // embed + final_norm
    for b in 0..cfg.n_layers {
        bytes += 2 * 4 * d; // norm gains
        for (name, o, i) in block_matrices(cfg, b) {
            let (o, i) = (o as u128, i as u128);
            bytes += match (store, acc.layers.get(&name).copied()) {
                (WeightStore::Factored, Some(LayerCompression::LowRank { rank })) => {
                    4 * rank as u128 * (o + i)
                }
                (WeightStore::FactoredQuant, Some(LayerCompression::LowRank { rank })) => {
                    let r = rank as u128;
                    // w1: o×r codes + o scales; w2: r×i codes + r scales
                    r * (o + i) + 4 * (o + r)
                }
                _ => 4 * o * i,
            };
        }
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_params_match_config() {
        let cfg = ModelConfig::mini();
        let r = report(&cfg, &CompressionAccounting::dense(), 64);
        assert_eq!(r.n_params, cfg.n_params());
    }

    #[test]
    fn llama7b_dense_macs_match_paper_scale() {
        // Paper Table 1: 6.7B params, 423.93G MACs. Our model at 64 tokens
        // should land within a few percent (they include some small terms
        // we fold differently).
        let cfg = ModelConfig::llama7b();
        let r = report(&cfg, &CompressionAccounting::dense(), 64);
        assert!((r.params_billions() - 6.7).abs() < 0.1, "params {}", r.params_billions());
        assert!(
            (r.macs_giga() - 423.93).abs() / 423.93 < 0.05,
            "macs {}G vs paper 423.93G",
            r.macs_giga()
        );
    }

    #[test]
    fn paper_80pct_budget_reproduces_table1_row() {
        // 80% budget = last 12 of 32 modules at module budget 0.46
        // -> paper row: 5.4B params, ~340G MACs.
        let cfg = ModelConfig::llama7b();
        let mut acc = CompressionAccounting::dense();
        for b in (32 - 12)..32 {
            for (name, o, i) in block_matrices(&cfg, b) {
                let r = (0.46 * (o * i) as f64 / (o + i) as f64) as usize;
                acc.set(&name, LayerCompression::LowRank { rank: r });
            }
        }
        let r = report(&cfg, &acc, 64);
        assert!((r.params_billions() - 5.4).abs() < 0.15, "params {}", r.params_billions());
        assert!((r.macs_giga() - 339.99).abs() / 339.99 < 0.05, "macs {}", r.macs_giga());
    }

    #[test]
    fn paper_50pct_budget_reproduces_table1_row() {
        // 50% budget = last 24 modules at 0.33 -> 3.5B params, 215.61G MACs.
        let cfg = ModelConfig::llama7b();
        let mut acc = CompressionAccounting::dense();
        for b in (32 - 24)..32 {
            for (name, o, i) in block_matrices(&cfg, b) {
                let r = (0.33 * (o * i) as f64 / (o + i) as f64) as usize;
                acc.set(&name, LayerCompression::LowRank { rank: r });
            }
        }
        let r = report(&cfg, &acc, 64);
        assert!((r.params_billions() - 3.5).abs() < 0.15, "params {}", r.params_billions());
        assert!((r.macs_giga() - 215.61).abs() / 215.61 < 0.06, "macs {}", r.macs_giga());
    }

    #[test]
    fn lowrank_always_cheaper_when_budget_below_one() {
        let cfg = ModelConfig::mini();
        let dense = report(&cfg, &CompressionAccounting::dense(), 64);
        let mut acc = CompressionAccounting::dense();
        for b in 0..cfg.n_layers {
            for (name, o, i) in block_matrices(&cfg, b) {
                let r = (0.5 * (o * i) as f64 / (o + i) as f64) as usize;
                acc.set(&name, LayerCompression::LowRank { rank: r });
            }
        }
        let comp = report(&cfg, &acc, 64);
        assert!(comp.n_params < dense.n_params);
        assert!(comp.macs < dense.macs);
    }

    #[test]
    fn decode_step_matches_hand_formula() {
        let cfg = ModelConfig::mini();
        let acc = CompressionAccounting::dense();
        let (d, l) = (cfg.d_model as u128, cfg.n_layers as u128);
        let weights: u128 = (0..cfg.n_layers)
            .flat_map(|b| block_matrices(&cfg, b))
            .map(|(_, o, i)| (o * i) as u128)
            .sum();
        let head = (cfg.vocab * cfg.d_model) as u128;
        for pos in [0usize, 1, 7, 63] {
            let want = weights + head + 2 * (pos as u128 + 1) * d * l;
            assert_eq!(decode_step_macs(&cfg, &acc, pos), want, "pos {pos}");
        }
    }

    #[test]
    fn decode_report_sums_steps_and_recompute_dominates() {
        let cfg = ModelConfig::mini();
        let acc = CompressionAccounting::dense();
        let rep = decode_report(&cfg, &acc, 16, 8);
        // prefill: per-position cached-step MACs minus the head, plus ONE
        // head for the sampled last position (the prefill head is sliced)
        let head = (cfg.vocab * cfg.d_model) as u128;
        let prefill: u128 =
            (0..16).map(|p| decode_step_macs(&cfg, &acc, p) - head).sum::<u128>() + head;
        let decode: u128 = (16..23).map(|p| decode_step_macs(&cfg, &acc, p)).sum();
        assert_eq!(rep.prefill_macs, prefill);
        assert_eq!(rep.decode_macs, decode);
        assert_eq!(rep.cached_macs(), prefill + decode);
        let recompute: u128 = (1..=8u128)
            .map(|k| report(&cfg, &acc, 16 + k as usize - 1).macs)
            .sum();
        assert_eq!(rep.recompute_macs, recompute);
        assert!(rep.recompute_macs > rep.cached_macs(), "recompute must cost more");
        assert!(rep.savings() > 1.0);
        // degenerate generations stay well-defined
        let zero = decode_report(&cfg, &acc, 4, 0);
        assert_eq!(zero.decode_macs, 0);
        assert_eq!(zero.recompute_macs, 0);
        let one = decode_report(&cfg, &acc, 4, 1);
        assert_eq!(one.decode_macs, 0, "first token rides on the prefill logits");
        assert_eq!(one.recompute_macs, report(&cfg, &acc, 4).macs);
    }

    #[test]
    fn factored_decode_steps_are_cheaper() {
        let cfg = ModelConfig::mini();
        let mut acc = CompressionAccounting::dense();
        for b in 0..cfg.n_layers {
            for (name, o, i) in block_matrices(&cfg, b) {
                let r = (0.4 * (o * i) as f64 / (o + i) as f64) as usize;
                acc.set(&name, LayerCompression::LowRank { rank: r.max(1) });
            }
        }
        let dense = CompressionAccounting::dense();
        for pos in [0usize, 5, 31] {
            assert!(
                decode_step_macs(&cfg, &acc, pos) < decode_step_macs(&cfg, &dense, pos),
                "pos {pos}"
            );
        }
        let f = decode_report(&cfg, &acc, 12, 6);
        let d = decode_report(&cfg, &dense, 12, 6);
        assert!(f.cached_macs() < d.cached_macs());
        assert!(f.cached_macs() < d.recompute_macs, "factored-KV beats dense-recompute");
    }

    #[test]
    fn spec_report_bills_draft_verify_and_waste_by_hand() {
        let cfg = ModelConfig::mini();
        let verifier = CompressionAccounting::dense();
        let mut draft = CompressionAccounting::dense();
        for b in 0..cfg.n_layers {
            for (name, o, i) in block_matrices(&cfg, b) {
                let r = (0.3 * (o * i) as f64 / (o + i) as f64) as usize;
                draft.set(&name, LayerCompression::LowRank { rank: r.max(1) });
            }
        }
        let p = 6usize;
        let dstep = |pos: usize| decode_step_macs(&cfg, &draft, pos);
        let vstep = |pos: usize| decode_step_macs(&cfg, &verifier, pos);
        // round 1: k=3 drafted, 1 accepted (g 1→3); round 2: k=3, all 3
        // accepted (g 3→7, draft now lags by one); round 3: degenerate
        // k=0 verifier-only round (g 7→8).
        let trace = [
            SpecRound { drafted: 3, accepted: 1 },
            SpecRound { drafted: 3, accepted: 3 },
            SpecRound { drafted: 0, accepted: 0 },
        ];
        let rep = spec_report(&cfg, &draft, &verifier, p, &trace);
        assert_eq!((rep.rounds, rep.drafted, rep.accepted, rep.rejected), (3, 6, 4, 2));
        assert_eq!(rep.generated, 8);
        assert!((rep.accept_rate() - 4.0 / 6.0).abs() < 1e-12);
        // draft prefill: decode_report's prefill convention
        assert_eq!(
            rep.draft_prefill_macs,
            decode_report(&cfg, &draft, p, 1).prefill_macs
        );
        // round 1: C=6, chunk Cd=6..=6 + steps 7,8 → draft positions 6..9;
        //          verify positions 6..=9; waste = positions 8,9
        // round 2: g=3 ⇒ C=8; draft rolled back to 8, chunk 8..=8 + steps
        //          9,10 → positions 8..11; verify 8..=11; full accept ⇒
        //          no waste, draft lags at 11
        // round 3: g=7 ⇒ C=12; no draft; verify position 12 only
        let want_draft: u128 = (6..9).map(dstep).sum::<u128>() + (8..11).map(dstep).sum::<u128>();
        let want_verify: u128 = (6..=9).map(vstep).sum::<u128>()
            + (8..=11).map(vstep).sum::<u128>()
            + vstep(12);
        let want_waste: u128 = (8..=9).map(vstep).sum();
        assert_eq!(rep.draft_macs, want_draft);
        assert_eq!(rep.verify_macs, want_verify);
        assert_eq!(rep.wasted_macs, want_waste);
        assert_eq!(
            rep.spec_macs(),
            rep.draft_prefill_macs + want_draft + want_verify
        );
        // an empty trace is just the draft prefill
        let none = spec_report(&cfg, &draft, &verifier, p, &[]);
        assert_eq!(none.generated, 1);
        assert_eq!(none.spec_macs(), none.draft_prefill_macs);
        assert_eq!(none.wasted_macs + none.verify_macs + none.draft_macs, 0);
    }

    #[test]
    fn request_cost_matches_analytic_reports_exactly() {
        let cfg = ModelConfig::mini();
        let mut acc = CompressionAccounting::dense();
        for b in 0..cfg.n_layers {
            for (name, o, i) in block_matrices(&cfg, b) {
                let r = (0.5 * (o * i) as f64 / (o + i) as f64) as usize;
                acc.set(&name, LayerCompression::LowRank { rank: r.max(1) });
            }
        }
        for acc in [CompressionAccounting::dense(), acc] {
            let cm = CostModel::from_accounting(&cfg, &acc);
            // Score ≡ report(T).macs for every T
            for t in [1usize, 2, 8, 64] {
                assert_eq!(cm.score(t).prefill_macs, report(&cfg, &acc, t).macs, "score {t}");
                assert_eq!(cm.score(t).total_macs(), report(&cfg, &acc, t).macs);
            }
            // Generate ≡ decode_report(P, G).cached_macs(), term by term
            for (p, g) in [(1usize, 1usize), (8, 1), (16, 8), (5, 32), (12, 6)] {
                let rep = decode_report(&cfg, &acc, p, g);
                let cost = cm.generate(p, g);
                assert_eq!(cost.prefill_macs, rep.prefill_macs, "prefill P={p} G={g}");
                assert_eq!(cost.decode_macs, rep.decode_macs, "decode P={p} G={g}");
                assert_eq!(cost.total_macs(), rep.cached_macs(), "total P={p} G={g}");
            }
            // both construction paths agree
            assert_eq!(cm, CostModel::new(&cfg, report(&cfg, &acc, 1).macs));
        }
    }

    #[test]
    fn request_cost_prices_inference_requests() {
        use crate::engine::InferenceRequest;
        let cfg = ModelConfig::mini();
        let acc = CompressionAccounting::dense();
        let cm = CostModel::from_accounting(&cfg, &acc);
        // Score request → the full-forward price, zero KV
        let s = cm.price(&InferenceRequest::score(0, vec![1; 8]), 32);
        assert_eq!(s, cm.score(8));
        assert_eq!(s.kv_bytes, 0);
        // Generate with an explicit cap prices that cap…
        let g = cm.price(&InferenceRequest::generate(1, vec![1; 8], Some(4)), 32);
        assert_eq!(g, cm.generate(8, 4));
        // …without one, the engine default applies
        let g = cm.price(&InferenceRequest::generate(2, vec![1; 8], None), 32);
        assert_eq!(g, cm.generate(8, 32));
        // KV footprint: (prompt + worst_new) positions × L × K,V × d × f32
        let want = (8 + 32) as u128
            * (cfg.n_layers as u128)
            * 2
            * (cfg.d_model as u128)
            * 4;
        assert_eq!(g.kv_bytes, want);
        // worst_new clamps to ≥ 1 (a generate always yields one token)
        assert_eq!(cm.generate(4, 0), cm.generate(4, 1));
    }

    #[test]
    fn weight_bytes_follow_the_store() {
        let cfg = ModelConfig::mini();
        let dense_acc = CompressionAccounting::dense();
        // with nothing factored, every store coincides
        for store in [WeightStore::Dense, WeightStore::Factored, WeightStore::FactoredQuant] {
            assert_eq!(
                weight_bytes(&cfg, &dense_acc, store),
                4 * report(&cfg, &dense_acc, 1).n_params as u128,
                "{store:?}"
            );
        }
        let mut acc = CompressionAccounting::dense();
        for b in 0..cfg.n_layers {
            for (name, o, i) in block_matrices(&cfg, b) {
                let r = (0.5 * (o * i) as f64 / (o + i) as f64) as usize;
                acc.set(&name, LayerCompression::LowRank { rank: r.max(1) });
            }
        }
        let d = weight_bytes(&cfg, &acc, WeightStore::Dense);
        let f = weight_bytes(&cfg, &acc, WeightStore::Factored);
        let q = weight_bytes(&cfg, &acc, WeightStore::FactoredQuant);
        // dense store ignores factors entirely
        assert_eq!(d, weight_bytes(&cfg, &dense_acc, WeightStore::Dense));
        // f32 factors beat dense at budget 0.5; int8 codes beat f32 factors
        assert!(f < d, "factored {f} vs dense {d}");
        assert!(q < f, "quantized {q} vs factored {f}");
        // the factored store prices exactly 4 bytes per factored param
        assert_eq!(f, 4 * report(&cfg, &acc, 1).n_params as u128);
        // pruned matrices are stored dense under every store
        let mut pruned = CompressionAccounting::dense();
        pruned.set("blocks.0.w_gate", LayerCompression::PrunedOut { kept_out: 10 });
        assert_eq!(
            weight_bytes(&cfg, &pruned, WeightStore::Factored),
            weight_bytes(&cfg, &dense_acc, WeightStore::Dense)
        );
    }

    #[test]
    fn pruned_accounting() {
        let cfg = ModelConfig::mini();
        let mut acc = CompressionAccounting::dense();
        acc.set("blocks.0.w_gate", LayerCompression::PrunedOut { kept_out: 100 });
        acc.set("blocks.0.w_down", LayerCompression::PrunedIn { kept_in: 100 });
        let r = report(&cfg, &acc, 64);
        let dense = report(&cfg, &CompressionAccounting::dense(), 64);
        let saved = (cfg.d_ff - 100) * cfg.d_model * 2;
        assert_eq!(dense.n_params - r.n_params, saved);
    }
}
