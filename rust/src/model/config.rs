//! Model configuration, deserialized from `manifest.json` (the Python
//! `compile.config.ModelConfig` is the source of truth; this mirrors it).

use crate::runtime::ModelConfigJson;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub train_batch: usize,
    pub train_seq: usize,
    pub eval_batch: usize,
    pub eval_seq: usize,
}

impl ModelConfig {
    pub fn from_manifest(j: &ModelConfigJson) -> ModelConfig {
        ModelConfig {
            vocab: j.vocab,
            d_model: j.d_model,
            n_heads: j.n_heads,
            n_layers: j.n_layers,
            d_ff: j.d_ff,
            rope_theta: j.rope_theta,
            norm_eps: j.norm_eps,
            train_batch: j.train_batch,
            train_seq: j.train_seq,
            eval_batch: j.eval_batch,
            eval_seq: j.eval_seq,
        }
    }

    /// The paper's LLaMA-7B dimensions — used by budget-math tests and the
    /// cost model, never instantiated as tensors.
    pub fn llama7b() -> ModelConfig {
        ModelConfig {
            vocab: 32000,
            d_model: 4096,
            n_heads: 32,
            n_layers: 32,
            d_ff: 11008,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            train_batch: 1,
            train_seq: 2048,
            eval_batch: 1,
            eval_seq: 2048,
        }
    }

    /// Mini reproduction config (must match `python/compile/config.py`).
    pub fn mini() -> ModelConfig {
        ModelConfig {
            vocab: 320,
            d_model: 128,
            n_heads: 4,
            n_layers: 8,
            d_ff: 344,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            train_batch: 16,
            train_seq: 64,
            eval_batch: 32,
            eval_seq: 128,
        }
    }

    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Parameters in one decoder module (the paper's "7 decomposable
    /// matrices" plus the two norm gains).
    pub fn params_per_block(&self) -> usize {
        4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff + 2 * self.d_model
    }

    /// Total parameters (tied LM head).
    pub fn n_params(&self) -> usize {
        self.vocab * self.d_model + self.n_layers * self.params_per_block() + self.d_model
    }

    /// Fraction of parameters held by the decoder modules (paper: >96% on
    /// LLaMA-7B, which justifies compressing only those).
    pub fn decoder_fraction(&self) -> f64 {
        (self.n_layers * self.params_per_block()) as f64 / self.n_params() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_matches_paper_table1() {
        let cfg = ModelConfig::llama7b();
        let total = cfg.n_params() as f64;
        assert!((total - 6.7e9).abs() / 6.7e9 < 0.05, "total={total}");
        assert!(cfg.decoder_fraction() > 0.96);
    }

    #[test]
    fn mini_head_dim() {
        let cfg = ModelConfig::mini();
        assert_eq!(cfg.head_dim(), 32);
        assert_eq!(cfg.n_params(), 320 * 128 + 8 * cfg.params_per_block() + 128);
    }
}
