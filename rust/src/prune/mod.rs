//! Structured-pruning baseline — the LLM-Pruner comparator of Table 1.
//!
//! Prunes whole FFN channels and attention heads, group-consistently:
//! removing FFN channel `c` zeroes row `c` of `w_gate`/`w_up` and column
//! `c` of `w_down`; removing head `h` zeroes its row-slices of
//! `wq`/`wk`/`wv` and the matching column-slice of `wo`. Importance is
//! either weight magnitude or activation-aware (Wanda-style `|W|·‖X‖`,
//! using the same calibration captures the ROM pass consumes). Masks keep
//! HLO shapes static; `#Params`/`#MACs` are accounted from the kept
//! channel/head counts. Recovery fine-tune runs through
//! `train_step_masked` (see [`crate::train`]).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::data::CalibBatch;
use crate::model::macs::{CompressionAccounting, LayerCompression};
use crate::model::{schema, ModelConfig, ParamStore};
use crate::rom::budget::ModuleSchedule;
use crate::rom::covariance::valid_row_flags;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Channel/head importance criterion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Importance {
    /// |W| row sums (no calibration data needed).
    Magnitude,
    /// Wanda-style: Σ_j |W_cj| · ‖X_j‖₂ over calibration inputs.
    ActivationAware,
}

/// Result of a structured pruning pass.
#[derive(Debug)]
pub struct PrunedModel {
    /// Parameters with pruned channels zeroed (dense shapes preserved).
    pub params: ParamStore,
    /// One f32 mask per maskable matrix, schema order (for fine-tuning).
    pub masks: Vec<Tensor>,
    /// Kept FFN channels / heads per pruned block.
    pub kept_ffn: BTreeMap<usize, Vec<usize>>,
    pub kept_heads: BTreeMap<usize, Vec<usize>>,
    pub schedule: ModuleSchedule,
}

impl PrunedModel {
    /// Accounting view (Table 1's #Params / #MACs columns).
    pub fn accounting(&self, cfg: &ModelConfig) -> CompressionAccounting {
        let mut acc = CompressionAccounting::dense();
        for (&block, kept) in &self.kept_ffn {
            let k = kept.len();
            acc.set(&format!("blocks.{block}.w_gate"), LayerCompression::PrunedOut { kept_out: k });
            acc.set(&format!("blocks.{block}.w_up"), LayerCompression::PrunedOut { kept_out: k });
            acc.set(&format!("blocks.{block}.w_down"), LayerCompression::PrunedIn { kept_in: k });
        }
        for (&block, kept) in &self.kept_heads {
            let hd = cfg.head_dim();
            let k = kept.len() * hd;
            acc.set(&format!("blocks.{block}.wq"), LayerCompression::PrunedOut { kept_out: k });
            acc.set(&format!("blocks.{block}.wk"), LayerCompression::PrunedOut { kept_out: k });
            acc.set(&format!("blocks.{block}.wv"), LayerCompression::PrunedOut { kept_out: k });
            acc.set(&format!("blocks.{block}.wo"), LayerCompression::PrunedIn { kept_in: k });
        }
        acc
    }
}

/// Structured pruner, optionally bound to a runtime (activation capture
/// is only needed for [`Importance::ActivationAware`]).
pub struct Pruner<'rt> {
    runtime: Option<&'rt Runtime>,
    cfg: ModelConfig,
}

impl<'rt> Pruner<'rt> {
    pub fn new(runtime: &'rt Runtime) -> Pruner<'rt> {
        let cfg = ModelConfig::from_manifest(&runtime.manifest().model_config);
        Pruner { runtime: Some(runtime), cfg }
    }

    /// Runtime-free pruner: magnitude importance only.
    pub fn offline(cfg: ModelConfig) -> Pruner<'static> {
        Pruner { runtime: None, cfg }
    }

    /// Prune the scheduled trailing modules to `schedule.module_budget` of
    /// their parameters (keeping that fraction of channels & heads).
    pub fn prune(
        &self,
        params: &ParamStore,
        calib: &[CalibBatch],
        schedule: ModuleSchedule,
        importance: Importance,
    ) -> Result<PrunedModel> {
        if importance == Importance::ActivationAware && calib.is_empty() {
            bail!("activation-aware pruning needs calibration batches");
        }
        let cfg = &self.cfg;
        let keep_ffn = ((cfg.d_ff as f64) * schedule.module_budget).round().max(1.0) as usize;
        let keep_heads =
            ((cfg.n_heads as f64) * schedule.module_budget).round().max(1.0) as usize;

        // input-column norms per block (only for activation-aware)
        let xnorms = if importance == Importance::ActivationAware {
            Some(self.input_norms(params, calib)?)
        } else {
            None
        };

        let mut out = params.clone();
        let mut kept_ffn = BTreeMap::new();
        let mut kept_heads = BTreeMap::new();

        for block in 0..cfg.n_layers {
            if !schedule.compresses(block) {
                continue;
            }
            let norms = xnorms.as_ref().map(|m| &m[&block]);

            // ---- FFN channels ----
            let gate = params.get(&format!("blocks.{block}.w_gate"))?.as_f32()?;
            let up = params.get(&format!("blocks.{block}.w_up"))?.as_f32()?;
            let d = cfg.d_model;
            let scores: Vec<f64> = (0..cfg.d_ff)
                .map(|c| {
                    let mut s = 0.0f64;
                    for j in 0..d {
                        let w = gate[c * d + j].abs() + up[c * d + j].abs();
                        let x = norms.map(|n| n.x_ffn[j]).unwrap_or(1.0);
                        s += w as f64 * x;
                    }
                    s
                })
                .collect();
            let keep = top_k(&scores, keep_ffn);
            kept_ffn.insert(block, keep.clone());

            // ---- attention heads ----
            let hd = cfg.head_dim();
            let wq = params.get(&format!("blocks.{block}.wq"))?.as_f32()?;
            let wk = params.get(&format!("blocks.{block}.wk"))?.as_f32()?;
            let wv = params.get(&format!("blocks.{block}.wv"))?.as_f32()?;
            let head_scores: Vec<f64> = (0..cfg.n_heads)
                .map(|h| {
                    let mut s = 0.0f64;
                    for r in h * hd..(h + 1) * hd {
                        for j in 0..d {
                            let w = wq[r * d + j].abs() + wk[r * d + j].abs() + wv[r * d + j].abs();
                            let x = norms.map(|n| n.x_attn[j]).unwrap_or(1.0);
                            s += w as f64 * x;
                        }
                    }
                    s
                })
                .collect();
            let keep_h = top_k(&head_scores, keep_heads);
            kept_heads.insert(block, keep_h.clone());

            self.apply_masks(&mut out, block, &keep, &keep_h)?;
        }

        let masks = build_masks(cfg, &kept_ffn, &kept_heads);
        Ok(PrunedModel { params: out, masks, kept_ffn, kept_heads, schedule })
    }

    /// Zero pruned rows/cols in the stored weights.
    fn apply_masks(
        &self,
        params: &mut ParamStore,
        block: usize,
        keep_ffn: &[usize],
        keep_heads: &[usize],
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (d, f, hd) = (cfg.d_model, cfg.d_ff, cfg.head_dim());
        let ffn_keep: Vec<bool> = membership(f, keep_ffn);
        let head_keep: Vec<bool> = membership(cfg.n_heads, keep_heads);

        for field in ["w_gate", "w_up"] {
            let name = format!("blocks.{block}.{field}");
            let mut t = params.get(&name)?.clone();
            let data = t.as_f32_mut()?;
            for c in 0..f {
                if !ffn_keep[c] {
                    data[c * d..(c + 1) * d].fill(0.0);
                }
            }
            params.set(&name, t)?;
        }
        {
            let name = format!("blocks.{block}.w_down");
            let mut t = params.get(&name)?.clone();
            let data = t.as_f32_mut()?;
            for r in 0..d {
                for c in 0..f {
                    if !ffn_keep[c] {
                        data[r * f + c] = 0.0;
                    }
                }
            }
            params.set(&name, t)?;
        }
        for field in ["wq", "wk", "wv"] {
            let name = format!("blocks.{block}.{field}");
            let mut t = params.get(&name)?.clone();
            let data = t.as_f32_mut()?;
            for h in 0..cfg.n_heads {
                if !head_keep[h] {
                    data[h * hd * d..(h + 1) * hd * d].fill(0.0);
                }
            }
            params.set(&name, t)?;
        }
        {
            let name = format!("blocks.{block}.wo");
            let mut t = params.get(&name)?.clone();
            let data = t.as_f32_mut()?;
            for r in 0..d {
                for h in 0..cfg.n_heads {
                    if !head_keep[h] {
                        data[r * d + h * hd..r * d + (h + 1) * hd].fill(0.0);
                    }
                }
            }
            params.set(&name, t)?;
        }
        Ok(())
    }

    /// ‖X_j‖₂ of the calibration inputs feeding each matrix family.
    fn input_norms(
        &self,
        params: &ParamStore,
        calib: &[CalibBatch],
    ) -> Result<BTreeMap<usize, InputNorms>> {
        let runtime = self
            .runtime
            .context("activation-aware pruning needs a runtime for capture")?;
        let cfg = &self.cfg;
        let (eb, es) = (cfg.eval_batch, cfg.eval_seq);
        let mut out = BTreeMap::new();
        // stream hidden states once, reusing the capture graph
        let embed = params.get("embed")?.clone();
        let mut hidden: Vec<Tensor> = Vec::new();
        for b in calib {
            let tokens = Tensor::from_i32(&[eb, es], b.tokens.clone());
            let o = runtime.execute("embed_fwd", &[&embed, &tokens])?;
            hidden.push(o.into_iter().next().unwrap());
        }
        let cap_names = runtime.manifest().capture_names.clone();
        let idx_of = |n: &str| cap_names.iter().position(|c| c == n).map(|i| i + 1);
        let (ix_attn, ix_ffn) = (
            idx_of("x_attn").context("x_attn capture")?,
            idx_of("x_ffn").context("x_ffn capture")?,
        );

        for block in 0..cfg.n_layers {
            let mut attn_sq = vec![0.0f64; cfg.d_model];
            let mut ffn_sq = vec![0.0f64; cfg.d_model];
            for (bi, cb) in calib.iter().enumerate() {
                let mut args = params.block_flat(block);
                args.push(&hidden[bi]);
                let outs = runtime.execute("block_capture", &args)?;
                let flags = valid_row_flags(cb.batch, cb.seq, &cb.valid);
                accumulate_sq(&outs[ix_attn], &flags, &mut attn_sq)?;
                accumulate_sq(&outs[ix_ffn], &flags, &mut ffn_sq)?;
                hidden[bi] = outs.into_iter().next().unwrap();
            }
            out.insert(
                block,
                InputNorms {
                    x_attn: attn_sq.iter().map(|x| x.sqrt()).collect(),
                    x_ffn: ffn_sq.iter().map(|x| x.sqrt()).collect(),
                },
            );
        }
        Ok(out)
    }
}

#[derive(Debug, Clone)]
struct InputNorms {
    x_attn: Vec<f64>,
    x_ffn: Vec<f64>,
}

fn accumulate_sq(cap: &Tensor, flags: &[bool], acc: &mut [f64]) -> Result<()> {
    let d = *cap.shape().last().unwrap();
    let data = cap.as_f32()?;
    for (row, ok) in flags.iter().enumerate() {
        if !ok {
            continue;
        }
        let base = row * d;
        for j in 0..d {
            acc[j] += (data[base + j] as f64).powi(2);
        }
    }
    Ok(())
}

/// Indices of the k largest scores, ascending order.
fn top_k(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut keep: Vec<usize> = idx.into_iter().take(k).collect();
    keep.sort_unstable();
    keep
}

fn membership(n: usize, keep: &[usize]) -> Vec<bool> {
    let mut m = vec![false; n];
    for &i in keep {
        m[i] = true;
    }
    m
}

/// Build the per-matrix masks (1 = kept) in maskable schema order.
/// Public so compressed artifacts can rebuild masks from their serialized
/// kept-index sets on load (see [`crate::compress::CompressedModel`]).
pub fn build_masks(
    cfg: &ModelConfig,
    kept_ffn: &BTreeMap<usize, Vec<usize>>,
    kept_heads: &BTreeMap<usize, Vec<usize>>,
) -> Vec<Tensor> {
    let (d, f, hd) = (cfg.d_model, cfg.d_ff, cfg.head_dim());
    schema::maskable_names(cfg)
        .iter()
        .map(|name| {
            let block = schema::block_index(name).unwrap();
            let field = name.rsplit('.').next().unwrap();
            let shape = schema::param_shape(cfg, name);
            let mut mask = vec![1.0f32; shape.iter().product()];
            if let (Some(keep), true) = (kept_ffn.get(&block), matches!(field, "w_gate" | "w_up" | "w_down")) {
                let keep = membership(f, keep);
                match field {
                    "w_gate" | "w_up" => {
                        for c in 0..f {
                            if !keep[c] {
                                mask[c * d..(c + 1) * d].fill(0.0);
                            }
                        }
                    }
                    _ => {
                        for r in 0..d {
                            for c in 0..f {
                                if !keep[c] {
                                    mask[r * f + c] = 0.0;
                                }
                            }
                        }
                    }
                }
            }
            if let (Some(keep), true) = (kept_heads.get(&block), matches!(field, "wq" | "wk" | "wv" | "wo")) {
                let keep = membership(cfg.n_heads, keep);
                match field {
                    "wq" | "wk" | "wv" => {
                        for h in 0..cfg.n_heads {
                            if !keep[h] {
                                mask[h * hd * d..(h + 1) * hd * d].fill(0.0);
                            }
                        }
                    }
                    _ => {
                        for r in 0..d {
                            for h in 0..cfg.n_heads {
                                if !keep[h] {
                                    mask[r * d + h * hd..r * d + (h + 1) * hd].fill(0.0);
                                }
                            }
                        }
                    }
                }
            }
            Tensor::from_f32(&shape, mask)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_selects_largest() {
        let scores = vec![0.1, 5.0, 3.0, 4.0, 0.2];
        assert_eq!(top_k(&scores, 3), vec![1, 2, 3]);
        assert_eq!(top_k(&scores, 0), Vec::<usize>::new());
    }

    #[test]
    fn membership_flags() {
        assert_eq!(membership(4, &[0, 2]), vec![true, false, true, false]);
    }

    #[test]
    fn masks_match_kept_sets() {
        let cfg = ModelConfig { n_layers: 2, ..ModelConfig::mini() };
        let mut kept_ffn = BTreeMap::new();
        kept_ffn.insert(1usize, (0..100).collect::<Vec<_>>());
        let mut kept_heads = BTreeMap::new();
        kept_heads.insert(1usize, vec![0, 2]);
        let masks = build_masks(&cfg, &kept_ffn, &kept_heads);
        assert_eq!(masks.len(), 14);
        // block 0 untouched: all ones
        let m0 = masks[0].as_f32().unwrap();
        assert!(m0.iter().all(|&x| x == 1.0));
        // block 1 w_gate (index 7+4=11? order: per block wq wk wv wo w_gate w_up w_down)
        let m_gate = masks[7 + 4].as_f32().unwrap();
        let kept: f32 = m_gate.iter().sum();
        assert_eq!(kept as usize, 100 * cfg.d_model);
        // block 1 wq: two of four heads kept
        let m_q = masks[7].as_f32().unwrap();
        let kept_q: f32 = m_q.iter().sum();
        assert_eq!(kept_q as usize, 2 * cfg.head_dim() * cfg.d_model * cfg.d_model / cfg.d_model);
    }
}
