//! `repro` — LLM-ROM command-line launcher.
//!
//! Subcommands mirror the pipeline stages (artifacts must exist — run
//! `make artifacts` first):
//!
//! ```text
//! repro info                         # manifest / model / platform summary
//! repro gen-data [--seed N]          # preview world, corpus, tasks
//! repro train   [--steps N] [--out ckpt.rtz]
//! repro compress --ckpt ckpt.rtz --budget 0.8 [--out rom.rtz]
//! repro prune   --ckpt ckpt.rtz --budget 0.8 [--finetune N]
//! repro eval    --ckpt ckpt.rtz [--ppl]
//! repro tables  --ckpt ckpt.rtz [--table 1|2|3|4|all]
//! repro cost    --ckpt ckpt.rtz
//! ```
//!
//! Arg parsing is hand-rolled (offline build; no clap) but strict: unknown
//! flags are errors.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::data::CalibSource;
use llm_rom::model::{macs, ParamStore};
use llm_rom::prune::Importance;
use llm_rom::runtime::Runtime;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny strict flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got `{k}`"))?
                .to_string();
            // boolean flags take no value
            if matches!(key.as_str(), "ppl" | "no-pallas" | "magnitude") {
                flags.insert(key, "true".into());
                continue;
            }
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key, v);
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("bad --{key} `{v}`")),
        }
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    let artifacts = args.get_or("artifacts", llm_rom::DEFAULT_ARTIFACTS);

    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "info" => cmd_info(&artifacts),
        "gen-data" => cmd_gen_data(&args),
        "train" => cmd_train(&artifacts, &args),
        "compress" => cmd_compress(&artifacts, &args),
        "prune" => cmd_prune(&artifacts, &args),
        "eval" => cmd_eval(&artifacts, &args),
        "generate" => cmd_generate(&artifacts, &args),
        "tables" => cmd_tables(&artifacts, &args),
        "cost" => cmd_cost(&artifacts, &args),
        "spectrum" => cmd_spectrum(&artifacts, &args),
        other => bail!("unknown subcommand `{other}` (try `repro help`)"),
    }
}

const HELP: &str = "\
repro — LLM-ROM reproduction CLI

  info                          manifest / model / platform summary
  gen-data  [--seed N]          preview world, corpus, tasks
  train     [--steps N] [--out ckpt.rtz] [--seed N]
  compress  --ckpt C --budget B [--out rom.rtz] [--rows N] [--seq N]
            [--source combination|arc-c|corpus]
  prune     --ckpt C --budget B [--finetune N] [--magnitude] [--out p.rtz]
  eval      --ckpt C [--ppl] [--per-task N]
  generate  --ckpt C --prompt \"text\" [--max-new N] [--temp T] [--seed N]
  tables    --ckpt C [--table 1|2|3|4|all] [--finetune N]
  cost      --ckpt C            §4 cost table
  spectrum  --ckpt C [--blocks a..b] [--rows N]   latent-feature spectra
Global: [--artifacts DIR] (default ./artifacts)
";

fn xcfg_from(args: &Args) -> Result<ExperimentConfig> {
    let mut x = ExperimentConfig::default();
    x.seed = args.parse_num("seed", x.seed)?;
    x.train_steps = args.parse_num("steps", x.train_steps)?;
    x.calib_rows = args.parse_num("rows", x.calib_rows)?;
    x.calib_seq = args.parse_num("seq", x.calib_seq)?;
    x.eval_per_task = args.parse_num("per-task", x.eval_per_task)?;
    if let Some(src) = args.get("source") {
        x.calib_source = parse_source(src)?;
    }
    Ok(x)
}

fn parse_source(s: &str) -> Result<CalibSource> {
    Ok(match s {
        "combination" => CalibSource::Combination,
        "arc-c" => CalibSource::SingleTask(llm_rom::data::TaskKind::QaHard),
        "corpus" => CalibSource::Corpus,
        other => bail!("unknown calibration source `{other}`"),
    })
}

fn load_ckpt(exp: &Experiment, args: &Args) -> Result<ParamStore> {
    let path = args.get("ckpt").context("--ckpt required")?;
    ParamStore::load(&exp.cfg, path)
}

fn ensure_parent(path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

fn cmd_info(artifacts: &str) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let m = rt.manifest();
    let cfg = llm_rom::model::ModelConfig::from_manifest(&m.model_config);
    println!("platform        : {}", rt.platform());
    println!(
        "model           : MiniLLaMA d={} h={} L={} ff={} vocab={}",
        cfg.d_model, cfg.n_heads, cfg.n_layers, cfg.d_ff, cfg.vocab
    );
    println!("params          : {}", cfg.n_params());
    println!("decoder fraction: {:.2}%", 100.0 * cfg.decoder_fraction());
    println!("entries         : {}", m.entries.len());
    for (name, e) in &m.entries {
        println!(
            "  {name:<22} {:>3} args -> {:>2} outputs ({})",
            e.args.len(),
            e.outputs.len(),
            e.file
        );
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    use llm_rom::data::{render_corpus, Split, Task, World, ALL_TASKS};
    let seed = args.parse_num("seed", 42u64)?;
    let world = World::default_world(seed);
    println!(
        "world: {} people, {} objects, {} locations",
        world.n_people(),
        world.n_objects(),
        world.locations.len()
    );
    let corpus = render_corpus(&world, seed, 2_000, 1);
    println!("\ncorpus sample:\n{}", &corpus[..500.min(corpus.len())]);
    for kind in ALL_TASKS {
        let t = Task::new(&world, kind);
        let inst = &t.generate(Split::Eval, 1, seed)[0];
        println!("\n[{}] {}", kind.name(), inst.prompt);
        for (i, c) in inst.choices.iter().enumerate() {
            let mark = if i == inst.gold { "*" } else { " " };
            println!("  {mark} {c}");
        }
    }
    Ok(())
}

fn cmd_train(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let init = exp.init_params(artifacts)?;
    println!("training {} steps on the synthetic corpus…", exp.xcfg.train_steps);
    let trained = exp.train(init, |step, loss, lr| {
        println!("  step {step:>5}  loss {loss:.4}  lr {lr:.2e}");
    })?;
    let out = args.get_or("out", "runs/base.rtz");
    ensure_parent(&out)?;
    trained.params.save(&out)?;
    println!("saved {out} ({:.1}s)", trained.train_seconds);
    Ok(())
}

fn cmd_compress(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let budget: f64 = args.parse_num("budget", 0.8)?;
    println!("ROM compression to {:.0}% global budget…", budget * 100.0);
    let rom = exp.compress_at(&params, budget)?;
    let rep = macs::report(&exp.cfg, &rom.accounting(), 64);
    let dense = macs::report(&exp.cfg, &macs::CompressionAccounting::dense(), 64);
    println!(
        "params {} -> {} ({:.1}%), MACs {:.2}G -> {:.2}G",
        dense.n_params,
        rep.n_params,
        100.0 * rep.n_params as f64 / dense.n_params as f64,
        dense.macs_giga(),
        rep.macs_giga()
    );
    println!(
        "{} layers in {:.1}s ({:.2} s/layer), peak capture {:.1} MB",
        rom.timings.len(),
        rom.total_rom_seconds(),
        rom.mean_seconds_per_layer(),
        rom.peak_capture_bytes as f64 / 1e6
    );
    let out = args.get_or("out", "runs/rom.rtz");
    ensure_parent(&out)?;
    rom.params.save(&out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_prune(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let budget: f64 = args.parse_num("budget", 0.8)?;
    let importance = if args.get("magnitude").is_some() {
        Importance::Magnitude
    } else {
        Importance::ActivationAware
    };
    println!("structured pruning to {:.0}% ({importance:?})…", budget * 100.0);
    let pruned = exp.prune_at(&params, budget, importance)?;
    let rep = macs::report(&exp.cfg, &pruned.accounting(&exp.cfg), 64);
    println!("params after: {} ({:.2}G MACs)", rep.n_params, rep.macs_giga());
    let finetune: usize = args.parse_num("finetune", 0)?;
    let final_params = if finetune > 0 {
        println!("recovery fine-tune: {finetune} steps…");
        exp.finetune_pruned(&pruned, finetune, |s, l, _| {
            println!("  step {s:>4}  loss {l:.4}");
        })?
    } else {
        pruned.params.clone()
    };
    let out = args.get_or("out", "runs/pruned.rtz");
    ensure_parent(&out)?;
    final_params.save(&out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_eval(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let rep = exp.evaluate(&params, args.get("ppl").is_some())?;
    println!("{}", llm_rom::eval::format_table("Evaluation", &[("model".into(), rep)]));
    Ok(())
}

fn cmd_generate(artifacts: &str, args: &Args) -> Result<()> {
    use llm_rom::data::{Tokenizer, BOS};
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let prompt = args.get("prompt").context("--prompt required")?;
    let max_new: usize = args.parse_num("max-new", 120)?;
    let temp: f32 = args.parse_num("temp", 0.0)?;
    let seed: u64 = args.parse_num("seed", 0)?;

    let tk = Tokenizer::new();
    let mut ids = vec![BOS];
    ids.extend(tk.encode(prompt));
    // KV-cached incremental decoding on the pure-rust reference model
    let model = llm_rom::model::ReferenceModel::new(&params);
    let t0 = std::time::Instant::now();
    let out = model.generate(&ids, max_new, temp, seed)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}{}", prompt, tk.decode(&out));
    eprintln!(
        "\n[{} prompt + {} generated tokens in {:.2}s — {:.1} tok/s, KV-cached rust path]",
        ids.len(),
        out.len(),
        dt,
        out.len() as f64 / dt
    );
    Ok(())
}

fn cmd_tables(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let which = args.get_or("table", "all");
    let ft_steps: usize = args.parse_num("finetune", 60)?;
    let budget: f64 = args.parse_num("budget", 0.8)?;
    llm_rom::coordinator::run_tables(&exp, &params, &which, ft_steps, budget)
}

fn cmd_spectrum(artifacts: &str, args: &Args) -> Result<()> {
    use llm_rom::coordinator::spectrum;
    use llm_rom::rom::RomPipeline;
    let rt = Runtime::new(artifacts)?;
    let mut xcfg = xcfg_from(args)?;
    if args.get("rows").is_none() {
        xcfg.calib_rows = 128; // spectra stabilize quickly
    }
    let exp = Experiment::new(&rt, xcfg);
    let params = load_ckpt(&exp, args)?;
    let blocks = match args.get("blocks") {
        None => 0..exp.cfg.n_layers,
        Some(spec) => {
            let (a, b) = spec.split_once("..").context("--blocks a..b")?;
            a.parse().context("blocks start")?..b.parse().context("blocks end")?
        }
    };
    let calib = exp.calibration(exp.xcfg.calib_rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
    let pipeline = RomPipeline::new(&rt);
    let rows = spectrum::measure_spectra(&pipeline, &params, &calib, blocks)?;
    println!("{}", spectrum::format_spectra(&rows));
    println!("(ROM keeps r(b) components; r@99% ≪ dim is the paper's premise)");
    Ok(())
}

fn cmd_cost(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let mut report = llm_rom::coordinator::CostReport::default();
    for budget in [0.9, 0.8, 0.5] {
        let rom = exp.compress_at(&params, budget)?;
        report.push(format!("{:.0}%", budget * 100.0), &rom);
    }
    println!("{}", report.format());
    let bound =
        llm_rom::coordinator::cost::layerwise_memory_bound(&exp.cfg, exp.xcfg.calib_rows, exp.xcfg.calib_seq);
    println!("layerwise memory bound (this config): {:.1} MB", bound as f64 / 1e6);
    println!(
        "layerwise memory bound (LLaMA-7B @512 rows): {:.2} GB  (paper: <10 GB)",
        llm_rom::coordinator::cost::llama7b_memory_bound_bytes() as f64 / 1e9
    );
    Ok(())
}
