//! `repro` — LLM-ROM command-line launcher.
//!
//! Subcommands mirror the pipeline stages (artifacts must exist — run
//! `make artifacts` first):
//!
//! ```text
//! repro info                         # manifest / model / platform summary
//! repro gen-data [--seed N]          # preview world, corpus, tasks
//! repro train    [--steps N] [--out ckpt.rtz]
//! repro compress --ckpt ckpt.rtz [--method NAME] [--budget B]
//! repro sweep    --ckpt ckpt.rtz [--methods a,b,c] [--budget B]
//! repro eval     --ckpt ckpt.rtz [--ppl]
//! repro serve    --ckpt artifact.rtz [--mode dense|factored|factored-quant] | --self-check
//! repro bench-serve [--ckpt artifact.rtz] [--budget B] [--threads N] [--json FILE]
//! repro bench-kernels [--ckpt artifact.rtz] [--budget B] [--threads N] [--json FILE]
//! repro generate --ckpt artifact.rtz [--prompt TEXT | --requests N] | --self-check
//! repro bench-decode [--ckpt artifact.rtz] [--budget B] [--threads N] [--json FILE]
//! repro bench-parallel [--ckpt artifact.rtz] [--threads N] [--json FILE]
//! repro daemon   --ckpt artifact.rtz [--addr HOST:PORT] [--slots N] | --self-check
//! repro loadgen  --addr HOST:PORT [--connections N] [--rps R] [--duration S]
//! repro bench-daemon [--ckpt artifact.rtz] [--budget B] [--threads N] [--json FILE]
//! repro tables   --ckpt ckpt.rtz [--table 1|2|3|4|all]
//! repro cost     --ckpt ckpt.rtz
//! ```
//!
//! Arg parsing is hand-rolled (offline build; no clap) but strict and
//! spec-driven: every subcommand declares its own flag set (including
//! which flags are boolean), unknown flags are errors that print the
//! subcommand's spec, and `repro help <cmd>` / `repro <cmd> --help` print
//! it on demand. Compression methods are resolved through the
//! [`llm_rom::compress`] registry, so `compress` and `sweep` pick up new
//! methods with no CLI changes.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use llm_rom::compress::{self, CompressedModel, Provenance};
use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::daemon::{self, Daemon, DaemonConfig, HttpClient, LoadgenConfig};
use llm_rom::data::CalibSource;
use llm_rom::decode::{self, DecodeConfig, DecodeScheduler, GenRequest, KvCache, Sampling};
use llm_rom::engine::{self, EngineConfig, EngineCore, InferenceRequest};
use llm_rom::exec::ExecConfig;
use llm_rom::model::macs::{self, CompressionAccounting};
use llm_rom::model::{ModelConfig, ParamStore};
use llm_rom::rom::ModuleSchedule;
use llm_rom::runtime::{Manifest, Runtime};
use llm_rom::serve::{self, ExecMode, ServeConfig, ServeEngine, ServeModel};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

// ---------------------------------------------------------------------------
// Flag specs: one table per subcommand, shared flag constants.

/// One flag of a subcommand. `value: None` marks a boolean switch (takes
/// no value); `Some(placeholder)` marks a value-taking flag.
#[derive(Clone, Copy)]
struct Flag {
    name: &'static str,
    value: Option<&'static str>,
    help: &'static str,
}

const fn flag(name: &'static str, value: &'static str, help: &'static str) -> Flag {
    Flag { name, value: Some(value), help }
}

const fn switch(name: &'static str, help: &'static str) -> Flag {
    Flag { name, value: None, help }
}

/// A subcommand spec: name, one-line summary, and its flag set.
struct Cmd {
    name: &'static str,
    summary: &'static str,
    flags: &'static [Flag],
}

const SEED: Flag = flag("seed", "N", "RNG seed (synthetic workloads, sampling)");
const THREADS: Flag =
    flag("threads", "N", "worker-pool threads (0 = all cores; results are identical for any N)");
const KV_CAP: Flag =
    flag("kv-cap-mb", "MB", "fail if the KV cache pool would preallocate more than MB megabytes");
const SERVE_REQUESTS: Flag = flag("requests", "N", "synthetic requests to serve");
const SERVE_SEQ: Flag = flag("seq", "N", "tokens per synthetic request");
const SERVE_WORKERS: Flag = flag("workers", "N", "serving worker threads");
const SERVE_BATCH: Flag = flag("batch", "N", "max requests per dispatch batch");
const JSON_OUT: Flag = flag("json", "FILE", "also write the benchmark as machine-readable JSON");
const MAX_NEW: Flag = flag("max-new", "N", "tokens to generate per request");
const STREAM: Flag =
    switch("stream", "print tokens as they are produced (event-stream path, flushed per token)");
const DEADLINE_MS: Flag = flag(
    "deadline-ms",
    "MS",
    "per-request deadline; overdue requests are evicted mid-flight (finish reason `deadline`)",
);
const CANCEL_AFTER: Flag = flag(
    "cancel-after",
    "N",
    "cancel every request once its Nth streamed token arrives (applied at scheduling-step \
     boundaries, so a request keeps at least 2 tokens; exercises mid-flight eviction)",
);
const TEMP: Flag = flag("temp", "T", "sampling temperature (0 = greedy)");
const TOP_K: Flag = flag("top-k", "K", "restrict sampling to the K best logits (0 = off)");
const SLOTS: Flag = flag("slots", "N", "concurrent KV cache slots (continuous batching)");
const PROMPT_LEN: Flag = flag("prompt-len", "N", "tokens per synthetic prompt");
const ADDR: Flag = flag("addr", "HOST:PORT", "daemon address");
const QUEUE_CAP: Flag =
    flag("queue-cap", "N", "bounded admission queue depth (a full queue sheds new work with 429)");
const CONNECTIONS: Flag = flag("connections", "N", "concurrent load-generator connections");
const RPS: Flag = flag("rps", "R", "open-loop target arrival rate, requests per second");
const DURATION: Flag = flag("duration", "S", "arrival window in seconds");
const MIX: Flag = flag(
    "mix",
    "I:B",
    "interactive:batch request ratio (default 0:1 = all batch; interactive requests carry \
     tier + deadline_ms on the wire)",
);
const CKPT: Flag = flag("ckpt", "FILE", "checkpoint to load (.rtz)");
const BUDGET: Flag = flag("budget", "B", "global parameter budget in (0, 1]");
const DRAFT: Flag = flag(
    "draft",
    "FILE",
    "low-budget draft artifact (.rtz) of the same checkpoint; enables speculative decoding \
     (greedy streams stay bitwise identical to verifier-only decode)",
);
const SPEC_K: Flag = flag(
    "spec-k",
    "K",
    "draft tokens proposed per speculative round (requires --draft; default 4)",
);
const ROWS: Flag = flag("rows", "N", "calibration rows");
const SEQ: Flag = flag("seq", "N", "calibration sequence length");
const SOURCE: Flag = flag("source", "SRC", "calibration source: combination|arc-c|corpus");
const FINETUNE: Flag = flag("finetune", "N", "recovery fine-tune steps");
const PER_TASK: Flag = flag("per-task", "N", "eval instances per task");
const OUT: Flag = flag("out", "FILE", "output checkpoint path (.rtz)");
const NO_OBS: Flag = switch(
    "no-obs",
    "detach the observability plane (flight recorder + metrics registry); printed output \
     is bitwise identical either way — the non-perturbation bar scripts/verify.sh diffs",
);
const TRACE_OUT: Flag = flag(
    "trace-out",
    "FILE",
    "write the causal-plane flight-recorder transcript as JSONL (with --self-check: the \
     scheduler phase's trace, byte-identical across --threads; daemon serving mode: the \
     full transcript at drain)",
);

static COMMANDS: &[Cmd] = &[
    Cmd { name: "info", summary: "manifest / model / platform summary", flags: &[] },
    Cmd { name: "gen-data", summary: "preview world, corpus, tasks", flags: &[SEED] },
    Cmd {
        name: "train",
        summary: "train the base model on the synthetic corpus",
        flags: &[flag("steps", "N", "training steps"), OUT, SEED],
    },
    Cmd {
        name: "compress",
        summary: "compress a checkpoint with a registered method",
        flags: &[
            CKPT,
            flag("method", "NAME", "registry name (default rom-feature); see `repro sweep`"),
            BUDGET,
            OUT,
            FINETUNE,
            ROWS,
            SEQ,
            SOURCE,
            THREADS,
            SEED,
        ],
    },
    Cmd {
        name: "sweep",
        summary: "run several methods across one or more budgets; comparison table + rank ladder",
        flags: &[
            CKPT,
            flag("methods", "A,B,C", "comma-separated registry names (default: all registered)"),
            BUDGET,
            flag(
                "budgets",
                "B1,B2,..",
                "comma-separated budget ladder in (0, 1] (supersedes --budget; one table per \
                 budget plus a ladder.json manifest of every artifact produced)",
            ),
            FINETUNE,
            ROWS,
            SEQ,
            SOURCE,
            PER_TASK,
            THREADS,
            SEED,
        ],
    },
    Cmd {
        name: "eval",
        summary: "zero-shot six-task evaluation (+ optional perplexity)",
        flags: &[CKPT, switch("ppl", "also report corpus perplexity"), PER_TASK, SEED],
    },
    Cmd {
        name: "serve",
        summary: "serve a compressed artifact with the factored-form engine",
        flags: &[
            CKPT,
            flag("mode", "dense|factored|factored-quant", "execution mode (default factored)"),
            SERVE_REQUESTS,
            SERVE_SEQ,
            SERVE_WORKERS,
            SERVE_BATCH,
            THREADS,
            switch(
                "self-check",
                "build a mini artifact offline, serve it in every mode, verify logits + \
                 quantized tolerance + MACs + weight bytes + tiered scheduler vs FIFO",
            ),
            NO_OBS,
            TRACE_OUT,
            SEED,
        ],
    },
    Cmd {
        name: "bench-serve",
        summary: "dense vs factored serving comparison on one artifact",
        flags: &[
            CKPT,
            BUDGET,
            SERVE_REQUESTS,
            SERVE_SEQ,
            SERVE_WORKERS,
            SERVE_BATCH,
            THREADS,
            SEED,
            JSON_OUT,
        ],
    },
    Cmd {
        name: "generate",
        summary: "KV-cached autoregressive generation (continuous batching)",
        flags: &[
            CKPT,
            flag("mode", "dense|factored|factored-quant", "execution mode (default factored)"),
            flag("prompt", "TEXT", "prompt text (omit for a synthetic workload)"),
            SERVE_REQUESTS,
            PROMPT_LEN,
            MAX_NEW,
            TEMP,
            TOP_K,
            SLOTS,
            THREADS,
            KV_CAP,
            STREAM,
            DEADLINE_MS,
            CANCEL_AFTER,
            DRAFT,
            SPEC_K,
            switch(
                "speculative",
                "with --self-check: also assert the speculative path (draft+verify) is \
                 bitwise identical to verifier-only greedy decode with exact MAC accounting",
            ),
            switch(
                "self-check",
                "offline: assert KV-cached decode ≡ full-recompute logits/streams + MAC \
                 accounting + tiered scheduler vs FIFO",
            ),
            NO_OBS,
            TRACE_OUT,
            SEED,
        ],
    },
    Cmd {
        name: "bench-decode",
        summary: "recompute vs KV-cached decode comparison (dense + factored)",
        flags: &[CKPT, BUDGET, SERVE_REQUESTS, PROMPT_LEN, MAX_NEW, SLOTS, THREADS, SEED, JSON_OUT],
    },
    Cmd {
        name: "bench-kernels",
        summary: "scalar vs SIMD vs packed vs quantized kernel microbenchmark",
        flags: &[CKPT, BUDGET, THREADS, SEED, JSON_OUT],
    },
    Cmd {
        name: "bench-parallel",
        summary: "1 vs N-thread scaling on the factored path (serve/decode/compress)",
        flags: &[
            CKPT,
            BUDGET,
            SERVE_REQUESTS,
            SERVE_SEQ,
            PROMPT_LEN,
            MAX_NEW,
            SLOTS,
            THREADS,
            SEED,
            JSON_OUT,
        ],
    },
    Cmd {
        name: "daemon",
        summary: "HTTP/1.1 + SSE front-end over the streaming engine core",
        flags: &[
            CKPT,
            ADDR,
            flag("mode", "dense|factored|factored-quant", "execution mode (default factored)"),
            DRAFT,
            SPEC_K,
            SLOTS,
            QUEUE_CAP,
            MAX_NEW,
            flag("retry-after", "S", "Retry-After seconds advertised on 429 responses"),
            THREADS,
            switch(
                "self-check",
                "offline: client+server in one process over loopback — SSE ≡ in-process \
                 events, queue saturation → 429, disconnect cancels, drain exits, \
                 observability plane non-perturbing",
            ),
            NO_OBS,
            TRACE_OUT,
            SEED,
        ],
    },
    Cmd {
        name: "loadgen",
        summary: "open-loop wire-path load generator against a running daemon",
        flags: &[
            ADDR,
            CONNECTIONS,
            RPS,
            DURATION,
            PROMPT_LEN,
            MAX_NEW,
            MIX,
            switch("unary", "use unary completion envelopes instead of SSE streams"),
            flag("vocab", "N", "prompt token range (default: the artifacts manifest vocab)"),
            SEED,
            JSON_OUT,
        ],
    },
    Cmd {
        name: "bench-daemon",
        summary: "self-hosted daemon + loadgen wire-path benchmark",
        flags: &[
            CKPT,
            BUDGET,
            CONNECTIONS,
            RPS,
            DURATION,
            PROMPT_LEN,
            MAX_NEW,
            MIX,
            SLOTS,
            QUEUE_CAP,
            THREADS,
            SEED,
            JSON_OUT,
        ],
    },
    Cmd {
        name: "tables",
        summary: "regenerate the paper's tables 1-4",
        flags: &[CKPT, flag("table", "1|2|3|4|all", "which table(s)"), FINETUNE, BUDGET, ROWS, SEQ, SOURCE, PER_TASK, SEED],
    },
    Cmd {
        name: "cost",
        summary: "§4 computational-cost table across budgets",
        flags: &[CKPT, ROWS, SEQ, SEED],
    },
    Cmd {
        name: "spectrum",
        summary: "latent-feature spectra of the activation covariances",
        flags: &[CKPT, flag("blocks", "A..B", "block range (default: all)"), ROWS, SEQ, SEED],
    },
];

/// Flags valid for every subcommand.
static GLOBAL_FLAGS: &[Flag] = &[
    flag("artifacts", "DIR", "artifacts directory (default ./artifacts)"),
    switch("help", "print this subcommand's flags"),
];

fn command_spec(name: &str) -> Option<&'static Cmd> {
    COMMANDS.iter().find(|c| c.name == name)
}

fn find_flag(spec: &'static Cmd, key: &str) -> Option<&'static Flag> {
    spec.flags.iter().chain(GLOBAL_FLAGS.iter()).find(|f| f.name == key)
}

fn usage(spec: &Cmd) -> String {
    let mut s = format!("repro {} — {}\n\nflags:\n", spec.name, spec.summary);
    for f in spec.flags.iter().chain(GLOBAL_FLAGS.iter()) {
        let head = match f.value {
            Some(v) => format!("--{} {v}", f.name),
            None => format!("--{}", f.name),
        };
        s.push_str(&format!("  {head:<18} {}\n", f.help));
    }
    s
}

fn general_help() -> String {
    let mut s = String::from("repro — LLM-ROM reproduction CLI\n\n");
    for c in COMMANDS {
        s.push_str(&format!("  {:<10} {}\n", c.name, c.summary));
    }
    s.push_str("\ncompression methods (for compress/sweep): ");
    s.push_str(&compress::METHODS.join(", "));
    s.push_str("\nrun `repro help <command>` or `repro <command> --help` for flags\n");
    s
}

// ---------------------------------------------------------------------------
// Strict spec-driven parser.

struct Args {
    cmd: String,
    /// `repro help <topic>` argument.
    topic: Option<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1).collect())
    }

    fn parse_from(argv: Vec<String>) -> Result<Args> {
        let mut it = argv.into_iter();
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        if matches!(cmd.as_str(), "help" | "--help" | "-h") {
            return Ok(Args { cmd: "help".into(), topic: it.next(), flags: BTreeMap::new() });
        }
        let spec = command_spec(&cmd)
            .with_context(|| format!("unknown subcommand `{cmd}` (try `repro help`)"))?;
        let mut flags = BTreeMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got `{k}`"))?
                .to_string();
            let f = find_flag(spec, &key).with_context(|| {
                format!("unknown flag --{key} for `{cmd}`\n\n{}", usage(spec))
            })?;
            match f.value {
                None => {
                    flags.insert(key, "true".into());
                }
                Some(_) => {
                    let v = it.next().with_context(|| format!("--{key} needs a value"))?;
                    flags.insert(key, v);
                }
            }
        }
        Ok(Args { cmd, topic: None, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("bad --{key} `{v}`")),
        }
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    if args.cmd == "help" {
        match args.topic.as_deref() {
            Some(topic) => {
                let spec = command_spec(topic)
                    .with_context(|| format!("unknown subcommand `{topic}` (try `repro help`)"))?;
                print!("{}", usage(spec));
            }
            None => print!("{}", general_help()),
        }
        return Ok(());
    }
    if args.get("help").is_some() {
        let spec = command_spec(&args.cmd).expect("validated during parse");
        print!("{}", usage(spec));
        return Ok(());
    }
    let artifacts = args.get_or("artifacts", llm_rom::DEFAULT_ARTIFACTS);

    match args.cmd.as_str() {
        "info" => cmd_info(&artifacts),
        "gen-data" => cmd_gen_data(&args),
        "train" => cmd_train(&artifacts, &args),
        "compress" => cmd_compress(&artifacts, &args),
        "sweep" => cmd_sweep(&artifacts, &args),
        "eval" => cmd_eval(&artifacts, &args),
        "serve" => cmd_serve(&artifacts, &args),
        "bench-serve" => cmd_bench_serve(&artifacts, &args),
        "generate" => cmd_generate(&artifacts, &args),
        "bench-decode" => cmd_bench_decode(&artifacts, &args),
        "bench-kernels" => cmd_bench_kernels(&artifacts, &args),
        "bench-parallel" => cmd_bench_parallel(&artifacts, &args),
        "daemon" => cmd_daemon(&artifacts, &args),
        "loadgen" => cmd_loadgen(&artifacts, &args),
        "bench-daemon" => cmd_bench_daemon(&artifacts, &args),
        "tables" => cmd_tables(&artifacts, &args),
        "cost" => cmd_cost(&artifacts, &args),
        "spectrum" => cmd_spectrum(&artifacts, &args),
        other => bail!("unknown subcommand `{other}` (try `repro help`)"),
    }
}

/// The `--threads` knob as an [`ExecConfig`] (absent or 0 = all cores).
fn exec_from(args: &Args) -> Result<ExecConfig> {
    Ok(ExecConfig::with_threads(args.parse_num("threads", 0usize)?))
}

/// The `--no-obs` / `--trace-out` knobs: whether the observability plane
/// attaches, and where (if anywhere) the causal-plane transcript goes.
/// A trace export without the plane that records it is a contradiction,
/// so that combination is rejected up front.
fn obs_from(args: &Args) -> Result<(bool, Option<std::path::PathBuf>)> {
    let obs = args.get("no-obs").is_none();
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    anyhow::ensure!(
        obs || trace_out.is_none(),
        "--trace-out needs the observability plane (drop --no-obs)"
    );
    Ok((obs, trace_out))
}

fn xcfg_from(args: &Args) -> Result<ExperimentConfig> {
    let d = ExperimentConfig::default();
    let calib_source = match args.get("source") {
        Some(src) => parse_source(src)?,
        None => d.calib_source,
    };
    Ok(ExperimentConfig {
        seed: args.parse_num("seed", d.seed)?,
        train_steps: args.parse_num("steps", d.train_steps)?,
        calib_rows: args.parse_num("rows", d.calib_rows)?,
        calib_seq: args.parse_num("seq", d.calib_seq)?,
        eval_per_task: args.parse_num("per-task", d.eval_per_task)?,
        calib_source,
        exec: exec_from(args)?,
        ..d
    })
}

fn parse_source(s: &str) -> Result<CalibSource> {
    Ok(match s {
        "combination" => CalibSource::Combination,
        "arc-c" => CalibSource::SingleTask(llm_rom::data::TaskKind::QaHard),
        "corpus" => CalibSource::Corpus,
        other => bail!("unknown calibration source `{other}`"),
    })
}

fn load_ckpt(exp: &Experiment, args: &Args) -> Result<ParamStore> {
    let path = args.get("ckpt").context("--ckpt required")?;
    ParamStore::load(&exp.cfg, path)
}

fn ensure_parent(path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

fn cmd_info(artifacts: &str) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let m = rt.manifest();
    let cfg = llm_rom::model::ModelConfig::from_manifest(&m.model_config);
    println!("platform        : {}", rt.platform());
    println!(
        "model           : MiniLLaMA d={} h={} L={} ff={} vocab={}",
        cfg.d_model, cfg.n_heads, cfg.n_layers, cfg.d_ff, cfg.vocab
    );
    println!("params          : {}", cfg.n_params());
    println!("decoder fraction: {:.2}%", 100.0 * cfg.decoder_fraction());
    println!("methods         : {}", compress::METHODS.join(", "));
    println!("entries         : {}", m.entries.len());
    for (name, e) in &m.entries {
        println!(
            "  {name:<22} {:>3} args -> {:>2} outputs ({})",
            e.args.len(),
            e.outputs.len(),
            e.file
        );
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    use llm_rom::data::{render_corpus, Split, Task, World, ALL_TASKS};
    let seed = args.parse_num("seed", 42u64)?;
    let world = World::default_world(seed);
    println!(
        "world: {} people, {} objects, {} locations",
        world.n_people(),
        world.n_objects(),
        world.locations.len()
    );
    let corpus = render_corpus(&world, seed, 2_000, 1);
    println!("\ncorpus sample:\n{}", &corpus[..500.min(corpus.len())]);
    for kind in ALL_TASKS {
        let t = Task::new(&world, kind);
        let inst = &t.generate(Split::Eval, 1, seed)[0];
        println!("\n[{}] {}", kind.name(), inst.prompt);
        for (i, c) in inst.choices.iter().enumerate() {
            let mark = if i == inst.gold { "*" } else { " " };
            println!("  {mark} {c}");
        }
    }
    Ok(())
}

fn cmd_train(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let init = exp.init_params(artifacts)?;
    println!("training {} steps on the synthetic corpus…", exp.xcfg.train_steps);
    let trained = exp.train(init, |step, loss, lr| {
        println!("  step {step:>5}  loss {loss:.4}  lr {lr:.2e}");
    })?;
    let out = args.get_or("out", "runs/base.rtz");
    ensure_parent(&out)?;
    trained.params.save(&out)?;
    println!("saved {out} ({:.1}s)", trained.train_seconds);
    Ok(())
}

/// Print the params/MACs delta of a compressed artifact vs dense.
fn print_cost(exp: &Experiment, cm: &CompressedModel) {
    let rep = cm.macs_report(&exp.cfg, 64);
    let dense = macs::report(&exp.cfg, &CompressionAccounting::dense(), 64);
    println!(
        "params {} -> {} ({:.1}%), MACs {:.2}G -> {:.2}G",
        dense.n_params,
        rep.n_params,
        100.0 * rep.n_params as f64 / dense.n_params as f64,
        dense.macs_giga(),
        rep.macs_giga()
    );
    if !cm.timings.is_empty() {
        println!(
            "{} layers in {:.1}s ({:.2} s/layer), peak capture {:.1} MB",
            cm.timings.len(),
            cm.total_seconds(),
            cm.mean_seconds_per_layer(),
            cm.peak_capture_bytes as f64 / 1e6
        );
    }
}

fn cmd_compress(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let method = args.get_or("method", "rom-feature");
    compress::resolve(&method)?; // fail fast on unknown names
    let budget: f64 = args.parse_num("budget", 0.8)?;
    println!("compressing with `{method}` to {:.0}% global budget…", budget * 100.0);
    let mut cm = exp.compress_method(&params, &method, budget)?;
    print_cost(&exp, &cm);
    let finetune: usize = args.parse_num("finetune", 0)?;
    if finetune > 0 {
        if cm.masks.is_some() {
            println!("recovery fine-tune (masked): {finetune} steps…");
        } else {
            println!(
                "recovery fine-tune (unconstrained): {finetune} steps — training leaves \
                 the low-rank manifold, so the artifact's accounting reverts to dense"
            );
        }
        cm.params = exp.finetune_compressed(&cm, finetune, |s, l, _| {
            println!("  step {s:>4}  loss {l:.4}");
        })?;
        if cm.masks.is_none() {
            // the saved metadata must describe the saved weights
            cm.accounting = CompressionAccounting::dense();
        }
    }
    let out = args.get_or("out", "runs/compressed.rtz");
    ensure_parent(&out)?;
    cm.save(&out)?;
    println!(
        "saved {out} (method {}, budget {:.2}, calib {})",
        cm.provenance.method, cm.provenance.global_budget, cm.provenance.calib_label
    );
    Ok(())
}

fn cmd_sweep(artifacts: &str, args: &Args) -> Result<()> {
    use llm_rom::util::json::Json;
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let methods: Vec<String> = args
        .get("methods")
        .map(|s| s.to_string())
        .unwrap_or_else(|| compress::METHODS.join(","))
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    for m in &methods {
        compress::resolve(m)?; // fail fast on unknown names
    }
    // --budgets B1,B2,.. runs the whole rank ladder in one invocation;
    // --budget stays as the single-point alias
    let budgets: Vec<f64> = match args.get("budgets") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<f64>().with_context(|| format!("--budgets: bad number {s:?}")))
            .collect::<Result<_>>()?,
        None => vec![args.parse_num("budget", 0.8)?],
    };
    anyhow::ensure!(!budgets.is_empty(), "--budgets needs at least one value");
    for &b in &budgets {
        anyhow::ensure!(b > 0.0 && b <= 1.0, "budget {b} outside (0, 1]");
    }
    let ladder_run = args.get("budgets").is_some();
    let ft_steps: usize = args.parse_num("finetune", 0)?;
    let mut ladder: Vec<Json> = Vec::new();
    for &budget in &budgets {
        println!("sweeping {} methods at {:.0}% budget…", methods.len(), budget * 100.0);
        let table = llm_rom::coordinator::sweep_table_with(
            &exp,
            &params,
            &methods,
            budget,
            ft_steps,
            |method, cm| {
                if !ladder_run {
                    return Ok(());
                }
                let pct = (budget * 100.0).round() as u32;
                let path = format!("runs/sweep/{method}_b{pct}.rtz");
                ensure_parent(&path)?;
                cm.save(&path)?;
                let ranks: std::collections::BTreeMap<String, Json> = cm
                    .factors
                    .iter()
                    .map(|(name, f)| (name.clone(), Json::Num(f.rank as f64)))
                    .collect();
                let rep = macs::report(&exp.cfg, &cm.accounting, 1);
                ladder.push(Json::Obj(
                    [
                        ("artifact".to_string(), Json::Str(path)),
                        ("method".to_string(), Json::Str(method.to_string())),
                        ("budget".to_string(), Json::Num(budget)),
                        ("ranks".to_string(), Json::Obj(ranks)),
                        ("macs_per_token".to_string(), Json::Num(rep.macs as f64)),
                    ]
                    .into_iter()
                    .collect(),
                ));
                Ok(())
            },
        )?;
        println!("{table}");
    }
    if ladder_run {
        let out = "runs/sweep/ladder.json";
        ensure_parent(out)?;
        std::fs::write(out, Json::Arr(ladder).to_string())?;
        println!("wrote {out} ({} artifacts across {} budgets)", methods.len() * budgets.len(), budgets.len());
    }
    Ok(())
}

fn cmd_eval(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let rep = exp.evaluate(&params, args.get("ppl").is_some())?;
    println!("{}", llm_rom::eval::format_table("Evaluation", &[("model".into(), rep)]));
    Ok(())
}

/// Model config for serve paths, which must work without a PJRT runtime:
/// prefer the AOT manifest when present, fall back to the mini config (the
/// Python exporter's defaults — shape validation on artifact load catches
/// any mismatch).
fn serve_cfg(artifacts: &str) -> ModelConfig {
    match Manifest::load(artifacts) {
        Ok(m) => ModelConfig::from_manifest(&m.model_config),
        Err(_) => ModelConfig::mini(),
    }
}

fn cmd_serve(artifacts: &str, args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let exec = exec_from(args)?;
    let (obs, trace_out) = obs_from(args)?;
    let mode = match args.get("mode") {
        None => ExecMode::Factored,
        Some(s) => ExecMode::parse(s)?,
    };
    if args.get("self-check").is_some() {
        return serve_self_check(mode, seed, exec, obs, trace_out.as_deref());
    }
    anyhow::ensure!(trace_out.is_none(), "--trace-out requires --self-check for `serve`");
    let path = args.get("ckpt").context("--ckpt required (or --self-check)")?;
    let cfg = serve_cfg(artifacts);
    let cm = CompressedModel::load(&cfg, path)?;
    let requests: usize = args.parse_num("requests", 8)?;
    let seq: usize = args.parse_num("seq", cfg.eval_seq.min(64))?;
    let workers: usize = args.parse_num("workers", 2)?;
    let batch: usize = args.parse_num("batch", 4)?;
    let model = ServeModel::from_artifact(&cm, mode)?;
    println!(
        "serving {path} [{}]: {}/{} matrices factored, {requests} requests x {seq} tokens, \
         {workers} workers (batch {batch}, {} threads)",
        mode.name(),
        model.n_factored(),
        7 * cfg.n_layers,
        exec.resolve(),
    );
    let engine = ServeEngine::new(model, ServeConfig { workers, max_batch: batch, exec });
    let (results, stats) = engine.run(serve::synth_requests(&cfg, requests, seq, seed))?;
    println!(
        "served {} requests ({} tokens) in {:.3}s — {:.0} tok/s, {:.1} µs/token, \
         {:.3} MMACs/token",
        stats.core.requests,
        stats.core.tokens,
        stats.core.wall_s,
        stats.tokens_per_s(),
        stats.s_per_token() * 1e6,
        stats.macs_per_token() as f64 / 1e6,
    );
    println!(
        "latency mean {:.2}ms  p95 {:.2}ms  ({} dispatch batches)",
        stats.core.latency.mean * 1e3,
        stats.core.latency.p95 * 1e3,
        stats.batches
    );
    if let Some(r) = results.first() {
        let v = cfg.vocab;
        let last = &r.logits[(r.tokens - 1) * v..];
        let argmax = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!("request 0: argmax next-token id = {argmax}");
    }
    Ok(())
}

/// `repro serve --self-check`: build a mini artifact offline (data-free
/// weight-space ROM at budget 0.5), round-trip it through `.rtz`, and
/// serve it in every mode — asserting the factored path matches dense
/// logits to ≤1e-4, the quantized factored path tracks the f32 factored
/// path within its stated tolerance (same MACs, strictly fewer weight
/// bytes, both byte counts equal to the analytic
/// [`macs::weight_bytes`]), and every path executes exactly the
/// analytically-accounted MACs — then exercising the priced, tiered
/// admission scheduler ([`scheduler_self_check_phase`]) on an adversarial
/// flood-plus-trickle trace, on a model built in `mode` (so
/// `--mode factored-quant` runs the int8 kernels under the scheduler).
/// The CI smoke test behind `scripts/verify.sh`, which runs it at
/// `--threads 1` and `--threads 4` and diffs the output (everything
/// printed is deterministic, so any thread-count divergence fails the
/// gate — including the quantized kernels). With the observability plane
/// attached (`obs`, the default) the scheduler phase additionally asserts
/// the flight recorder and metrics registry agree with
/// [`llm_rom::engine::CoreStats`] exactly — printing nothing, so output
/// stays bitwise identical to a `--no-obs` run.
fn serve_self_check(
    mode: ExecMode,
    seed: u64,
    exec: ExecConfig,
    obs: bool,
    trace_out: Option<&std::path::Path>,
) -> Result<()> {
    let cfg = serve::demo_config();
    let cm = serve::demo_artifact(&cfg, 0.5, seed ^ 0x5EED)?;
    anyhow::ensure!(!cm.factors.is_empty(), "demo artifact carries no factors");

    // 1. factors survive .rtz serialization losslessly
    let dir = std::env::temp_dir().join(format!("serve_check_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("mini.rtz");
    cm.save(&path)?;
    let loaded = CompressedModel::load(&cfg, &path)?;
    anyhow::ensure!(
        loaded.factors.len() == cm.factors.len(),
        "factor count changed across .rtz round-trip"
    );
    for (name, f) in &cm.factors {
        let g = loaded.factors.get(name).context("factor lost in round-trip")?;
        anyhow::ensure!(
            g.rank == f.rank && g.w1.data() == f.w1.data() && g.w2.data() == f.w2.data(),
            "factor `{name}` not lossless across .rtz"
        );
    }
    println!(
        "[1/5] .rtz factor round-trip: lossless ({} factored matrices)",
        loaded.factors.len()
    );

    // 2. factored serving matches dense serving on the same batch
    let requests = serve::synth_requests(&cfg, 6, 24, seed);
    let mut outputs: Vec<(Vec<Vec<f32>>, u128)> = Vec::new();
    for m in [ExecMode::Dense, ExecMode::Factored, ExecMode::FactoredQuant] {
        let engine = ServeEngine::new(
            ServeModel::from_artifact(&loaded, m)?,
            ServeConfig { workers: 2, max_batch: 2, exec },
        );
        let (results, stats) = engine.run(requests.clone())?;
        outputs.push((results.into_iter().map(|r| r.logits).collect(), stats.core.macs));
    }
    let pairwise_max = |a: &[Vec<f32>], b: &[Vec<f32>]| -> (f64, f64) {
        let (mut diff, mut mag) = (0.0f64, 0.0f64);
        for (ra, rb) in a.iter().zip(b) {
            for (x, y) in ra.iter().zip(rb) {
                diff = diff.max((x - y).abs() as f64);
                mag = mag.max(y.abs() as f64);
            }
        }
        (diff, mag)
    };
    let (max_diff, _) = pairwise_max(&outputs[0].0, &outputs[1].0);
    anyhow::ensure!(
        max_diff <= 1e-4,
        "dense vs factored logits diverge: max |Δ| = {max_diff:.3e}"
    );
    println!("[2/5] dense vs factored logits: max |Δ| = {max_diff:.2e} (bound 1e-4)");

    // 3. the quantized factored path: logits within the stated tolerance
    //    of the f32 factored path, identical MACs (quantization changes
    //    bytes, not arithmetic shape), and the weight-byte win — with the
    //    measured bytes of every mode equal to the analytic accounting
    let (quant_diff, fact_mag) = pairwise_max(&outputs[2].0, &outputs[1].0);
    let quant_bound = 0.05 * fact_mag.max(1.0);
    anyhow::ensure!(
        quant_diff <= quant_bound,
        "factored-quant logits off the f32 factored path: \
         max |Δ| = {quant_diff:.3e} (bound {quant_bound:.3e})"
    );
    anyhow::ensure!(
        outputs[2].1 == outputs[1].1,
        "factored-quant must execute exactly the factored MACs"
    );
    let mut mode_bytes = Vec::new();
    for m in [ExecMode::Dense, ExecMode::Factored, ExecMode::FactoredQuant] {
        let measured = ServeModel::from_artifact(&loaded, m)?.weight_bytes();
        let analytic = macs::weight_bytes(&cfg, &loaded.accounting, m.weight_store());
        anyhow::ensure!(
            measured == analytic,
            "{} weight bytes: measured {measured} != analytic {analytic}",
            m.name()
        );
        mode_bytes.push(measured);
    }
    anyhow::ensure!(
        mode_bytes[2] < mode_bytes[1] && mode_bytes[1] < mode_bytes[0],
        "weight bytes must shrink dense → factored → factored-quant: {mode_bytes:?}"
    );
    println!(
        "[3/5] factored-quant logits: max |Δ| = {quant_diff:.2e} (bound {quant_bound:.2e}), \
         MACs identical to factored; weight bytes {} → {} → {} all equal the analytic \
         accounting",
        mode_bytes[0], mode_bytes[1], mode_bytes[2]
    );

    // 4. MAC accounting: factored strictly fewer, both exactly analytic
    let (dense_macs, fact_macs) = (outputs[0].1, outputs[1].1);
    let analytic = |acc: &CompressionAccounting| -> u128 {
        requests.iter().map(|r| macs::report(&cfg, acc, r.tokens.len()).macs).sum()
    };
    anyhow::ensure!(
        fact_macs == analytic(&loaded.accounting),
        "served factored MACs != artifact accounting"
    );
    anyhow::ensure!(
        dense_macs == analytic(&CompressionAccounting::dense()),
        "served dense MACs != dense accounting"
    );
    anyhow::ensure!(fact_macs < dense_macs, "factored path must execute fewer MACs");
    println!(
        "[4/5] MACs: factored {fact_macs} vs dense {dense_macs} ({:.2}x fewer), \
         both equal the analytic accounting",
        dense_macs as f64 / fact_macs as f64
    );
    // 5. the priced, tiered admission scheduler on an adversarial trace,
    //    executing in the requested mode (factored-quant runs the int8
    //    kernels under the scheduler — still bitwise thread-invariant)
    let model = ServeModel::from_artifact(&loaded, mode)?;
    scheduler_self_check_phase("[5/5]", &model, &loaded.accounting, seed, exec, obs, trace_out)?;

    std::fs::remove_dir_all(&dir).ok();
    println!("serve self-check: OK");
    Ok(())
}

/// The shared final phase of `repro serve --self-check` (`[5/5]`) and
/// `repro generate --self-check` (`[4/4]`; the printed line carries the
/// caller's `phase_label`): the priced, tiered admission scheduler
/// under an adversarial trace — an up-front batch flood plus an
/// interactive trickle contending for one slot. Everything is measured
/// in scheduling rounds, never wall clock, so the printed line is
/// bitwise identical across `--threads` (diffed by `scripts/verify.sh`).
///
/// Asserts:
/// - no tier starves: every request in both runs finishes, and
///   interactive queue waits stay within the round budget;
/// - deadline hit-rate (admission within the round budget) strictly
///   beats the identical trace replayed FIFO (tiers/deadlines stripped);
/// - the admission meter and per-tenant ledger equal the analytic
///   [`macs::decode_report`] sums;
/// - the stripped single-tier / no-deadline / unlimited-meter config
///   reduces exactly to FIFO admission order.
///
/// With `obs`, the tiered run also carries the flight recorder and the
/// metrics registry, and this phase silently asserts both against the
/// run's [`llm_rom::engine::CoreStats`]: the replayed transcript
/// ([`llm_rom::obs::reconstruct`]) and the registry counters must equal
/// the engine accounting *exactly*. Nothing extra is printed — output is
/// bitwise identical with and without `obs`, which `scripts/verify.sh`
/// diffs. `trace_out` additionally exports the transcript as JSONL
/// (round/seq/MAC-denominated, byte-identical across `--threads`).
fn scheduler_self_check_phase(
    phase_label: &str,
    model: &ServeModel,
    acc: &CompressionAccounting,
    seed: u64,
    exec: ExecConfig,
    obs: bool,
    trace_out: Option<&std::path::Path>,
) -> Result<()> {
    use llm_rom::engine::{EventKind, TenantUsage, Tier};
    use llm_rom::obs::{self, MetricsRegistry, TraceEvent};
    use std::sync::Arc;

    const BATCH_N: usize = 8;
    const INTERACTIVE_N: usize = 3;
    const PROMPT: usize = 6;
    const MAX_NEW: usize = 4;
    /// An interactive request is a deadline hit when admitted within
    /// this many scheduling rounds of its submission.
    const ROUND_BUDGET: usize = 10;

    let cfg = model.config().clone();
    let ecfg = EngineConfig {
        slots: 1,
        queue_cap: BATCH_N + INTERACTIVE_N,
        max_new: MAX_NEW,
        capacity: PROMPT + MAX_NEW,
        sampling: Sampling::Greedy,
        seed,
        eos: None,
        exec,
        ..EngineConfig::default()
    };
    let total = BATCH_N + INTERACTIVE_N;
    let prompts = engine::synth_token_streams(&cfg, total, PROMPT, seed ^ 0x5C4D);

    // One run of the trace: the batch flood queues before the first
    // round; interactive request `k` arrives before round `1 + 2k`.
    // `tiered: false` strips tiers, tenants, and deadlines — the exact
    // FIFO-reduction config.
    type ObsCapture = Option<(Vec<TraceEvent>, Arc<MetricsRegistry>)>;
    type Trace = (BTreeMap<usize, usize>, Vec<usize>, llm_rom::engine::CoreStats, ObsCapture);
    let run_trace = |tiered: bool| -> Result<Trace> {
        let mut session = EngineCore::new(model, ecfg).session();
        // the tiered run carries the observability plane (when enabled);
        // the FIFO baseline never does, proving by construction that the
        // two planes don't feed back into scheduling
        let observe = tiered && obs;
        let registry = Arc::new(MetricsRegistry::new());
        if observe {
            session.enable_tracing(obs::DEFAULT_TRACE_CAP);
            session.attach_metrics(Arc::clone(&registry));
        }
        let mut submit_round: BTreeMap<usize, usize> = BTreeMap::new();
        for id in 0..BATCH_N {
            let mut req = InferenceRequest::generate(id, prompts[id].clone(), None);
            if tiered {
                req = req.with_tenant("flood");
            }
            anyhow::ensure!(session.try_submit(req)?.is_none(), "flood request {id} bounced");
            submit_round.insert(id, 0);
        }
        let mut admit_round: BTreeMap<usize, usize> = BTreeMap::new();
        let mut admit_order: Vec<usize> = Vec::new();
        let mut round = 0usize;
        let mut next_interactive = 0usize;
        loop {
            while next_interactive < INTERACTIVE_N
                && (round >= 1 + 2 * next_interactive || !session.has_work())
            {
                let id = BATCH_N + next_interactive;
                let mut req = InferenceRequest::generate(id, prompts[id].clone(), None);
                if tiered {
                    // far-future deadlines: they order admission (EDF)
                    // but can never expire mid-run
                    req = req
                        .with_tier(Tier::Interactive)
                        .with_tenant("trickle")
                        .with_deadline(1e6 + id as f64);
                }
                anyhow::ensure!(
                    session.try_submit(req)?.is_none(),
                    "interactive request {id} bounced"
                );
                submit_round.insert(id, round);
                next_interactive += 1;
            }
            if !session.has_work() {
                break;
            }
            session.step()?;
            round += 1;
            for ev in session.take_events() {
                if matches!(ev.kind, EventKind::Admitted { .. }) {
                    admit_round.insert(ev.id, round);
                    admit_order.push(ev.id);
                }
            }
        }
        let trace = session.take_trace();
        let (_finished, stats) = session.finish();
        let waits: BTreeMap<usize, usize> = admit_round
            .iter()
            .map(|(id, &r)| (*id, r - submit_round[id]))
            .collect();
        Ok((waits, admit_order, stats, observe.then_some((trace, registry))))
    };

    let (waits, _order, stats, obs_capture) = run_trace(true)?;
    let (fifo_waits, fifo_order, fifo_stats, _) = run_trace(false)?;

    // stripped config reduces exactly to FIFO: admission == arrival
    anyhow::ensure!(
        fifo_order == (0..total).collect::<Vec<_>>(),
        "single-tier / no-deadline / unlimited-meter run must reduce to FIFO admission"
    );

    // no tier starves: every request in both runs was admitted and ran
    // to completion
    anyhow::ensure!(
        waits.len() == total && fifo_waits.len() == total,
        "every request must be admitted under both policies"
    );
    anyhow::ensure!(
        stats.requests == total && fifo_stats.requests == total,
        "every request must finish under both policies"
    );

    // bounded interactive wait + deadline hit-rate strictly beating FIFO
    let int_ids = BATCH_N..total;
    let max_wait = |w: &BTreeMap<usize, usize>| int_ids.clone().map(|id| w[&id]).max().unwrap_or(0);
    let hits =
        |w: &BTreeMap<usize, usize>| int_ids.clone().filter(|id| w[id] <= ROUND_BUDGET).count();
    let (int_wait, fifo_int_wait) = (max_wait(&waits), max_wait(&fifo_waits));
    let (tiered_hits, fifo_hits) = (hits(&waits), hits(&fifo_waits));
    anyhow::ensure!(
        int_wait <= ROUND_BUDGET,
        "interactive tier starved: waited {int_wait} rounds (budget {ROUND_BUDGET})"
    );
    anyhow::ensure!(
        tiered_hits > fifo_hits,
        "tiered deadline hit-rate ({tiered_hits}/{INTERACTIVE_N}) must strictly beat FIFO \
         ({fifo_hits}/{INTERACTIVE_N}) on the same trace"
    );

    // admission meter and tenant ledger == analytic decode_report sums
    let per_req = macs::decode_report(&cfg, acc, PROMPT, MAX_NEW).cached_macs();
    let expected = per_req * total as u128;
    anyhow::ensure!(
        stats.admitted_macs == expected && fifo_stats.admitted_macs == expected,
        "admitted-MAC meter {} != analytic decode_report sum {expected}",
        stats.admitted_macs
    );
    let row = |n: usize| TenantUsage { requests: n, declared_macs: per_req * n as u128 };
    anyhow::ensure!(
        stats.tenants.get("flood") == Some(&row(BATCH_N))
            && stats.tenants.get("trickle") == Some(&row(INTERACTIVE_N)),
        "per-tenant fairness ledger != analytic per-tenant sums"
    );

    // observability plane (when attached): the flight recorder's replay
    // and the metrics registry must equal the engine accounting exactly.
    // Deliberately silent — printed output is bitwise identical with and
    // without the plane, which scripts/verify.sh diffs.
    if let Some((trace, registry)) = obs_capture {
        let replay = obs::reconstruct(&trace);
        anyhow::ensure!(
            replay.enqueued == total
                && replay.admitted == total
                && replay.finished == total
                && replay.preemptions == stats.preemptions
                && replay.decode_rounds == stats.decode_rounds
                && replay.admitted_macs == stats.admitted_macs
                && replay.executed_macs == stats.macs,
            "flight-recorder replay diverges from CoreStats: {replay:?}"
        );
        let ledger: BTreeMap<String, (usize, u128)> = stats
            .tenants
            .iter()
            .map(|(k, v)| (k.clone(), (v.requests, v.declared_macs)))
            .collect();
        anyhow::ensure!(
            replay.tenants == ledger,
            "replayed tenant ledger diverges from the fairness ledger"
        );
        anyhow::ensure!(
            registry.requests.get() == stats.requests as u64
                && registry.generated_tokens.get() == stats.generated_tokens as u64
                && registry.decode_rounds.get() == stats.decode_rounds as u64,
            "metrics registry counters diverge from CoreStats"
        );
        anyhow::ensure!(
            registry.admitted_macs.get() == obs::sat_u64(stats.admitted_macs)
                && registry.executed_macs.get() == obs::sat_u64(stats.macs),
            "metrics registry MAC meters diverge from CoreStats"
        );
        anyhow::ensure!(
            registry.tier_admissions.get("interactive") == INTERACTIVE_N as u64
                && registry.tier_admissions.get("batch") == BATCH_N as u64
                && registry.tenant_requests.get("flood") == BATCH_N as u64
                && registry.tenant_requests.get("trickle") == INTERACTIVE_N as u64,
            "per-tier/per-tenant label families diverge from the trace"
        );
        if let Some(path) = trace_out {
            if let Some(p) = path.to_str() {
                ensure_parent(p)?;
            }
            std::fs::write(path, obs::render_jsonl(&trace))
                .with_context(|| format!("write trace to {}", path.display()))?;
        }
    }

    println!(
        "{phase_label} scheduler: interactive admitted within {int_wait} rounds under an \
         {BATCH_N}-deep batch flood (FIFO: {fifo_int_wait}); deadline hit-rate \
         {tiered_hits}/{INTERACTIVE_N} vs FIFO {fifo_hits}/{INTERACTIVE_N}; admitted meter \
         {expected} MACs == analytic decode_report sum; stripped config reduces to FIFO"
    );
    Ok(())
}

/// Artifact for a `bench-*` command: `--ckpt` when given (plain
/// checkpoints wrap as dense identity artifacts), otherwise a synthetic
/// mini artifact at `--budget`. `salt` keeps each bench's fallback
/// artifact on its own seed stream.
fn bench_artifact(artifacts: &str, args: &Args, salt: u64) -> Result<(CompressedModel, String)> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let budget: f64 = args.parse_num("budget", 0.5)?;
    match args.get("ckpt") {
        Some(path) => {
            let cfg = serve_cfg(artifacts);
            Ok((load_artifact_or_ckpt(&cfg, path)?, path.to_string()))
        }
        None => {
            let cfg = ModelConfig::mini();
            println!(
                "no --ckpt: benchmarking a synthetic mini artifact \
                 (rom-weight-svd @ {:.0}% budget)",
                budget * 100.0
            );
            Ok((serve::demo_artifact(&cfg, budget, seed ^ salt)?, format!("mini@{budget:.2}")))
        }
    }
}

fn cmd_bench_serve(artifacts: &str, args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let (cm, label) = bench_artifact(artifacts, args, 0xBE7C)?;
    let requests: usize = args.parse_num("requests", 8)?;
    let seq: usize = args.parse_num("seq", 32)?;
    let workers: usize = args.parse_num("workers", 2)?;
    let batch: usize = args.parse_num("batch", 4)?;
    let exec = exec_from(args)?;
    println!(
        "bench-serve {label}: {requests} requests x {seq} tokens, {workers} workers \
         (batch {batch}, {} threads)",
        exec.resolve()
    );
    let bench = llm_rom::coordinator::serve_bench(
        &cm,
        requests,
        seq,
        ServeConfig { workers, max_batch: batch, exec },
        seed,
    )?;
    println!("{}", bench.format());
    write_bench_json(args, &bench.to_json())?;
    Ok(())
}

/// `repro bench-kernels`: the serving hot path's matmul variants head to
/// head — scalar, SIMD-dotted blocked, packed-panel, int8-quantized — on
/// one microbenchmark shape, plus factored vs factored-quant tokens/sec
/// on the artifact itself. `make bench` writes this as
/// `BENCH_kernels.json`; `scripts/verify.sh` gates the committed `gflops`
/// and `tokens_per_s` samples against a fresh run.
fn cmd_bench_kernels(artifacts: &str, args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let (cm, label) = bench_artifact(artifacts, args, 0x4E75)?;
    let exec = exec_from(args)?;
    println!(
        "bench-kernels {label}: scalar vs SIMD vs packed vs quantized ({} threads)",
        exec.resolve()
    );
    let bench = llm_rom::coordinator::kernels_bench(&cm, exec, seed)?;
    println!("{}", bench.format());
    write_bench_json(args, &bench.to_json())?;
    Ok(())
}

/// Write a benchmark's JSON payload when `--json FILE` was given.
fn write_bench_json(args: &Args, payload: &llm_rom::util::json::Json) -> Result<()> {
    if let Some(path) = args.get("json") {
        ensure_parent(path)?;
        std::fs::write(path, format!("{payload}\n"))
            .with_context(|| format!("write benchmark JSON {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Load a `.rtz` for the decode/serve paths: a compressed artifact when it
/// carries `__compress_meta__`, otherwise a plain checkpoint wrapped as a
/// dense identity artifact (so `repro generate` also works on `repro
/// train` output).
fn load_artifact_or_ckpt(cfg: &ModelConfig, path: &str) -> Result<CompressedModel> {
    match CompressedModel::load(cfg, path) {
        Ok(cm) => Ok(cm),
        // only the "not a compressed artifact" failure falls back to the
        // plain-checkpoint path — a *corrupt* artifact (bad sidecar, bad
        // metadata) must surface its own diagnosis, not silently serve
        // dense as an identity
        Err(e) if e.to_string().contains(&format!("no `{}` entry", compress::META_KEY)) => {
            let params = ParamStore::load(cfg, path)
                .with_context(|| format!("load {path} as a plain checkpoint"))?;
            Ok(CompressedModel::identity(
                params,
                Provenance {
                    method: "dense".into(),
                    global_budget: 1.0,
                    schedule: ModuleSchedule { start_block: cfg.n_layers, module_budget: 1.0 },
                    calib_label: "none".into(),
                    calib_rows: 0,
                    calib_seq: 0,
                },
            ))
        }
        Err(e) => Err(e),
    }
}

/// Drive `requests` through the scheduler — on the event-stream path when
/// `--stream`/`--cancel-after` ask for it (printing `Token` events as they
/// are produced, flushed per token), otherwise as one batch run. Token
/// payloads and results are identical either way; streaming only changes
/// *when* the caller sees them.
fn run_generate(
    scheduler: &DecodeScheduler,
    requests: Vec<GenRequest>,
    stream: bool,
    cancel_after: Option<usize>,
    inline_text: bool,
) -> Result<(Vec<llm_rom::decode::GenResult>, llm_rom::decode::DecodeStats)> {
    use llm_rom::decode::{EventKind, StreamControl};
    use std::io::Write;
    if !stream && cancel_after.is_none() {
        return scheduler.run(requests);
    }
    let mut out = std::io::stdout();
    let res = scheduler.run_streaming(requests, |ev| {
        if let EventKind::Token { index, token, text } = &ev.kind {
            if stream {
                if inline_text {
                    let _ = write!(out, "{text}");
                } else {
                    let _ = write!(out, "r{}:{token} ", ev.id);
                }
                let _ = out.flush(); // the whole point: per-token delivery
            }
            if cancel_after.is_some_and(|n| index + 1 >= n) {
                return StreamControl::Cancel;
            }
        }
        StreamControl::Continue
    })?;
    if stream {
        println!();
    }
    Ok(res)
}

/// Printable admission seq (`-` for requests evicted straight from the
/// queue, which never held a slot).
fn admitted_label(admitted: Option<usize>) -> String {
    admitted.map(|a| a.to_string()).unwrap_or_else(|| "-".into())
}

fn cmd_generate(artifacts: &str, args: &Args) -> Result<()> {
    use llm_rom::data::{Tokenizer, BOS};
    let seed: u64 = args.parse_num("seed", 0)?;
    let exec = exec_from(args)?;
    let stream = args.get("stream").is_some();
    let (obs, trace_out) = obs_from(args)?;
    if args.get("self-check").is_some() {
        if args.get("speculative").is_some() {
            anyhow::ensure!(!stream, "--speculative self-check does not take --stream");
            anyhow::ensure!(
                trace_out.is_none(),
                "--trace-out applies to the non-speculative self-check"
            );
            return speculative_self_check(seed, exec);
        }
        if stream {
            anyhow::ensure!(
                trace_out.is_none(),
                "--trace-out applies to the non-stream self-check (drop --stream)"
            );
            return stream_self_check(seed, exec);
        }
        return decode_self_check(seed, exec, obs, trace_out.as_deref());
    }
    anyhow::ensure!(
        args.get("speculative").is_none(),
        "--speculative requires --self-check (use --draft for real workloads)"
    );
    anyhow::ensure!(trace_out.is_none(), "--trace-out requires --self-check for `generate`");
    let path = args.get("ckpt").context("--ckpt required (or --self-check)")?;
    let cfg = serve_cfg(artifacts);
    let cm = load_artifact_or_ckpt(&cfg, path)?;
    let mode = match args.get("mode") {
        None => ExecMode::Factored,
        Some(s) => ExecMode::parse(s)?,
    };
    let model = ServeModel::from_artifact(&cm, mode)?;
    anyhow::ensure!(
        args.get("spec-k").is_none() || args.get("draft").is_some(),
        "--spec-k requires --draft"
    );
    let spec_k: usize = args.parse_num("spec-k", 4)?;
    let draft_model: Option<ServeModel> = match args.get("draft") {
        None => None,
        Some(draft_path) => {
            let draft_cm = load_artifact_or_ckpt(&cfg, draft_path)?;
            cm.check_spec_draft(&draft_cm)?;
            Some(ServeModel::from_artifact(&draft_cm, mode)?)
        }
    };
    let max_new: usize = args.parse_num("max-new", 48)?;
    let temp: f32 = args.parse_num("temp", 0.0)?;
    let top_k: usize = args.parse_num("top-k", 0)?;
    let slots: usize = args.parse_num("slots", 4)?;
    let cap_mb: usize = args.parse_num("kv-cap-mb", 0)?;
    let max_cache_bytes = if cap_mb > 0 { Some(cap_mb * 1_000_000) } else { None };
    let sampling = Sampling::parse(temp, top_k)?;
    let deadline_s: Option<f64> = match args.get("deadline-ms") {
        None => None,
        Some(_) => Some(args.parse_num("deadline-ms", 0.0f64)? / 1e3),
    };
    let cancel_n: usize = args.parse_num("cancel-after", 0)?;
    let cancel_after = if cancel_n > 0 { Some(cancel_n) } else { None };
    let spec_k_eff = if draft_model.is_some() { spec_k.max(1) } else { 0 };

    match args.get("prompt") {
        Some(prompt) => {
            // single-request decode of a text prompt
            let tk = Tokenizer::new();
            let mut ids = vec![BOS];
            ids.extend(tk.encode(prompt));
            let config = DecodeConfig {
                slots: 1,
                capacity: ids.len() + max_new,
                max_new,
                sampling,
                seed,
                exec,
                max_cache_bytes,
                spec_k: spec_k_eff,
                ..DecodeConfig::default()
            };
            let scheduler = match &draft_model {
                Some(d) => DecodeScheduler::with_draft(&model, d, config)?,
                None => DecodeScheduler::new(&model, config),
            };
            let reqs = vec![GenRequest { id: 0, prompt: ids, max_new: None, deadline_s }];
            if stream {
                use std::io::Write;
                print!("{prompt}");
                let _ = std::io::stdout().flush();
            }
            let (results, stats) = run_generate(&scheduler, reqs, stream, cancel_after, true)?;
            let r = &results[0];
            if !stream {
                println!("{}{}", prompt, r.text);
            }
            eprintln!(
                "\n[{} [{}], {} prompt + {} generated tokens, {} — ttft {:.1}ms, \
                 {:.1} tok/s, {:.3} MMACs/token, {:.2}x fewer MACs than recompute]",
                mode.name(),
                sampling.label(),
                r.prompt_len,
                r.tokens.len(),
                r.finish.name(),
                r.ttft_s * 1e3,
                stats.tokens_per_s(),
                stats.macs_per_generated_token() as f64 / 1e6,
                stats.mac_savings(),
            );
            if stats.spec_drafted > 0 {
                eprintln!(
                    "[speculative: {}/{} drafted tokens accepted ({:.0}%) over {} rounds]",
                    stats.spec_accepted,
                    stats.spec_drafted,
                    stats.spec_accept_rate() * 100.0,
                    stats.decode_rounds,
                );
            }
        }
        None => {
            // synthetic multi-request workload: the continuous-batching demo
            let n: usize = args.parse_num("requests", 6)?;
            let prompt_len: usize = args.parse_num("prompt-len", 16)?;
            let config = DecodeConfig {
                slots,
                capacity: prompt_len + max_new,
                max_new,
                sampling,
                seed,
                exec,
                max_cache_bytes,
                spec_k: spec_k_eff,
                ..DecodeConfig::default()
            };
            println!(
                "generate [{}] [{}]: {n} synthetic requests x {prompt_len} prompt tokens, \
                 max-new {max_new}, {slots} slots, {} threads",
                mode.name(),
                sampling.label(),
                exec.resolve(),
            );
            let mut reqs = decode::synth_gen_requests(&cfg, n, prompt_len, seed);
            for r in &mut reqs {
                r.deadline_s = deadline_s;
            }
            let scheduler = match &draft_model {
                Some(d) => DecodeScheduler::with_draft(&model, d, config)?,
                None => DecodeScheduler::new(&model, config),
            };
            let (results, stats) = run_generate(&scheduler, reqs, stream, cancel_after, false)?;
            for r in &results {
                let snippet: String = r.text.chars().take(24).collect();
                println!(
                    "  request {:>2}: admitted #{:<2} {} tokens ({}), ttft {:>7.2}ms, \
                     text \"{}\"",
                    r.id,
                    admitted_label(r.admitted),
                    r.tokens.len(),
                    r.finish.name(),
                    r.ttft_s * 1e3,
                    snippet.escape_default(),
                );
            }
            println!(
                "generated {} tokens in {:.3}s — {:.0} tok/s, {:.3} MMACs/token \
                 ({:.2}x fewer than recompute)",
                stats.generated_tokens(),
                stats.core.wall_s,
                stats.tokens_per_s(),
                stats.macs_per_generated_token() as f64 / 1e6,
                stats.mac_savings(),
            );
            println!(
                "ttft p50 {:.2}ms p95 {:.2}ms — inter-token p50 {:.2}ms p95 {:.2}ms — \
                 peak {} active, {} mid-run admissions over {} rounds",
                stats.ttft.p50 * 1e3,
                stats.ttft.p95 * 1e3,
                stats.inter_token.p50 * 1e3,
                stats.inter_token.p95 * 1e3,
                stats.peak_active,
                stats.mid_run_admissions,
                stats.decode_rounds,
            );
            if stats.spec_drafted > 0 {
                println!(
                    "speculative: {}/{} drafted tokens accepted ({:.0}%) over {} rounds",
                    stats.spec_accepted,
                    stats.spec_drafted,
                    stats.spec_accept_rate() * 100.0,
                    stats.decode_rounds,
                );
            }
        }
    }
    Ok(())
}

/// `repro generate --self-check`: fully-offline verification of the decode
/// subsystem on a synthetic factored artifact —
///
/// 1. KV-cached incremental logits (chunked prefill + single-token steps)
///    match the full-recompute forward in both exec modes, and the
///    factored-KV logits match the *dense* recompute logits, all ≤1e-4;
/// 2. greedy KV-cached token streams equal full-recompute streams under
///    continuous batching (more requests than slots, mid-run admission);
/// 3. executed MACs equal `macs::decode_report`'s analytic accounting per
///    request, and factored-KV executes strictly fewer MACs than
///    dense-recompute;
/// 4. the priced, tiered admission scheduler beats FIFO on an adversarial
///    flood-plus-trickle trace ([`scheduler_self_check_phase`]).
///
/// Run by `scripts/verify.sh` next to `repro serve --self-check`, at
/// `--threads 1` and `--threads 4` with an output diff (everything printed
/// is deterministic, so thread-count divergence fails the gate).
fn decode_self_check(
    seed: u64,
    exec: ExecConfig,
    obs: bool,
    trace_out: Option<&std::path::Path>,
) -> Result<()> {
    let cfg = serve::demo_config();
    let cm = serve::demo_artifact(&cfg, 0.5, seed ^ 0xDECD)?;
    anyhow::ensure!(!cm.factors.is_empty(), "demo artifact carries no factors");
    let dense = ServeModel::from_artifact(&cm, ExecMode::Dense)?;
    let fact = ServeModel::from_artifact(&cm, ExecMode::Factored)?;

    // 1. incremental ≡ recompute logits
    let prompt = serve::synth_requests(&cfg, 1, 24, seed)[0].tokens.clone();
    let (full_dense, _) = dense.forward_logits(&prompt)?;
    let incremental = |model: &ServeModel| -> Result<Vec<f32>> {
        let mut cache = KvCache::new(&cfg, prompt.len());
        let mut inc = Vec::new();
        let split = prompt.len() / 2;
        let (l, _) = model.forward_cached(&prompt[..split], &mut cache)?;
        inc.extend(l);
        for &t in &prompt[split..] {
            let (l, _) = model.forward_step(t, &mut cache)?;
            inc.extend(l);
        }
        Ok(inc)
    };
    let max_diff = |a: &[f32], b: &[f32]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0f64, f64::max)
    };
    for (label, model, reference) in [
        ("dense-KV vs dense-recompute", &dense, &full_dense),
        ("factored-KV vs dense-recompute", &fact, &full_dense),
    ] {
        let inc = incremental(model)?;
        let d = max_diff(&inc, reference);
        anyhow::ensure!(d <= 1e-4, "{label}: max |Δlogits| = {d:.3e} > 1e-4");
        println!("[1/4] {label}: max |Δlogits| = {d:.2e} (bound 1e-4)");
    }

    // 2. + 3. greedy streams and MAC accounting under continuous batching
    let reqs = decode::synth_gen_requests(&cfg, 6, 12, seed);
    let config = DecodeConfig {
        slots: 2,
        capacity: 12 + 16,
        max_new: 16,
        sampling: Sampling::Greedy,
        seed,
        eos: None,
        exec,
        ..DecodeConfig::default()
    };
    let mut totals: Vec<(u128, u128)> = Vec::new(); // (cached, recompute) per mode
    for (label, model, acc) in [
        ("dense", &dense, CompressionAccounting::dense()),
        ("factored", &fact, cm.accounting.clone()),
    ] {
        let scheduler = DecodeScheduler::new(model, config);
        let (kv_results, kv_stats) = scheduler.run(reqs.clone())?;
        let (rc_results, _) = decode::run_recompute(model, &reqs, &config)?;
        anyhow::ensure!(kv_results.len() == rc_results.len(), "{label}: result count");
        for (a, b) in kv_results.iter().zip(&rc_results) {
            anyhow::ensure!(a.id == b.id, "{label}: result order");
            anyhow::ensure!(
                a.tokens == b.tokens,
                "{label}: request {} KV stream diverged from recompute",
                a.id
            );
            let rep = macs::decode_report(&cfg, &acc, a.prompt_len, a.tokens.len());
            anyhow::ensure!(
                a.macs == rep.cached_macs(),
                "{label}: request {} executed {} MACs, analytic says {}",
                a.id,
                a.macs,
                rep.cached_macs()
            );
            anyhow::ensure!(
                a.recompute_macs == rep.recompute_macs && b.macs == rep.recompute_macs,
                "{label}: recompute accounting mismatch on request {}",
                a.id
            );
        }
        anyhow::ensure!(
            kv_stats.mid_run_admissions > 0,
            "{label}: 6 requests through 2 slots must admit mid-run"
        );
        println!(
            "[2/4] {label}: {} greedy streams identical KV vs recompute \
             ({} mid-run admissions, peak {} active)",
            kv_results.len(),
            kv_stats.mid_run_admissions,
            kv_stats.peak_active
        );
        totals.push((kv_stats.core.macs, kv_stats.recompute_macs));
    }
    let (dense_recompute, fact_cached) = (totals[0].1, totals[1].0);
    anyhow::ensure!(
        fact_cached < totals[0].0,
        "factored-KV must execute fewer MACs than dense-KV"
    );
    anyhow::ensure!(
        fact_cached < dense_recompute,
        "factored-KV must execute fewer MACs than dense-recompute"
    );
    println!(
        "[3/4] MACs: factored-KV {fact_cached} vs dense-recompute {dense_recompute} \
         ({:.2}x fewer), all equal the analytic decode accounting",
        dense_recompute as f64 / fact_cached as f64
    );

    // 4. the priced, tiered admission scheduler on an adversarial trace
    scheduler_self_check_phase("[4/4]", &fact, &cm.accounting, seed, exec, obs, trace_out)?;

    println!("decode self-check: OK");
    Ok(())
}

/// `repro generate --self-check --speculative`: fully-offline verification
/// of the speculative decoding path on a draft/verifier artifact pair of
/// the same synthetic checkpoint —
///
/// 1. bitwise identity: for every `--spec-k` in {1, 2, 3, 4}, the
///    speculative greedy stream equals the verifier-only greedy stream
///    exactly (the draft model changes wall-clock, never output);
/// 2. exact MAC accounting: the executed MACs of every speculative run
///    equal `macs::spec_report`'s analytic schedule (draft prefill +
///    catch-up + steps, chunked verify, rejected-tail waste all billed);
/// 3. the engine path agrees: a draft-bound [`DecodeScheduler`] produces
///    the same streams as a plain one and reports the same acceptance
///    counters the per-request [`SpecDecoder`] measured.
///
/// Run by `scripts/verify.sh` at `--threads 1` and `--threads 4` with an
/// output diff — the printed acceptance rates are round/MAC-denominated
/// (never wall-clock), so thread-count divergence fails the gate.
fn speculative_self_check(seed: u64, exec: ExecConfig) -> Result<()> {
    use llm_rom::decode::SpecDecoder;
    let cfg = serve::demo_config();
    let verifier_cm = serve::demo_artifact(&cfg, 0.8, seed ^ 0x5BEC)?;
    let draft_cm = serve::demo_artifact(&cfg, 0.35, seed ^ 0x5BEC)?;
    verifier_cm.check_spec_draft(&draft_cm)?;
    let verifier = ServeModel::from_artifact(&verifier_cm, ExecMode::Factored)?;
    let draft = ServeModel::from_artifact(&draft_cm, ExecMode::Factored)?;

    let (n, prompt_len, max_new) = (4usize, 12usize, 16usize);
    let reqs = decode::synth_gen_requests(&cfg, n, prompt_len, seed);
    let config = DecodeConfig {
        slots: 2,
        capacity: prompt_len + max_new,
        max_new,
        sampling: Sampling::Greedy,
        seed,
        eos: None,
        exec,
        ..DecodeConfig::default()
    };
    let (reference, _) = DecodeScheduler::new(&verifier, config).run(reqs.clone())?;

    // 1. + 2. per spec-k: bitwise identity and exact MAC accounting
    for spec_k in [1usize, 2, 3, 4] {
        let spec = SpecDecoder::from_artifacts(&verifier_cm, &draft_cm, ExecMode::Factored, spec_k)?;
        let (mut drafted, mut accepted, mut rounds) = (0usize, 0usize, 0usize);
        for (req, base) in reqs.iter().zip(&reference) {
            let stream = spec.generate(&req.prompt, max_new, None, exec)?;
            anyhow::ensure!(
                stream.tokens == base.tokens,
                "spec-k {spec_k}: request {} speculative stream != verifier-only stream",
                req.id
            );
            let analytic = macs::spec_report(
                &cfg,
                &draft_cm.accounting,
                &verifier_cm.accounting,
                req.prompt.len(),
                &stream.rounds,
            );
            let expected = macs::decode_report(
                &cfg,
                &verifier_cm.accounting,
                req.prompt.len(),
                1,
            )
            .prefill_macs
                + analytic.spec_macs();
            anyhow::ensure!(
                stream.macs == expected,
                "spec-k {spec_k}: request {} executed {} MACs, analytic schedule says {}",
                req.id,
                stream.macs,
                expected
            );
            drafted += stream.drafted();
            accepted += stream.accepted();
            rounds += stream.rounds.len();
        }
        println!(
            "[1/3] spec-k {spec_k}: {n} streams bitwise ≡ verifier-only greedy, \
             MACs ≡ analytic — {accepted}/{drafted} drafted accepted over {rounds} rounds",
        );
    }
    println!("[2/3] executed MACs equal the analytic speculative accounting for every spec-k");

    // 3. the engine path: a draft-bound scheduler is output-invisible
    let spec_config = DecodeConfig { spec_k: 3, ..config };
    let sched = DecodeScheduler::with_draft(&verifier, &draft, spec_config)?;
    let (engine_results, engine_stats) = sched.run(reqs.clone())?;
    for (a, b) in reference.iter().zip(&engine_results) {
        anyhow::ensure!(
            a.tokens == b.tokens && a.finish == b.finish,
            "engine speculative stream diverged on request {}",
            a.id
        );
    }
    anyhow::ensure!(engine_stats.spec_drafted > 0, "engine drafted nothing at spec-k 3");
    println!(
        "[3/3] engine path: {} streams bitwise ≡ verifier-only — acceptance {}/{} \
         ({:.0}%) over {} rounds",
        engine_results.len(),
        engine_stats.spec_accepted,
        engine_stats.spec_drafted,
        engine_stats.spec_accept_rate() * 100.0,
        engine_stats.decode_rounds,
    );
    println!("speculative self-check: OK");
    Ok(())
}

/// `repro generate --stream --self-check`: fully-offline verification of
/// the streaming inference core on a synthetic factored artifact —
///
/// 1. streamed ≡ batch: for every request, the concatenated `Token` event
///    payloads are byte-identical to the batch `run()` token stream, the
///    finish reasons and executed MACs agree, each event stream follows
///    the lifecycle grammar (`Admitted → Prefilled → Token* → Finished`),
///    and TTFT/inter-token samples derive from the event timeline;
/// 2. cancellation: cancelling every request after its 3rd streamed token
///    evicts it mid-flight (`cancelled`, exactly 3 tokens kept) and the
///    freed slots keep serving the queue (mid-run admissions);
/// 3. deadline: an already-expired deadline deterministically yields
///    exactly one token per request (`deadline`), and the evictions free
///    slots for the queued requests.
///
/// Run by `scripts/verify.sh` at `--threads 1` and `--threads 4` with an
/// output diff — everything printed (event order, token counts, reasons)
/// is deterministic, so thread-count divergence fails the gate.
fn stream_self_check(seed: u64, exec: ExecConfig) -> Result<()> {
    use llm_rom::decode::{EventKind, StreamControl};
    let cfg = serve::demo_config();
    let cm = serve::demo_artifact(&cfg, 0.5, seed ^ 0x57E0)?;
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored)?;
    let config = DecodeConfig {
        slots: 2,
        capacity: 12 + 10,
        max_new: 10,
        sampling: Sampling::Greedy,
        seed,
        eos: None,
        exec,
        ..DecodeConfig::default()
    };
    let reqs = decode::synth_gen_requests(&cfg, 6, 12, seed);
    let scheduler = DecodeScheduler::new(&model, config);

    // 1. streamed events ≡ batch results
    let (batch, batch_stats) = scheduler.run(reqs.clone())?;
    let mut events: Vec<(usize, llm_rom::decode::EventKind)> = Vec::new();
    let (streamed, stream_stats) = scheduler.run_streaming(reqs.clone(), |ev| {
        events.push((ev.id, ev.kind.clone()));
        StreamControl::Continue
    })?;
    anyhow::ensure!(batch.len() == streamed.len(), "result counts diverge");
    for (a, b) in batch.iter().zip(&streamed) {
        anyhow::ensure!(a.id == b.id, "result order diverges");
        let from_events: Vec<i32> = events
            .iter()
            .filter(|(id, _)| *id == a.id)
            .filter_map(|(_, k)| match k {
                EventKind::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        anyhow::ensure!(
            from_events == a.tokens && b.tokens == a.tokens,
            "request {}: streamed Token events != batch token stream",
            a.id
        );
        anyhow::ensure!(a.finish == b.finish && a.macs == b.macs, "request {}: bookkeeping", a.id);
        let kinds: Vec<&llm_rom::decode::EventKind> =
            events.iter().filter(|(id, _)| *id == a.id).map(|(_, k)| k).collect();
        anyhow::ensure!(
            matches!(kinds.first(), Some(EventKind::Admitted { .. }))
                && matches!(kinds.get(1), Some(EventKind::Prefilled { .. }))
                && matches!(kinds.last(), Some(EventKind::Finished { .. }))
                && kinds.len() == 3 + a.tokens.len(),
            "request {}: event stream violates Admitted→Prefilled→Token*→Finished",
            a.id
        );
    }
    anyhow::ensure!(
        stream_stats.ttft.n == 6 && stream_stats.inter_token.n == 6 * 9,
        "TTFT/inter-token samples must cover the event timeline"
    );
    anyhow::ensure!(
        stream_stats.core.macs == batch_stats.core.macs,
        "streamed MACs != batch MACs"
    );
    println!(
        "[1/3] streamed ≡ batch: {} requests, {} events, {} tokens — identical streams, \
         reasons, and MACs",
        streamed.len(),
        events.len(),
        stream_stats.generated_tokens(),
    );

    // 2. cancellation mid-flight: every request stops after 3 tokens
    let (cancelled, c_stats) = scheduler.run_streaming(reqs.clone(), |ev| {
        match &ev.kind {
            EventKind::Token { index, .. } if index + 1 >= 3 => StreamControl::Cancel,
            _ => StreamControl::Continue,
        }
    })?;
    for r in &cancelled {
        anyhow::ensure!(
            r.finish.name() == "cancelled" && r.tokens.len() == 3,
            "request {}: expected cancellation after 3 tokens, got {} ({})",
            r.id,
            r.tokens.len(),
            r.finish.name()
        );
    }
    anyhow::ensure!(
        c_stats.mid_run_admissions > 0,
        "cancellations must free slots for the queue"
    );
    println!(
        "[2/3] cancellation: 6/6 requests evicted after exactly 3 tokens, \
         {} mid-run admissions into freed slots",
        c_stats.mid_run_admissions
    );

    // 3. deadline eviction: already-expired deadlines yield exactly one
    // token each (token-boundary enforcement is deterministic)
    let mut dl_reqs = reqs;
    for r in &mut dl_reqs {
        r.deadline_s = Some(0.0);
    }
    let (expired, d_stats) = scheduler.run(dl_reqs)?;
    for r in &expired {
        anyhow::ensure!(
            r.finish.name() == "deadline" && r.tokens.len() == 1 && r.admitted.is_some(),
            "request {}: expected deadline eviction after its prefill token",
            r.id
        );
    }
    anyhow::ensure!(
        d_stats.mid_run_admissions > 0,
        "deadline evictions must free slots for the queue"
    );
    println!(
        "[3/3] deadline: 6/6 requests evicted after exactly 1 token, \
         {} mid-run admissions into freed slots",
        d_stats.mid_run_admissions
    );
    println!("stream self-check: OK");
    Ok(())
}

fn cmd_bench_decode(artifacts: &str, args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let (cm, label) = bench_artifact(artifacts, args, 0xDEC0)?;
    let requests: usize = args.parse_num("requests", 6)?;
    let prompt_len: usize = args.parse_num("prompt-len", 16)?;
    let max_new: usize = args.parse_num("max-new", 24)?;
    // 4 slots: 6 requests still admit mid-run, and decode rounds carry
    // enough concurrent sequences to scale on small core counts
    let slots: usize = args.parse_num("slots", 4)?;
    let exec = exec_from(args)?;
    println!(
        "bench-decode {label}: {requests} requests x {prompt_len} prompt tokens, \
         max-new {max_new}, {slots} slots, {} threads",
        exec.resolve()
    );
    let bench =
        llm_rom::coordinator::decode_bench(&cm, requests, prompt_len, max_new, slots, exec, seed)?;
    println!("{}", bench.format());
    write_bench_json(args, &bench.to_json())?;
    Ok(())
}

/// `repro bench-parallel`: the 1-vs-N-thread scaling comparison on the
/// factored path (serve throughput, decode throughput, offline compress
/// wall-clock), failing hard if any output moves with the thread count.
/// `make bench` writes it as `BENCH_parallel.json`.
fn cmd_bench_parallel(artifacts: &str, args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let (cm, label) = bench_artifact(artifacts, args, 0x9A2A)?;
    let requests: usize = args.parse_num("requests", 8)?;
    let seq: usize = args.parse_num("seq", 32)?;
    let prompt_len: usize = args.parse_num("prompt-len", 16)?;
    let max_new: usize = args.parse_num("max-new", 24)?;
    let slots: usize = args.parse_num("slots", 4)?;
    let threads: usize = match args.parse_num("threads", 0usize)? {
        0 => ExecConfig::auto().resolve().max(2),
        t => t,
    };
    println!(
        "bench-parallel {label}: {requests} requests (serve x{seq} tok, decode \
         x{prompt_len}+{max_new} tok, {slots} slots), 1 vs {threads} threads"
    );
    let bench = llm_rom::coordinator::parallel_bench(
        &cm, requests, seq, prompt_len, max_new, slots, threads, seed,
    )?;
    print!("{}", bench.format());
    anyhow::ensure!(
        bench.serve_logits_match && bench.decode_streams_match,
        "thread-count divergence: logits identical = {}, streams identical = {}",
        bench.serve_logits_match,
        bench.decode_streams_match
    );
    write_bench_json(args, &bench.to_json())?;
    Ok(())
}

fn cmd_daemon(artifacts: &str, args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let exec = exec_from(args)?;
    let (obs, trace_out) = obs_from(args)?;
    if args.get("self-check").is_some() {
        return daemon_self_check(seed, exec, obs, trace_out.as_deref());
    }
    let path = args.get("ckpt").context("--ckpt required (or --self-check)")?;
    let cfg = serve_cfg(artifacts);
    let cm = load_artifact_or_ckpt(&cfg, path)?;
    let mode = match args.get("mode") {
        None => ExecMode::Factored,
        Some(s) => ExecMode::parse(s)?,
    };
    let model = ServeModel::from_artifact(&cm, mode)?;
    anyhow::ensure!(
        args.get("spec-k").is_none() || args.get("draft").is_some(),
        "--spec-k requires --draft"
    );
    // speculative decoding is a deployment decision, fixed at startup —
    // nothing about it is negotiated on the wire
    let draft_model: Option<ServeModel> = match args.get("draft") {
        None => None,
        Some(draft_path) => {
            let draft_cm = load_artifact_or_ckpt(&cfg, draft_path)?;
            cm.check_spec_draft(&draft_cm)?;
            Some(ServeModel::from_artifact(&draft_cm, mode)?)
        }
    };
    let engine = EngineConfig {
        slots: args.parse_num("slots", 4)?,
        queue_cap: args.parse_num("queue-cap", 64)?,
        max_new: args.parse_num("max-new", 32)?,
        seed,
        exec,
        spec_k: if draft_model.is_some() { args.parse_num("spec-k", 4usize)?.max(1) } else { 0 },
        ..EngineConfig::default()
    };
    let config = DaemonConfig {
        addr: args.get_or("addr", "127.0.0.1:8700"),
        engine,
        retry_after_s: args.parse_num("retry-after", 1u32)?,
        obs,
    };
    let server = match &draft_model {
        Some(d) => Daemon::bind_with_draft(&model, d, config)?,
        None => Daemon::bind(&model, config)?,
    };
    println!(
        "daemon [{}{}] listening on http://{} — {} slots, queue {} ({} threads; \
         stop with POST /admin/drain)",
        mode.name(),
        if draft_model.is_some() {
            format!(", speculative k={}", engine.spec_k)
        } else {
            String::new()
        },
        server.addr(),
        engine.slots,
        engine.queue_cap,
        exec.resolve(),
    );
    let report = server.serve()?;
    println!(
        "daemon drained: {} requests ({} scored + {} generated tokens), {} SSE streams, \
         shed {} (429) + {} (503), {} bad requests, {} disconnect cancels",
        report.stats.requests,
        report.stats.scored_tokens,
        report.stats.generated_tokens,
        report.sse_streams,
        report.shed_429,
        report.shed_503,
        report.bad_requests,
        report.disconnect_cancels,
    );
    if let Some(path) = &trace_out {
        write_trace_lines(path, &report.trace)?;
        println!("wrote {} causal-plane events to {}", report.trace.len(), path.display());
    }
    Ok(())
}

/// Write buffered causal-plane JSONL lines (already rendered, no trailing
/// newlines) to `path` as an NDJSON file.
fn write_trace_lines(path: &std::path::Path, lines: &[String]) -> Result<()> {
    if let Some(p) = path.to_str() {
        ensure_parent(p)?;
    }
    let mut out = String::with_capacity(lines.iter().map(|l| l.len() + 1).sum());
    for line in lines {
        out.push_str(line);
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("write trace to {}", path.display()))
}

fn cmd_loadgen(artifacts: &str, args: &Args) -> Result<()> {
    let cfg = serve_cfg(artifacts);
    let lg = LoadgenConfig {
        addr: args.get("addr").context("--addr required (a running `repro daemon`)")?.to_string(),
        connections: args.parse_num("connections", 4)?,
        rps: args.parse_num("rps", 20.0)?,
        duration_s: args.parse_num("duration", 2.0)?,
        prompt_len: args.parse_num("prompt-len", 8)?,
        max_new: args.parse_num("max-new", 8)?,
        stream: args.get("unary").is_none(),
        seed: args.parse_num("seed", 0)?,
        vocab: args.parse_num("vocab", cfg.vocab)?,
        mix: daemon::parse_mix(args.get("mix").unwrap_or("0:1"))?,
        deadline_ms: 250.0,
    };
    println!(
        "loadgen -> http://{}: {} connections, {} rps for {}s ({}, mix {}:{})",
        lg.addr,
        lg.connections,
        lg.rps,
        lg.duration_s,
        if lg.stream { "SSE" } else { "unary" },
        lg.mix.0,
        lg.mix.1,
    );
    let report = daemon::run_loadgen(&lg)?;
    print!("{}", report.format());
    write_bench_json(args, &report.to_json())?;
    Ok(())
}

fn cmd_bench_daemon(artifacts: &str, args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let (cm, label) = bench_artifact(artifacts, args, 0xDA30)?;
    let connections: usize = args.parse_num("connections", 4)?;
    let rps: f64 = args.parse_num("rps", 40.0)?;
    let duration_s: f64 = args.parse_num("duration", 2.0)?;
    let prompt_len: usize = args.parse_num("prompt-len", 8)?;
    let max_new: usize = args.parse_num("max-new", 8)?;
    let slots: usize = args.parse_num("slots", 4)?;
    let queue_cap: usize = args.parse_num("queue-cap", 8)?;
    let mix = daemon::parse_mix(args.get("mix").unwrap_or("0:1"))?;
    let exec = exec_from(args)?;
    println!(
        "bench-daemon {label}: {connections} connections at {rps} rps for {duration_s}s \
         (prompt {prompt_len} + {max_new} new, {slots} slots, queue {queue_cap}, {} threads, \
         mix {}:{})",
        exec.resolve(),
        mix.0,
        mix.1,
    );
    let bench = llm_rom::coordinator::daemon_bench(
        &cm, connections, rps, duration_s, prompt_len, max_new, slots, queue_cap, exec, seed, mix,
    )?;
    println!("{}", bench.format());
    write_bench_json(args, &bench.to_json())?;
    Ok(())
}

/// Collect one full SSE transcript for a request body.
fn sse_collect(
    addr: std::net::SocketAddr,
    body: &llm_rom::util::json::Json,
) -> Result<Vec<(String, String)>> {
    let mut client = HttpClient::connect(addr)?;
    let resp = client.post_json("/v1/generate", body)?;
    anyhow::ensure!(resp.status == 200, "expected 200 SSE stream, got {}", resp.status);
    anyhow::ensure!(resp.is_sse(), "expected an SSE response");
    drain_sse(&mut client)
}

/// Read SSE frames off an already-streaming client until `finished`.
fn drain_sse(client: &mut HttpClient) -> Result<Vec<(String, String)>> {
    let mut frames = Vec::new();
    while let Some(f) = client.next_sse_frame()? {
        let done = f.event == "finished";
        frames.push((f.event, f.data));
        if done {
            break;
        }
    }
    Ok(frames)
}

/// Generate-request envelope for the self-check clients.
fn gen_body(prompt: &[i32], max_new: usize, stream: bool) -> llm_rom::util::json::Json {
    use llm_rom::util::json::Json;
    daemon::wire::obj(vec![
        ("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("max_new", Json::Num(max_new as f64)),
        ("stream", Json::Bool(stream)),
    ])
}

/// `repro daemon --self-check`: fully-offline verification of the
/// HTTP/SSE transport against the in-process engine — client and server
/// in one process over loopback, on a synthetic factored artifact:
///
/// 1. wire ≡ engine: score and unary generate envelopes carry the batch
///    results, SSE transcripts are byte-identical to the in-process
///    event frames, and a malformed body gets a structured 400 envelope;
/// 2. load shedding: with the engine paused (determinism hook), the
///    bounded queue fills to cap and the next request is shed with `429`
///    + `Retry-After`; the resumed streams complete byte-identical;
/// 3. disconnect: dropping a client mid-stream cancels its request at a
///    token boundary and frees the slot (observed via `/healthz`), and a
///    follow-up stream completes byte-identical on the reused slot;
/// 4. drain: `POST /admin/drain` flips `/readyz` to 503, refuses new
///    work with 503, finishes the in-flight streams, and exits;
/// 5. observability: `GET /metrics` parses as Prometheus text at a
///    deterministic quiesce point with counters equal to the analytic
///    accounting exactly (when [`DaemonConfig::obs`] is on; zero engine
///    counters when off), the post-drain registry mirrors the engine's
///    `CoreStats`, and the causal-plane trace parses as JSONL with one
///    `finished` record per request. The in-process reference run always
///    carries the obs plane, so `--no-obs` still proves non-perturbation:
///    phase 1 diffs its SSE frames against the daemon's either way.
///
/// Run by `scripts/verify.sh` at `--threads 1` and `--threads 4` with an
/// output diff — SSE frames mirror the engine's thread-invariant event
/// stream and carry no wall-clock fields, so everything printed is
/// deterministic (and identical with `--no-obs`, the non-perturbation
/// bar).
fn daemon_self_check(
    seed: u64,
    exec: ExecConfig,
    obs: bool,
    trace_out: Option<&std::path::Path>,
) -> Result<()> {
    use llm_rom::obs::{self, MetricsRegistry};
    use std::collections::{BTreeMap, VecDeque};
    use std::sync::Arc;

    let cfg = serve::demo_config();
    let cm = serve::demo_artifact(&cfg, 0.5, seed ^ 0xDA30)?;
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored)?;
    let engine_cfg = EngineConfig {
        slots: 2,
        queue_cap: 3,
        max_new: 6,
        capacity: 8 + 32,
        sampling: Sampling::Greedy,
        seed,
        eos: None,
        exec,
        ..EngineConfig::default()
    };
    // 13 requests, one script for both runs: id 0 scores, id 9 is the
    // stream the client will abandon (long max_new so plenty of frames
    // outlive the hang-up), everything else generates 6 greedy tokens
    let prompts = engine::synth_token_streams(&cfg, 13, 8, seed);
    let script: Vec<InferenceRequest> = prompts
        .iter()
        .enumerate()
        .map(|(id, p)| match id {
            0 => InferenceRequest::score(0, p.clone()),
            9 => InferenceRequest::generate(9, p.clone(), Some(32)),
            _ => InferenceRequest::generate(id, p.clone(), Some(6)),
        })
        .collect();

    // in-process reference: the same requests through one session,
    // collecting the exact frames every SSE response must mirror. The
    // obs plane rides along unconditionally here — phase 1 then diffs
    // these frames against a daemon running with or without it, which is
    // the non-perturbation proof in both directions.
    let core = EngineCore::new(&model, engine_cfg);
    let mut session = core.session();
    let ref_registry = Arc::new(MetricsRegistry::new());
    session.enable_tracing(obs::DEFAULT_TRACE_CAP);
    session.attach_metrics(Arc::clone(&ref_registry));
    let mut expected: BTreeMap<usize, Vec<(String, String)>> = BTreeMap::new();
    let mut queue: VecDeque<InferenceRequest> = script.into();
    while let Some(r) = queue.pop_front() {
        if let Some(back) = session.try_submit(r)? {
            queue.push_front(back);
            session.step()?;
            for ev in session.take_events() {
                let (e, d) = daemon::wire::event_sse(&ev);
                expected.entry(ev.id).or_default().push((e.to_string(), d));
            }
        }
    }
    while session.has_work() {
        session.step()?;
        for ev in session.take_events() {
            let (e, d) = daemon::wire::event_sse(&ev);
            expected.entry(ev.id).or_default().push((e.to_string(), d));
        }
    }
    let ref_trace = session.take_trace();
    let (reference, ref_stats) = session.finish();
    anyhow::ensure!(reference.len() == 13, "reference run retired {} of 13", reference.len());

    // the reference run drained cleanly, so its flight recorder must
    // replay into the session's accounting *exactly* — and the timing
    // registry must agree counter for counter (silent: printed output is
    // identical with --no-obs)
    let replay = obs::reconstruct(&ref_trace);
    anyhow::ensure!(
        replay.enqueued == 13 && replay.admitted == 13 && replay.finished == 13,
        "reference trace lifecycle counts off: {replay:?}"
    );
    anyhow::ensure!(
        replay.admitted_macs == ref_stats.admitted_macs && replay.executed_macs == ref_stats.macs,
        "reference trace MACs diverge from CoreStats: replay {replay:?} vs {ref_stats:?}"
    );
    anyhow::ensure!(
        replay.decode_rounds == ref_stats.decode_rounds,
        "reference trace decode rounds {} != stats {}",
        replay.decode_rounds,
        ref_stats.decode_rounds
    );
    anyhow::ensure!(
        ref_registry.requests.get() == 13
            && ref_registry.scored_tokens.get() == ref_stats.scored_tokens as u64
            && ref_registry.generated_tokens.get() == ref_stats.generated_tokens as u64
            && ref_registry.executed_macs.get() == obs::sat_u64(ref_stats.macs)
            && ref_registry.admitted_macs.get() == obs::sat_u64(ref_stats.admitted_macs)
            && ref_registry.cancelled.get() == 0,
        "reference registry diverges from CoreStats"
    );

    let server = Daemon::bind(
        &model,
        DaemonConfig { addr: "127.0.0.1:0".into(), engine: engine_cfg, retry_after_s: 1, obs },
    )?;
    let ctl = server.control();
    let addr = server.addr();
    // what admission has charged by the deterministic quiesce point after
    // phase 3: ids 0..=10 (score 8 tokens, nine 6-token generates, the
    // abandoned 32-token stream) — the /metrics scrape asserts the
    // counter equals this analytic total exactly
    let price = macs::CostModel::new(model.config(), model.macs_for(1));
    let quiesce_admitted = price.score(8).total_macs()
        + 9 * price.generate(8, 6).total_macs()
        + price.generate(8, 32).total_macs();
    let report = std::thread::scope(|s| -> Result<llm_rom::daemon::DaemonReport> {
        let srv = s.spawn(move || server.serve());
        let phases =
            self_check_phases(addr, &ctl, &prompts, &expected, &reference, obs, quiesce_admitted);
        if phases.is_err() {
            // unblock the daemon so the scope can join even when a phase
            // assertion fails mid-run
            ctl.drain();
        }
        let outcome = srv.join().map_err(|_| anyhow::anyhow!("daemon thread panicked"))?;
        phases?;
        let report = outcome?;
        println!(
            "[4/5] drain: readyz → 503, new work shed with 503, in-flight streams ran to \
             completion, daemon exited"
        );
        Ok(report)
    })?;
    anyhow::ensure!(report.stats.requests == 13, "retired {} of 13", report.stats.requests);
    anyhow::ensure!(report.stats.scored_tokens == 8, "scored {} of 8", report.stats.scored_tokens);
    anyhow::ensure!(
        report.stats.cancelled == 1
            && report.disconnect_cancels == 1
            && report.shed_429 == 1
            && report.shed_503 == 1
            && report.bad_requests == 1,
        "daemon report counters off: {report:?}"
    );
    anyhow::ensure!(report.sse_streams == 11, "opened {} of 11 streams", report.sse_streams);

    // [5/5] the daemon's own obs plane, post-drain. With obs on, the
    // timing registry must mirror the drained engine's CoreStats counter
    // for counter and the causal trace must parse as JSONL with one
    // `finished` record per request; with --no-obs both stay empty. The
    // printed line is identical either way — verify.sh diffs the two.
    let registry = ctl.metrics();
    if obs {
        anyhow::ensure!(
            registry.requests.get() == report.stats.requests as u64
                && registry.scored_tokens.get() == report.stats.scored_tokens as u64
                && registry.generated_tokens.get() == report.stats.generated_tokens as u64
                && registry.executed_macs.get() == obs::sat_u64(report.stats.macs)
                && registry.admitted_macs.get() == obs::sat_u64(report.stats.admitted_macs)
                && registry.cancelled.get() == report.stats.cancelled as u64
                && registry.decode_rounds.get() == report.stats.decode_rounds as u64,
            "daemon registry diverges from the drained CoreStats"
        );
        let finished = report
            .trace
            .iter()
            .filter(|line| line.contains("\"ev\":\"finished\""))
            .count();
        anyhow::ensure!(
            finished == 13,
            "daemon trace carries {finished} finished records, want 13"
        );
        for line in &report.trace {
            llm_rom::util::json::Json::parse(line)
                .with_context(|| format!("trace line is not valid JSON: {line}"))?;
        }
    } else {
        anyhow::ensure!(
            registry.requests.get() == 0 && report.trace.is_empty(),
            "--no-obs must leave the engine registry and trace empty"
        );
    }
    if let Some(path) = trace_out {
        write_trace_lines(path, &report.trace)?;
    }
    println!(
        "[5/5] observability: /metrics counters equal the analytic accounting, registry \
         mirrors the drained CoreStats, causal trace replays the lifecycle (bitwise \
         identical output with --no-obs)"
    );
    println!(
        "daemon self-check: OK ({} requests, {} SSE streams, 1 shed_429, 1 shed_503, \
         1 disconnect cancel)",
        report.stats.requests,
        report.sse_streams
    );
    Ok(())
}

/// The client-side script of [`daemon_self_check`]: phases 1–3 plus the
/// drain sequence of phase 4 (its completion line prints after the
/// daemon thread joins) and the `/metrics` scrape half of phase 5 —
/// taken at the deterministic quiesce point after phase 3, where exactly
/// ids 0..=10 have retired (`expected_admitted` is their analytic
/// admission charge).
fn self_check_phases(
    addr: std::net::SocketAddr,
    ctl: &llm_rom::daemon::DaemonControl,
    prompts: &[Vec<i32>],
    expected: &std::collections::BTreeMap<usize, Vec<(String, String)>>,
    reference: &[llm_rom::engine::FinishedRequest],
    obs: bool,
    expected_admitted: u128,
) -> Result<()> {
    use anyhow::ensure;
    use llm_rom::obs;
    use llm_rom::util::json::Json;
    use std::time::{Duration, Instant};

    // [1/5] wire ≡ engine on every request shape
    let mut c = HttpClient::connect(addr)?;
    let score_body = daemon::wire::obj(vec![(
        "tokens",
        Json::Arr(prompts[0].iter().map(|&t| Json::Num(t as f64)).collect()),
    )]);
    let resp = c.post_json("/v1/score", &score_body)?;
    ensure!(resp.status == 200, "score request: status {}", resp.status);
    let env = resp.json()?;
    ensure!(env.get("id")?.as_usize()? == 0, "score envelope id");
    ensure!(env.get("reason")?.as_str()? == reference[0].reason.name(), "score reason");
    ensure!(env.get("prompt_len")?.as_usize()? == 8, "score prompt_len");
    let resp = c.post_json("/v1/generate", &gen_body(&prompts[1], 6, false))?;
    ensure!(resp.status == 200, "unary generate: status {}", resp.status);
    let env = resp.json()?;
    let got: Vec<i32> =
        env.get("tokens")?.as_arr()?.iter().map(|t| t.as_i32()).collect::<Result<_>>()?;
    ensure!(got == reference[1].tokens, "unary generate tokens diverge from in-process run");
    ensure!(env.get("reason")?.as_str()? == reference[1].reason.name(), "unary reason");
    for id in 2usize..=5 {
        let frames = sse_collect(addr, &gen_body(&prompts[id], 6, true))?;
        ensure!(
            frames == expected[&id],
            "request {id}: SSE transcript diverges from the in-process event stream"
        );
    }
    let resp = c.post_raw("/v1/generate", b"{not json")?;
    ensure!(resp.status == 400, "malformed body: status {}", resp.status);
    ensure!(
        resp.json()?.get("error")?.get("status")?.as_usize()? == 400,
        "malformed body must return the structured error envelope"
    );
    println!(
        "[1/5] wire ≡ engine: score + unary envelopes and 4 SSE streams byte-identical \
         to the in-process run; malformed body → 400 envelope"
    );

    // [2/5] deterministic load shedding: pause, fill the queue to cap,
    // overflow sheds 429, resume completes everything
    ctl.pause();
    let mut queued: Vec<HttpClient> = Vec::new();
    for id in 6usize..=8 {
        let mut qc = HttpClient::connect(addr)?;
        let resp = qc.post_json("/v1/generate", &gen_body(&prompts[id], 6, true))?;
        ensure!(resp.status == 200 && resp.is_sse(), "queued stream {id}: {}", resp.status);
        queued.push(qc);
    }
    let t0 = Instant::now();
    while ctl.snapshot().queue_depth < 3 {
        ensure!(t0.elapsed() < Duration::from_secs(10), "queue never reached cap");
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut shed = HttpClient::connect(addr)?;
    let resp = shed.post_json("/v1/generate", &gen_body(&prompts[8], 6, true))?;
    ensure!(resp.status == 429, "over-capacity request: status {}", resp.status);
    // phase [1/5] already ran traffic, so the header carries the meter's
    // drain-time estimate — wall-clock dependent, so assert presence only
    ensure!(
        matches!(resp.header("retry-after").map(|v| v.parse::<u64>()), Some(Ok(s)) if s >= 1),
        "429 must advertise a positive integer Retry-After"
    );
    ctl.resume();
    for (id, qc) in (6usize..=8).zip(queued.iter_mut()) {
        let frames = drain_sse(qc)?;
        ensure!(frames == expected[&id], "resumed stream {id} diverges");
    }
    println!(
        "[2/5] load shedding: queue filled to 3/3 while paused, next request shed with \
         429 + Retry-After; resumed streams byte-identical"
    );

    // [3/5] mid-stream disconnect cancels and frees the slot
    let mut doomed = HttpClient::connect(addr)?;
    let resp = doomed.post_json("/v1/generate", &gen_body(&prompts[9], 32, true))?;
    ensure!(resp.status == 200 && resp.is_sse(), "doomed stream: status {}", resp.status);
    let mut seen = 0usize;
    while let Some(f) = doomed.next_sse_frame()? {
        if f.event == "token" {
            seen += 1;
            if seen == 2 {
                break;
            }
        }
    }
    ensure!(seen == 2, "doomed stream ended before 2 tokens");
    drop(doomed); // hang up mid-stream
    let mut health = HttpClient::connect(addr)?;
    let t0 = Instant::now();
    loop {
        let h = health.get("/healthz")?.json()?;
        if h.get("cancelled")?.as_usize()? == 1 && h.get("active")?.as_usize()? == 0 {
            break;
        }
        ensure!(
            t0.elapsed() < Duration::from_secs(10),
            "daemon never cancelled the dropped stream"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let frames = sse_collect(addr, &gen_body(&prompts[10], 6, true))?;
    ensure!(frames == expected[&10], "post-cancel stream diverges");
    println!(
        "[3/5] disconnect: mid-stream hang-up cancelled the request and freed its slot; \
         follow-up stream byte-identical"
    );

    // [5/5] groundwork, asserted silently so stdout stays identical with
    // --no-obs: scrape /metrics at this quiesce point — ids 0..=10 have
    // retired, nothing is in flight, so every asserted counter is
    // deterministic (executed MACs are not: the disconnect lands at a
    // wall-clock-dependent token boundary — deliberately not asserted)
    let resp = health.get("/metrics")?;
    ensure!(resp.status == 200, "metrics: status {}", resp.status);
    ensure!(
        resp.header("content-type").is_some_and(|ct| ct.starts_with("text/plain")),
        "metrics content type"
    );
    let text = std::str::from_utf8(&resp.body).context("metrics body is not UTF-8")?;
    let samples = obs::parse_exposition(text).context("GET /metrics must parse as Prometheus text")?;
    let sample = |key: &str| samples.get(key).copied().unwrap_or(f64::NAN);
    // wire-level counters live on the daemon, not the engine session, so
    // they are exact in both obs modes
    ensure!(
        sample("repro_daemon_sse_streams_total") == 9.0
            && sample("repro_daemon_shed_429_total") == 1.0
            && sample("repro_daemon_bad_requests_total") == 1.0
            && sample("repro_daemon_disconnect_cancels_total") == 1.0,
        "daemon wire counters off at the quiesce point"
    );
    if obs {
        ensure!(
            sample("repro_requests_total") == 11.0
                && sample("repro_scored_tokens_total") == 8.0
                && sample("repro_cancelled_total") == 1.0,
            "engine lifecycle counters off at the quiesce point"
        );
        ensure!(
            sample("repro_admitted_macs_total") == obs::sat_u64(expected_admitted) as f64,
            "admitted-MAC counter {} != analytic charge {}",
            sample("repro_admitted_macs_total"),
            expected_admitted
        );
        ensure!(
            sample("repro_tier_admissions_total{tier=\"batch\"}") == 11.0,
            "tier label family off at the quiesce point"
        );
        ensure!(
            samples.contains_key("repro_ttft_seconds_bucket{le=\"+Inf\"}")
                && samples.contains_key("repro_phase_seconds_bucket{phase=\"decode\",le=\"+Inf\"}"),
            "latency histogram families missing from the exposition"
        );
    } else {
        ensure!(
            sample("repro_requests_total") == 0.0,
            "--no-obs must leave the engine registry detached"
        );
    }

    // [4/5] graceful drain with streams in flight
    let mut in_a = HttpClient::connect(addr)?;
    let ra = in_a.post_json("/v1/generate", &gen_body(&prompts[11], 6, true))?;
    ensure!(ra.status == 200 && ra.is_sse(), "in-flight stream A: {}", ra.status);
    let mut in_b = HttpClient::connect(addr)?;
    let rb = in_b.post_json("/v1/generate", &gen_body(&prompts[12], 6, true))?;
    ensure!(rb.status == 200 && rb.is_sse(), "in-flight stream B: {}", rb.status);
    let mut admin = HttpClient::connect(addr)?;
    let resp = admin.post_json("/admin/drain", &daemon::wire::obj(vec![]))?;
    ensure!(resp.status == 200, "drain: status {}", resp.status);
    let resp = admin.get("/readyz")?;
    ensure!(resp.status == 503, "readyz while draining: status {}", resp.status);
    let resp = admin.post_json("/v1/generate", &gen_body(&prompts[12], 6, true))?;
    ensure!(resp.status == 503, "post-drain submission: status {}", resp.status);
    for (id, qc) in [(11usize, &mut in_a), (12usize, &mut in_b)] {
        let frames = drain_sse(qc)?;
        ensure!(frames == expected[&id], "draining stream {id} diverges");
    }
    Ok(())
}

fn cmd_tables(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let which = args.get_or("table", "all");
    let ft_steps: usize = args.parse_num("finetune", 60)?;
    let budget: f64 = args.parse_num("budget", 0.8)?;
    llm_rom::coordinator::run_tables(&exp, &params, &which, ft_steps, budget)
}

fn cmd_spectrum(artifacts: &str, args: &Args) -> Result<()> {
    use llm_rom::coordinator::spectrum;
    use llm_rom::rom::RomPipeline;
    let rt = Runtime::new(artifacts)?;
    let mut xcfg = xcfg_from(args)?;
    if args.get("rows").is_none() {
        xcfg.calib_rows = 128; // spectra stabilize quickly
    }
    let exp = Experiment::new(&rt, xcfg);
    let params = load_ckpt(&exp, args)?;
    let blocks = match args.get("blocks") {
        None => 0..exp.cfg.n_layers,
        Some(spec) => {
            let (a, b) = spec.split_once("..").context("--blocks a..b")?;
            a.parse().context("blocks start")?..b.parse().context("blocks end")?
        }
    };
    let calib = exp.calibration(exp.xcfg.calib_rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
    let pipeline = RomPipeline::new(&rt);
    let rows = spectrum::measure_spectra(&pipeline, &params, &calib, blocks)?;
    println!("{}", spectrum::format_spectra(&rows));
    println!("(ROM keeps r(b) components; r@99% ≪ dim is the paper's premise)");
    Ok(())
}

fn cmd_cost(artifacts: &str, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let exp = Experiment::new(&rt, xcfg_from(args)?);
    let params = load_ckpt(&exp, args)?;
    let mut report = llm_rom::coordinator::CostReport::default();
    for budget in [0.9, 0.8, 0.5] {
        let cm = exp.compress_method(&params, "rom-feature", budget)?;
        report.push(format!("{:.0}%", budget * 100.0), &cm);
    }
    println!("{}", report.format());
    let bound = llm_rom::coordinator::cost::layerwise_memory_bound(
        &exp.cfg,
        exp.xcfg.calib_rows,
        exp.xcfg.calib_seq,
    );
    println!("layerwise memory bound (this config): {:.1} MB", bound as f64 / 1e6);
    println!(
        "layerwise memory bound (LLaMA-7B @512 rows): {:.2} GB  (paper: <10 GB)",
        llm_rom::coordinator::cost::llama7b_memory_bound_bytes() as f64 / 1e9
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn per_subcommand_flag_specs() {
        // `--ppl` is a boolean only where `eval` declares it…
        let a = Args::parse_from(argv(&["eval", "--ckpt", "c.rtz", "--ppl"])).unwrap();
        assert_eq!(a.get("ppl"), Some("true"));
        // …and is rejected by subcommands that don't declare it, instead
        // of being silently swallowed as a boolean (the old global list).
        assert!(Args::parse_from(argv(&["compress", "--ppl"])).is_err());
        // value-taking flags still take values where declared
        let a = Args::parse_from(argv(&["compress", "--ckpt", "c.rtz", "--method", "rom-feature"]))
            .unwrap();
        assert_eq!(a.get("method"), Some("rom-feature"));
    }

    #[test]
    fn unknown_flags_and_commands_error_with_spec() {
        let e = Args::parse_from(argv(&["eval", "--bogus", "1"])).unwrap_err();
        assert!(e.to_string().contains("--bogus"));
        assert!(e.to_string().contains("--ppl"), "error should print the spec: {e}");
        assert!(Args::parse_from(argv(&["frobnicate"])).is_err());
    }

    #[test]
    fn help_forms() {
        let a = Args::parse_from(argv(&["help", "compress"])).unwrap();
        assert_eq!(a.cmd, "help");
        assert_eq!(a.topic.as_deref(), Some("compress"));
        let a = Args::parse_from(argv(&["--help", "sweep"])).unwrap();
        assert_eq!(a.topic.as_deref(), Some("sweep"));
        let a = Args::parse_from(argv(&["compress", "--help"])).unwrap();
        assert_eq!(a.get("help"), Some("true"));
        assert!(Args::parse_from(argv(&[])).unwrap().cmd == "help");
    }

    #[test]
    fn usage_lists_every_flag() {
        let spec = command_spec("sweep").unwrap();
        let u = usage(spec);
        for f in spec.flags {
            assert!(u.contains(&format!("--{}", f.name)), "{u}");
        }
        assert!(u.contains("--artifacts"));
        let h = general_help();
        for c in COMMANDS {
            assert!(h.contains(c.name));
        }
        assert!(h.contains("rom-feature"));
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse_from(argv(&["compress", "--budget"])).is_err());
        assert!(Args::parse_from(argv(&["eval", "stray"])).is_err());
    }

    #[test]
    fn speculative_and_ladder_flags_parse_where_declared() {
        let a = Args::parse_from(argv(&[
            "generate", "--ckpt", "c.rtz", "--draft", "d.rtz", "--spec-k", "3",
        ]))
        .unwrap();
        assert_eq!(a.get("draft"), Some("d.rtz"));
        assert_eq!(a.get("spec-k"), Some("3"));
        let a = Args::parse_from(argv(&[
            "generate", "--self-check", "--speculative", "--threads", "4",
        ]))
        .unwrap();
        assert_eq!(a.get("speculative"), Some("true"));
        let a = Args::parse_from(argv(&[
            "daemon", "--ckpt", "c.rtz", "--draft", "d.rtz", "--spec-k", "2",
        ]))
        .unwrap();
        assert_eq!(a.get("draft"), Some("d.rtz"));
        let a = Args::parse_from(argv(&["sweep", "--ckpt", "c.rtz", "--budgets", "0.4,0.6,0.8"]))
            .unwrap();
        assert_eq!(a.get("budgets"), Some("0.4,0.6,0.8"));
        // neither flag leaks into subcommands that don't declare it
        assert!(Args::parse_from(argv(&["serve", "--draft", "d.rtz"])).is_err());
        assert!(Args::parse_from(argv(&["compress", "--budgets", "0.5"])).is_err());
    }
}
