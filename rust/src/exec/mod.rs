//! Unified parallel execution core — the one worker-pool substrate behind
//! every compute layer of the crate.
//!
//! Before this module the hot paths ran on three ad-hoc threading islands
//! (the ROM pipeline's `thread::scope` over eigendecompositions, the serve
//! engine's worker threads, and everything else single-threaded). They now
//! all share one substrate:
//!
//! - [`ExecConfig`] — the global `--threads` knob (`0` = all cores),
//!   threaded through the CLI, [`crate::compress::CompressCtx`],
//!   [`crate::serve::ServeConfig`], and [`crate::decode::DecodeConfig`].
//! - [`ExecPool`] — a scoped worker pool with *deterministic* fan-out
//!   primitives: static contiguous chunking, results written into
//!   pre-sized slots, so for any pure per-item function the output is
//!   **bitwise identical for every thread count, including 1**. Callers
//!   that reduce across items (e.g. covariance accumulation) keep the
//!   contract by fixing the reduction tree independently of the worker
//!   count (see `rom::covariance::accumulate_rows_tiled`).
//!
//! The determinism contract is the load-bearing design decision: it makes
//! `--threads` purely a performance knob, asserted (not assumed) by the
//! cross-thread-count tests in `tests/proptests.rs` and by
//! `scripts/verify.sh` running the serve/decode self-checks at both
//! `--threads 1` and `--threads 4`.

/// Span hook for the observability timing plane: receives the label, item
/// count, and wall-clock duration of a pool fan-out. Implementations must
/// be purely observational — [`ExecPool::observe`] guarantees the wrapped
/// closure's behaviour is unchanged whether a sink is attached or not.
pub trait SpanObserver: Sync {
    fn span(&self, label: &'static str, items: usize, seconds: f64);
}

/// Worker threads to use when the knob is `0` (auto).
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The global parallelism knob. `threads == 0` means "all cores".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecConfig {
    pub threads: usize,
}

impl ExecConfig {
    /// Use every available core.
    pub fn auto() -> ExecConfig {
        ExecConfig { threads: 0 }
    }

    /// Single-threaded execution.
    pub fn serial() -> ExecConfig {
        ExecConfig { threads: 1 }
    }

    /// An explicit thread count (`0` = auto).
    pub fn with_threads(threads: usize) -> ExecConfig {
        ExecConfig { threads }
    }

    /// The concrete worker count this config resolves to.
    pub fn resolve(&self) -> usize {
        if self.threads == 0 {
            auto_threads()
        } else {
            self.threads
        }
    }

    /// A pool sized to this config.
    pub fn pool(&self) -> ExecPool {
        ExecPool::new(self.threads)
    }
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig::auto()
    }
}

/// A scoped worker pool over `threads` workers.
///
/// Workers are spawned per call via `std::thread::scope`, so the pool is a
/// plain value (`Copy`) that can be shared freely; there is no channel
/// state and nothing to shut down. Every primitive uses *static*
/// contiguous chunking — chunk boundaries depend only on the item count
/// and the pool size, never on timing — and writes results into pre-sized
/// slots, so output order always equals input order.
#[derive(Debug, Clone, Copy)]
pub struct ExecPool {
    threads: usize,
}

impl ExecPool {
    /// A pool over `threads` workers (`0` = all cores).
    pub fn new(threads: usize) -> ExecPool {
        ExecPool { threads: if threads == 0 { auto_threads() } else { threads } }
    }

    /// The single-threaded pool: every primitive degenerates to a plain
    /// serial loop (no threads are ever spawned).
    pub fn serial() -> ExecPool {
        ExecPool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split this pool's thread budget across `groups` concurrent users,
    /// at least one thread each — the anti-oversubscription story when an
    /// outer fan-out (requests, sequences) nests an inner one (row-sharded
    /// matmuls).
    pub fn split(&self, groups: usize) -> ExecPool {
        ExecPool { threads: (self.threads / groups.max(1)).max(1) }
    }

    /// Map `f` over `items`, returning results in input order.
    ///
    /// Items are split into at most `threads` contiguous chunks; each
    /// worker writes its results into the pre-sized slot range for its
    /// chunk, so the output is identical for any thread count.
    pub fn parallel_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let bounds = chunk_bounds(n, self.threads);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest: &mut [Option<R>] = &mut out[..];
            for &(start, end) in &bounds {
                let (slots, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
                rest = tail;
                let chunk = &items[start..end];
                scope.spawn(move || {
                    for (off, (slot, item)) in slots.iter_mut().zip(chunk).enumerate() {
                        *slot = Some(f(start + off, item));
                    }
                });
            }
        });
        out.into_iter().map(|r| r.expect("exec worker filled every slot")).collect()
    }

    /// Run `f(index, &mut item)` over every item, chunked contiguously
    /// across the workers.
    pub fn parallel_for<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            for (i, t) in items.iter_mut().enumerate() {
                f(i, t);
            }
            return;
        }
        let bounds = chunk_bounds(n, self.threads);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest: &mut [T] = items;
            for &(start, end) in &bounds {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
                rest = tail;
                scope.spawn(move || {
                    for (off, t) in chunk.iter_mut().enumerate() {
                        f(start + off, t);
                    }
                });
            }
        });
    }

    /// Fallible [`ExecPool::parallel_for`]: every chunk stops at its first
    /// error; the first error in *chunk order* is returned (deterministic
    /// for a deterministic `f`). Items after a failing one in the same
    /// chunk are left untouched.
    pub fn try_parallel_for<T, E, F>(&self, items: &mut [T], f: F) -> Result<(), E>
    where
        T: Send,
        E: Send,
        F: Fn(usize, &mut T) -> Result<(), E> + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            for (i, t) in items.iter_mut().enumerate() {
                f(i, t)?;
            }
            return Ok(());
        }
        let bounds = chunk_bounds(n, self.threads);
        let mut outcomes: Vec<Result<(), E>> = Vec::with_capacity(bounds.len());
        outcomes.resize_with(bounds.len(), || Ok(()));
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest: &mut [T] = items;
            for (&(start, end), outcome) in bounds.iter().zip(outcomes.iter_mut()) {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
                rest = tail;
                scope.spawn(move || {
                    for (off, t) in chunk.iter_mut().enumerate() {
                        if let Err(e) = f(start + off, t) {
                            *outcome = Err(e);
                            return;
                        }
                    }
                });
            }
        });
        for o in outcomes {
            o?;
        }
        Ok(())
    }

    /// Partition `data` into unit-aligned contiguous spans (one per
    /// worker) and run `f(first_unit_index, span)` on each — the substrate
    /// of the row-sharded matmul kernels, where `unit` is the output row
    /// width. `data.len()` must be a multiple of `unit`.
    pub fn parallel_chunks<T, F>(&self, data: &mut [T], unit: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(unit > 0, "parallel_chunks: zero unit");
        assert_eq!(data.len() % unit, 0, "parallel_chunks: {} % {unit} != 0", data.len());
        let units = data.len() / unit;
        if self.threads <= 1 || units <= 1 {
            if !data.is_empty() {
                f(0, data);
            }
            return;
        }
        let bounds = chunk_bounds(units, self.threads);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest: &mut [T] = data;
            for &(start, end) in &bounds {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((end - start) * unit);
                rest = tail;
                scope.spawn(move || f(start, chunk));
            }
        });
    }

    /// Run `f` and report its wall-clock duration to `sink` under `label`.
    /// With no sink attached this is a plain call — no clock is read, so
    /// the un-observed path is byte-for-byte the old one. The timing never
    /// feeds back into scheduling; it only lands in the metrics plane.
    pub fn observe<R>(
        &self,
        sink: Option<&dyn SpanObserver>,
        label: &'static str,
        items: usize,
        f: impl FnOnce() -> R,
    ) -> R {
        match sink {
            None => f(),
            Some(obs) => {
                let start = std::time::Instant::now();
                let out = f();
                obs.span(label, items, start.elapsed().as_secs_f64());
                out
            }
        }
    }

    /// Run `f(worker_index)` once per worker concurrently, collecting the
    /// results in worker order — the shape of a shared-queue worker loop
    /// (the serve engine's request workers).
    pub fn broadcast<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.threads <= 1 {
            return vec![f(0)];
        }
        let mut out: Vec<Option<R>> = Vec::with_capacity(self.threads);
        out.resize_with(self.threads, || None);
        std::thread::scope(|scope| {
            let f = &f;
            for (w, slot) in out.iter_mut().enumerate() {
                scope.spawn(move || {
                    *slot = Some(f(w));
                });
            }
        });
        out.into_iter().map(|r| r.expect("broadcast worker finished")).collect()
    }
}

/// Static chunk boundaries: `min(parts, n)` contiguous chunks whose sizes
/// differ by at most one, in index order. Depends only on `(n, parts)`.
fn chunk_bounds(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(n).max(1);
    let (base, rem) = (n / parts, n % parts);
    let mut bounds = Vec::with_capacity(parts);
    let mut start = 0;
    for w in 0..parts {
        let len = base + usize::from(w < rem);
        bounds.push((start, start + len));
        start += len;
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_bounds_cover_exactly_once() {
        for n in [0usize, 1, 2, 5, 7, 64, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let b = chunk_bounds(n, parts);
                assert!(!b.is_empty());
                assert_eq!(b[0].0, 0);
                assert_eq!(b.last().unwrap().1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "contiguous");
                }
                for &(s, e) in &b {
                    assert!(n == 0 || e > s, "no empty chunk for n={n} parts={parts}");
                }
                // sizes differ by at most one
                let sizes: Vec<usize> = b.iter().map(|&(s, e)| e - s).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "n={n} parts={parts}: {sizes:?}");
            }
        }
    }

    #[test]
    fn parallel_map_preserves_order_for_any_thread_count() {
        let items: Vec<usize> = (0..37).collect();
        let want: Vec<usize> = items.iter().map(|x| x * x + 1).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            let pool = ExecPool::new(threads);
            let got = pool.parallel_map(&items, |i, &x| {
                assert_eq!(i, x, "index matches item position");
                x * x + 1
            });
            assert_eq!(got, want, "threads={threads}");
        }
        // empty and singleton inputs
        let empty: Vec<usize> = Vec::new();
        assert!(ExecPool::new(4).parallel_map(&empty, |_, &x: &usize| x).is_empty());
        assert_eq!(ExecPool::new(4).parallel_map(&[9usize], |_, &x| x + 1), vec![10]);
    }

    #[test]
    fn parallel_for_touches_every_item_once() {
        for threads in [1usize, 2, 5, 16] {
            let mut items = vec![0u32; 23];
            ExecPool::new(threads).parallel_for(&mut items, |i, v| *v += i as u32 + 1);
            for (i, v) in items.iter().enumerate() {
                assert_eq!(*v, i as u32 + 1, "threads={threads}");
            }
        }
    }

    #[test]
    fn try_parallel_for_returns_first_error_in_chunk_order() {
        for threads in [1usize, 2, 4] {
            let mut items: Vec<usize> = (0..20).collect();
            let err = ExecPool::new(threads)
                .try_parallel_for(&mut items, |i, _| {
                    if i == 3 || i == 17 {
                        Err(i)
                    } else {
                        Ok(())
                    }
                })
                .unwrap_err();
            assert_eq!(err, 3, "threads={threads}: earliest chunk's error wins");
            let ok: Result<(), usize> =
                ExecPool::new(threads).try_parallel_for(&mut items, |_, _| Ok(()));
            assert!(ok.is_ok());
        }
    }

    #[test]
    fn parallel_chunks_are_unit_aligned_and_disjoint() {
        let unit = 5;
        for threads in [1usize, 2, 3, 7] {
            let mut data = vec![0usize; 9 * unit];
            ExecPool::new(threads).parallel_chunks(&mut data, unit, |first, chunk| {
                assert_eq!(chunk.len() % unit, 0);
                for (off, v) in chunk.iter_mut().enumerate() {
                    *v = first * unit + off; // absolute flat index
                }
            });
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i, "threads={threads}");
            }
        }
        // empty data is a no-op
        let mut empty: Vec<usize> = Vec::new();
        ExecPool::new(4).parallel_chunks(&mut empty, 3, |_, _| panic!("no work"));
    }

    #[test]
    fn broadcast_runs_every_worker() {
        let hits = AtomicUsize::new(0);
        let ids = ExecPool::new(4).broadcast(|w| {
            hits.fetch_add(1, Ordering::Relaxed);
            w
        });
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        assert_eq!(ExecPool::serial().broadcast(|w| w), vec![0]);
    }

    #[test]
    fn observe_runs_closure_and_reports_span() {
        use std::sync::Mutex;
        struct Rec(Mutex<Vec<(&'static str, usize)>>);
        impl SpanObserver for Rec {
            fn span(&self, label: &'static str, items: usize, seconds: f64) {
                assert!(seconds >= 0.0);
                self.0.lock().unwrap().push((label, items));
            }
        }
        let pool = ExecPool::new(2);
        // no sink: plain call
        assert_eq!(pool.observe(None, "prefill", 3, || 41 + 1), 42);
        // sink attached: same result, one span recorded
        let rec = Rec(Mutex::new(Vec::new()));
        let got = pool.observe(Some(&rec), "decode", 5, || "ok");
        assert_eq!(got, "ok");
        assert_eq!(*rec.0.lock().unwrap(), vec![("decode", 5)]);
    }

    #[test]
    fn config_resolution_and_split() {
        assert_eq!(ExecConfig::serial().resolve(), 1);
        assert_eq!(ExecConfig::with_threads(3).resolve(), 3);
        assert!(ExecConfig::auto().resolve() >= 1);
        assert_eq!(ExecConfig::default(), ExecConfig::auto());
        assert_eq!(ExecPool::new(0).threads(), auto_threads());
        let pool = ExecPool::new(8);
        assert_eq!(pool.split(2).threads(), 4);
        assert_eq!(pool.split(3).threads(), 2);
        assert_eq!(pool.split(100).threads(), 1);
        assert_eq!(pool.split(0).threads(), 8);
    }
}
