//! Small shared utilities: deterministic PRNG, timing helpers, latency
//! summaries.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::LatencySummary;
pub use timer::Stopwatch;
