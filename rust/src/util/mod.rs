//! Small shared utilities: deterministic PRNG, timing helpers.

pub mod bench;
pub mod json;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
