//! Small shared utilities: deterministic PRNG, timing helpers, latency
//! summaries and the shared request-lifecycle stats core.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::{LatencySummary, RequestStats};
pub use timer::Stopwatch;
