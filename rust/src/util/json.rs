//! Minimal JSON parser — substrate for reading `artifacts/manifest.json`.
//!
//! The build is fully offline (no serde), so this crate carries its own
//! ~250-line recursive-descent parser. It supports the complete JSON
//! grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null); it does not aim for serde's zero-copy performance — the manifest
//! is parsed once at startup.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow::anyhow!("missing key `{key}`")),
            _ => bail!("not an object (wanted key `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_i32(&self) -> Result<i32> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 {
            bail!("not an integer: {x}");
        }
        Ok(x as i32)
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_str()?.to_string())).collect()
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", escape(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input at byte {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected `{}` at byte {}, found `{}`", b as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected `,` or `}}` at byte {}, found `{}`", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected `,` or `]` at byte {}, found `{}`", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| anyhow::anyhow!("truncated surrogate"))?;
                                    let low = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    bail!("lone high surrogate")
                                }
                            } else {
                                code
                            };
                            out.push(char::from_u32(c).ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                        }
                        c => bail!("bad escape `\\{}`", c as char),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // multi-byte UTF-8: find the full sequence
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    let s = std::str::from_utf8(
                        self.bytes.get(start..end).ok_or_else(|| anyhow::anyhow!("truncated utf8"))?,
                    )?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let x: f64 = s.parse().map_err(|_| anyhow::anyhow!("bad number `{s}` at byte {start}"))?;
        Ok(Json::Num(x))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert_eq!(v.get("d").unwrap(), &Json::Null);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "xs": ["a","b"], "dims": [2, 4]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("xs").unwrap().str_vec().unwrap(), vec!["a", "b"]);
        assert_eq!(v.get("dims").unwrap().usize_vec().unwrap(), vec![2, 4]);
        assert!(v.get("missing").is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
    }

    #[test]
    fn display_roundtrip() {
        let text = r#"{"a":[1,2.5,"x"],"b":{"c":true}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }
}
