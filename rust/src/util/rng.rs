//! Deterministic PRNG (splitmix64 seeding + xoshiro256**).
//!
//! The whole reproduction is seed-stable: data generation, task sampling,
//! and init all flow from explicit seeds, so every table regenerates
//! identically. No external crate — the generator is 40 lines.

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to spread a small seed over the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-task / per-split generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses rejection-free Lemire reduction.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn forks_are_independent_streams() {
        let mut base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
