//! Small-sample-safe latency summaries and the request-lifecycle
//! accounting core shared by every inference front-end.
//!
//! [`RequestStats`] is the common denominator of one engine run —
//! requests completed, tokens delivered, MACs executed, wall clock, and
//! per-request completion latency. The serving engine's
//! [`crate::serve::ServeStats`] and the decode scheduler's
//! [`crate::decode::DecodeStats`] both embed one `RequestStats` core and
//! add only their regime-specific columns (dispatch batches; TTFT /
//! inter-token latency and KV-vs-recompute MACs), so the derived rates
//! are computed in exactly one place.
//!
//! Percentiles use the nearest-rank method over a total order
//! (`f64::total_cmp`), and the degenerate sample counts a light run
//! produces — zero or one completed request — yield well-defined values
//! (0.0 / the lone sample) instead of panicking or indexing out of range.

/// Five-number summary of a latency sample set.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    /// Number of samples summarized.
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl LatencySummary {
    /// Summarize a sample set (any order). Empty input returns the all-zero
    /// summary; a single sample is every percentile of itself.
    pub fn from_unsorted(mut samples: Vec<f64>) -> LatencySummary {
        samples.sort_by(f64::total_cmp);
        let n = samples.len();
        if n == 0 {
            return LatencySummary::default();
        }
        LatencySummary {
            n,
            mean: samples.iter().sum::<f64>() / n as f64,
            p50: percentile(&samples, 0.50),
            p95: percentile(&samples, 0.95),
            max: samples[n - 1],
        }
    }

    /// Summarize a fixed-bound histogram: `counts` holds one per-bucket
    /// (non-cumulative) count per bound plus a final overflow bucket, and
    /// `sum` is the exact sum of all observations (so `mean` stays exact
    /// even though the percentiles quantize to bucket upper bounds).
    ///
    /// Same nearest-rank rule as [`LatencySummary::from_unsorted`], applied
    /// to the histogram's implied sorted order: rank `r` resolves to the
    /// upper bound of the bucket containing the `r`-th observation.
    /// Overflow observations clamp to the last finite bound (the bucket
    /// has no upper edge), which also bounds `max` — callers that track
    /// the exact max separately can patch it in afterwards. Empty
    /// histograms return the all-zero summary.
    pub fn from_histogram(bounds: &[f64], counts: &[u64], sum: f64) -> LatencySummary {
        assert_eq!(
            counts.len(),
            bounds.len() + 1,
            "per-bucket counts must include the overflow bucket"
        );
        let n_u64: u64 = counts.iter().sum();
        if n_u64 == 0 {
            return LatencySummary::default();
        }
        let value_at = |rank: u64| -> f64 {
            let mut seen = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                seen += c;
                if rank <= seen {
                    // overflow bucket clamps to the last finite bound
                    return bounds[i.min(bounds.len() - 1)];
                }
            }
            bounds[bounds.len() - 1]
        };
        let rank_of = |q: f64| -> u64 { ((q * n_u64 as f64).ceil() as u64).clamp(1, n_u64) };
        LatencySummary {
            n: n_u64 as usize,
            mean: sum / n_u64 as f64,
            p50: value_at(rank_of(0.50)),
            p95: value_at(rank_of(0.95)),
            max: value_at(n_u64),
        }
    }
}

/// The accounting every request front-end shares: one completed engine
/// run reduced to requests, tokens, MACs, wall clock, and the
/// per-request completion-latency distribution.
#[derive(Debug, Clone, Default)]
pub struct RequestStats {
    /// Requests completed (including cancelled/deadline-evicted ones).
    pub requests: usize,
    /// Tokens delivered to callers — prompt positions scored on the serve
    /// path, tokens generated on the decode path.
    pub tokens: usize,
    /// MACs actually executed across all requests.
    pub macs: u128,
    /// Wall clock of the whole run.
    pub wall_s: f64,
    /// Per-request completion latency (run start → request finished:
    /// queue wait plus compute, what a caller of a loaded server sees).
    pub latency: LatencySummary,
}

impl RequestStats {
    /// Delivered tokens per wall-clock second.
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Wall clock amortized per delivered token.
    pub fn s_per_token(&self) -> f64 {
        if self.tokens > 0 {
            self.wall_s / self.tokens as f64
        } else {
            0.0
        }
    }

    /// Executed MACs amortized per delivered token.
    pub fn macs_per_token(&self) -> u128 {
        if self.tokens > 0 {
            self.macs / self.tokens as u128
        } else {
            0
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted slice. Total on every
/// input: empty slices give 0.0, a single sample is returned for any `q`,
/// and `q` outside [0, 1] is clamped.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_samples_are_well_defined() {
        // the 0-completed-requests boundary: no panic, no garbage index
        assert_eq!(percentile(&[], 0.95), 0.0);
        let s = LatencySummary::from_unsorted(Vec::new());
        assert_eq!(s.n, 0);
        assert_eq!((s.mean, s.p50, s.p95, s.max), (0.0, 0.0, 0.0, 0.0));
    }

    #[test]
    fn single_sample_is_every_percentile() {
        // the 1-completed-request boundary
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(percentile(&[3.5], q), 3.5, "q={q}");
        }
        let s = LatencySummary::from_unsorted(vec![3.5]);
        assert_eq!(s.n, 1);
        assert_eq!((s.mean, s.p50, s.p95, s.max), (3.5, 3.5, 3.5, 3.5));
    }

    #[test]
    fn two_samples() {
        let sorted = [1.0, 2.0];
        assert_eq!(percentile(&sorted, 0.0), 1.0);
        assert_eq!(percentile(&sorted, 0.5), 1.0);
        assert_eq!(percentile(&sorted, 0.51), 2.0);
        assert_eq!(percentile(&sorted, 1.0), 2.0);
        let s = LatencySummary::from_unsorted(vec![2.0, 1.0]);
        assert_eq!(s.mean, 1.5);
        assert_eq!(s.p50, 1.0);
        assert_eq!(s.p95, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn nearest_rank_on_a_hundred_samples() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&sorted, 0.50), 50.0);
        assert_eq!(percentile(&sorted, 0.95), 95.0);
        assert_eq!(percentile(&sorted, 1.0), 100.0);
        assert_eq!(percentile(&sorted, 0.001), 1.0);
    }

    #[test]
    fn out_of_range_q_is_clamped() {
        let sorted = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&sorted, -1.0), 1.0);
        assert_eq!(percentile(&sorted, 7.0), 3.0);
    }

    #[test]
    fn request_stats_rates() {
        let s = RequestStats {
            requests: 4,
            tokens: 40,
            macs: 4_000,
            wall_s: 2.0,
            latency: LatencySummary::from_unsorted(vec![0.5, 1.0, 1.5, 2.0]),
        };
        assert_eq!(s.tokens_per_s(), 20.0);
        assert_eq!(s.s_per_token(), 0.05);
        assert_eq!(s.macs_per_token(), 100);
        // the degenerate run: every rate is zero, not NaN or a panic
        let z = RequestStats::default();
        assert_eq!(z.tokens_per_s(), 0.0);
        assert_eq!(z.s_per_token(), 0.0);
        assert_eq!(z.macs_per_token(), 0);
    }

    #[test]
    fn histogram_summary_boundary_cases() {
        let bounds = [0.001, 0.01, 0.1];
        // 0 samples: all-zero summary, same as from_unsorted(vec![])
        let s = LatencySummary::from_histogram(&bounds, &[0, 0, 0, 0], 0.0);
        assert_eq!(s, LatencySummary::default());
        // 1 sample: every percentile is its bucket's upper bound
        let s = LatencySummary::from_histogram(&bounds, &[0, 1, 0, 0], 0.004);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 0.004);
        assert_eq!((s.p50, s.p95, s.max), (0.01, 0.01, 0.01));
        // 2 samples in distinct buckets: nearest-rank p50 is the lower one
        let s = LatencySummary::from_histogram(&bounds, &[1, 0, 1, 0], 0.05);
        assert_eq!(s.n, 2);
        assert_eq!(s.mean, 0.025);
        assert_eq!((s.p50, s.p95, s.max), (0.001, 0.1, 0.1));
    }

    #[test]
    fn histogram_summary_single_bucket_and_overflow() {
        // single-bound histogram, all mass in the one finite bucket
        let s = LatencySummary::from_histogram(&[0.5], &[10, 0], 2.0);
        assert_eq!(s.n, 10);
        assert_eq!(s.mean, 0.2);
        assert_eq!((s.p50, s.p95, s.max), (0.5, 0.5, 0.5));
        // overflow observations clamp to the last finite bound
        let s = LatencySummary::from_histogram(&[0.5], &[0, 3], 30.0);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, 10.0);
        assert_eq!((s.p50, s.p95, s.max), (0.5, 0.5, 0.5));
    }

    #[test]
    fn histogram_summary_matches_raw_percentiles_at_bucket_edges() {
        // samples placed exactly on bucket bounds: histogram and raw
        // nearest-rank agree
        let bounds = [1.0, 2.0, 3.0, 4.0];
        let samples = vec![1.0, 2.0, 2.0, 3.0, 4.0];
        let mut counts = [0u64; 5];
        for s in &samples {
            let i = bounds.iter().position(|b| s <= b).unwrap();
            counts[i] += 1;
        }
        let from_hist =
            LatencySummary::from_histogram(&bounds, &counts, samples.iter().sum());
        let from_raw = LatencySummary::from_unsorted(samples);
        assert_eq!(from_hist, from_raw);
    }

    #[test]
    fn summary_orders_inputs() {
        let s = LatencySummary::from_unsorted(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p95, 5.0);
        assert_eq!(s.max, 5.0);
    }
}
