//! Tiny benchmark harness — criterion stand-in for the offline build.
//!
//! Each `[[bench]]` target is a plain `main` (harness = false) that calls
//! [`bench`] for its cases: warmup, then adaptive iteration until the
//! measurement window is filled, reporting mean / p50 / p95 like
//! criterion's summary line. Output is stable text for EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10}  mean {}  p50 {}  p95 {}",
            self.name,
            format!("x{}", self.iters),
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
        )
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:8.3} s")
    } else if s >= 1e-3 {
        format!("{:8.3} ms", s * 1e3)
    } else {
        format!("{:8.3} µs", s * 1e6)
    }
}

/// Run `f` repeatedly for ~`window` seconds (after one warmup call) and
/// report timing stats. The closure should return something observable to
/// keep the optimizer honest; its result is black-boxed.
pub fn bench<T>(name: &str, window: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup + estimate
    let t0 = Instant::now();
    std::hint::black_box(f());
    let est = t0.elapsed().as_secs_f64().max(1e-9);

    // one-shot for cases slower than the window (end-to-end pipeline
    // benches on a 1-core box); otherwise at least 3 samples
    let target_iters = if est >= window.as_secs_f64() {
        1
    } else {
        ((window.as_secs_f64() / est).ceil() as usize).clamp(3, 10_000)
    };
    let mut samples = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        p50_s: p(0.5),
        p95_s: p(0.95),
    };
    println!("{}", r.report());
    r
}

/// Standard measurement window for the bench targets.
pub fn default_window() -> Duration {
    Duration::from_secs_f64(
        std::env::var("BENCH_WINDOW_S").ok().and_then(|s| s.parse().ok()).unwrap_or(2.0),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            std::hint::black_box((0..1000).sum::<usize>())
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s > 0.0);
        assert!(r.p50_s <= r.p95_s + 1e-12);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).contains("s"));
        assert!(fmt_time(0.002).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
    }
}
