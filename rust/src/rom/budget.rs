//! Budget allocation (paper §2.1): global budget -> (how many trailing
//! modules to compress, at what per-module budget, with what ranks).
//!
//! Rank rule: a dense `d2×d1` layer becomes factors of `r(d1+d2)` params,
//! so a module budget `b` maps to `r = ⌊b·d1·d2/(d1+d2)⌋` per matrix.
//! This reproduces the paper's published LLaMA-7B ranks exactly (attn
//! {1228, ·, 675}, ffn {1791, 1373, 985}) — the single exception, attn@0.46
//! printed as 954 instead of 942, corresponds to b=0.466 and is documented
//! as a paper rounding anomaly in the tests.

use crate::model::ModelConfig;

/// Rank of the factored pair for a dense `d_out × d_in` layer at module
/// budget `b` (fraction of the dense parameter count).
pub fn rank_for_budget(d_out: usize, d_in: usize, b: f64) -> usize {
    assert!(b > 0.0 && b <= 1.0, "module budget {b} out of (0, 1]");
    let r = (b * (d_out * d_in) as f64 / (d_out + d_in) as f64) as usize;
    r.max(1).min(d_out.min(d_in))
}

/// Which trailing modules get compressed, and how hard.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModuleSchedule {
    /// First compressed block (blocks `start_block..n_layers`).
    pub start_block: usize,
    /// Per-module parameter budget applied uniformly to the 7 matrices.
    pub module_budget: f64,
}

impl ModuleSchedule {
    pub fn n_compressed(&self, cfg: &ModelConfig) -> usize {
        cfg.n_layers - self.start_block
    }

    pub fn compresses(&self, block: usize) -> bool {
        block >= self.start_block
    }

    /// Achieved global budget (compressed params / dense params), counting
    /// the whole model (embeddings and norms stay dense).
    pub fn global_budget(&self, cfg: &ModelConfig) -> f64 {
        let dense = cfg.n_params() as f64;
        let mut after = dense;
        for b in self.start_block..cfg.n_layers {
            for (_, o, i) in crate::model::macs::block_matrices(cfg, b) {
                let r = rank_for_budget(o, i, self.module_budget);
                after -= (o * i) as f64;
                after += (r * (o + i)) as f64;
            }
        }
        after / dense
    }
}

/// Solve the per-module budget needed to hit `global` when compressing the
/// last `k` modules. Returns `None` when infeasible (`b` would fall outside
/// (0, 1]) — e.g. asking 50% globally from only 2 modules.
pub fn solve_module_budget(cfg: &ModelConfig, k: usize, global: f64) -> Option<f64> {
    assert!(k <= cfg.n_layers);
    let dense = cfg.n_params() as f64;
    // matrix params in the compressed span
    let mut span = 0.0;
    for b in (cfg.n_layers - k)..cfg.n_layers {
        for (_, o, i) in crate::model::macs::block_matrices(cfg, b) {
            span += (o * i) as f64;
        }
    }
    if span == 0.0 {
        return None;
    }
    // dense - span + b·span = global·dense
    let b = (global * dense - (dense - span)) / span;
    (b > 0.0 && b <= 1.0).then_some(b)
}

/// The paper's empirical presets, expressed as module fractions so they
/// scale to any depth: 90% -> last ¼ at 0.60, 80% -> last ⅜ at 0.46,
/// 50% -> last ¾ at 0.33 (on LLaMA-7B: 8/12/24 of 32 modules).
pub fn paper_preset(cfg: &ModelConfig, global: f64) -> ModuleSchedule {
    let l = cfg.n_layers as f64;
    let (frac, b) = if global >= 0.85 {
        (0.25, 0.60)
    } else if global >= 0.65 {
        (0.375, 0.46)
    } else {
        (0.75, 0.33)
    };
    let k = (l * frac).round() as usize;
    ModuleSchedule { start_block: cfg.n_layers - k, module_budget: b }
}

/// All feasible `(k, module_budget)` pairs for a global budget — the
/// search space of the paper's §2.1 empirical selection.
pub fn candidates(cfg: &ModelConfig, global: f64) -> Vec<ModuleSchedule> {
    (1..=cfg.n_layers)
        .filter_map(|k| {
            solve_module_budget(cfg, k, global).map(|b| ModuleSchedule {
                start_block: cfg.n_layers - k,
                module_budget: b,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_ranks_llama7b() {
        // §2.1: attn 4096×4096, ffn 11008×4096
        assert_eq!(rank_for_budget(4096, 4096, 0.60), 1228);
        assert_eq!(rank_for_budget(4096, 4096, 0.33), 675);
        assert_eq!(rank_for_budget(11008, 4096, 0.60), 1791);
        assert_eq!(rank_for_budget(11008, 4096, 0.46), 1373);
        assert_eq!(rank_for_budget(11008, 4096, 0.33), 985);
        // the paper prints 954 for attn@0.46; the formula gives 942, and
        // 954 corresponds to b = 0.466 — documented anomaly:
        assert_eq!(rank_for_budget(4096, 4096, 0.46), 942);
        assert_eq!(rank_for_budget(4096, 4096, 0.466), 954);
    }

    #[test]
    fn rank_bounds() {
        assert_eq!(rank_for_budget(8, 8, 1e-9), 1); // floor at 1
        assert!(rank_for_budget(64, 64, 1.0) <= 64); // cap at min dim
    }

    #[test]
    fn paper_presets_hit_global_budgets_llama7b() {
        let cfg = ModelConfig::llama7b();
        for (g, want_k) in [(0.9, 8), (0.8, 12), (0.5, 24)] {
            let s = paper_preset(&cfg, g);
            assert_eq!(s.n_compressed(&cfg), want_k, "g={g}");
            let achieved = s.global_budget(&cfg);
            assert!((achieved - g).abs() < 0.03, "g={g}: achieved {achieved}");
        }
    }

    #[test]
    fn solve_inverts_global_budget() {
        let cfg = ModelConfig::mini();
        for g in [0.9, 0.8, 0.6, 0.5] {
            for k in 2..=cfg.n_layers {
                if let Some(b) = solve_module_budget(&cfg, k, g) {
                    let s = ModuleSchedule { start_block: cfg.n_layers - k, module_budget: b };
                    let achieved = s.global_budget(&cfg);
                    // rank floor() quantization costs <2%
                    assert!((achieved - g).abs() < 0.02, "g={g} k={k}: {achieved}");
                }
            }
        }
    }

    #[test]
    fn infeasible_budgets_rejected() {
        let cfg = ModelConfig::mini();
        // 50% global from one module is impossible
        assert!(solve_module_budget(&cfg, 1, 0.5).is_none());
        // ~100% from anything is fine (b -> 1)
        assert!(solve_module_budget(&cfg, 4, 0.999).is_some());
    }

    #[test]
    fn candidates_nonempty_and_sorted_by_k() {
        let cfg = ModelConfig::mini();
        let cs = candidates(&cfg, 0.8);
        assert!(!cs.is_empty());
        for w in cs.windows(2) {
            assert!(w[0].start_block > w[1].start_block);
            // deeper compression span -> gentler per-module budget
            assert!(w[0].module_budget <= w[1].module_budget + 1e-12);
        }
    }
}
