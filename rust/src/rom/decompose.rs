//! ROM re-parameterization (paper §2): principal components of the layer
//! output covariance -> low-rank factors `W1 = V_rᵀ`, `W2 = V_r W`.

use anyhow::{bail, Result};

use crate::linalg::{eigh, matmul, EigenDecomposition, Matrix};

/// Low-rank factors of one decomposed layer.
#[derive(Debug, Clone)]
pub struct RomFactors {
    /// `V_rᵀ ∈ R^{d2×r}` — projection back to the output space.
    pub w1: Matrix,
    /// `V_r W ∈ R^{r×d1}` — compressed layer.
    pub w2: Matrix,
    pub rank: usize,
    /// Fraction of covariance eigenvalue mass captured by the top-r modes.
    pub energy: f64,
}

impl RomFactors {
    /// Effective dense weight `W1 W2 = V_rᵀ V_r W` (same shape as the
    /// original — used to run the compressed model through the unmodified
    /// HLO graphs; numerically identical to executing the factored form).
    pub fn effective_weight(&self) -> Matrix {
        matmul(&self.w1, &self.w2)
    }

    pub fn d_out(&self) -> usize {
        self.w1.rows()
    }

    pub fn d_in(&self) -> usize {
        self.w2.cols()
    }

    /// Parameter count of the factored pair.
    pub fn n_params(&self) -> usize {
        self.rank * (self.d_out() + self.d_in())
    }
}

/// Decompose `w` (d2×d1) given the covariance of its calibration outputs
/// (d2×d2) and a target rank.
pub fn decompose_weight(w: &Matrix, cov: &Matrix, rank: usize) -> Result<RomFactors> {
    let d2 = w.rows();
    if cov.rows() != d2 || cov.cols() != d2 {
        bail!("covariance {}x{} does not match d2={d2}", cov.rows(), cov.cols());
    }
    if rank == 0 || rank > d2 {
        bail!("rank {rank} out of [1, {d2}]");
    }
    let dec = eigh(cov)?;
    Ok(factors_from_eigen(w, &dec, rank))
}

/// Same, reusing an existing eigendecomposition (rank sweeps).
pub fn factors_from_eigen(w: &Matrix, dec: &EigenDecomposition, rank: usize) -> RomFactors {
    let vr = dec.vectors.top_rows(rank); // (r, d2)
    let w1 = vr.transpose(); // (d2, r)
    let w2 = matmul(&vr, w); // (r, d1)
    let total: f64 = dec.values.iter().map(|l| l.max(0.0)).sum();
    let kept: f64 = dec.values.iter().take(rank).map(|l| l.max(0.0)).sum();
    let energy = if total > 0.0 { kept / total } else { 1.0 };
    RomFactors { w1, w2, rank, energy }
}

/// Smallest rank capturing at least `energy` of the eigenvalue mass — the
/// energy-based alternative allocator (extension; the paper uses budgets).
pub fn rank_for_energy(dec: &EigenDecomposition, energy: f64) -> usize {
    assert!((0.0..=1.0).contains(&energy));
    let total: f64 = dec.values.iter().map(|l| l.max(0.0)).sum();
    if total == 0.0 {
        return 1;
    }
    let mut acc = 0.0;
    for (i, l) in dec.values.iter().enumerate() {
        acc += l.max(0.0);
        if acc / total >= energy {
            return i + 1;
        }
    }
    dec.values.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_transb_f32;
    use crate::util::Rng;

    /// Build (W, X, Y=XWᵀ, cov(Y)) with X low-rank so ROM can be lossless.
    fn setup(d1: usize, d2: usize, n: usize, x_rank: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_fn(d2, d1, |_, _| rng.normal() * 0.1);
        // X = A B with A (n, x_rank), B (x_rank, d1)
        let a = Matrix::from_fn(n, x_rank, |_, _| rng.normal());
        let b = Matrix::from_fn(x_rank, d1, |_, _| rng.normal());
        let x = matmul(&a, &b);
        let y = matmul(&x, &w.transpose());
        let cov = matmul(&y.transpose(), &y);
        (w, x, cov)
    }

    #[test]
    fn factor_shapes_and_params() {
        let (w, _x, cov) = setup(12, 8, 64, 8, 0);
        let f = decompose_weight(&w, &cov, 3).unwrap();
        assert_eq!(f.w1.rows(), 8);
        assert_eq!(f.w1.cols(), 3);
        assert_eq!(f.w2.rows(), 3);
        assert_eq!(f.w2.cols(), 12);
        assert_eq!(f.n_params(), 3 * (8 + 12));
        assert_eq!(f.effective_weight().rows(), 8);
    }

    #[test]
    fn full_rank_is_exact() {
        let (w, _x, cov) = setup(10, 6, 50, 6, 1);
        let f = decompose_weight(&w, &cov, 6).unwrap();
        // V is orthonormal at full rank -> VᵀV = I -> W_eff = W
        assert!(f.effective_weight().sub(&w).max_abs() < 1e-8);
        assert!((f.energy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lossless_when_activations_lowrank() {
        // If Y lives in an r-dim subspace, rank-r ROM reproduces Y exactly
        // even though W_eff != W: that is the whole point of decomposing in
        // the *feature* space rather than the weight space.
        let (w, x, cov) = setup(16, 12, 80, 4, 2);
        let f = decompose_weight(&w, &cov, 4).unwrap();
        let y = matmul(&x, &w.transpose());
        let y_rom = matmul(&x, &f.effective_weight().transpose());
        assert!(y_rom.sub(&y).max_abs() < 1e-6, "err {}", y_rom.sub(&y).max_abs());
        assert!(f.energy > 1.0 - 1e-9);
    }

    #[test]
    fn reconstruction_error_decreases_with_rank() {
        let (w, x, cov) = setup(14, 10, 120, 10, 3);
        let y = matmul(&x, &w.transpose());
        let dec = eigh(&cov).unwrap();
        let mut prev = f64::INFINITY;
        for rank in [1, 2, 4, 6, 8, 10] {
            let f = factors_from_eigen(&w, &dec, rank);
            let err = matmul(&x, &f.effective_weight().transpose()).sub(&y).frobenius_norm();
            assert!(err <= prev + 1e-9, "rank {rank}: {err} > {prev}");
            prev = err;
        }
        assert!(prev < 1e-6); // full rank exact
    }

    #[test]
    fn rom_beats_weight_svd_on_feature_metric() {
        // ROM minimizes output error under the calibration distribution;
        // truncating W's own SVD ignores the data. With anisotropic X, ROM
        // must win on ‖Y - Ŷ‖.
        let mut rng = Rng::new(4);
        let (d1, d2, n, r) = (16, 12, 200, 3);
        let w = Matrix::from_fn(d2, d1, |_, _| rng.normal() * 0.1);
        // X strongly anisotropic: a few dominant directions
        let mut x = Matrix::zeros(n, d1);
        for i in 0..n {
            for j in 0..d1 {
                let scale = if j < 3 { 10.0 } else { 0.1 };
                x[(i, j)] = rng.normal() * scale;
            }
        }
        let y = matmul(&x, &w.transpose());
        let cov = matmul(&y.transpose(), &y);
        let rom = decompose_weight(&w, &cov, r).unwrap();
        let rom_err = matmul(&x, &rom.effective_weight().transpose()).sub(&y).frobenius_norm();

        // weight-space truncation: top-r left singular vectors of W == top
        // eigenvectors of W Wᵀ
        let wwt = matmul(&w, &w.transpose());
        let dec = eigh(&wwt).unwrap();
        let svd = factors_from_eigen(&w, &dec, r);
        let svd_err = matmul(&x, &svd.effective_weight().transpose()).sub(&y).frobenius_norm();
        assert!(rom_err < svd_err, "rom {rom_err} vs svd {svd_err}");
    }

    #[test]
    fn energy_rank_selection() {
        let (_w, _x, cov) = setup(10, 8, 60, 2, 5);
        let dec = eigh(&cov).unwrap();
        let r = rank_for_energy(&dec, 0.999);
        assert!(r <= 3, "low-rank data should need ~2 modes, got {r}");
        assert_eq!(rank_for_energy(&dec, 0.0), 1);
        // full energy: the selected rank really captures all of the
        // (clamped-positive) eigenvalue mass, and is minimal in doing so
        let r_full = rank_for_energy(&dec, 1.0);
        assert!(r_full >= 1 && r_full <= 8);
        let mass = |k: usize| dec.values.iter().take(k).map(|l| l.max(0.0)).sum::<f64>();
        let total = mass(dec.values.len());
        assert!(mass(r_full) >= total * (1.0 - 1e-12), "rank {r_full} misses mass");
        if r_full > 1 {
            assert!(mass(r_full - 1) < total, "rank {r_full} not minimal");
        }
    }

    #[test]
    fn invalid_inputs_rejected() {
        let (w, _x, cov) = setup(6, 4, 20, 4, 6);
        assert!(decompose_weight(&w, &cov, 0).is_err());
        assert!(decompose_weight(&w, &cov, 5).is_err());
        let bad_cov = Matrix::zeros(3, 3);
        assert!(decompose_weight(&w, &bad_cov, 2).is_err());
    }

    #[test]
    fn f32_consistency_with_hot_path() {
        // factored apply in f32 (runtime path) ≈ f64 reference
        let (w, x, cov) = setup(8, 6, 40, 6, 7);
        let f = decompose_weight(&w, &cov, 3).unwrap();
        let weff = f.effective_weight();
        let x32: Vec<f32> = x.to_f32();
        let w32: Vec<f32> = weff.to_f32();
        let y32 = matmul_transb_f32(&x32, &w32, x.rows(), x.cols(), weff.rows());
        let y64 = matmul(&x, &weff.transpose());
        for i in 0..x.rows() {
            for j in 0..weff.rows() {
                assert!((y32[i * weff.rows() + j] as f64 - y64[(i, j)]).abs() < 1e-3);
            }
        }
    }
}
