//! The layerwise ROM driver (paper §2): stream calibration activations
//! through the model block by block, decompose each of the 7 matrices per
//! compressed module sequentially, and propagate the *compressed*
//! activations forward.
//!
//! Within a module the matrices are processed in dataflow order as four
//! groups — `{wq,wk,wv}` (shared input), `{wo}`, `{w_gate,w_up}`,
//! `{w_down}` — re-running the block's capture graph between groups so each
//! group's calibration outputs already include the error introduced by the
//! groups before it; across modules the streamed hidden states come from
//! the compressed prefix. This is exactly the paper's "ROM of the previous
//! layer generates inputs for the next layer".

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::CalibBatch;
use crate::exec::{ExecConfig, ExecPool};
use crate::linalg::Matrix;
use crate::model::macs::{block_matrices, CompressionAccounting, LayerCompression};
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

use super::budget::{rank_for_budget, ModuleSchedule};
use super::covariance::{
    accumulate_rows_tiled, valid_row_flags, zero_invalid_rows, CovarianceAccumulator,
};
use super::decompose::{decompose_weight, RomFactors};

/// Matrix groups in dataflow order, with their capture names.
const GROUPS: [&[(&str, &str)]; 4] = [
    &[("wq", "y_q"), ("wk", "y_k"), ("wv", "y_v")],
    &[("wo", "y_o")],
    &[("w_gate", "y_gate"), ("w_up", "y_up")],
    &[("w_down", "y_down")],
];

/// Which space the principal components are computed in — the paper's
/// core claim is that **feature-space** decomposition (covariance of the
/// calibration outputs) beats **weight-space** truncation (SVD of W
/// itself) at equal budget. `Weight` exists as the ablation baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecompositionSpace {
    /// Paper §2: eigendecompose cov(Y) over calibration activations.
    Feature,
    /// Ablation: eigendecompose W·Wᵀ (data-free truncated SVD of W).
    Weight,
}

/// ROM pass configuration.
#[derive(Debug, Clone)]
pub struct RomConfig {
    pub schedule: ModuleSchedule,
    /// Use the AOT Pallas Gram kernel for covariance (vs the pure-Rust
    /// accumulator — both paths are exact; the flag exists for the
    /// CPU-only ablation and the perf benches).
    pub pallas_covariance: bool,
    /// Normalize covariance by sample count before eigendecomposition
    /// (does not change eigenvectors; keeps magnitudes stable).
    pub normalize: bool,
    /// Worker-pool budget for the pass: covariance accumulation fans out
    /// over fixed row tiles and eigendecompositions across the matrices of
    /// a group/schedule, both deterministically — results are bitwise
    /// identical for any thread count (supersedes the old `parallel_eigen`
    /// bool).
    pub exec: ExecConfig,
    /// Paper §2 error propagation: calibrate each layer against the
    /// already-compressed prefix (true) or against the original model's
    /// activations (false — ablation).
    pub propagate_errors: bool,
    /// Feature-space (paper) vs weight-space (ablation) decomposition.
    pub space: DecompositionSpace,
}

impl Default for RomConfig {
    fn default() -> Self {
        RomConfig {
            schedule: ModuleSchedule { start_block: 0, module_budget: 0.5 },
            pallas_covariance: true,
            normalize: true,
            exec: ExecConfig::default(),
            propagate_errors: true,
            space: DecompositionSpace::Feature,
        }
    }
}

/// Per-matrix timing record (the paper's §4 "13 s per layer" analog).
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: String,
    /// Seconds spent on capture+covariance for this matrix's group,
    /// amortized over the group's matrices.
    pub covariance_s: f64,
    /// Seconds for eigendecomposition + re-parameterization.
    pub decompose_s: f64,
}

impl LayerTiming {
    pub fn total_s(&self) -> f64 {
        self.covariance_s + self.decompose_s
    }
}

/// Result of a ROM compression pass.
#[derive(Debug)]
pub struct RomModel {
    /// Parameters with `W_eff = W1·W2` substituted for compressed layers —
    /// runs through the unmodified dense HLO graphs.
    pub params: ParamStore,
    /// The factored form of every compressed matrix (for factored-form
    /// execution and accounting).
    pub factors: BTreeMap<String, RomFactors>,
    pub schedule: ModuleSchedule,
    pub timings: Vec<LayerTiming>,
    /// Peak bytes held in calibration captures at any point — the paper's
    /// layerwise-memory-bound argument (§4).
    pub peak_capture_bytes: usize,
}

impl RomModel {
    /// Accounting view (Table 1's #Params / #MACs columns).
    pub fn accounting(&self) -> CompressionAccounting {
        let mut acc = CompressionAccounting::dense();
        for (name, f) in &self.factors {
            acc.set(name, LayerCompression::LowRank { rank: f.rank });
        }
        acc
    }

    pub fn total_rom_seconds(&self) -> f64 {
        self.timings.iter().map(|t| t.total_s()).sum()
    }

    pub fn mean_seconds_per_layer(&self) -> f64 {
        if self.timings.is_empty() {
            0.0
        } else {
            self.total_rom_seconds() / self.timings.len() as f64
        }
    }
}

/// The layerwise compression driver.
pub struct RomPipeline<'rt> {
    runtime: &'rt Runtime,
    cfg: ModelConfig,
}

impl<'rt> RomPipeline<'rt> {
    pub fn new(runtime: &'rt Runtime) -> RomPipeline<'rt> {
        let cfg = ModelConfig::from_manifest(&runtime.manifest().model_config);
        RomPipeline { runtime, cfg }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Run the full ROM pass. `params` is consumed as the starting point;
    /// the returned [`RomModel`] owns the compressed parameters.
    pub fn compress(
        &self,
        params: &ParamStore,
        calib: &[CalibBatch],
        rcfg: &RomConfig,
    ) -> Result<RomModel> {
        if rcfg.space == DecompositionSpace::Weight {
            return compress_weight_space(&self.cfg, params, rcfg);
        }
        if !rcfg.propagate_errors {
            return self.compress_without_propagation(params, calib, rcfg);
        }
        if calib.is_empty() {
            bail!("ROM needs at least one calibration batch");
        }
        let (eb, es) = (self.cfg.eval_batch, self.cfg.eval_seq);
        for b in calib {
            if b.batch != eb || b.seq != es {
                bail!("calibration batch {}x{} != canonical {eb}x{es}", b.batch, b.seq);
            }
        }

        let pool = rcfg.exec.pool();
        let mut params = params.clone();
        let mut factors = BTreeMap::new();
        let mut timings = Vec::new();
        let mut peak_bytes = 0usize;

        // stage 0: embed all calibration chunks
        let embed = params.get("embed")?.clone();
        let mut hidden: Vec<Tensor> = Vec::with_capacity(calib.len());
        for b in calib {
            let tokens = Tensor::from_i32(&[eb, es], b.tokens.clone());
            let out = self.runtime.execute("embed_fwd", &[&embed, &tokens])?;
            hidden.push(out.into_iter().next().unwrap());
        }

        let dims: BTreeMap<String, (usize, usize)> = (0..self.cfg.n_layers)
            .flat_map(|b| block_matrices(&self.cfg, b))
            .map(|(name, o, i)| (name, (o, i)))
            .collect();

        for block in 0..self.cfg.n_layers {
            if rcfg.schedule.compresses(block) {
                for group in GROUPS {
                    let t_cov = Instant::now();
                    let mut accs: BTreeMap<&str, CovarianceAccumulator> = group
                        .iter()
                        .map(|(field, _)| {
                            let name = format!("blocks.{block}.{field}");
                            (*field, CovarianceAccumulator::new(dims[&name].0))
                        })
                        .collect();

                    for (bi, cb) in calib.iter().enumerate() {
                        let outs = self.block_capture(&params, block, &hidden[bi])?;
                        let bytes: usize = outs.values().map(|t| t.len() * 4).sum::<usize>()
                            + hidden.iter().map(|t| t.len() * 4).sum::<usize>();
                        peak_bytes = peak_bytes.max(bytes);
                        for (field, cap_name) in group {
                            let cap = outs
                                .get(*cap_name)
                                .with_context(|| format!("capture {cap_name} missing"))?;
                            self.accumulate(
                                accs.get_mut(field).unwrap(),
                                cap,
                                cb,
                                rcfg.pallas_covariance,
                                &pool,
                            )?;
                        }
                    }
                    let covariance_s = t_cov.elapsed().as_secs_f64() / group.len() as f64;

                    // decompose every matrix in the group
                    let jobs: Vec<(String, Matrix, Matrix, usize)> = group
                        .iter()
                        .map(|(field, _)| {
                            let name = format!("blocks.{block}.{field}");
                            let (d_out, d_in) = dims[&name];
                            let w = params.get(&name)?.to_matrix()?;
                            let cov = accs[field].finalize(rcfg.normalize);
                            let rank = rank_for_budget(d_out, d_in, rcfg.schedule.module_budget);
                            Ok((name, w, cov, rank))
                        })
                        .collect::<Result<_>>()?;

                    let results = decompose_jobs(jobs, &pool)?;
                    for (name, f, secs) in results {
                        params.set(&name, Tensor::from_matrix(&f.effective_weight()))?;
                        timings.push(LayerTiming {
                            name: name.clone(),
                            covariance_s,
                            decompose_s: secs,
                        });
                        factors.insert(name, f);
                    }
                }
            }
            // stream hidden states through the (possibly updated) block
            for h in hidden.iter_mut() {
                let mut args = params.block_flat(block);
                args.push(&*h);
                let out = self.runtime.execute("block_fwd", &args)?;
                *h = out.into_iter().next().unwrap();
            }
        }

        Ok(RomModel {
            params,
            factors,
            schedule: rcfg.schedule,
            timings,
            peak_capture_bytes: peak_bytes,
        })
    }

    /// Measure the calibration covariance of every decomposable matrix in
    /// `blocks` **without compressing anything** (spectrum analysis /
    /// EXPERIMENTS.md). Streams hidden states with the original weights.
    pub fn measure_covariances(
        &self,
        params: &ParamStore,
        calib: &[CalibBatch],
        blocks: std::ops::Range<usize>,
    ) -> Result<Vec<(String, Matrix, usize, usize)>> {
        if calib.is_empty() {
            bail!("need at least one calibration batch");
        }
        let (eb, es) = (self.cfg.eval_batch, self.cfg.eval_seq);
        let embed = params.get("embed")?.clone();
        let mut hidden: Vec<Tensor> = Vec::with_capacity(calib.len());
        for b in calib {
            let tokens = Tensor::from_i32(&[eb, es], b.tokens.clone());
            let o = self.runtime.execute("embed_fwd", &[&embed, &tokens])?;
            hidden.push(o.into_iter().next().unwrap());
        }
        let all: Vec<(&str, &str)> = GROUPS.iter().flat_map(|g| g.iter().copied()).collect();
        let mut out = Vec::new();
        for block in 0..self.cfg.n_layers {
            if blocks.contains(&block) {
                let mut accs: BTreeMap<&str, CovarianceAccumulator> = all
                    .iter()
                    .map(|(field, _)| {
                        let name = format!("blocks.{block}.{field}");
                        (*field, CovarianceAccumulator::new(dims_of(&self.cfg, &name).0))
                    })
                    .collect();
                for (bi, cb) in calib.iter().enumerate() {
                    let outs = self.block_capture(params, block, &hidden[bi])?;
                    for (field, cap_name) in &all {
                        let cap = outs.get(*cap_name).context("capture missing")?;
                        self.accumulate(
                            accs.get_mut(field).unwrap(),
                            cap,
                            cb,
                            true,
                            &ExecPool::serial(),
                        )?;
                    }
                }
                for (field, _) in &all {
                    let name = format!("blocks.{block}.{field}");
                    let (d_out, d_in) = dims_of(&self.cfg, &name);
                    out.push((name, accs[field].finalize(true), d_out, d_in));
                }
            }
            for h in hidden.iter_mut() {
                let mut args = params.block_flat(block);
                args.push(&*h);
                let o = self.runtime.execute("block_fwd", &args)?;
                *h = o.into_iter().next().unwrap();
            }
        }
        Ok(out)
    }

    /// Ablation path: feature-space ROM **without** error propagation —
    /// every layer is calibrated against the *original* model's
    /// activations (the paper's §2 argues the propagating variant is
    /// better; this path quantifies by how much).
    fn compress_without_propagation(
        &self,
        params: &ParamStore,
        calib: &[CalibBatch],
        rcfg: &RomConfig,
    ) -> Result<RomModel> {
        if calib.is_empty() {
            bail!("ROM needs at least one calibration batch");
        }
        let (eb, es) = (self.cfg.eval_batch, self.cfg.eval_seq);
        let pool = rcfg.exec.pool();
        let mut out = params.clone();
        let mut factors = BTreeMap::new();
        let mut timings = Vec::new();
        let mut peak_bytes = 0usize;

        let embed = params.get("embed")?.clone();
        let mut hidden: Vec<Tensor> = Vec::with_capacity(calib.len());
        for b in calib {
            let tokens = Tensor::from_i32(&[eb, es], b.tokens.clone());
            let o = self.runtime.execute("embed_fwd", &[&embed, &tokens])?;
            hidden.push(o.into_iter().next().unwrap());
        }
        let all: Vec<(&str, &str)> =
            GROUPS.iter().flat_map(|g| g.iter().copied()).collect();

        for block in 0..self.cfg.n_layers {
            if rcfg.schedule.compresses(block) {
                // single capture pass with ORIGINAL weights
                let t_cov = Instant::now();
                let mut accs: BTreeMap<&str, CovarianceAccumulator> = all
                    .iter()
                    .map(|(field, _)| {
                        let name = format!("blocks.{block}.{field}");
                        let (o, _) = dims_of(&self.cfg, &name);
                        (*field, CovarianceAccumulator::new(o))
                    })
                    .collect();
                for (bi, cb) in calib.iter().enumerate() {
                    let outs = self.block_capture(params, block, &hidden[bi])?;
                    // captures + resident hidden-state chunks, same as the
                    // propagating path — the §4 memory numbers must stay
                    // comparable across the ablation
                    let bytes: usize = outs.values().map(|t| t.len() * 4).sum::<usize>()
                        + hidden.iter().map(|t| t.len() * 4).sum::<usize>();
                    peak_bytes = peak_bytes.max(bytes);
                    for (field, cap_name) in &all {
                        let cap = outs.get(*cap_name).context("capture missing")?;
                        self.accumulate(
                            accs.get_mut(field).unwrap(),
                            cap,
                            cb,
                            rcfg.pallas_covariance,
                            &pool,
                        )?;
                    }
                }
                let covariance_s = t_cov.elapsed().as_secs_f64() / all.len() as f64;
                let jobs: Vec<(String, Matrix, Matrix, usize)> = all
                    .iter()
                    .map(|(field, _)| {
                        let name = format!("blocks.{block}.{field}");
                        let (d_out, d_in) = dims_of(&self.cfg, &name);
                        let w = params.get(&name)?.to_matrix()?;
                        let cov = accs[field].finalize(rcfg.normalize);
                        let rank = rank_for_budget(d_out, d_in, rcfg.schedule.module_budget);
                        Ok((name, w, cov, rank))
                    })
                    .collect::<Result<_>>()?;
                for (name, f, secs) in decompose_jobs(jobs, &pool)? {
                    out.set(&name, Tensor::from_matrix(&f.effective_weight()))?;
                    timings.push(LayerTiming {
                        name: name.clone(),
                        covariance_s,
                        decompose_s: secs,
                    });
                    factors.insert(name, f);
                }
            }
            // stream with ORIGINAL weights (no propagation)
            for h in hidden.iter_mut() {
                let mut args = params.block_flat(block);
                args.push(&*h);
                let o = self.runtime.execute("block_fwd", &args)?;
                *h = o.into_iter().next().unwrap();
            }
        }
        Ok(RomModel {
            params: out,
            factors,
            schedule: rcfg.schedule,
            timings,
            peak_capture_bytes: peak_bytes,
        })
    }

    /// Run `block_capture` and map capture names -> tensors.
    fn block_capture(
        &self,
        params: &ParamStore,
        block: usize,
        h: &Tensor,
    ) -> Result<BTreeMap<String, Tensor>> {
        let mut args = params.block_flat(block);
        args.push(h);
        let outs = self.runtime.execute("block_capture", &args)?;
        let names = &self.runtime.manifest().capture_names;
        // outs[0] is h_out; captures follow in manifest order
        let mut map = BTreeMap::new();
        for (name, t) in names.iter().zip(outs.into_iter().skip(1)) {
            map.insert(name.clone(), t);
        }
        Ok(map)
    }

    /// Fold one capture chunk into a covariance accumulator, excluding
    /// padded rows. The pure-Rust path fans the row work out over `pool`
    /// in fixed tiles (deterministic for any thread count); the Pallas
    /// path is a single kernel call and ignores the pool.
    fn accumulate(
        &self,
        acc: &mut CovarianceAccumulator,
        cap: &Tensor,
        cb: &CalibBatch,
        pallas: bool,
        pool: &ExecPool,
    ) -> Result<()> {
        let d = *cap.shape().last().unwrap();
        let n = cap.len() / d;
        let samples: usize = cb.valid.iter().map(|&v| v.min(cb.seq)).sum();
        if pallas {
            // zero invalid rows, then one Gram-kernel call
            let mut flat = cap.flatten_to_2d()?;
            {
                let data = flat.as_f32_mut()?;
                zero_invalid_rows(data, cb.batch, cb.seq, d, &cb.valid);
            }
            let entry = if d == self.cfg.d_model {
                "covariance_d"
            } else if d == self.cfg.d_ff {
                "covariance_ff"
            } else {
                bail!("no covariance kernel for dim {d}");
            };
            let out = self.runtime.execute(entry, &[&flat])?;
            acc.add_gram(&out[0], samples)?;
        } else {
            let flags = valid_row_flags(cb.batch, cb.seq, &cb.valid);
            let flat = cap.flatten_to_2d()?;
            accumulate_rows_tiled(acc, flat.as_f32()?, n, Some(&flags), pool)?;
        }
        Ok(())
    }
}

/// Ablation path: weight-space truncated SVD (`cov := W·Wᵀ`), no
/// calibration data and no runtime at all — everything else (ranks,
/// schedule, re-parameterization) identical to the feature-space path.
/// A free function so offline sessions (no PJRT) can run it too.
pub fn compress_weight_space(
    cfg: &ModelConfig,
    params: &ParamStore,
    rcfg: &RomConfig,
) -> Result<RomModel> {
    let mut out = params.clone();
    let mut factors = BTreeMap::new();
    let mut timings = Vec::new();
    // with no error propagation in weight space, every matrix of the
    // schedule is independent — fan the whole schedule out over the pool.
    // Workers fetch W from the (immutable here) store themselves, so peak
    // memory stays at one matrix per worker, not one per job.
    let pool = rcfg.exec.pool();
    let mut jobs: Vec<(String, usize)> = Vec::new();
    for block in 0..cfg.n_layers {
        if !rcfg.schedule.compresses(block) {
            continue;
        }
        for (name, d_out, d_in) in block_matrices(cfg, block) {
            jobs.push((name, rank_for_budget(d_out, d_in, rcfg.schedule.module_budget)));
        }
    }
    let results = {
        let src = &out;
        pool.parallel_map(&jobs, |_, job| {
            let (name, rank) = job;
            let t0 = Instant::now();
            let w = src.get(name)?.to_matrix()?;
            let wwt = crate::linalg::matmul(&w, &w.transpose());
            let f =
                decompose_weight(&w, &wwt, *rank).with_context(|| format!("decompose {name}"))?;
            Ok::<(String, RomFactors, f64), anyhow::Error>((
                name.clone(),
                f,
                t0.elapsed().as_secs_f64(),
            ))
        })
    };
    for res in results {
        let (name, f, secs) = res?;
        out.set(&name, Tensor::from_matrix(&f.effective_weight()))?;
        timings.push(LayerTiming { name: name.clone(), covariance_s: 0.0, decompose_s: secs });
        factors.insert(name, f);
    }
    Ok(RomModel {
        params: out,
        factors,
        schedule: rcfg.schedule,
        timings,
        peak_capture_bytes: 0,
    })
}

/// (d_out, d_in) of a block matrix by name.
fn dims_of(cfg: &ModelConfig, name: &str) -> (usize, usize) {
    let block = crate::model::schema::block_index(name).expect("block-scoped name");
    block_matrices(cfg, block)
        .into_iter()
        .find(|(n, _, _)| n == name)
        .map(|(_, o, i)| (o, i))
        .expect("known matrix")
}

/// Decompose a set of (name, W, cov, rank) jobs on the worker pool.
/// Results come back in job order and each job is decomposed by the same
/// serial routine, so the output is identical for any thread count (the
/// old hand-rolled `thread::scope` island, retired onto [`ExecPool`]).
#[allow(clippy::type_complexity)]
fn decompose_jobs(
    jobs: Vec<(String, Matrix, Matrix, usize)>,
    pool: &ExecPool,
) -> Result<Vec<(String, RomFactors, f64)>> {
    pool.parallel_map(&jobs, |_, job| {
        let (name, w, cov, rank) = job;
        let t0 = Instant::now();
        let f = decompose_weight(w, cov, *rank).with_context(|| format!("decompose {name}"))?;
        Ok::<(String, RomFactors, f64), anyhow::Error>((
            name.clone(),
            f,
            t0.elapsed().as_secs_f64(),
        ))
    })
    .into_iter()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_cover_all_seven_matrices() {
        let fields: Vec<&str> = GROUPS.iter().flat_map(|g| g.iter().map(|(f, _)| *f)).collect();
        assert_eq!(fields, vec!["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]);
    }

    #[test]
    fn decompose_jobs_bitwise_identical_for_any_thread_count() {
        use crate::util::Rng;
        let mut rng = Rng::new(0);
        let mk = |rng: &mut Rng| {
            let w = Matrix::from_fn(8, 6, |_, _| rng.normal());
            let y = Matrix::from_fn(30, 8, |_, _| rng.normal());
            let cov = crate::linalg::matmul(&y.transpose(), &y);
            (w, cov)
        };
        let (w1, c1) = mk(&mut rng);
        let (w2, c2) = mk(&mut rng);
        let (w3, c3) = mk(&mut rng);
        let jobs = vec![
            ("a".to_string(), w1, c1, 3),
            ("b".to_string(), w2, c2, 4),
            ("c".to_string(), w3, c3, 2),
        ];
        let serial = decompose_jobs(jobs.clone(), &ExecPool::serial()).unwrap();
        for threads in [2usize, 3, 8] {
            let parallel = decompose_jobs(jobs.clone(), &ExecPool::new(threads)).unwrap();
            assert_eq!(serial.len(), parallel.len());
            for ((n1, f1, _), (n2, f2, _)) in serial.iter().zip(&parallel) {
                assert_eq!(n1, n2, "threads={threads}: job order");
                assert_eq!(
                    f1.effective_weight().data(),
                    f2.effective_weight().data(),
                    "threads={threads}: {n1} not bitwise identical"
                );
            }
        }
    }
}
