//! Streaming covariance accumulation over calibration chunks.
//!
//! The paper computes the covariance of each layer's output over one large
//! calibration batch; HLO shapes are static, so we stream fixed-size chunks
//! and sum their Gram matrices (exact — Gram is additive over row blocks).
//! Rows from padded positions are zeroed before accumulation so they
//! contribute nothing (matching the Pallas kernel's row-masking).

use anyhow::{bail, Result};

use crate::exec::ExecPool;
use crate::linalg::Matrix;
use crate::tensor::Tensor;

/// Accumulates `C = Σ_chunks Yᵀ Y` in f64, plus the sample count.
#[derive(Debug, Clone)]
pub struct CovarianceAccumulator {
    dim: usize,
    acc: Matrix,
    samples: usize,
}

impl CovarianceAccumulator {
    pub fn new(dim: usize) -> Self {
        CovarianceAccumulator { dim, acc: Matrix::zeros(dim, dim), samples: 0 }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Add a `(n, d)` f32 chunk computed in Rust (the pure-CPU path).
    /// `valid_rows[i] == false` rows are skipped.
    pub fn update_rows(&mut self, rows: &[f32], n: usize, valid_rows: Option<&[bool]>) -> Result<()> {
        if rows.len() != n * self.dim {
            bail!("update_rows: {} values for {}x{}", rows.len(), n, self.dim);
        }
        let d = self.dim;
        for i in 0..n {
            if let Some(v) = valid_rows {
                if !v[i] {
                    continue;
                }
            }
            let row = &rows[i * d..(i + 1) * d];
            self.samples += 1;
            // rank-1 update on the upper triangle
            for a in 0..d {
                let ra = row[a] as f64;
                if ra == 0.0 {
                    continue;
                }
                let dst = self.acc.row_mut(a);
                for b in a..d {
                    dst[b] += ra * row[b] as f64;
                }
            }
        }
        Ok(())
    }

    /// Add a pre-computed `(d, d)` Gram tensor (output of the Pallas
    /// covariance kernel). `samples` is the number of valid rows that went
    /// into it (caller zeroed the invalid ones beforehand).
    pub fn add_gram(&mut self, gram: &Tensor, samples: usize) -> Result<()> {
        let shape = gram.shape();
        if shape != [self.dim, self.dim] {
            bail!("add_gram: shape {:?}, want [{}, {}]", shape, self.dim, self.dim);
        }
        let data = gram.as_f32()?;
        // kernel returns the full matrix; fold into the upper triangle
        for a in 0..self.dim {
            for b in a..self.dim {
                self.acc[(a, b)] += data[a * self.dim + b] as f64;
            }
        }
        self.samples += samples;
        Ok(())
    }

    /// Merge another accumulator (worker-pool reduction).
    pub fn merge(&mut self, other: &CovarianceAccumulator) -> Result<()> {
        if other.dim != self.dim {
            bail!("merge: dim {} vs {}", other.dim, self.dim);
        }
        self.acc = self.acc.add(&other.acc);
        self.samples += other.samples;
        Ok(())
    }

    /// Finalized symmetric covariance (upper triangle mirrored; optionally
    /// normalized by the sample count — normalization does not change the
    /// eigenvectors, but keeps magnitudes comparable across batch sizes).
    pub fn finalize(&self, normalize: bool) -> Matrix {
        let d = self.dim;
        let mut out = Matrix::zeros(d, d);
        let scale = if normalize && self.samples > 0 { 1.0 / self.samples as f64 } else { 1.0 };
        for a in 0..d {
            for b in a..d {
                let v = self.acc[(a, b)] * scale;
                out[(a, b)] = v;
                out[(b, a)] = v;
            }
        }
        out
    }
}

/// Row-tile size of the deterministic parallel accumulation. Fixed (never
/// derived from the worker count) so the reduction tree — per-tile Gram
/// sums merged in tile order — is identical for every thread count,
/// which keeps the accumulated covariance bitwise stable under
/// `--threads`.
pub const COV_TILE_ROWS: usize = 256;

/// Fold a `(n, d)` f32 chunk into `acc` with the row work fanned out over
/// `pool`: rows are split into fixed [`COV_TILE_ROWS`]-sized tiles, each
/// tile's Gram sum is computed independently (`parallel_map`), and the
/// partials reduce into `acc` through [`CovarianceAccumulator::merge`] in
/// tile order. Bitwise identical for any thread count (including 1),
/// because the tile boundaries and the merge order depend only on `n`.
pub fn accumulate_rows_tiled(
    acc: &mut CovarianceAccumulator,
    rows: &[f32],
    n: usize,
    valid_rows: Option<&[bool]>,
    pool: &ExecPool,
) -> Result<()> {
    let d = acc.dim();
    if rows.len() != n * d {
        bail!("accumulate_rows_tiled: {} values for {}x{}", rows.len(), n, d);
    }
    if n <= COV_TILE_ROWS {
        return acc.update_rows(rows, n, valid_rows);
    }
    let tiles: Vec<(usize, usize)> =
        (0..n).step_by(COV_TILE_ROWS).map(|s| (s, (s + COV_TILE_ROWS).min(n))).collect();
    let partials = pool.parallel_map(&tiles, |_, &(start, end)| {
        let mut part = CovarianceAccumulator::new(d);
        part.update_rows(
            &rows[start * d..end * d],
            end - start,
            valid_rows.map(|v| &v[start..end]),
        )?;
        Ok::<CovarianceAccumulator, anyhow::Error>(part)
    });
    for part in partials {
        acc.merge(&part?)?;
    }
    Ok(())
}

/// Zero the invalid rows of a flattened `(n, d)` f32 buffer in place.
/// `valid[b]` is the number of leading valid positions in sample `b` of a
/// `(batch, seq, d)` capture; row `b·seq + t` is valid iff `t < valid[b]`.
pub fn zero_invalid_rows(data: &mut [f32], batch: usize, seq: usize, d: usize, valid: &[usize]) {
    assert_eq!(data.len(), batch * seq * d);
    assert_eq!(valid.len(), batch);
    for b in 0..batch {
        for t in valid[b]..seq {
            let row = (b * seq + t) * d;
            data[row..row + d].fill(0.0);
        }
    }
}

/// Row-validity flags for a `(batch, seq)` capture (Rust-path filtering).
pub fn valid_row_flags(batch: usize, seq: usize, valid: &[usize]) -> Vec<bool> {
    let mut flags = vec![false; batch * seq];
    for b in 0..batch {
        for t in 0..valid[b].min(seq) {
            flags[b * seq + t] = true;
        }
    }
    flags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matches_direct_gram() {
        let mut rng = Rng::new(0);
        let (n, d) = (40, 8);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut acc = CovarianceAccumulator::new(d);
        acc.update_rows(&rows, n, None).unwrap();
        let got = acc.finalize(false);
        let y = Matrix::from_f32(n, d, &rows);
        let want = crate::linalg::matmul(&y.transpose(), &y);
        assert!(got.sub(&want).max_abs() < 1e-6);
        assert_eq!(acc.samples(), n);
    }

    #[test]
    fn chunked_equals_whole() {
        let mut rng = Rng::new(1);
        let (n, d) = (64, 6);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut whole = CovarianceAccumulator::new(d);
        whole.update_rows(&rows, n, None).unwrap();
        let mut chunked = CovarianceAccumulator::new(d);
        chunked.update_rows(&rows[..32 * d], 32, None).unwrap();
        chunked.update_rows(&rows[32 * d..], 32, None).unwrap();
        assert!(whole.finalize(false).sub(&chunked.finalize(false)).max_abs() < 1e-9);
    }

    #[test]
    fn invalid_rows_excluded() {
        let mut rng = Rng::new(2);
        let (n, d) = (10, 4);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let valid: Vec<bool> = (0..n).map(|i| i % 2 == 0).collect();
        let mut filtered = CovarianceAccumulator::new(d);
        filtered.update_rows(&rows, n, Some(&valid)).unwrap();
        // manually keep even rows
        let kept: Vec<f32> = (0..n)
            .filter(|i| i % 2 == 0)
            .flat_map(|i| rows[i * d..(i + 1) * d].to_vec())
            .collect();
        let mut manual = CovarianceAccumulator::new(d);
        manual.update_rows(&kept, n / 2, None).unwrap();
        assert!(filtered.finalize(false).sub(&manual.finalize(false)).max_abs() < 1e-9);
        assert_eq!(filtered.samples(), 5);
    }

    #[test]
    fn add_gram_equals_update_rows() {
        let mut rng = Rng::new(3);
        let (n, d) = (20, 5);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let y = Matrix::from_f32(n, d, &rows);
        let gram64 = crate::linalg::matmul(&y.transpose(), &y);
        let gram = Tensor::from_f32(&[d, d], gram64.to_f32());
        let mut a = CovarianceAccumulator::new(d);
        a.add_gram(&gram, n).unwrap();
        let mut b = CovarianceAccumulator::new(d);
        b.update_rows(&rows, n, None).unwrap();
        assert!(a.finalize(false).sub(&b.finalize(false)).max_abs() < 1e-3);
    }

    #[test]
    fn normalization_preserves_eigenvectors() {
        let mut rng = Rng::new(4);
        let (n, d) = (30, 6);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut acc = CovarianceAccumulator::new(d);
        acc.update_rows(&rows, n, None).unwrap();
        let raw = crate::linalg::eigh(&acc.finalize(false)).unwrap();
        let nrm = crate::linalg::eigh(&acc.finalize(true)).unwrap();
        for k in 0..d {
            let dot: f64 = raw.vectors.row(k).iter().zip(nrm.vectors.row(k)).map(|(a, b)| a * b).sum();
            assert!(dot.abs() > 1.0 - 1e-8, "component {k}");
        }
    }

    #[test]
    fn zero_invalid_rows_masks_correctly() {
        let (batch, seq, d) = (2, 3, 2);
        let mut data: Vec<f32> = (0..batch * seq * d).map(|x| x as f32 + 1.0).collect();
        zero_invalid_rows(&mut data, batch, seq, d, &[2, 0]);
        // sample 0: t∈{0,1} kept, t=2 zeroed; sample 1: all zeroed
        assert!(data[0] != 0.0 && data[d] != 0.0);
        assert_eq!(&data[2 * d..3 * d], &[0.0, 0.0][..]);
        for t in 0..seq {
            let row = (seq + t) * d;
            assert_eq!(&data[row..row + d], &[0.0, 0.0][..]);
        }
    }

    #[test]
    fn tiled_accumulation_is_thread_count_invariant() {
        let mut rng = Rng::new(6);
        let (n, d) = (3 * COV_TILE_ROWS + 37, 5);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let valid: Vec<bool> = (0..n).map(|i| i % 7 != 0).collect();
        let finalize = |threads: usize| {
            let mut acc = CovarianceAccumulator::new(d);
            accumulate_rows_tiled(&mut acc, &rows, n, Some(&valid), &ExecPool::new(threads))
                .unwrap();
            (acc.samples(), acc.finalize(true))
        };
        let (samples1, cov1) = finalize(1);
        for threads in [2usize, 3, 8] {
            let (s, c) = finalize(threads);
            assert_eq!(s, samples1, "threads={threads}");
            assert_eq!(c.data(), cov1.data(), "threads={threads}: covariance not bitwise stable");
        }
        // and it agrees with the untiled single pass to fp tolerance
        let mut whole = CovarianceAccumulator::new(d);
        whole.update_rows(&rows, n, Some(&valid)).unwrap();
        assert_eq!(whole.samples(), samples1);
        assert!(whole.finalize(true).sub(&cov1).max_abs() < 1e-9);
        // small chunks take the single-tile fast path
        let mut small = CovarianceAccumulator::new(d);
        accumulate_rows_tiled(&mut small, &rows[..8 * d], 8, None, &ExecPool::new(4)).unwrap();
        assert_eq!(small.samples(), 8);
        // shape mismatch is an error
        let mut bad = CovarianceAccumulator::new(d);
        assert!(accumulate_rows_tiled(&mut bad, &rows, n + 1, None, &ExecPool::serial()).is_err());
    }

    #[test]
    fn merge_matches_single_pass() {
        let mut rng = Rng::new(5);
        let (n, d) = (24, 4);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut a = CovarianceAccumulator::new(d);
        a.update_rows(&rows[..12 * d], 12, None).unwrap();
        let mut b = CovarianceAccumulator::new(d);
        b.update_rows(&rows[12 * d..], 12, None).unwrap();
        a.merge(&b).unwrap();
        let mut whole = CovarianceAccumulator::new(d);
        whole.update_rows(&rows, n, None).unwrap();
        assert!(a.finalize(false).sub(&whole.finalize(false)).max_abs() < 1e-9);
    }
}
