//! LLM-ROM — the paper's contribution (§2): layerwise reduced-order
//! modelling of latent features.
//!
//! For each decomposable weight `W ∈ R^{d2×d1}`:
//! 1. accumulate the covariance of its calibration output `Y = X Wᵀ`
//!    ([`covariance`], via the Pallas Gram kernel or the Rust fallback),
//! 2. eigendecompose and keep the top-r principal components `V_r`
//!    ([`decompose`], rank from the budget allocator in [`budget`]),
//! 3. re-parameterize `W ≈ V_rᵀ (V_r W) = W1 W2` ([`decompose`]),
//! 4. stream the *compressed* activations forward so later layers see the
//!    error introduced earlier ([`pipeline`]).

pub mod budget;
pub mod covariance;
pub mod decompose;
pub mod pipeline;

pub use budget::{paper_preset, rank_for_budget, solve_module_budget, ModuleSchedule};
pub use covariance::{accumulate_rows_tiled, CovarianceAccumulator, COV_TILE_ROWS};
pub use decompose::{decompose_weight, RomFactors};
pub use pipeline::{
    compress_weight_space, DecompositionSpace, LayerTiming, RomConfig, RomModel, RomPipeline,
};
