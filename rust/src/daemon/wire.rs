//! Typed JSON envelopes and SSE encoding: the lossless map between the
//! wire and the engine's [`InferenceRequest`] / [`Event`] /
//! [`FinishedRequest`] types.
//!
//! ## Request envelopes
//!
//! `POST /v1/generate` — `{"prompt": [int], "max_new"?: int,
//! "deadline_ms"?: num, "tier"?: "interactive"|"batch", "tenant"?: "…",
//! "stream"?: bool}` or `{"text": "…", …}` (the byte-level tokenizer
//! encodes it, BOS-prefixed; requires the model vocab to cover the byte
//! range). `POST /v1/score` — `{"tokens": [int], "logits"?: bool,
//! "deadline_ms"?, "tier"?, "tenant"?}` or `{"text": "…", …}`. The
//! scheduling fields feed the engine's priced admission policy
//! ([`crate::engine::Scheduler`]): `tier` defaults to `"batch"` (so
//! pre-PR-7 clients are unchanged), `tenant` labels the fairness ledger
//! row, and `deadline_ms` both orders admission (earliest first) and
//! bounds execution. Unknown keys are rejected — the envelope is typed,
//! not free-form. Token ids are validated against the model vocab here,
//! before the engine's own admissibility checks
//! ([`crate::engine::EngineConfig::validate`]).
//!
//! ## Response envelopes
//!
//! Non-streaming completions return [`finished_json`]: `{"id", "kind",
//! "reason", "prompt_len", "tokens", "text", "ttft_s", "latency_s",
//! "macs"}` (+ `"logits"` for score requests that asked). Errors are
//! always `{"error": {"status": int, "message": "…"}}` ([`error_json`]),
//! never a bare string and never a panic.
//!
//! ## SSE frames
//!
//! `stream: true` mirrors the engine's event stream, one frame per
//! [`Event`] in engine order: `event: admitted` `{"id","seq"}` →
//! `event: prefilled` `{"id","prompt_len","ttft_s"}` → `event: token`
//! `{"id","index","token","text"}`* → `event: finished`
//! `{"id","reason","tokens"}`. Wall-clock timestamps (`t_s`) are
//! deliberately not on the wire — everything else is bitwise
//! deterministic, and the self-check diffs it across thread counts.
//!
//! The schema carries no execution-mode field: the daemon's `--mode`
//! (`dense` / `factored` / `factored-quant`) is fixed at startup and
//! never negotiated per request, so a quantized deployment is an explicit
//! operator decision — clients see identical envelopes in every mode
//! (`factored-quant` logits differ only within its stated tolerance of
//! the f32 factored path).

use anyhow::{bail, ensure, Result};

use crate::data::{Tokenizer, VOCAB_USED};
use crate::engine::{Event, EventKind, FinishedRequest, InferenceRequest, Tier};
use crate::util::json::Json;

/// Build a JSON object from (key, value) pairs.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

/// A parsed inbound request: the engine request (id 0 — the server
/// assigns ids; `deadline_s` still *relative*, the engine thread rebases
/// it onto the session clock) plus the wire-only flags.
#[derive(Debug, Clone)]
pub struct WireRequest {
    pub req: InferenceRequest,
    pub stream: bool,
    pub want_logits: bool,
}

fn parse_body(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow::anyhow!("body is not UTF-8"))?;
    Json::parse(text)
}

fn check_keys(v: &Json, allowed: &[&str]) -> Result<()> {
    for key in v.as_obj()?.keys() {
        ensure!(allowed.contains(&key.as_str()), "unknown key `{key}`");
    }
    Ok(())
}

/// The `"prompt"`/`"tokens"`-or-`"text"` prompt field, validated against
/// the model vocab.
fn parse_prompt(v: &Json, ids_key: &str, vocab: usize) -> Result<Vec<i32>> {
    match (v.opt(ids_key), v.opt("text")) {
        (Some(_), Some(_)) => bail!("give `{ids_key}` or `text`, not both"),
        (Some(arr), None) => {
            let mut out = Vec::new();
            for (i, t) in arr.as_arr()?.iter().enumerate() {
                let t = t.as_i32().map_err(|e| anyhow::anyhow!("`{ids_key}[{i}]`: {e}"))?;
                ensure!(
                    (0..vocab as i32).contains(&t),
                    "`{ids_key}[{i}]` = {t} outside vocab 0..{vocab}"
                );
                out.push(t);
            }
            Ok(out)
        }
        (None, Some(text)) => {
            ensure!(
                vocab >= VOCAB_USED,
                "`text` prompts need the byte-level vocab ({VOCAB_USED}); this model has {vocab}"
            );
            let tk = Tokenizer::new();
            let mut out = vec![crate::data::BOS];
            out.extend(tk.encode(text.as_str()?));
            Ok(out)
        }
        (None, None) => bail!("missing `{ids_key}` (or `text`)"),
    }
}

/// The scheduling fields shared by both envelopes: `deadline_ms`
/// (relative, positive), `tier` (`"interactive"` / `"batch"`, default
/// batch), `tenant` (fairness-ledger label, non-empty).
fn apply_policy(v: &Json, mut req: InferenceRequest) -> Result<InferenceRequest> {
    if let Some(ms) = v.opt("deadline_ms") {
        let ms = ms.as_f64().map_err(|e| anyhow::anyhow!("`deadline_ms`: {e}"))?;
        ensure!(ms > 0.0 && ms.is_finite(), "`deadline_ms` must be positive and finite");
        req = req.with_deadline(ms / 1000.0);
    }
    if let Some(t) = v.opt("tier") {
        let t = t.as_str().map_err(|e| anyhow::anyhow!("`tier`: {e}"))?;
        req = req.with_tier(match t {
            "interactive" => Tier::Interactive,
            "batch" => Tier::Batch,
            other => bail!("`tier` must be \"interactive\" or \"batch\", got \"{other}\""),
        });
    }
    if let Some(t) = v.opt("tenant") {
        let t = t.as_str().map_err(|e| anyhow::anyhow!("`tenant`: {e}"))?;
        ensure!(!t.is_empty(), "`tenant` must be non-empty");
        req = req.with_tenant(t);
    }
    Ok(req)
}

/// Parse a `POST /v1/generate` body.
pub fn parse_generate(body: &[u8], vocab: usize) -> Result<WireRequest> {
    let v = parse_body(body)?;
    check_keys(&v, &["prompt", "text", "max_new", "deadline_ms", "tier", "tenant", "stream"])?;
    let prompt = parse_prompt(&v, "prompt", vocab)?;
    let max_new = match v.opt("max_new") {
        Some(n) => {
            let n = n.as_usize().map_err(|e| anyhow::anyhow!("`max_new`: {e}"))?;
            ensure!(n > 0, "`max_new` must be positive");
            Some(n)
        }
        None => None,
    };
    let stream = match v.opt("stream") {
        Some(Json::Bool(b)) => *b,
        Some(_) => bail!("`stream` must be a boolean"),
        None => false,
    };
    let req = apply_policy(&v, InferenceRequest::generate(0, prompt, max_new))?;
    Ok(WireRequest { req, stream, want_logits: false })
}

/// Parse a `POST /v1/score` body.
pub fn parse_score(body: &[u8], vocab: usize) -> Result<WireRequest> {
    let v = parse_body(body)?;
    check_keys(&v, &["tokens", "text", "logits", "deadline_ms", "tier", "tenant"])?;
    let tokens = parse_prompt(&v, "tokens", vocab)?;
    let want_logits = match v.opt("logits") {
        Some(Json::Bool(b)) => *b,
        Some(_) => bail!("`logits` must be a boolean"),
        None => false,
    };
    let req = apply_policy(&v, InferenceRequest::score(0, tokens))?;
    Ok(WireRequest { req, stream: false, want_logits })
}

/// The non-streaming completion envelope.
pub fn finished_json(f: &FinishedRequest, want_logits: bool) -> Json {
    let mut entries = vec![
        ("id", num(f.id as f64)),
        ("kind", Json::Str(if f.is_generate { "generate" } else { "score" }.to_string())),
        ("reason", Json::Str(f.reason.name().to_string())),
        ("prompt_len", num(f.prompt_len as f64)),
        ("tokens", Json::Arr(f.tokens.iter().map(|&t| num(t as f64)).collect())),
        ("text", Json::Str(f.text.clone())),
        ("ttft_s", num(f.ttft_s)),
        ("latency_s", num(f.latency_s)),
        ("macs", num(f.macs as f64)),
    ];
    if want_logits && !f.is_generate {
        entries.push(("logits", Json::Arr(f.logits.iter().map(|&x| num(x as f64)).collect())));
    }
    obj(entries)
}

/// The structured error envelope every non-2xx response carries.
pub fn error_json(status: u16, message: &str) -> Json {
    obj(vec![(
        "error",
        obj(vec![("status", num(status as f64)), ("message", Json::Str(message.to_string()))]),
    )])
}

/// One engine event as an SSE frame: `(event name, data payload)`.
/// Everything on the wire is deterministic — the wall-clock `t_s` stays
/// server-side (TTFT is reported in the completion envelope instead).
pub fn event_sse(ev: &Event) -> (&'static str, String) {
    let id = num(ev.id as f64);
    match &ev.kind {
        EventKind::Admitted { seq } => {
            ("admitted", obj(vec![("id", id), ("seq", num(*seq as f64))]).to_string())
        }
        EventKind::Prefilled { prompt_len, .. } => (
            "prefilled",
            obj(vec![("id", id), ("prompt_len", num(*prompt_len as f64))]).to_string(),
        ),
        EventKind::Token { index, token, text } => (
            "token",
            obj(vec![
                ("id", id),
                ("index", num(*index as f64)),
                ("token", num(*token as f64)),
                ("text", Json::Str(text.clone())),
            ])
            .to_string(),
        ),
        EventKind::Finished { reason, tokens } => (
            "finished",
            obj(vec![
                ("id", id),
                ("reason", Json::Str(reason.name().to_string())),
                ("tokens", num(*tokens as f64)),
            ])
            .to_string(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FinishReason;

    #[test]
    fn generate_envelope_roundtrips() {
        let w = parse_generate(
            br#"{"prompt": [1, 2, 3], "max_new": 4, "stream": true, "deadline_ms": 250}"#,
            64,
        )
        .unwrap();
        assert!(w.stream);
        assert_eq!(w.req.prompt_len(), 3);
        assert_eq!(w.req.deadline_s, Some(0.25));
        let crate::engine::RequestKind::Generate { ref prompt, max_new } = w.req.kind else {
            panic!("expected generate");
        };
        assert_eq!(prompt, &vec![1, 2, 3]);
        assert_eq!(max_new, Some(4));
    }

    #[test]
    fn score_envelope_roundtrips() {
        let w = parse_score(br#"{"tokens": [5, 6], "logits": true}"#, 64).unwrap();
        assert!(!w.stream);
        assert!(w.want_logits);
        assert!(matches!(w.req.kind, crate::engine::RequestKind::Score { .. }));
    }

    #[test]
    fn scheduling_fields_roundtrip_on_both_envelopes() {
        let w = parse_generate(
            br#"{"prompt": [1], "tier": "interactive", "tenant": "acme", "deadline_ms": 40}"#,
            64,
        )
        .unwrap();
        assert_eq!(w.req.tier, Tier::Interactive);
        assert_eq!(w.req.tenant.as_deref(), Some("acme"));
        assert_eq!(w.req.deadline_s, Some(0.04));
        let w = parse_score(br#"{"tokens": [2], "tier": "batch", "deadline_ms": 500}"#, 64).unwrap();
        assert_eq!(w.req.tier, Tier::Batch);
        assert!(w.req.tenant.is_none());
        assert_eq!(w.req.deadline_s, Some(0.5));
        // omitted fields keep the pre-PR-7 defaults
        let w = parse_generate(br#"{"prompt": [1]}"#, 64).unwrap();
        assert_eq!(w.req.tier, Tier::Batch);
        assert!(w.req.tenant.is_none() && w.req.deadline_s.is_none());
    }

    #[test]
    fn text_prompts_need_the_byte_vocab() {
        assert!(parse_generate(br#"{"text": "hi"}"#, 64).is_err(), "demo vocab is too small");
        let w = parse_generate(br#"{"text": "hi"}"#, VOCAB_USED).unwrap();
        assert_eq!(w.req.prompt_len(), 3, "BOS + 2 bytes");
    }

    #[test]
    fn bad_bodies_are_errors_not_panics() {
        for body in [
            &b"not json"[..],
            br#"{"prompt": [1], "bogus": 1}"#,
            br#"{"prompt": "not-an-array"}"#,
            br#"{"prompt": [99]}"#,             // out of vocab (64)
            br#"{"prompt": [-1]}"#,            // negative id
            br#"{"prompt": [1], "text": "x"}"#, // both prompt forms
            br#"{"max_new": 4}"#,              // no prompt at all
            br#"{"prompt": [1], "max_new": 0}"#,
            br#"{"prompt": [1], "stream": 1}"#,
            br#"{"prompt": [1], "deadline_ms": -5}"#,
            br#"{"prompt": [1], "tier": "premium"}"#, // not a tier name
            br#"{"prompt": [1], "tier": 3}"#,
            br#"{"prompt": [1], "tenant": ""}"#,
        ] {
            assert!(parse_generate(body, 64).is_err(), "{}", String::from_utf8_lossy(body));
        }
        assert!(parse_score(br#"{"tokens": [1], "stream": true}"#, 64).is_err(), "not a score key");
    }

    #[test]
    fn error_envelope_is_structured() {
        let e = error_json(429, "queue full");
        assert_eq!(e.to_string(), r#"{"error":{"message":"queue full","status":429}}"#);
    }

    #[test]
    fn sse_frames_are_deterministic_payloads() {
        let ev = |kind| Event { id: 3, t_s: 0.123, kind };
        let (name, data) = event_sse(&ev(EventKind::Admitted { seq: 1 }));
        assert_eq!((name, data.as_str()), ("admitted", r#"{"id":3,"seq":1}"#));
        let (name, data) = event_sse(&ev(EventKind::Prefilled { prompt_len: 5, ttft_s: 0.9 }));
        assert_eq!((name, data.as_str()), ("prefilled", r#"{"id":3,"prompt_len":5}"#));
        let (name, data) =
            event_sse(&ev(EventKind::Token { index: 2, token: 17, text: "q".into() }));
        assert_eq!((name, data.as_str()), ("token", r#"{"id":3,"index":2,"text":"q","token":17}"#));
        let (name, data) =
            event_sse(&ev(EventKind::Finished { reason: FinishReason::MaxTokens, tokens: 6 }));
        assert_eq!((name, data.as_str()), ("finished", r#"{"id":3,"reason":"max-tokens","tokens":6}"#));
        // no wall-clock field leaks onto the wire
        assert!(!data.contains("t_s"));
    }

    #[test]
    fn finished_envelope_carries_the_result() {
        let f = FinishedRequest {
            id: 2,
            admitted: Some(0),
            reason: FinishReason::MaxTokens,
            is_generate: true,
            prompt_len: 3,
            tokens: vec![7, 8],
            text: String::new(),
            logits: Vec::new(),
            ttft_s: 0.5,
            latency_s: 1.0,
            macs: 100,
            recompute_macs: 200,
        };
        let j = finished_json(&f, false);
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("reason").unwrap().as_str().unwrap(), "max-tokens");
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "generate");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.opt("logits").is_none(), "logits only on request");
    }
}
