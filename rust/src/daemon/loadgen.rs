//! Open-loop wire-path load generator for the daemon.
//!
//! `repro loadgen` drives a running daemon over real sockets through the
//! same [`super::http::HttpClient`] the self-check uses, so the numbers
//! include every wire cost: connect, serialize, parse, SSE framing.
//!
//! The arrival process is **open-loop**: request `i` of a
//! `--rps R --duration S` run is *due* at `t0 + i/R`, independent of how
//! fast earlier requests completed. `--connections N` workers pull due
//! requests from a shared cursor, each holding one keep-alive connection
//! (re-dialed after an SSE stream, which closes the socket). When all
//! workers are stuck behind a slow server, arrivals fall behind their
//! due times — latency is therefore measured **from the due time**, not
//! from the send, so queueing delay the client itself suffered is
//! charged to the server (no coordinated omission).
//!
//! Per-request the worker records completion latency, TTFT (due → first
//! `token` SSE frame), and inter-token gaps; 429s and transport errors
//! are counted, not retried — shed capacity is the signal, not a bug.

use std::net::{SocketAddr, ToSocketAddrs};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;
use crate::util::{LatencySummary, Rng};

use super::http::HttpClient;
use super::wire;

/// Knobs for one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Daemon address, e.g. `127.0.0.1:8700`.
    pub addr: String,
    /// Concurrent client connections (workers).
    pub connections: usize,
    /// Target open-loop arrival rate, requests per second.
    pub rps: f64,
    /// Arrival window in seconds; `ceil(rps * duration)` requests total.
    pub duration_s: f64,
    /// Synthetic prompt length in tokens.
    pub prompt_len: usize,
    /// `max_new` sent with each generate request.
    pub max_new: usize,
    /// `stream: true` (SSE) or unary completion envelopes.
    pub stream: bool,
    /// Seed for the synthetic prompts.
    pub seed: u64,
    /// Model vocab — prompts are sampled in `0..vocab`.
    pub vocab: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            connections: 4,
            rps: 20.0,
            duration_s: 2.0,
            prompt_len: 8,
            max_new: 8,
            stream: true,
            seed: 0,
            vocab: 0,
        }
    }
}

/// What one load-generation run observed from the client side.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    pub target_rps: f64,
    /// Completed-request rate over the whole run's wall clock.
    pub achieved_rps: f64,
    /// Requests sent (connect attempted).
    pub sent: usize,
    /// Requests that completed with a 200 / full SSE stream.
    pub ok: usize,
    /// Requests shed by the daemon with 429.
    pub shed_429: usize,
    /// Transport failures and non-200/429 statuses.
    pub errors: usize,
    /// Generated tokens observed across all completed requests.
    pub tokens: usize,
    pub wall_s: f64,
    /// Due-time → completion, per completed request.
    pub latency: LatencySummary,
    /// Due-time → first `token` SSE frame (streaming runs only).
    pub ttft: LatencySummary,
    /// Gaps between consecutive `token` frames (streaming runs only).
    pub inter_token: LatencySummary,
}

impl LoadReport {
    pub fn format(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "loadgen: target {:.1} rps -> achieved {:.1} rps over {:.2}s\n",
            self.target_rps, self.achieved_rps, self.wall_s
        ));
        out.push_str(&format!(
            "  sent {}  ok {}  shed_429 {}  errors {}  tokens {}\n",
            self.sent, self.ok, self.shed_429, self.errors, self.tokens
        ));
        let line = |name: &str, l: &LatencySummary| {
            format!(
                "  {name:<12} n {:<5} mean {:.4}s  p50 {:.4}s  p95 {:.4}s  max {:.4}s\n",
                l.n, l.mean, l.p50, l.p95, l.max
            )
        };
        out.push_str(&line("latency", &self.latency));
        out.push_str(&line("ttft", &self.ttft));
        out.push_str(&line("inter_token", &self.inter_token));
        out
    }

    pub fn to_json(&self) -> Json {
        wire::obj(vec![
            ("target_rps", Json::Num(self.target_rps)),
            ("achieved_rps", Json::Num(self.achieved_rps)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed_429", Json::Num(self.shed_429 as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("latency", lat_json(&self.latency)),
            ("ttft", lat_json(&self.ttft)),
            ("inter_token", lat_json(&self.inter_token)),
        ])
    }
}

fn lat_json(l: &LatencySummary) -> Json {
    wire::obj(vec![
        ("n", Json::Num(l.n as f64)),
        ("mean_s", Json::Num(l.mean)),
        ("p50_s", Json::Num(l.p50)),
        ("p95_s", Json::Num(l.p95)),
        ("max_s", Json::Num(l.max)),
    ])
}

/// Deterministic synthetic prompt for request `i`: `prompt_len` tokens
/// in `0..vocab`, independent of worker scheduling.
pub fn synth_prompt(seed: u64, i: usize, prompt_len: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..prompt_len.max(1)).map(|_| rng.below(vocab.max(1)) as i32).collect()
}

/// One worker's tallies, merged after the run.
#[derive(Default)]
struct Partial {
    sent: usize,
    ok: usize,
    shed_429: usize,
    errors: usize,
    tokens: usize,
    lat: Vec<f64>,
    ttft: Vec<f64>,
    itl: Vec<f64>,
}

/// What one request did, as observed on the wire.
enum Outcome {
    /// Completed: generated tokens, ttft, inter-token gaps.
    Ok(usize, Option<f64>, Vec<f64>),
    Shed429,
    Error,
}

/// Drive one request on an existing connection. `Err` means the
/// connection is unusable afterwards (the caller re-dials).
fn drive(
    client: &mut HttpClient,
    cfg: &LoadgenConfig,
    i: usize,
    due: Instant,
) -> Result<Outcome> {
    let prompt = synth_prompt(cfg.seed, i, cfg.prompt_len, cfg.vocab);
    let body = wire::obj(vec![
        ("prompt", Json::Arr(prompt.into_iter().map(|t| Json::Num(t as f64)).collect())),
        ("max_new", Json::Num(cfg.max_new as f64)),
        ("stream", Json::Bool(cfg.stream)),
    ]);
    let resp = client.post_json("/v1/generate", &body)?;
    if resp.status == 429 {
        return Ok(Outcome::Shed429);
    }
    if resp.status != 200 {
        return Ok(Outcome::Error);
    }
    if !resp.is_sse() {
        let tokens = resp
            .json()
            .ok()
            .and_then(|j| j.get("tokens").ok().and_then(|t| t.as_arr().ok().map(|a| a.len())))
            .unwrap_or(0);
        return Ok(Outcome::Ok(tokens, None, Vec::new()));
    }
    // SSE: walk the frames, timing the token events
    let mut tokens = 0usize;
    let mut ttft: Option<f64> = None;
    let mut itl: Vec<f64> = Vec::new();
    let mut last_token: Option<Instant> = None;
    let mut finished = false;
    while let Some(frame) = client.next_sse_frame()? {
        match frame.event.as_str() {
            "token" => {
                let now = Instant::now();
                if let Some(prev) = last_token {
                    itl.push((now - prev).as_secs_f64());
                } else {
                    ttft = Some((now - due).as_secs_f64());
                }
                last_token = Some(now);
                tokens += 1;
            }
            "finished" => {
                finished = true;
                break;
            }
            _ => {}
        }
    }
    ensure!(finished, "SSE stream ended without a finished event");
    Ok(Outcome::Ok(tokens, ttft, itl))
}

fn worker(
    cfg: &LoadgenConfig,
    addr: SocketAddr,
    next: &AtomicUsize,
    total: usize,
    t0: Instant,
) -> Partial {
    let mut part = Partial::default();
    let mut client: Option<HttpClient> = None;
    loop {
        let i = next.fetch_add(1, Ordering::SeqCst);
        if i >= total {
            return part;
        }
        let due = t0 + Duration::from_secs_f64(i as f64 / cfg.rps.max(1e-9));
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        if client.is_none() {
            match HttpClient::connect(addr) {
                Ok(c) => client = Some(c),
                Err(_) => {
                    part.sent += 1;
                    part.errors += 1;
                    continue;
                }
            }
        }
        part.sent += 1;
        let outcome = drive(client.as_mut().expect("connected above"), cfg, i, due);
        match outcome {
            Ok(Outcome::Ok(tokens, ttft, itl)) => {
                part.ok += 1;
                part.tokens += tokens;
                part.lat.push((Instant::now() - due).as_secs_f64());
                if let Some(t) = ttft {
                    part.ttft.push(t);
                }
                part.itl.extend(itl);
                if cfg.stream {
                    // SSE responses close the connection
                    client = None;
                }
            }
            Ok(Outcome::Shed429) => part.shed_429 += 1,
            Ok(Outcome::Error) => part.errors += 1,
            Err(_) => {
                part.errors += 1;
                client = None;
            }
        }
    }
}

/// Run the load generator against a daemon at `cfg.addr` and summarize
/// what the wire saw.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadReport> {
    ensure!(cfg.connections > 0, "loadgen needs at least one connection");
    ensure!(cfg.rps > 0.0 && cfg.rps.is_finite(), "rps must be positive");
    ensure!(cfg.duration_s > 0.0 && cfg.duration_s.is_finite(), "duration must be positive");
    let addr = cfg
        .addr
        .to_socket_addrs()
        .with_context(|| format!("resolve `{}`", cfg.addr))?
        .next()
        .with_context(|| format!("`{}` resolved to no address", cfg.addr))?;
    let total = (cfg.rps * cfg.duration_s).ceil().max(1.0) as usize;
    let next = AtomicUsize::new(0);
    let t0 = Instant::now();
    let parts: Vec<Partial> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections)
            .map(|_| s.spawn(|| worker(cfg, addr, &next, total, t0)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("loadgen worker panicked")).collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut merged = Partial::default();
    for p in parts {
        merged.sent += p.sent;
        merged.ok += p.ok;
        merged.shed_429 += p.shed_429;
        merged.errors += p.errors;
        merged.tokens += p.tokens;
        merged.lat.extend(p.lat);
        merged.ttft.extend(p.ttft);
        merged.itl.extend(p.itl);
    }
    Ok(LoadReport {
        target_rps: cfg.rps,
        achieved_rps: if wall_s > 0.0 { merged.ok as f64 / wall_s } else { 0.0 },
        sent: merged.sent,
        ok: merged.ok,
        shed_429: merged.shed_429,
        errors: merged.errors,
        tokens: merged.tokens,
        wall_s,
        latency: LatencySummary::from_unsorted(merged.lat),
        ttft: LatencySummary::from_unsorted(merged.ttft),
        inter_token: LatencySummary::from_unsorted(merged.itl),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_prompts_are_deterministic_and_in_vocab() {
        let a = synth_prompt(7, 3, 16, 64);
        let b = synth_prompt(7, 3, 16, 64);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.iter().all(|&t| (0..64).contains(&t)));
        assert_ne!(a, synth_prompt(7, 4, 16, 64), "per-request variation");
        // degenerate knobs stay well-defined
        assert_eq!(synth_prompt(7, 0, 0, 1).len(), 1);
    }

    #[test]
    fn report_json_has_the_full_shape() {
        let r = LoadReport {
            target_rps: 10.0,
            achieved_rps: 9.5,
            sent: 20,
            ok: 19,
            shed_429: 1,
            errors: 0,
            tokens: 152,
            wall_s: 2.0,
            latency: LatencySummary::from_unsorted(vec![0.1, 0.2]),
            ttft: LatencySummary::from_unsorted(vec![0.05]),
            inter_token: LatencySummary::from_unsorted(vec![0.01, 0.02, 0.03]),
        };
        let j = r.to_json();
        assert_eq!(j.get("sent").unwrap().as_usize().unwrap(), 20);
        assert_eq!(j.get("shed_429").unwrap().as_usize().unwrap(), 1);
        let lat = j.get("latency").unwrap();
        assert_eq!(lat.get("n").unwrap().as_usize().unwrap(), 2);
        let text = r.format();
        assert!(text.contains("shed_429 1"));
        assert!(text.contains("ttft"));
        // serialized form is deterministic (sorted keys)
        assert_eq!(j.to_string(), r.to_json().to_string());
    }

    #[test]
    fn loadgen_rejects_nonsense_knobs() {
        let mut cfg = LoadgenConfig { addr: "127.0.0.1:1".into(), ..LoadgenConfig::default() };
        cfg.connections = 0;
        assert!(run_loadgen(&cfg).is_err());
        cfg.connections = 1;
        cfg.rps = 0.0;
        assert!(run_loadgen(&cfg).is_err());
        cfg.rps = 10.0;
        cfg.duration_s = f64::NAN;
        assert!(run_loadgen(&cfg).is_err());
    }
}
