//! Open-loop wire-path load generator for the daemon.
//!
//! `repro loadgen` drives a running daemon over real sockets through the
//! same [`super::http::HttpClient`] the self-check uses, so the numbers
//! include every wire cost: connect, serialize, parse, SSE framing.
//!
//! The arrival process is **open-loop**: request `i` of a
//! `--rps R --duration S` run is *due* at `t0 + i/R`, independent of how
//! fast earlier requests completed. `--connections N` workers pull due
//! requests from a shared cursor, each holding one keep-alive connection
//! (re-dialed after an SSE stream, which closes the socket). When all
//! workers are stuck behind a slow server, arrivals fall behind their
//! due times — latency is therefore measured **from the due time**, not
//! from the send, so queueing delay the client itself suffered is
//! charged to the server (no coordinated omission).
//!
//! Per-request the worker records completion latency, TTFT (due → first
//! `token` SSE frame), and inter-token gaps; 429s and transport errors
//! are counted, not retried — shed capacity is the signal, not a bug.
//!
//! `--mix interactive:batch` shapes an adversarial tiered trace: request
//! `i` is interactive when `i mod (a+b) < a`, carrying `tier:
//! "interactive"` and a `deadline_ms` on the wire (the default `0:1` mix
//! sends bodies byte-identical to the pre-tier ones). The report then
//! splits completion latency per tier and scores the deadline hit-rate —
//! the fraction of deadline-carrying requests that finished without a
//! server-side `deadline` eviction.
//!
//! When the daemon serves `GET /metrics`, the run also scrapes it before
//! and after and folds the *delta* into [`LoadReport::server`] — the
//! server's own TTFT / inter-token / queue-wait histograms over exactly
//! the scraped window, next to the client-side view (`make bench` lands
//! both in `BENCH_daemon.json`). A daemon without the obs plane (or an
//! older one without the endpoint) degrades to `server: None`.

use std::collections::BTreeMap;
use std::net::{SocketAddr, ToSocketAddrs};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;
use crate::util::{LatencySummary, Rng};

use super::http::HttpClient;
use super::wire;

/// Knobs for one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Daemon address, e.g. `127.0.0.1:8700`.
    pub addr: String,
    /// Concurrent client connections (workers).
    pub connections: usize,
    /// Target open-loop arrival rate, requests per second.
    pub rps: f64,
    /// Arrival window in seconds; `ceil(rps * duration)` requests total.
    pub duration_s: f64,
    /// Synthetic prompt length in tokens.
    pub prompt_len: usize,
    /// `max_new` sent with each generate request.
    pub max_new: usize,
    /// `stream: true` (SSE) or unary completion envelopes.
    pub stream: bool,
    /// Seed for the synthetic prompts.
    pub seed: u64,
    /// Model vocab — prompts are sampled in `0..vocab`.
    pub vocab: usize,
    /// `interactive:batch` request ratio; `(0, 1)` (the default) sends
    /// an all-batch trace with bodies byte-identical to pre-tier runs.
    pub mix: (u32, u32),
    /// `deadline_ms` attached to interactive-tier requests (`0` sends
    /// none). Only the `mix` decides which requests carry it.
    pub deadline_ms: f64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            connections: 4,
            rps: 20.0,
            duration_s: 2.0,
            prompt_len: 8,
            max_new: 8,
            stream: true,
            seed: 0,
            vocab: 0,
            mix: (0, 1),
            deadline_ms: 250.0,
        }
    }
}

/// Parse an `interactive:batch` mix like `1:4` (both non-negative, not
/// both zero).
pub fn parse_mix(s: &str) -> Result<(u32, u32)> {
    let (a, b) = s.split_once(':').context("mix must look like `interactive:batch`, e.g. 1:4")?;
    let a: u32 = a.trim().parse().with_context(|| format!("bad interactive share `{a}`"))?;
    let b: u32 = b.trim().parse().with_context(|| format!("bad batch share `{b}`"))?;
    ensure!(a + b > 0, "mix must have at least one positive share");
    Ok((a, b))
}

/// The tier of request `i` under a mix: the first `a` of every `a + b`
/// requests are interactive — deterministic in the request index alone.
pub fn tier_of(mix: (u32, u32), i: usize) -> crate::engine::Tier {
    let (a, b) = mix;
    if a == 0 {
        return crate::engine::Tier::Batch;
    }
    if b == 0 {
        return crate::engine::Tier::Interactive;
    }
    if (i as u64) % u64::from(a + b) < u64::from(a) {
        crate::engine::Tier::Interactive
    } else {
        crate::engine::Tier::Batch
    }
}

/// What one load-generation run observed from the client side.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    pub target_rps: f64,
    /// Completed-request rate over the whole run's wall clock.
    pub achieved_rps: f64,
    /// Requests sent (connect attempted).
    pub sent: usize,
    /// Requests that completed with a 200 / full SSE stream.
    pub ok: usize,
    /// Requests shed by the daemon with 429.
    pub shed_429: usize,
    /// Transport failures and non-200/429 statuses.
    pub errors: usize,
    /// Generated tokens observed across all completed requests.
    pub tokens: usize,
    pub wall_s: f64,
    /// Due-time → completion, per completed request.
    pub latency: LatencySummary,
    /// Due-time → first `token` SSE frame (streaming runs only).
    pub ttft: LatencySummary,
    /// Gaps between consecutive `token` frames (streaming runs only).
    pub inter_token: LatencySummary,
    /// Completion latency of interactive-tier requests only.
    pub interactive_latency: LatencySummary,
    /// Completion latency of batch-tier requests only.
    pub batch_latency: LatencySummary,
    /// Requests sent carrying a deadline.
    pub deadline_total: usize,
    /// Of those, completed without a server-side `deadline` eviction.
    pub deadline_hits: usize,
    /// Server-side view over the run: the `/metrics` delta between a
    /// scrape right before the first arrival and one after the last
    /// completion. `None` when the daemon has no obs plane (or no
    /// `/metrics` endpoint at all).
    pub server: Option<ServerMetrics>,
}

/// The daemon's own accounting of a load-generation window, recovered
/// from two `/metrics` scrapes ([`crate::obs::exposition_delta`] +
/// [`crate::obs::histogram_from_samples`]). Histogram percentiles
/// quantize to the registry's fixed bucket bounds; counters are exact.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServerMetrics {
    /// Requests the engine retired during the window.
    pub requests: u64,
    pub generated_tokens: u64,
    /// MACs executed during the window (u64-saturated counter).
    pub executed_macs: u64,
    /// Server-measured time to first token (queue wait + prefill).
    pub ttft: LatencySummary,
    pub inter_token: LatencySummary,
    /// Submission → admission wait inside the engine queue.
    pub queue_wait: LatencySummary,
}

impl LoadReport {
    /// Deadline hit-rate in `[0, 1]`; `1.0` when no request carried one.
    pub fn deadline_hit_rate(&self) -> f64 {
        if self.deadline_total == 0 {
            1.0
        } else {
            self.deadline_hits as f64 / self.deadline_total as f64
        }
    }
}

impl LoadReport {
    pub fn format(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "loadgen: target {:.1} rps -> achieved {:.1} rps over {:.2}s\n",
            self.target_rps, self.achieved_rps, self.wall_s
        ));
        out.push_str(&format!(
            "  sent {}  ok {}  shed_429 {}  errors {}  tokens {}\n",
            self.sent, self.ok, self.shed_429, self.errors, self.tokens
        ));
        let line = |name: &str, l: &LatencySummary| {
            format!(
                "  {name:<12} n {:<5} mean {:.4}s  p50 {:.4}s  p95 {:.4}s  max {:.4}s\n",
                l.n, l.mean, l.p50, l.p95, l.max
            )
        };
        out.push_str(&line("latency", &self.latency));
        out.push_str(&line("ttft", &self.ttft));
        out.push_str(&line("inter_token", &self.inter_token));
        if self.interactive_latency.n > 0 {
            out.push_str(&line("interactive", &self.interactive_latency));
            out.push_str(&line("batch", &self.batch_latency));
        }
        if self.deadline_total > 0 {
            out.push_str(&format!(
                "  deadline hit-rate {}/{} ({:.1}%)\n",
                self.deadline_hits,
                self.deadline_total,
                100.0 * self.deadline_hit_rate()
            ));
        }
        if let Some(srv) = &self.server {
            out.push_str(&format!(
                "  server side (/metrics delta): {} requests, {} generated tokens, \
                 {} MACs executed\n",
                srv.requests, srv.generated_tokens, srv.executed_macs
            ));
            out.push_str(&line("srv ttft", &srv.ttft));
            out.push_str(&line("srv itl", &srv.inter_token));
            out.push_str(&line("srv queue", &srv.queue_wait));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut entries = vec![
            ("target_rps", Json::Num(self.target_rps)),
            ("achieved_rps", Json::Num(self.achieved_rps)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed_429", Json::Num(self.shed_429 as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("latency", lat_json(&self.latency)),
            ("ttft", lat_json(&self.ttft)),
            ("inter_token", lat_json(&self.inter_token)),
            ("interactive_latency", lat_json(&self.interactive_latency)),
            ("batch_latency", lat_json(&self.batch_latency)),
            ("deadline_total", Json::Num(self.deadline_total as f64)),
            ("deadline_hits", Json::Num(self.deadline_hits as f64)),
            ("deadline_hit_rate", Json::Num(self.deadline_hit_rate())),
        ];
        if let Some(srv) = &self.server {
            entries.push((
                "server_metrics",
                wire::obj(vec![
                    ("requests", Json::Num(srv.requests as f64)),
                    ("generated_tokens", Json::Num(srv.generated_tokens as f64)),
                    ("executed_macs", Json::Num(srv.executed_macs as f64)),
                    ("ttft", lat_json(&srv.ttft)),
                    ("inter_token", lat_json(&srv.inter_token)),
                    ("queue_wait", lat_json(&srv.queue_wait)),
                ]),
            ));
        }
        wire::obj(entries)
    }
}

fn lat_json(l: &LatencySummary) -> Json {
    wire::obj(vec![
        ("n", Json::Num(l.n as f64)),
        ("mean_s", Json::Num(l.mean)),
        ("p50_s", Json::Num(l.p50)),
        ("p95_s", Json::Num(l.p95)),
        ("max_s", Json::Num(l.max)),
    ])
}

/// Deterministic synthetic prompt for request `i`: `prompt_len` tokens
/// in `0..vocab`, independent of worker scheduling.
pub fn synth_prompt(seed: u64, i: usize, prompt_len: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..prompt_len.max(1)).map(|_| rng.below(vocab.max(1)) as i32).collect()
}

/// One worker's tallies, merged after the run.
#[derive(Default)]
struct Partial {
    sent: usize,
    ok: usize,
    shed_429: usize,
    errors: usize,
    tokens: usize,
    lat: Vec<f64>,
    ttft: Vec<f64>,
    itl: Vec<f64>,
    lat_interactive: Vec<f64>,
    lat_batch: Vec<f64>,
    deadline_total: usize,
    deadline_hits: usize,
}

/// What one request did, as observed on the wire.
enum Outcome {
    /// Completed: generated tokens, ttft, inter-token gaps, finish
    /// reason (from the completion envelope / `finished` SSE frame).
    Ok(usize, Option<f64>, Vec<f64>, String),
    Shed429,
    Error,
}

/// Drive one request on an existing connection. `Err` means the
/// connection is unusable afterwards (the caller re-dials).
fn drive(
    client: &mut HttpClient,
    cfg: &LoadgenConfig,
    i: usize,
    due: Instant,
) -> Result<Outcome> {
    let prompt = synth_prompt(cfg.seed, i, cfg.prompt_len, cfg.vocab);
    let mut entries = vec![
        ("prompt", Json::Arr(prompt.into_iter().map(|t| Json::Num(t as f64)).collect())),
        ("max_new", Json::Num(cfg.max_new as f64)),
        ("stream", Json::Bool(cfg.stream)),
    ];
    if tier_of(cfg.mix, i) == crate::engine::Tier::Interactive {
        entries.push(("tier", Json::Str("interactive".to_string())));
        if cfg.deadline_ms > 0.0 {
            entries.push(("deadline_ms", Json::Num(cfg.deadline_ms)));
        }
    }
    let body = wire::obj(entries);
    let resp = client.post_json("/v1/generate", &body)?;
    if resp.status == 429 {
        return Ok(Outcome::Shed429);
    }
    if resp.status != 200 {
        return Ok(Outcome::Error);
    }
    if !resp.is_sse() {
        let envelope = resp.json().ok();
        let tokens = envelope
            .as_ref()
            .and_then(|j| j.get("tokens").ok().and_then(|t| t.as_arr().ok().map(|a| a.len())))
            .unwrap_or(0);
        let reason = envelope
            .as_ref()
            .and_then(|j| j.get("reason").ok().and_then(|r| r.as_str().ok().map(String::from)))
            .unwrap_or_default();
        return Ok(Outcome::Ok(tokens, None, Vec::new(), reason));
    }
    // SSE: walk the frames, timing the token events
    let mut tokens = 0usize;
    let mut ttft: Option<f64> = None;
    let mut itl: Vec<f64> = Vec::new();
    let mut last_token: Option<Instant> = None;
    let mut finished: Option<String> = None;
    while let Some(frame) = client.next_sse_frame()? {
        match frame.event.as_str() {
            "token" => {
                let now = Instant::now();
                if let Some(prev) = last_token {
                    itl.push((now - prev).as_secs_f64());
                } else {
                    ttft = Some((now - due).as_secs_f64());
                }
                last_token = Some(now);
                tokens += 1;
            }
            "finished" => {
                let reason = Json::parse(&frame.data)
                    .ok()
                    .and_then(|j| {
                        j.get("reason").ok().and_then(|r| r.as_str().ok().map(String::from))
                    })
                    .unwrap_or_default();
                finished = Some(reason);
                break;
            }
            _ => {}
        }
    }
    let reason = finished.context("SSE stream ended without a finished event")?;
    Ok(Outcome::Ok(tokens, ttft, itl, reason))
}

fn worker(
    cfg: &LoadgenConfig,
    addr: SocketAddr,
    next: &AtomicUsize,
    total: usize,
    t0: Instant,
) -> Partial {
    let mut part = Partial::default();
    let mut client: Option<HttpClient> = None;
    loop {
        let i = next.fetch_add(1, Ordering::SeqCst);
        if i >= total {
            return part;
        }
        let due = t0 + Duration::from_secs_f64(i as f64 / cfg.rps.max(1e-9));
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        if client.is_none() {
            match HttpClient::connect(addr) {
                Ok(c) => client = Some(c),
                Err(_) => {
                    part.sent += 1;
                    part.errors += 1;
                    continue;
                }
            }
        }
        part.sent += 1;
        let tier = tier_of(cfg.mix, i);
        let has_deadline = tier == crate::engine::Tier::Interactive && cfg.deadline_ms > 0.0;
        if has_deadline {
            part.deadline_total += 1;
        }
        let outcome = drive(client.as_mut().expect("connected above"), cfg, i, due);
        match outcome {
            Ok(Outcome::Ok(tokens, ttft, itl, reason)) => {
                part.ok += 1;
                part.tokens += tokens;
                let lat = (Instant::now() - due).as_secs_f64();
                part.lat.push(lat);
                match tier {
                    crate::engine::Tier::Interactive => part.lat_interactive.push(lat),
                    crate::engine::Tier::Batch => part.lat_batch.push(lat),
                }
                if has_deadline && reason != "deadline" {
                    part.deadline_hits += 1;
                }
                if let Some(t) = ttft {
                    part.ttft.push(t);
                }
                part.itl.extend(itl);
                if cfg.stream {
                    // SSE responses close the connection
                    client = None;
                }
            }
            Ok(Outcome::Shed429) => part.shed_429 += 1,
            Ok(Outcome::Error) => part.errors += 1,
            Err(_) => {
                part.errors += 1;
                client = None;
            }
        }
    }
}

/// One `/metrics` scrape, parsed. `None` on any failure — a daemon
/// without the endpoint (or with the obs plane detached: engine counters
/// all zero still parse, so that case is caught by the zero-delta check
/// in [`server_metrics`]) must not fail the load run.
fn scrape_metrics(addr: SocketAddr) -> Option<BTreeMap<String, f64>> {
    let mut client = HttpClient::connect(addr).ok()?;
    let resp = client.get("/metrics").ok()?;
    if resp.status != 200 {
        return None;
    }
    let text = std::str::from_utf8(&resp.body).ok()?;
    crate::obs::parse_exposition(text).ok()
}

/// Fold two scrapes into the server's view of the window. `None` when
/// the delta carries no retired requests — an obs-less daemon exposes
/// only wire counters, which would render as an all-zero (misleading)
/// server block.
fn server_metrics(
    before: &BTreeMap<String, f64>,
    after: &BTreeMap<String, f64>,
) -> Option<ServerMetrics> {
    use crate::obs::{exposition_delta, histogram_from_samples};
    let delta = exposition_delta(after, before);
    let counter = |key: &str| delta.get(key).copied().unwrap_or(0.0).max(0.0).round() as u64;
    if counter("repro_requests_total") == 0 {
        return None;
    }
    let hist = |name: &str| {
        histogram_from_samples(&delta, name)
            .map(|(bounds, counts, sum)| LatencySummary::from_histogram(&bounds, &counts, sum))
            .unwrap_or_default()
    };
    Some(ServerMetrics {
        requests: counter("repro_requests_total"),
        generated_tokens: counter("repro_generated_tokens_total"),
        executed_macs: counter("repro_executed_macs_total"),
        ttft: hist("repro_ttft_seconds"),
        inter_token: hist("repro_inter_token_seconds"),
        queue_wait: hist("repro_queue_wait_seconds"),
    })
}

/// Run the load generator against a daemon at `cfg.addr` and summarize
/// what the wire saw.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadReport> {
    ensure!(cfg.connections > 0, "loadgen needs at least one connection");
    ensure!(cfg.rps > 0.0 && cfg.rps.is_finite(), "rps must be positive");
    ensure!(cfg.duration_s > 0.0 && cfg.duration_s.is_finite(), "duration must be positive");
    let addr = cfg
        .addr
        .to_socket_addrs()
        .with_context(|| format!("resolve `{}`", cfg.addr))?
        .next()
        .with_context(|| format!("`{}` resolved to no address", cfg.addr))?;
    let total = (cfg.rps * cfg.duration_s).ceil().max(1.0) as usize;
    let next = AtomicUsize::new(0);
    let before = scrape_metrics(addr);
    let t0 = Instant::now();
    let parts: Vec<Partial> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections)
            .map(|_| s.spawn(|| worker(cfg, addr, &next, total, t0)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("loadgen worker panicked")).collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let server = match (&before, scrape_metrics(addr)) {
        (Some(b), Some(a)) => server_metrics(b, &a),
        _ => None,
    };
    let mut merged = Partial::default();
    for p in parts {
        merged.sent += p.sent;
        merged.ok += p.ok;
        merged.shed_429 += p.shed_429;
        merged.errors += p.errors;
        merged.tokens += p.tokens;
        merged.lat.extend(p.lat);
        merged.ttft.extend(p.ttft);
        merged.itl.extend(p.itl);
        merged.lat_interactive.extend(p.lat_interactive);
        merged.lat_batch.extend(p.lat_batch);
        merged.deadline_total += p.deadline_total;
        merged.deadline_hits += p.deadline_hits;
    }
    Ok(LoadReport {
        target_rps: cfg.rps,
        achieved_rps: if wall_s > 0.0 { merged.ok as f64 / wall_s } else { 0.0 },
        sent: merged.sent,
        ok: merged.ok,
        shed_429: merged.shed_429,
        errors: merged.errors,
        tokens: merged.tokens,
        wall_s,
        latency: LatencySummary::from_unsorted(merged.lat),
        ttft: LatencySummary::from_unsorted(merged.ttft),
        inter_token: LatencySummary::from_unsorted(merged.itl),
        interactive_latency: LatencySummary::from_unsorted(merged.lat_interactive),
        batch_latency: LatencySummary::from_unsorted(merged.lat_batch),
        deadline_total: merged.deadline_total,
        deadline_hits: merged.deadline_hits,
        server,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_prompts_are_deterministic_and_in_vocab() {
        let a = synth_prompt(7, 3, 16, 64);
        let b = synth_prompt(7, 3, 16, 64);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.iter().all(|&t| (0..64).contains(&t)));
        assert_ne!(a, synth_prompt(7, 4, 16, 64), "per-request variation");
        // degenerate knobs stay well-defined
        assert_eq!(synth_prompt(7, 0, 0, 1).len(), 1);
    }

    #[test]
    fn report_json_has_the_full_shape() {
        let r = LoadReport {
            target_rps: 10.0,
            achieved_rps: 9.5,
            sent: 20,
            ok: 19,
            shed_429: 1,
            errors: 0,
            tokens: 152,
            wall_s: 2.0,
            latency: LatencySummary::from_unsorted(vec![0.1, 0.2]),
            ttft: LatencySummary::from_unsorted(vec![0.05]),
            inter_token: LatencySummary::from_unsorted(vec![0.01, 0.02, 0.03]),
            interactive_latency: LatencySummary::from_unsorted(vec![0.1]),
            batch_latency: LatencySummary::from_unsorted(vec![0.2]),
            deadline_total: 4,
            deadline_hits: 3,
            server: Some(ServerMetrics {
                requests: 19,
                generated_tokens: 152,
                executed_macs: 1_000_000,
                ttft: LatencySummary::from_unsorted(vec![0.05]),
                inter_token: LatencySummary::from_unsorted(vec![0.01]),
                queue_wait: LatencySummary::from_unsorted(vec![0.001]),
            }),
        };
        let j = r.to_json();
        assert_eq!(j.get("sent").unwrap().as_usize().unwrap(), 20);
        assert_eq!(j.get("shed_429").unwrap().as_usize().unwrap(), 1);
        let lat = j.get("latency").unwrap();
        assert_eq!(lat.get("n").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("deadline_hits").unwrap().as_usize().unwrap(), 3);
        assert!((j.get("deadline_hit_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-12);
        assert_eq!(j.get("interactive_latency").unwrap().get("n").unwrap().as_usize().unwrap(), 1);
        let srv = j.get("server_metrics").unwrap();
        assert_eq!(srv.get("requests").unwrap().as_usize().unwrap(), 19);
        assert_eq!(srv.get("ttft").unwrap().get("n").unwrap().as_usize().unwrap(), 1);
        let text = r.format();
        assert!(text.contains("shed_429 1"));
        assert!(text.contains("ttft"));
        assert!(text.contains("interactive"));
        assert!(text.contains("deadline hit-rate 3/4"));
        assert!(text.contains("server side (/metrics delta)"));
        // serialized form is deterministic (sorted keys)
        assert_eq!(j.to_string(), r.to_json().to_string());
        // without a scrape the block is absent, not zeroed
        let bare = LoadReport::default();
        assert!(bare.to_json().get("server_metrics").is_err());
        assert!(!bare.format().contains("server side"));
    }

    #[test]
    fn server_metrics_delta_recovers_counters_and_histograms() {
        use crate::obs::{parse_exposition, MetricsRegistry};
        let m = MetricsRegistry::new();
        let before = parse_exposition(&m.render()).unwrap();
        m.requests.add(3);
        m.generated_tokens.add(24);
        m.executed_macs.add(5_000);
        m.ttft.observe(0.004);
        m.ttft.observe(0.004);
        m.queue_wait.observe(0.0001);
        let after = parse_exposition(&m.render()).unwrap();
        let srv = server_metrics(&before, &after).unwrap();
        assert_eq!(srv.requests, 3);
        assert_eq!(srv.generated_tokens, 24);
        assert_eq!(srv.executed_macs, 5_000);
        assert_eq!(srv.ttft.n, 2);
        assert!(srv.ttft.p50 >= 0.004, "percentile quantizes to a bucket upper bound");
        assert_eq!(srv.queue_wait.n, 1);
        assert_eq!(srv.inter_token.n, 0);
        // an idle window (obs-less daemon or no traffic) yields None
        assert_eq!(server_metrics(&after, &after), None);
    }

    #[test]
    fn mix_parses_and_assigns_tiers_deterministically() {
        use crate::engine::Tier;
        assert_eq!(parse_mix("1:4").unwrap(), (1, 4));
        assert_eq!(parse_mix(" 2 : 3 ").unwrap(), (2, 3));
        assert_eq!(parse_mix("0:1").unwrap(), (0, 1));
        for bad in ["", "1", "1:", ":2", "a:b", "0:0", "-1:2"] {
            assert!(parse_mix(bad).is_err(), "`{bad}` should not parse");
        }
        // 1:4 — exactly the first of every 5 requests is interactive
        let tiers: Vec<Tier> = (0..10).map(|i| tier_of((1, 4), i)).collect();
        for (i, t) in tiers.iter().enumerate() {
            let want = if i % 5 == 0 { Tier::Interactive } else { Tier::Batch };
            assert_eq!(*t, want, "request {i}");
        }
        // degenerate mixes collapse to one tier
        assert!((0..10).all(|i| tier_of((0, 1), i) == Tier::Batch));
        assert!((0..10).all(|i| tier_of((3, 0), i) == Tier::Interactive));
    }

    #[test]
    fn empty_report_has_a_perfect_hit_rate() {
        let r = LoadReport::default();
        assert_eq!(r.deadline_hit_rate(), 1.0, "no deadlines, nothing missed");
        assert!(!r.format().contains("deadline hit-rate"));
    }

    #[test]
    fn loadgen_rejects_nonsense_knobs() {
        let mut cfg = LoadgenConfig { addr: "127.0.0.1:1".into(), ..LoadgenConfig::default() };
        cfg.connections = 0;
        assert!(run_loadgen(&cfg).is_err());
        cfg.connections = 1;
        cfg.rps = 0.0;
        assert!(run_loadgen(&cfg).is_err());
        cfg.rps = 10.0;
        cfg.duration_s = f64::NAN;
        assert!(run_loadgen(&cfg).is_err());
    }
}
