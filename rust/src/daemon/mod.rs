//! HTTP/1.1 + SSE transport front-end for the inference engine: the
//! `repro daemon`.
//!
//! PR 5 unified serve and decode behind one streaming engine core
//! ([`crate::engine`]); this module puts that core on the wire without
//! adding a single dependency — a hand-rolled, hermetic HTTP/1.1 server
//! over `std::net`, good enough for a reproduction daemon and fully
//! exercisable offline over loopback.
//!
//! # Endpoints
//!
//! | Endpoint            | Meaning                                               |
//! |---------------------|-------------------------------------------------------|
//! | `POST /v1/generate` | KV-cached generation; `"stream": true` for SSE        |
//! | `POST /v1/score`    | Full-forward scoring of a token sequence              |
//! | `GET /healthz`      | Live [`crate::engine::EngineSnapshot`] + wire counters|
//! | `GET /readyz`       | `200` accepting / `503` draining                      |
//! | `POST /admin/drain` | Stop accepting, finish in-flight, exit                |
//!
//! Request/response envelopes map losslessly onto
//! [`crate::engine::InferenceRequest`] / `FinishedRequest`; the exact
//! schema (and the SSE frame sequence `admitted` → `prefilled` →
//! `token`* → `finished`) is documented in [`wire`]. Both envelopes
//! accept the optional scheduling fields `tier` (`"interactive"` /
//! `"batch"`, default batch — pre-PR-7 clients are unchanged), `tenant`
//! (labels the per-tenant fairness-ledger row in the engine stats), and
//! `deadline_ms` (relative; orders admission earliest-deadline-first and
//! bounds execution); unknown keys are still rejected. Streaming frames
//! mirror the engine's event stream, which is bitwise invariant to
//! `--threads` — so SSE payloads diff clean across thread counts, which
//! is exactly what `repro daemon --self-check` (and `scripts/verify.sh`)
//! asserts.
//!
//! # Operational behavior
//!
//! - **Load shedding**: the engine's bounded admission queue is the
//!   backpressure source of truth; a full queue surfaces as `429`
//!   instead of unbounded buffering. Caps are denominated both in
//!   request count and in *metered MACs* (the analytic per-request price
//!   from [`crate::model::macs::CostModel`]), and the `Retry-After`
//!   header is the meter's estimated drain time of the queued MAC
//!   backlog (`queued_macs`, surfaced on `/healthz`) at the observed
//!   execution rate — falling back to the configured constant before any
//!   work has run.
//! - **Cancellation**: a client disconnecting mid-SSE-stream cancels its
//!   request at the next token boundary and frees the slot for the
//!   queue.
//! - **Graceful drain**: `POST /admin/drain` (or
//!   [`DaemonControl::drain`]) flips the daemon into draining — new
//!   inference work gets `503`, everything already admitted runs to
//!   completion, then [`Daemon::serve`] returns its [`DaemonReport`].
//! - **Robustness**: malformed requests — bad JSON, unknown fields,
//!   out-of-vocab tokens, oversized heads/bodies — are structured `4xx`
//!   envelopes, never a panic and never a connection left hanging.
//!
//! [`loadgen`] closes the loop client-side: `repro loadgen` drives a
//! running daemon open-loop through the same [`http::HttpClient`] and
//! reports achieved RPS plus TTFT / inter-token / completion-latency
//! percentiles ([`crate::coordinator::daemon_bench`] packages a
//! self-hosted run of it as `BENCH_daemon.json`).
//!
//! `examples/http_serving.rs` walks the whole lifecycle end to end in
//! one process.

pub mod http;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use self::http::{HttpClient, SseFrame};
pub use self::loadgen::{parse_mix, run_loadgen, LoadReport, LoadgenConfig};
pub use self::server::{Daemon, DaemonConfig, DaemonControl, DaemonReport};
