//! HTTP/1.1 + SSE transport front-end for the inference engine: the
//! `repro daemon`.
//!
//! PR 5 unified serve and decode behind one streaming engine core
//! ([`crate::engine`]); this module puts that core on the wire without
//! adding a single dependency — a hand-rolled, hermetic HTTP/1.1 server
//! over `std::net`, good enough for a reproduction daemon and fully
//! exercisable offline over loopback.
//!
//! The daemon is execution-mode agnostic: the [`crate::serve::ServeModel`]
//! it binds is built once at startup from the `--mode` flag (`dense`,
//! `factored`, or `factored-quant` — the int8 quantized factored path,
//! selected explicitly and never substituted silently), and nothing on
//! the wire changes with the mode; only the kernels behind the logits do.
//! The same holds for speculative decoding: `--draft draft.rtz`
//! (+ `--spec-k`) pairs a low-budget artifact of the same checkpoint with
//! the serving model at bind time ([`Daemon::bind_with_draft`]), greedy
//! generate requests then draft+verify internally with bitwise-identical
//! output — a deployment decision, never negotiated on the wire.
//!
//! # Endpoints
//!
//! | Endpoint            | Meaning                                               |
//! |---------------------|-------------------------------------------------------|
//! | `POST /v1/generate` | KV-cached generation; `"stream": true` for SSE        |
//! | `POST /v1/score`    | Full-forward scoring of a token sequence              |
//! | `GET /healthz`      | Live [`crate::engine::EngineSnapshot`] + wire counters|
//! | `GET /readyz`       | `200` accepting / `503` draining                      |
//! | `GET /metrics`      | Timing plane: Prometheus text exposition              |
//! | `GET /admin/trace`  | Causal plane: flight-recorder transcript as JSONL     |
//! | `POST /admin/drain` | Stop accepting, finish in-flight, exit                |
//!
//! Request/response envelopes map losslessly onto
//! [`crate::engine::InferenceRequest`] / `FinishedRequest`; the exact
//! schema (and the SSE frame sequence `admitted` → `prefilled` →
//! `token`* → `finished`) is documented in [`wire`]. Both envelopes
//! accept the optional scheduling fields `tier` (`"interactive"` /
//! `"batch"`, default batch — pre-PR-7 clients are unchanged), `tenant`
//! (labels the per-tenant fairness-ledger row in the engine stats), and
//! `deadline_ms` (relative; orders admission earliest-deadline-first and
//! bounds execution); unknown keys are still rejected. Streaming frames
//! mirror the engine's event stream, which is bitwise invariant to
//! `--threads` — so SSE payloads diff clean across thread counts, which
//! is exactly what `repro daemon --self-check` (and `scripts/verify.sh`)
//! asserts.
//!
//! # Operational behavior
//!
//! - **Load shedding**: the engine's bounded admission queue is the
//!   backpressure source of truth; a full queue surfaces as `429`
//!   instead of unbounded buffering. Caps are denominated both in
//!   request count and in *metered MACs* (the analytic per-request price
//!   from [`crate::model::macs::CostModel`]), and the `Retry-After`
//!   header is the meter's estimated drain time of the queued MAC
//!   backlog (`queued_macs`, surfaced on `/healthz`) at the observed
//!   execution rate. The rate comes from the metrics registry (or the
//!   lifetime snapshot when metrics are off); the configured constant is
//!   used only for a truly cold engine that has executed no work yet.
//! - **Cancellation**: a client disconnecting mid-SSE-stream cancels its
//!   request at the next token boundary and frees the slot for the
//!   queue.
//! - **Graceful drain**: `POST /admin/drain` (or
//!   [`DaemonControl::drain`]) flips the daemon into draining — new
//!   inference work gets `503`, everything already admitted runs to
//!   completion, then [`Daemon::serve`] returns its [`DaemonReport`].
//! - **Robustness**: malformed requests — bad JSON, unknown fields,
//!   out-of-vocab tokens, oversized heads/bodies — are structured `4xx`
//!   envelopes, never a panic and never a connection left hanging.
//!
//! # Observability
//!
//! The daemon serves both planes of [`crate::obs`] (attached to the
//! engine session unless `--no-obs` / [`DaemonConfig::obs`]` = false`):
//!
//! - **`GET /metrics`** renders the timing plane as Prometheus text
//!   exposition format (version 0.0.4, `Content-Type: text/plain;
//!   version=0.0.4`): `repro_`-prefixed counter/gauge families mirroring
//!   the engine's analytic accounting *exactly* (requests, tokens,
//!   admitted/executed MACs — asserted equal to
//!   [`crate::engine::CoreStats`] by the `[5/5]` self-check phase),
//!   per-tier/per-tenant label families from the fairness ledger,
//!   fixed-bound histograms (TTFT, inter-token, queue wait, per-phase
//!   kernel time) with cumulative `le` buckets, and `repro_daemon_*`
//!   wire-level counters. Families render in a fixed order, so scrapes
//!   diff cleanly.
//! - **`GET /admin/trace`** serves the causal plane: the engine flight
//!   recorder's transcript as JSONL (`application/x-ndjson`, one
//!   sorted-key object per event, ring-bounded). Events carry only
//!   rounds, arrival seqs, tiers, and MACs — no wall clock — so the
//!   export is byte-identical across `--threads`; `repro daemon
//!   --trace-out FILE` writes the same lines to disk at drain.
//!
//! [`loadgen`] closes the loop client-side: `repro loadgen` drives a
//! running daemon open-loop through the same [`http::HttpClient`] and
//! reports achieved RPS plus TTFT / inter-token / completion-latency
//! percentiles ([`crate::coordinator::daemon_bench`] packages a
//! self-hosted run of it as `BENCH_daemon.json`).
//!
//! `examples/http_serving.rs` walks the whole lifecycle end to end in
//! one process.

pub mod http;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use self::http::{HttpClient, SseFrame};
pub use self::loadgen::{parse_mix, run_loadgen, LoadReport, LoadgenConfig};
pub use self::server::{Daemon, DaemonConfig, DaemonControl, DaemonReport};
