//! The daemon itself: one engine thread owning a [`Session`], an accept
//! loop, and per-connection handler threads, glued by mpsc channels.
//!
//! Concurrency layout — the session is **not** shared:
//!
//! - The *engine thread* is the only owner of the [`Session`]. Handlers
//!   talk to it through a command channel (`Submit` / `Cancel` / `Drain`)
//!   and get per-request reply channels back. It steps the session,
//!   routes events to per-request SSE senders, hands completed requests
//!   to their waiters via [`Session::drain_finished`], and publishes an
//!   [`EngineSnapshot`] into a lock-free cell after every round.
//! - The *accept loop* (the thread calling [`Daemon::serve`]) accepts
//!   connections non-blocking and spawns one scoped handler thread each.
//! - *Handler threads* parse HTTP requests, submit to the engine, and
//!   either wait for the completion envelope or forward SSE frames as
//!   the engine emits them. A failed frame write (client gone) sends
//!   `Cancel`, so the slot is reclaimed at the next token boundary; the
//!   engine independently detects a dropped stream receiver the same
//!   way.
//!
//! Load shedding is the engine's own bounded-queue backpressure
//! surfaced over the wire: [`Session::try_submit`] handing the request
//! back — whether the cap it hit was request-count or metered-MAC
//! denominated ([`EngineConfig::max_queued_macs`]) — becomes `429` with
//! a `Retry-After` computed as the estimated drain time of the queued
//! MAC backlog at the observed execution rate (the configured constant
//! until any work has run). Draining (via
//! [`DaemonControl::drain`] or `POST /admin/drain`) refuses new
//! inference work with `503`, finishes everything admitted, then stops
//! the whole daemon.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{
    CoreStats, EngineConfig, EngineCore, EngineSnapshot, FinishedRequest, InferenceRequest,
    Session,
};
use crate::obs::{MetricsRegistry, METRICS_NS, DEFAULT_TRACE_CAP};
use crate::serve::ServeModel;
use crate::util::json::Json;

use super::http::{self, Conn, HttpRequest, ReadOutcome, Response};
use super::wire;

/// Daemon knobs on top of the engine's.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`Daemon::addr`]).
    pub addr: String,
    pub engine: EngineConfig,
    /// Fallback `Retry-After` seconds advertised on 429 responses until
    /// the engine has observed an execution rate; after that the header
    /// carries the estimated drain time of the queued MAC backlog.
    pub retry_after_s: u32,
    /// Attach the observability plane to the engine session: the timing
    /// plane's metrics registry (served on `GET /metrics`) and the causal
    /// plane's flight recorder (served on `GET /admin/trace`, exported by
    /// `--trace-out`). Observability is strictly non-perturbing — output
    /// is bitwise identical either way — so this exists to prove that,
    /// not to save cost.
    pub obs: bool,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            addr: "127.0.0.1:0".to_string(),
            engine: EngineConfig::default(),
            retry_after_s: 1,
            obs: true,
        }
    }
}

/// An SSE frame in flight from the engine thread to a handler:
/// `(event name, data payload)`.
type SseMsg = (&'static str, String);

/// Handler → engine commands.
enum Cmd {
    Submit {
        req: InferenceRequest,
        /// `Some` for `stream: true` requests: the per-request SSE sink.
        stream: Option<Sender<SseMsg>>,
        reply: Sender<SubmitReply>,
    },
    /// Client went away (or explicitly hung up): reclaim the slot.
    Cancel(usize),
    Drain,
}

/// Engine → handler replies on the per-request channel.
enum SubmitReply {
    /// In the bounded queue; `id` is the daemon-assigned request id.
    Accepted { id: usize },
    /// Bounded queue full — shed (429).
    QueueFull,
    /// Request failed engine validation (400).
    Invalid(String),
    /// Daemon is draining (503).
    Draining,
    /// The completion envelope for non-streaming waiters.
    Finished(Box<FinishedRequest>),
}

/// Lock-free published copy of the latest [`EngineSnapshot`] — written
/// by the engine thread after every round, read by `/healthz`,
/// `/readyz`, and [`DaemonControl::snapshot`].
#[derive(Default)]
struct SnapCell {
    queue_depth: AtomicUsize,
    queue_cap: AtomicUsize,
    active: AtomicUsize,
    slots: AtomicUsize,
    free_slots: AtomicUsize,
    admitted: AtomicUsize,
    finished: AtomicUsize,
    scored_tokens: AtomicUsize,
    generated_tokens: AtomicUsize,
    macs: AtomicU64,
    queued_macs: AtomicU64,
    cancelled: AtomicUsize,
    deadline_evictions: AtomicUsize,
    mid_run_admissions: AtomicUsize,
    decode_rounds: AtomicUsize,
}

impl SnapCell {
    fn store(&self, s: &EngineSnapshot) {
        self.queue_depth.store(s.queue_depth, Ordering::SeqCst);
        self.queue_cap.store(s.queue_cap, Ordering::SeqCst);
        self.active.store(s.active, Ordering::SeqCst);
        self.slots.store(s.slots, Ordering::SeqCst);
        self.free_slots.store(s.free_slots, Ordering::SeqCst);
        self.admitted.store(s.admitted, Ordering::SeqCst);
        self.finished.store(s.finished, Ordering::SeqCst);
        self.scored_tokens.store(s.scored_tokens, Ordering::SeqCst);
        self.generated_tokens.store(s.generated_tokens, Ordering::SeqCst);
        self.macs.store(s.macs as u64, Ordering::SeqCst);
        self.queued_macs.store(s.queued_macs as u64, Ordering::SeqCst);
        self.cancelled.store(s.cancelled, Ordering::SeqCst);
        self.deadline_evictions.store(s.deadline_evictions, Ordering::SeqCst);
        self.mid_run_admissions.store(s.mid_run_admissions, Ordering::SeqCst);
        self.decode_rounds.store(s.decode_rounds, Ordering::SeqCst);
    }

    fn load(&self) -> EngineSnapshot {
        EngineSnapshot {
            queue_depth: self.queue_depth.load(Ordering::SeqCst),
            queue_cap: self.queue_cap.load(Ordering::SeqCst),
            active: self.active.load(Ordering::SeqCst),
            slots: self.slots.load(Ordering::SeqCst),
            free_slots: self.free_slots.load(Ordering::SeqCst),
            admitted: self.admitted.load(Ordering::SeqCst),
            finished: self.finished.load(Ordering::SeqCst),
            scored_tokens: self.scored_tokens.load(Ordering::SeqCst),
            generated_tokens: self.generated_tokens.load(Ordering::SeqCst),
            macs: self.macs.load(Ordering::SeqCst) as u128,
            queued_macs: self.queued_macs.load(Ordering::SeqCst) as u128,
            cancelled: self.cancelled.load(Ordering::SeqCst),
            deadline_evictions: self.deadline_evictions.load(Ordering::SeqCst),
            mid_run_admissions: self.mid_run_admissions.load(Ordering::SeqCst),
            decode_rounds: self.decode_rounds.load(Ordering::SeqCst),
        }
    }
}

/// State shared by the engine thread, accept loop, handlers, and
/// control handles.
struct Shared {
    /// Refuse new inference work; finish what was admitted.
    draining: AtomicBool,
    /// Engine exited — accept loop and handlers wind down.
    stopped: AtomicBool,
    /// Determinism hook for tests and the self-check: a paused engine
    /// keeps answering commands (submissions queue, snapshots publish)
    /// but runs no scheduling rounds, making queue saturation and
    /// shedding exactly reproducible. Ignored once draining.
    paused: AtomicBool,
    snap: SnapCell,
    // wire-level counters (the engine counts engine-level ones)
    http_requests: AtomicUsize,
    shed_429: AtomicUsize,
    shed_503: AtomicUsize,
    bad_requests: AtomicUsize,
    disconnect_cancels: AtomicUsize,
    sse_streams: AtomicUsize,
    /// The timing plane. Always constructed (so `GET /metrics` always
    /// answers); fed by the engine session only when [`DaemonConfig::obs`]
    /// is on.
    metrics: Arc<MetricsRegistry>,
    /// Causal-plane JSONL lines drained from the engine session's flight
    /// recorder, ring-bounded at [`DEFAULT_TRACE_CAP`]. Served by
    /// `GET /admin/trace` and returned in [`DaemonReport::trace`].
    trace: Mutex<VecDeque<String>>,
    /// Daemon start time — the denominator of the snapshot-derived
    /// execution-rate fallback in [`retry_after_secs`].
    started: Instant,
    obs: bool,
    retry_after_s: u32,
    vocab: usize,
}

/// `Retry-After` for a shed request: the estimated drain time of the
/// queued MAC backlog at the observed execution rate, at least 1 s. The
/// rate comes from the metrics registry when the obs plane is attached,
/// and otherwise from the published snapshot's executed-MAC total over
/// the daemon's lifetime — so a snapshot that already carries
/// finished-request stats yields a rate estimate, and the configured
/// constant is used only for a truly cold engine (no work executed yet).
fn retry_after_secs(shared: &Shared) -> u64 {
    let snap_rate = || {
        let macs = shared.snap.macs.load(Ordering::SeqCst) as f64;
        let elapsed = shared.started.elapsed().as_secs_f64();
        (macs > 0.0 && elapsed > 0.0).then(|| macs / elapsed)
    };
    match shared.metrics.macs_rate().or_else(snap_rate) {
        Some(rate) => {
            let backlog = shared.snap.queued_macs.load(Ordering::SeqCst) as f64;
            (backlog / rate).ceil().max(1.0) as u64
        }
        None => shared.retry_after_s.max(1) as u64,
    }
}

/// Wire-level accounting of one daemon run, alongside the engine's
/// [`CoreStats`].
#[derive(Debug, Clone, Default)]
pub struct DaemonReport {
    pub stats: CoreStats,
    /// HTTP requests answered (any status, any endpoint).
    pub http_requests: usize,
    /// Inference submissions shed with 429 (queue full).
    pub shed_429: usize,
    /// Inference submissions refused with 503 (draining).
    pub shed_503: usize,
    /// Malformed requests answered with 4xx envelopes.
    pub bad_requests: usize,
    /// Mid-stream client disconnects that cancelled a request.
    pub disconnect_cancels: usize,
    /// SSE streams opened.
    pub sse_streams: usize,
    /// Causal-plane flight-recorder transcript (JSONL lines, oldest
    /// first) — empty unless [`DaemonConfig::obs`] was on. What
    /// `repro daemon --trace-out` writes to disk.
    pub trace: Vec<String>,
}

/// A cloneable handle for steering a running daemon from another thread:
/// drain it, pause/resume the engine (test hook), read the live
/// snapshot.
#[derive(Clone)]
pub struct DaemonControl {
    shared: Arc<Shared>,
    cmd: Sender<Cmd>,
    addr: SocketAddr,
}

impl DaemonControl {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Latest published [`EngineSnapshot`].
    pub fn snapshot(&self) -> EngineSnapshot {
        self.shared.snap.load()
    }

    /// The daemon's timing-plane registry (what `GET /metrics` renders).
    /// Always present; its counters stay zero unless
    /// [`DaemonConfig::obs`] attached it to the engine session.
    pub fn metrics(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.shared.metrics)
    }

    /// Causal-plane JSONL lines buffered so far (what `GET /admin/trace`
    /// serves), oldest first.
    pub fn trace_lines(&self) -> Vec<String> {
        self.shared.trace.lock().expect("trace buffer poisoned").iter().cloned().collect()
    }

    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// True once the engine exited and [`Daemon::serve`] is returning.
    pub fn stopped(&self) -> bool {
        self.shared.stopped.load(Ordering::SeqCst)
    }

    /// Stop accepting inference work, finish everything admitted, then
    /// shut the daemon down (same as `POST /admin/drain`).
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        let _ = self.cmd.send(Cmd::Drain);
    }

    /// Suspend scheduling rounds (submissions still queue, snapshots
    /// still publish). Determinism hook: lets tests fill the bounded
    /// queue to a known depth before any admission happens.
    pub fn pause(&self) {
        self.shared.paused.store(true, Ordering::SeqCst);
    }

    pub fn resume(&self) {
        self.shared.paused.store(false, Ordering::SeqCst);
    }
}

/// A bound-but-not-yet-serving daemon: the listener exists (so the
/// ephemeral port is known and clients can already connect) but
/// requests are only processed once [`Daemon::serve`] runs.
pub struct Daemon<'m> {
    model: &'m ServeModel,
    /// Speculative draft model, fixed at bind time — the decode mode is a
    /// daemon-side deployment decision, never negotiated on the wire.
    draft: Option<&'m ServeModel>,
    engine: EngineConfig,
    listener: TcpListener,
    addr: SocketAddr,
    shared: Arc<Shared>,
    cmd_tx: Sender<Cmd>,
    cmd_rx: Receiver<Cmd>,
}

impl<'m> Daemon<'m> {
    pub fn bind(model: &'m ServeModel, config: DaemonConfig) -> Result<Daemon<'m>> {
        let listener = TcpListener::bind(config.addr.as_str())
            .with_context(|| format!("bind {}", config.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let addr = listener.local_addr().context("local_addr")?;
        let shared = Arc::new(Shared {
            draining: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            paused: AtomicBool::new(false),
            snap: SnapCell::default(),
            http_requests: AtomicUsize::new(0),
            shed_429: AtomicUsize::new(0),
            shed_503: AtomicUsize::new(0),
            bad_requests: AtomicUsize::new(0),
            disconnect_cancels: AtomicUsize::new(0),
            sse_streams: AtomicUsize::new(0),
            metrics: Arc::new(MetricsRegistry::new()),
            trace: Mutex::new(VecDeque::new()),
            started: Instant::now(),
            obs: config.obs,
            retry_after_s: config.retry_after_s,
            vocab: model.config().vocab,
        });
        let slots = config.engine.slots.max(1);
        shared.snap.store(&EngineSnapshot {
            queue_cap: config.engine.queue_cap.max(1),
            slots,
            free_slots: slots,
            ..EngineSnapshot::default()
        });
        let (cmd_tx, cmd_rx) = channel();
        Ok(Daemon { model, draft: None, engine: config.engine, listener, addr, shared, cmd_tx, cmd_rx })
    }

    /// [`Daemon::bind`] with a speculative draft model bound for the whole
    /// run. The pair is validated here, before the listener serves a
    /// single request — greedy streams stay bitwise identical to a
    /// draft-less daemon, only throughput (and the `repro_spec_*` metrics
    /// counters) change.
    pub fn bind_with_draft(
        model: &'m ServeModel,
        draft: &'m ServeModel,
        config: DaemonConfig,
    ) -> Result<Daemon<'m>> {
        // fail fast on a mismatched pair or spec_k 0 — the same checks the
        // engine applies, surfaced at startup instead of mid-serve
        EngineCore::with_draft(model, draft, config.engine)?;
        let mut daemon = Daemon::bind(model, config)?;
        daemon.draft = Some(draft);
        Ok(daemon)
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn control(&self) -> DaemonControl {
        DaemonControl {
            shared: Arc::clone(&self.shared),
            cmd: self.cmd_tx.clone(),
            addr: self.addr,
        }
    }

    /// Run until drained: engine thread + accept loop + one scoped
    /// handler thread per connection. Returns the run's accounting once
    /// every admitted request finished and every handler exited.
    pub fn serve(self) -> Result<DaemonReport> {
        let Daemon { model, draft, engine, listener, addr: _, shared, cmd_tx, cmd_rx } = self;
        let core = match draft {
            Some(d) => EngineCore::with_draft(model, d, engine)?,
            None => EngineCore::new(model, engine),
        };
        let stats = std::thread::scope(|s| -> Result<CoreStats> {
            let eng = s.spawn(|| engine_loop(core, &shared, cmd_rx));
            let mut accept_err: Option<std::io::Error> = None;
            loop {
                if shared.stopped.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let shared = Arc::clone(&shared);
                        let cmd_tx = cmd_tx.clone();
                        s.spawn(move || handle_connection(stream, &shared, &cmd_tx));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(e) => {
                        // fatal accept error: drain what's in flight, then
                        // surface the error
                        accept_err = Some(e);
                        shared.draining.store(true, Ordering::SeqCst);
                        let _ = cmd_tx.send(Cmd::Drain);
                        while !shared.stopped.load(Ordering::SeqCst) {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        break;
                    }
                }
            }
            let out = eng.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))?;
            match accept_err {
                Some(e) => Err(e).context("accept"),
                None => out,
            }
        })?;
        let trace: Vec<String> =
            shared.trace.lock().expect("trace buffer poisoned").iter().cloned().collect();
        Ok(DaemonReport {
            stats,
            http_requests: shared.http_requests.load(Ordering::SeqCst),
            shed_429: shared.shed_429.load(Ordering::SeqCst),
            shed_503: shared.shed_503.load(Ordering::SeqCst),
            bad_requests: shared.bad_requests.load(Ordering::SeqCst),
            disconnect_cancels: shared.disconnect_cancels.load(Ordering::SeqCst),
            sse_streams: shared.sse_streams.load(Ordering::SeqCst),
            trace,
        })
    }
}

// ---- engine thread -------------------------------------------------------

/// The engine thread's mutable state: the session plus the per-request
/// delivery channels.
struct EngineLoop<'m> {
    session: Session<'m>,
    /// SSE sinks by request id (streaming requests only).
    streams: HashMap<usize, Sender<SseMsg>>,
    /// Completion waiters by request id (non-streaming requests).
    waiters: HashMap<usize, Sender<SubmitReply>>,
    /// Monotonic daemon-assigned request ids.
    next_id: usize,
    drain: bool,
}

impl<'m> EngineLoop<'m> {
    fn handle(&mut self, cmd: Cmd, shared: &Shared) {
        match cmd {
            Cmd::Drain => self.drain = true,
            Cmd::Cancel(id) => {
                self.streams.remove(&id);
                self.waiters.remove(&id);
                if self.session.cancel(id) {
                    shared.disconnect_cancels.fetch_add(1, Ordering::SeqCst);
                }
            }
            Cmd::Submit { mut req, stream, reply } => {
                if self.drain {
                    let _ = reply.send(SubmitReply::Draining);
                    return;
                }
                req.id = self.next_id;
                // deadlines arrive client-relative; rebase onto the
                // session clock at admission-queue entry
                if let Some(rel) = req.deadline_s {
                    req.deadline_s = Some(self.session.elapsed_s() + rel);
                }
                match self.session.try_submit(req) {
                    Err(e) => {
                        let _ = reply.send(SubmitReply::Invalid(format!("{e:#}")));
                    }
                    Ok(Some(_back)) => {
                        let _ = reply.send(SubmitReply::QueueFull);
                    }
                    Ok(None) => {
                        let id = self.next_id;
                        self.next_id += 1;
                        let is_stream = stream.is_some();
                        if let Some(tx) = stream {
                            self.streams.insert(id, tx);
                        }
                        if reply.send(SubmitReply::Accepted { id }).is_err() {
                            // handler died before hearing the accept:
                            // don't let the request hold a slot
                            self.streams.remove(&id);
                            self.session.cancel(id);
                        } else if !is_stream {
                            self.waiters.insert(id, reply);
                        }
                    }
                }
            }
        }
    }

    /// Forward this round's events to their SSE sinks; a dead sink
    /// (handler gone — client disconnected) cancels its request.
    fn route_events(&mut self, shared: &Shared) {
        let events = self.session.take_events();
        let mut dead: Vec<usize> = Vec::new();
        for ev in &events {
            if let Some(tx) = self.streams.get(&ev.id) {
                if tx.send(wire::event_sse(ev)).is_err() && !dead.contains(&ev.id) {
                    dead.push(ev.id);
                }
            }
        }
        for id in dead {
            self.streams.remove(&id);
            if self.session.cancel(id) {
                shared.disconnect_cancels.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    /// Hand completed requests to their waiters and drop their SSE
    /// sinks (closing the event stream ends the SSE response).
    fn deliver_finished(&mut self) {
        for f in self.session.drain_finished() {
            self.streams.remove(&f.id);
            if let Some(w) = self.waiters.remove(&f.id) {
                let _ = w.send(SubmitReply::Finished(Box::new(f)));
            }
        }
    }
}

/// Drain the session's flight recorder into the shared JSONL ring (a
/// no-op when tracing is off — `take_trace` returns nothing).
fn drain_trace(session: &mut Session<'_>, shared: &Shared) {
    let events = session.take_trace();
    if events.is_empty() {
        return;
    }
    let mut buf = shared.trace.lock().expect("trace buffer poisoned");
    for ev in events {
        if buf.len() == DEFAULT_TRACE_CAP {
            buf.pop_front();
        }
        buf.push_back(ev.to_json().to_string());
    }
}

fn engine_loop(
    core: EngineCore<'_>,
    shared: &Shared,
    rx: Receiver<Cmd>,
) -> Result<CoreStats> {
    let mut session = core.session();
    if shared.obs {
        session.attach_metrics(Arc::clone(&shared.metrics));
        session.enable_tracing(DEFAULT_TRACE_CAP);
    }
    let mut lp = EngineLoop {
        session,
        streams: HashMap::new(),
        waiters: HashMap::new(),
        next_id: 0,
        drain: false,
    };
    let mut senders_gone = false;
    loop {
        // absorb every queued command without blocking
        loop {
            match rx.try_recv() {
                Ok(cmd) => lp.handle(cmd, shared),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    senders_gone = true;
                    break;
                }
            }
        }
        // one scheduling round (unless paused; draining overrides pause
        // so a drain can never hang behind the test hook)
        let paused = shared.paused.load(Ordering::SeqCst) && !lp.drain;
        let mut worked = false;
        if !paused && lp.session.has_work() {
            match lp.session.step() {
                Ok(w) => worked = w,
                Err(e) => {
                    shared.draining.store(true, Ordering::SeqCst);
                    shared.stopped.store(true, Ordering::SeqCst);
                    return Err(e);
                }
            }
        }
        lp.route_events(shared);
        lp.deliver_finished();
        drain_trace(&mut lp.session, shared);
        let snap = lp.session.snapshot();
        if shared.obs {
            shared.metrics.queue_depth.set(snap.queue_depth as u64);
            shared.metrics.active_lanes.set(snap.active as u64);
            shared.metrics.queued_macs.set(snap.queued_macs.min(u64::MAX as u128) as u64);
        }
        shared.snap.store(&snap);
        if (lp.drain || senders_gone) && !lp.session.has_work() {
            break;
        }
        if !worked {
            // idle (or paused): park on the command channel for a tick
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(cmd) => lp.handle(cmd, shared),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => senders_gone = true,
            }
        }
    }
    shared.draining.store(true, Ordering::SeqCst);
    drain_trace(&mut lp.session, shared);
    let (_leftover, stats) = lp.session.finish();
    shared.snap.finished.store(stats.requests, Ordering::SeqCst);
    shared.stopped.store(true, Ordering::SeqCst);
    Ok(stats)
}

// ---- connection handlers -------------------------------------------------

/// Whether the connection survives the response.
enum Flow {
    KeepAlive,
    Close,
}

fn handle_connection(stream: TcpStream, shared: &Shared, cmd_tx: &Sender<Cmd>) {
    let Ok(mut conn) = Conn::new(stream) else {
        return;
    };
    loop {
        match http::read_request(&mut conn) {
            Ok(ReadOutcome::Idle) => {
                if shared.stopped.load(Ordering::SeqCst) {
                    return;
                }
            }
            Ok(ReadOutcome::Eof) => return,
            Ok(ReadOutcome::Malformed { status, message }) => {
                shared.bad_requests.fetch_add(1, Ordering::SeqCst);
                shared.http_requests.fetch_add(1, Ordering::SeqCst);
                let resp = Response::json(status, &wire::error_json(status, &message));
                let _ = resp.write(conn.stream_mut(), false);
                return;
            }
            Ok(ReadOutcome::Request(req)) => {
                shared.http_requests.fetch_add(1, Ordering::SeqCst);
                let keep = req.keep_alive();
                match dispatch(&req, &mut conn, shared, cmd_tx) {
                    Flow::KeepAlive if keep => {}
                    _ => return,
                }
            }
            Err(_) => return,
        }
    }
}

fn respond(conn: &mut Conn, status: u16, body: &Json) -> Flow {
    match Response::json(status, body).write(conn.stream_mut(), true) {
        Ok(()) => Flow::KeepAlive,
        Err(_) => Flow::Close,
    }
}

fn dispatch(req: &HttpRequest, conn: &mut Conn, shared: &Shared, cmd_tx: &Sender<Cmd>) -> Flow {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond(conn, 200, &health_json(shared)),
        ("GET", "/readyz") => {
            let draining = shared.draining.load(Ordering::SeqCst);
            let body = wire::obj(vec![
                ("ready", Json::Bool(!draining)),
                ("draining", Json::Bool(draining)),
            ]);
            respond(conn, if draining { 503 } else { 200 }, &body)
        }
        ("POST", "/admin/drain") => {
            shared.draining.store(true, Ordering::SeqCst);
            let _ = cmd_tx.send(Cmd::Drain);
            respond(conn, 200, &wire::obj(vec![("draining", Json::Bool(true))]))
        }
        ("GET", "/metrics") => {
            let resp =
                Response::text(200, "text/plain; version=0.0.4", metrics_exposition(shared));
            match resp.write(conn.stream_mut(), true) {
                Ok(()) => Flow::KeepAlive,
                Err(_) => Flow::Close,
            }
        }
        ("GET", "/admin/trace") => {
            let mut body = String::new();
            for line in shared.trace.lock().expect("trace buffer poisoned").iter() {
                body.push_str(line);
                body.push('\n');
            }
            let resp = Response::text(200, "application/x-ndjson", body);
            match resp.write(conn.stream_mut(), true) {
                Ok(()) => Flow::KeepAlive,
                Err(_) => Flow::Close,
            }
        }
        ("POST", "/v1/generate") => handle_inference(req, conn, shared, cmd_tx, true),
        ("POST", "/v1/score") => handle_inference(req, conn, shared, cmd_tx, false),
        (
            _,
            "/healthz" | "/readyz" | "/admin/drain" | "/v1/generate" | "/v1/score" | "/metrics"
            | "/admin/trace",
        ) => {
            respond(conn, 405, &wire::error_json(405, &format!("{} not allowed here", req.method)))
        }
        (_, path) => respond(conn, 404, &wire::error_json(404, &format!("no endpoint `{path}`"))),
    }
}

/// The full `GET /metrics` body: the engine registry's exposition plus
/// the daemon's wire-level counters under a `daemon_` infix. Same
/// deterministic family order on every scrape.
fn metrics_exposition(shared: &Shared) -> String {
    let mut out = shared.metrics.render();
    for (name, help, v) in [
        ("daemon_http_requests_total", "HTTP requests answered (any status).", &shared.http_requests),
        ("daemon_shed_429_total", "Inference submissions shed with 429.", &shared.shed_429),
        ("daemon_shed_503_total", "Inference submissions refused with 503.", &shared.shed_503),
        ("daemon_bad_requests_total", "Malformed requests answered with 4xx.", &shared.bad_requests),
        ("daemon_disconnect_cancels_total", "Mid-stream disconnects that cancelled a request.", &shared.disconnect_cancels),
        ("daemon_sse_streams_total", "SSE streams opened.", &shared.sse_streams),
    ] {
        out.push_str(&format!("# HELP {METRICS_NS}_{name} {help}\n"));
        out.push_str(&format!("# TYPE {METRICS_NS}_{name} counter\n"));
        out.push_str(&format!("{METRICS_NS}_{name} {}\n", v.load(Ordering::SeqCst)));
    }
    out
}

fn health_json(shared: &Shared) -> Json {
    let s = shared.snap.load();
    let n = |x: usize| Json::Num(x as f64);
    wire::obj(vec![
        ("ok", Json::Bool(true)),
        ("draining", Json::Bool(shared.draining.load(Ordering::SeqCst))),
        ("queue_depth", n(s.queue_depth)),
        ("queue_cap", n(s.queue_cap)),
        ("active", n(s.active)),
        ("slots", n(s.slots)),
        ("free_slots", n(s.free_slots)),
        ("admitted", n(s.admitted)),
        ("finished", n(s.finished)),
        ("scored_tokens", n(s.scored_tokens)),
        ("generated_tokens", n(s.generated_tokens)),
        ("macs", Json::Num(s.macs as f64)),
        ("queued_macs", Json::Num(s.queued_macs as f64)),
        ("cancelled", n(s.cancelled)),
        ("deadline_evictions", n(s.deadline_evictions)),
        ("mid_run_admissions", n(s.mid_run_admissions)),
        ("decode_rounds", n(s.decode_rounds)),
        ("http_requests", n(shared.http_requests.load(Ordering::SeqCst))),
        ("shed_429", n(shared.shed_429.load(Ordering::SeqCst))),
        ("shed_503", n(shared.shed_503.load(Ordering::SeqCst))),
        ("bad_requests", n(shared.bad_requests.load(Ordering::SeqCst))),
        ("disconnect_cancels", n(shared.disconnect_cancels.load(Ordering::SeqCst))),
        ("sse_streams", n(shared.sse_streams.load(Ordering::SeqCst))),
    ])
}

fn handle_inference(
    req: &HttpRequest,
    conn: &mut Conn,
    shared: &Shared,
    cmd_tx: &Sender<Cmd>,
    generate: bool,
) -> Flow {
    if shared.draining.load(Ordering::SeqCst) {
        shared.shed_503.fetch_add(1, Ordering::SeqCst);
        return respond(conn, 503, &wire::error_json(503, "draining: not accepting new requests"));
    }
    let parsed = if generate {
        wire::parse_generate(&req.body, shared.vocab)
    } else {
        wire::parse_score(&req.body, shared.vocab)
    };
    let w = match parsed {
        Ok(w) => w,
        Err(e) => {
            shared.bad_requests.fetch_add(1, Ordering::SeqCst);
            return respond(conn, 400, &wire::error_json(400, &format!("{e:#}")));
        }
    };
    let (reply_tx, reply_rx) = channel();
    let (ev_tx, ev_rx) = if w.stream {
        let (tx, rx) = channel();
        (Some(tx), Some(rx))
    } else {
        (None, None)
    };
    if cmd_tx.send(Cmd::Submit { req: w.req, stream: ev_tx, reply: reply_tx }).is_err() {
        return respond(conn, 503, &wire::error_json(503, "engine stopped"));
    }
    match reply_rx.recv() {
        Ok(SubmitReply::Accepted { id }) => match ev_rx {
            Some(rx) => stream_events(conn, shared, cmd_tx, id, rx),
            None => match reply_rx.recv() {
                Ok(SubmitReply::Finished(f)) => {
                    respond(conn, 200, &wire::finished_json(&f, w.want_logits))
                }
                _ => respond(conn, 503, &wire::error_json(503, "engine stopped mid-request")),
            },
        },
        Ok(SubmitReply::QueueFull) => {
            shared.shed_429.fetch_add(1, Ordering::SeqCst);
            let body = wire::error_json(429, "admission queue full, retry later");
            let resp = Response::json(429, &body)
                .with_header("Retry-After", &retry_after_secs(shared).to_string());
            match resp.write(conn.stream_mut(), true) {
                Ok(()) => Flow::KeepAlive,
                Err(_) => Flow::Close,
            }
        }
        Ok(SubmitReply::Invalid(msg)) => {
            shared.bad_requests.fetch_add(1, Ordering::SeqCst);
            respond(conn, 400, &wire::error_json(400, &msg))
        }
        Ok(SubmitReply::Draining) => {
            shared.shed_503.fetch_add(1, Ordering::SeqCst);
            respond(conn, 503, &wire::error_json(503, "draining: not accepting new requests"))
        }
        Ok(SubmitReply::Finished(f)) => {
            // defensive: a result with no preceding accept still answers
            respond(conn, 200, &wire::finished_json(&f, w.want_logits))
        }
        Err(_) => respond(conn, 503, &wire::error_json(503, "engine stopped")),
    }
}

/// Forward SSE frames until the request finishes or the client goes
/// away; a failed write cancels the request so its slot is reclaimed.
fn stream_events(
    conn: &mut Conn,
    shared: &Shared,
    cmd_tx: &Sender<Cmd>,
    id: usize,
    ev_rx: Receiver<SseMsg>,
) -> Flow {
    shared.sse_streams.fetch_add(1, Ordering::SeqCst);
    if http::write_sse_head(conn.stream_mut()).is_err() {
        let _ = cmd_tx.send(Cmd::Cancel(id));
        return Flow::Close;
    }
    loop {
        match ev_rx.recv() {
            Ok((event, data)) => {
                if http::write_sse_frame(conn.stream_mut(), event, &data).is_err() {
                    let _ = cmd_tx.send(Cmd::Cancel(id));
                    return Flow::Close;
                }
                if event == "finished" {
                    return Flow::Close;
                }
            }
            // engine dropped the sink: the stream is complete (or the
            // engine exited) — either way close out
            Err(_) => return Flow::Close,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{demo_artifact, demo_config, ExecMode};

    #[test]
    fn bind_assigns_a_port_and_drain_stops_an_idle_daemon() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 5).unwrap();
        let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let daemon = Daemon::bind(&model, DaemonConfig::default()).unwrap();
        let addr = daemon.addr();
        assert_ne!(addr.port(), 0, "ephemeral port resolved");
        let ctl = daemon.control();
        let snap = ctl.snapshot();
        assert_eq!((snap.active, snap.finished), (0, 0));
        assert_eq!(snap.slots, 4, "engine defaults published before serve");
        ctl.drain();
        let report = daemon.serve().unwrap();
        assert!(ctl.stopped());
        assert_eq!(report.stats.requests, 0);
        assert_eq!(report.http_requests, 0);
    }
}
