//! Hand-rolled HTTP/1.1 over `std::net` — the hermetic wire substrate of
//! the daemon (server side) and the load generator / self-check (client
//! side). No new crates: a blocking [`Conn`] with a short socket read
//! timeout gives the accept/handler loops regular control-flow ticks
//! (drain and shutdown flags are checked between requests), and the
//! parser supports exactly the subset the daemon speaks — request line,
//! headers, `Content-Length` bodies, keep-alive, and Server-Sent Events
//! framed as `event:`/`data:` blocks terminated by a blank line.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;

/// Cap on the request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on a request body — prompts are token arrays, so this is generous.
pub const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
/// Socket read timeout: the tick at which blocked readers re-check
/// control flags (drain/shutdown) between requests.
const READ_TICK: Duration = Duration::from_millis(50);
/// Total budget for receiving one request once its first byte arrived.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(5);
/// Client-side budget for one response head / SSE frame.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(30);

/// One parsed HTTP/1.1 request. Header names are lowercased.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// HTTP/1.1 default is keep-alive unless the client opts out.
    pub fn keep_alive(&self) -> bool {
        !self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// What one attempt to read a request produced.
#[derive(Debug)]
pub enum ReadOutcome {
    Request(HttpRequest),
    /// No bytes arrived within one read tick — re-check flags and retry.
    Idle,
    /// Peer closed cleanly between requests.
    Eof,
    /// Unusable request; respond with `status` and close.
    Malformed { status: u16, message: String },
}

/// A blocking TCP connection with a byte buffer and tick-granular reads —
/// shared by the server handler and [`HttpClient`].
pub struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Result<Conn> {
        stream.set_read_timeout(Some(READ_TICK)).context("set_read_timeout")?;
        stream.set_nodelay(true).ok();
        Ok(Conn { stream, buf: Vec::new() })
    }

    pub fn stream_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }

    /// One read tick: append whatever arrived. `Ok(0)` is EOF; a timeout
    /// surfaces as `ErrorKind::WouldBlock`/`TimedOut`.
    fn fill_once(&mut self) -> std::io::Result<usize> {
        let mut tmp = [0u8; 4096];
        let n = self.stream.read(&mut tmp)?;
        self.buf.extend_from_slice(&tmp[..n]);
        Ok(n)
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn malformed(status: u16, message: impl Into<String>) -> ReadOutcome {
    ReadOutcome::Malformed { status, message: message.into() }
}

/// Read one request off the connection. Returns [`ReadOutcome::Idle`]
/// after one quiet read tick so the caller can re-check its control
/// flags; once a request's first byte arrives the whole request must
/// land within [`REQUEST_TIMEOUT`].
pub fn read_request(conn: &mut Conn) -> Result<ReadOutcome> {
    let t0 = Instant::now();
    let mut got_bytes = !conn.buf.is_empty();
    // ---- head: everything up to the blank line ----
    let head_end = loop {
        if let Some(pos) = find_subslice(&conn.buf, b"\r\n\r\n") {
            break pos;
        }
        if conn.buf.len() > MAX_HEAD_BYTES {
            return Ok(malformed(431, "request head too large"));
        }
        match conn.fill_once() {
            Ok(0) => {
                return Ok(if got_bytes {
                    malformed(400, "connection closed mid-request")
                } else {
                    ReadOutcome::Eof
                });
            }
            Ok(_) => got_bytes = true,
            Err(e) if is_timeout(&e) => {
                if !got_bytes {
                    return Ok(ReadOutcome::Idle);
                }
                if t0.elapsed() > REQUEST_TIMEOUT {
                    return Ok(malformed(408, "timed out reading request head"));
                }
            }
            Err(e) => return Err(e).context("read request head"),
        }
    };
    // ---- parse the head ----
    let head = match std::str::from_utf8(&conn.buf[..head_end]) {
        Ok(s) => s.to_string(),
        Err(_) => return Ok(malformed(400, "request head is not UTF-8")),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_ascii_whitespace();
    let (method, path, version) =
        (parts.next().unwrap_or(""), parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Ok(malformed(400, format!("bad request line `{request_line}`")));
    }
    let mut headers = BTreeMap::new();
    for line in lines {
        match line.split_once(':') {
            Some((k, v)) => {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
            None => return Ok(malformed(400, format!("bad header line `{line}`"))),
        }
    }
    // ---- body: exactly Content-Length bytes ----
    let body_len = match headers.get("content-length") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => return Ok(malformed(400, format!("bad content-length `{v}`"))),
        },
        None => 0,
    };
    if body_len > MAX_BODY_BYTES {
        return Ok(malformed(413, format!("body of {body_len} bytes exceeds {MAX_BODY_BYTES}")));
    }
    let total = head_end + 4 + body_len;
    while conn.buf.len() < total {
        match conn.fill_once() {
            Ok(0) => return Ok(malformed(400, "connection closed mid-body")),
            Ok(_) => {}
            Err(e) if is_timeout(&e) => {
                if t0.elapsed() > REQUEST_TIMEOUT {
                    return Ok(malformed(408, "timed out reading request body"));
                }
            }
            Err(e) => return Err(e).context("read request body"),
        }
    }
    let body = conn.buf[head_end + 4..total].to_vec();
    conn.buf.drain(..total);
    Ok(ReadOutcome::Request(HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body,
    }))
}

/// Reason phrases for the statuses the daemon emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// A buffered response: status, extra headers, body with its media type
/// (JSON everywhere except the plain-text observability endpoints).
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    pub content_type: &'static str,
}

impl Response {
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: body.to_string().into_bytes(),
            content_type: "application/json",
        }
    }

    /// A non-JSON body — Prometheus exposition (`text/plain;
    /// version=0.0.4`) and JSONL trace dumps (`application/x-ndjson`).
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response { status, headers: Vec::new(), body: body.into_bytes(), content_type }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    /// Serialize and send in one write (head + body).
    pub fn write(&self, stream: &mut TcpStream, keep_alive: bool) -> std::io::Result<()> {
        let mut out = Vec::with_capacity(self.body.len() + 256);
        out.extend_from_slice(
            format!("HTTP/1.1 {} {}\r\n", self.status, status_text(self.status)).as_bytes(),
        );
        out.extend_from_slice(format!("Content-Type: {}\r\n", self.content_type).as_bytes());
        out.extend_from_slice(format!("Content-Length: {}\r\n", self.body.len()).as_bytes());
        let conn = if keep_alive { "keep-alive" } else { "close" };
        out.extend_from_slice(format!("Connection: {conn}\r\n").as_bytes());
        for (k, v) in &self.headers {
            out.extend_from_slice(format!("{k}: {v}\r\n").as_bytes());
        }
        out.extend_from_slice(b"\r\n");
        out.extend_from_slice(&self.body);
        stream.write_all(&out)?;
        stream.flush()
    }
}

/// Send the head of an SSE response. SSE responses are `Connection:
/// close` — end-of-stream is the connection closing, which keeps the
/// framing self-delimiting without chunked encoding.
pub fn write_sse_head(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    stream.flush()
}

/// One `event:`/`data:` block terminated by a blank line.
pub fn write_sse_frame(stream: &mut TcpStream, event: &str, data: &str) -> std::io::Result<()> {
    stream.write_all(format!("event: {event}\ndata: {data}\n\n").as_bytes())?;
    stream.flush()
}

/// One parsed SSE frame.
#[derive(Debug, Clone, PartialEq)]
pub struct SseFrame {
    pub event: String,
    pub data: String,
}

/// A parsed client-side response.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    pub fn is_sse(&self) -> bool {
        self.header("content-type").is_some_and(|v| v.starts_with("text/event-stream"))
    }

    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body).context("response body is not UTF-8")?;
        Json::parse(text)
    }
}

/// Minimal HTTP/1.1 client over the same [`Conn`] substrate — the wire
/// path of `repro loadgen`, the daemon self-check, and the loopback
/// integration tests. One client = one connection; keep-alive reuse is
/// up to the caller issuing more requests on the same client.
pub struct HttpClient {
    conn: Conn,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(HttpClient { conn: Conn::new(stream)? })
    }

    pub fn get(&mut self, path: &str) -> Result<ClientResponse> {
        self.send(&format!("GET {path} HTTP/1.1\r\nHost: daemon\r\n\r\n"))?;
        self.read_response()
    }

    pub fn post_json(&mut self, path: &str, body: &Json) -> Result<ClientResponse> {
        self.post_raw(path, body.to_string().as_bytes())
    }

    /// POST arbitrary bytes — the malformed-body tests use this.
    pub fn post_raw(&mut self, path: &str, body: &[u8]) -> Result<ClientResponse> {
        let head = format!(
            "POST {path} HTTP/1.1\r\nHost: daemon\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        let mut req = head.into_bytes();
        req.extend_from_slice(body);
        self.conn.stream.write_all(&req).context("send request")?;
        self.conn.stream.flush().ok();
        self.read_response()
    }

    fn send(&mut self, raw: &str) -> Result<()> {
        self.conn.stream.write_all(raw.as_bytes()).context("send request")?;
        self.conn.stream.flush().ok();
        Ok(())
    }

    /// Block (up to [`CLIENT_TIMEOUT`]) until `pred` finds its marker in
    /// the buffer or EOF; returns the marker position, or None at EOF.
    fn fill_until(&mut self, pred: impl Fn(&[u8]) -> Option<usize>) -> Result<Option<usize>> {
        let t0 = Instant::now();
        loop {
            if let Some(pos) = pred(&self.conn.buf) {
                return Ok(Some(pos));
            }
            match self.conn.fill_once() {
                Ok(0) => return Ok(None),
                Ok(_) => {}
                Err(e) if is_timeout(&e) => {
                    if t0.elapsed() > CLIENT_TIMEOUT {
                        bail!("client timed out waiting for response data");
                    }
                }
                Err(e) => return Err(e).context("read response"),
            }
        }
    }

    fn read_response(&mut self) -> Result<ClientResponse> {
        let head_end = self
            .fill_until(|buf| find_subslice(buf, b"\r\n\r\n"))?
            .context("connection closed before response head")?;
        let head = std::str::from_utf8(&self.conn.buf[..head_end])
            .context("response head is not UTF-8")?
            .to_string();
        self.conn.buf.drain(..head_end + 4);
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or_default();
        let status: u16 = status_line
            .split_ascii_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("bad status line `{status_line}`"))?;
        let mut headers = BTreeMap::new();
        for line in lines {
            if let Some((k, v)) = line.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }
        let mut resp = ClientResponse { status, headers, body: Vec::new() };
        if resp.is_sse() {
            // body is the event stream: leave it buffered for next_sse_frame
            return Ok(resp);
        }
        if let Some(len) = resp.header("content-length").and_then(|v| v.parse::<usize>().ok()) {
            self.fill_until(|buf| (buf.len() >= len).then_some(len))?
                .context("connection closed mid response body")?;
            resp.body = self.conn.buf[..len].to_vec();
            self.conn.buf.drain(..len);
        }
        Ok(resp)
    }

    /// Next SSE frame off an event-stream response; `None` when the
    /// server closed the stream (end of events).
    pub fn next_sse_frame(&mut self) -> Result<Option<SseFrame>> {
        let end = match self.fill_until(|buf| find_subslice(buf, b"\n\n"))? {
            Some(end) => end,
            None => {
                ensure!(self.conn.buf.is_empty(), "connection closed mid SSE frame");
                return Ok(None);
            }
        };
        let block = std::str::from_utf8(&self.conn.buf[..end])
            .context("SSE frame is not UTF-8")?
            .to_string();
        self.conn.buf.drain(..end + 2);
        let mut frame = SseFrame { event: String::new(), data: String::new() };
        for line in block.lines() {
            if let Some(v) = line.strip_prefix("event:") {
                frame.event = v.trim().to_string();
            } else if let Some(v) = line.strip_prefix("data:") {
                frame.data = v.trim().to_string();
            }
        }
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn parses_posted_then_pipelined_requests() {
        let (mut client, server) = pair();
        let mut conn = Conn::new(server).unwrap();
        client
            .write_all(
                b"POST /v1/score HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n[1,2,3]GET /healthz HTTP/1.1\r\n\r\n",
            )
            .unwrap();
        let ReadOutcome::Request(req) = read_request(&mut conn).unwrap() else {
            panic!("expected a request");
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/score");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"[1,2,3]");
        assert!(req.keep_alive());
        // the pipelined second request is already buffered
        let ReadOutcome::Request(req2) = read_request(&mut conn).unwrap() else {
            panic!("expected the pipelined request");
        };
        assert_eq!((req2.method.as_str(), req2.path.as_str()), ("GET", "/healthz"));
        assert!(req2.body.is_empty());
    }

    #[test]
    fn idle_then_eof() {
        let (client, server) = pair();
        let mut conn = Conn::new(server).unwrap();
        assert!(matches!(read_request(&mut conn).unwrap(), ReadOutcome::Idle));
        drop(client);
        assert!(matches!(read_request(&mut conn).unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn malformed_head_is_a_400_not_a_panic() {
        let (mut client, server) = pair();
        let mut conn = Conn::new(server).unwrap();
        client.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
        let ReadOutcome::Malformed { status, .. } = read_request(&mut conn).unwrap() else {
            panic!("expected malformed");
        };
        assert_eq!(status, 400);
        // oversized declared body is refused up-front
        let (mut client2, server2) = pair();
        let mut conn2 = Conn::new(server2).unwrap();
        client2
            .write_all(b"POST /v1/score HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n")
            .unwrap();
        let ReadOutcome::Malformed { status, .. } = read_request(&mut conn2).unwrap() else {
            panic!("expected malformed");
        };
        assert_eq!(status, 413);
    }

    #[test]
    fn response_roundtrip_through_the_client() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Conn::new(stream).unwrap();
            loop {
                match read_request(&mut conn).unwrap() {
                    ReadOutcome::Request(req) => {
                        assert_eq!(req.path, "/healthz");
                        let body = Json::parse(r#"{"ok":true}"#).unwrap();
                        Response::json(429, &body)
                            .with_header("Retry-After", "1")
                            .write(conn.stream_mut(), false)
                            .unwrap();
                        return;
                    }
                    ReadOutcome::Idle => continue,
                    other => panic!("unexpected outcome: {other:?}"),
                }
            }
        });
        let mut client = HttpClient::connect(addr).unwrap();
        let resp = client.get("/healthz").unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.json().unwrap().get("ok").unwrap(), &Json::Bool(true));
        server.join().unwrap();
    }

    #[test]
    fn sse_frames_roundtrip_until_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Conn::new(stream).unwrap();
            loop {
                match read_request(&mut conn).unwrap() {
                    ReadOutcome::Request(_) => break,
                    ReadOutcome::Idle => continue,
                    other => panic!("unexpected outcome: {other:?}"),
                }
            }
            let stream = conn.stream_mut();
            write_sse_head(stream).unwrap();
            write_sse_frame(stream, "token", r#"{"index":0}"#).unwrap();
            write_sse_frame(stream, "finished", r#"{"reason":"eos"}"#).unwrap();
            // dropping the connection ends the stream
        });
        let mut client = HttpClient::connect(addr).unwrap();
        let resp = client.post_json("/v1/generate", &Json::parse("{}").unwrap()).unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.is_sse());
        let f1 = client.next_sse_frame().unwrap().unwrap();
        assert_eq!(f1, SseFrame { event: "token".into(), data: r#"{"index":0}"#.into() });
        let f2 = client.next_sse_frame().unwrap().unwrap();
        assert_eq!(f2.event, "finished");
        assert_eq!(client.next_sse_frame().unwrap(), None, "close ends the stream");
        server.join().unwrap();
    }
}
