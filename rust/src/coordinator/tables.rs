//! Table harness: regenerate every table of the paper's evaluation section
//! on the MiniLLaMA reproduction (see DESIGN.md §4 for the mapping).
//!
//! Every row is produced through the unified compression API
//! ([`crate::compress`]): methods are resolved by registry name and return
//! [`CompressedModel`] artifacts, so adding a method to the registry adds
//! it to `repro sweep` with no harness changes.
//!
//! - **Table 1** — dense vs ROM vs structured pruning (± fine-tune) at 80%
//!   and 50% global budgets, with #Params/#MACs columns.
//! - **Table 2** — calibration batch-size sweep (512/128/32 rows).
//! - **Table 3** — calibration sequence-length sweep (128/64/32).
//! - **Table 4** — calibration distribution (combination / single-task /
//!   generic corpus).
//! - **Method sweep** — any registered method list at one budget, in a
//!   single comparison table (`repro sweep --methods a,b,c`).
//! - **Serve table** — dense vs factored execution of one artifact through
//!   the serving engine, with MAC/latency/throughput columns and the
//!   logits agreement bound (`repro bench-serve`).

use anyhow::{ensure, Result};

use crate::compress::CompressedModel;
use crate::data::{CalibSource, TaskKind};
use crate::eval::{format_table, EvalReport};
use crate::model::macs::{self, CompressionAccounting};
use crate::model::ParamStore;
use crate::serve::{synth_requests, ExecMode, ServeConfig, ServeEngine, ServeModel};

use super::experiment::Experiment;

/// MAC horizon used for the cost columns (paper ≈ 64-token forward).
const MACS_TOKENS: usize = 64;

fn cost_label(exp: &Experiment, acc: &CompressionAccounting) -> String {
    let rep = macs::report(&exp.cfg, acc, MACS_TOKENS);
    format!("{:.2}M/{:.2}G", rep.n_params as f64 / 1e6, rep.macs_giga())
}

/// Evaluate one compressed artifact into a labelled table row.
fn method_row(
    exp: &Experiment,
    cm: &CompressedModel,
    label: &str,
    with_ppl: bool,
) -> Result<(String, EvalReport)> {
    let rep = exp.evaluate(&cm.params, with_ppl)?;
    Ok((format!("{label} ({})", cost_label(exp, &cm.accounting)), rep))
}

/// Table 1: the headline comparison, via the method registry.
pub fn table1(exp: &Experiment, base: &ParamStore, ft_steps: usize) -> Result<String> {
    let mut rows: Vec<(String, EvalReport)> = Vec::new();

    let dense_acc = CompressionAccounting::dense();
    let dense_rep = exp.evaluate(base, true)?;
    rows.push((format!("dense ({})", cost_label(exp, &dense_acc)), dense_rep));

    for budget in [0.8, 0.5] {
        let pct = (budget * 100.0) as u32;

        let pruned = exp.compress_method(base, "prune-activation", budget)?;
        rows.push(method_row(exp, &pruned, &format!("prune@{pct}%"), true)?);

        if ft_steps > 0 {
            let ft = exp.finetune_compressed(&pruned, ft_steps, |_, _, _| {})?;
            let rep = exp.evaluate(&ft, true)?;
            rows.push((
                format!("prune+ft@{pct}% ({})", cost_label(exp, &pruned.accounting)),
                rep,
            ));
        }

        let rom = exp.compress_method(base, "rom-feature", budget)?;
        rows.push(method_row(exp, &rom, &format!("LLM-ROM@{pct}%"), true)?);
    }
    Ok(format_table("Table 1 — ROM vs structured pruning", &rows))
}

/// Table 2: calibration batch-size (row-count) sweep at fixed seq len.
/// The paper sweeps 512/128/32 (a 16:4:1 ratio); we sweep the same ratio
/// anchored at the configured `calib_rows` so wall-clock stays bounded.
pub fn table2(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    let top = exp.xcfg.calib_rows.max(64);
    for rows_n in [top, top / 4, top / 16] {
        let calib = exp.calibration(rows_n, exp.xcfg.calib_seq, exp.xcfg.calib_source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((format!("batch {rows_n}"), rep));
    }
    Ok(format_table("Table 2 — effect of calibration batch size", &rows))
}

/// Table 3: calibration sequence-length sweep at fixed batch size.
pub fn table3(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    for seq in [128usize, 64, 32] {
        let calib = exp.calibration(exp.xcfg.calib_rows, seq, exp.xcfg.calib_source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((format!("seq {seq}"), rep));
    }
    Ok(format_table("Table 3 — effect of calibration sequence length", &rows))
}

/// Table 4: calibration distribution sweep.
pub fn table4(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    for (label, source) in [
        ("combination", CalibSource::Combination),
        ("arc-c only", CalibSource::SingleTask(TaskKind::QaHard)),
        ("corpus", CalibSource::Corpus),
    ] {
        let calib = exp.calibration(exp.xcfg.calib_rows, exp.xcfg.calib_seq, source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((label.to_string(), rep));
    }
    Ok(format_table("Table 4 — choice of calibration dataset", &rows))
}

/// Multi-method comparison at one budget: dense, then each requested
/// registry method (plus a fine-tuned row for mask-carrying methods when
/// `ft_steps > 0`), in one table — the `repro sweep` payload.
pub fn sweep_table(
    exp: &Experiment,
    base: &ParamStore,
    methods: &[String],
    budget: f64,
    ft_steps: usize,
) -> Result<String> {
    let pct = (budget * 100.0).round() as u32;
    let mut rows: Vec<(String, EvalReport)> = Vec::new();
    rows.push((
        format!("dense ({})", cost_label(exp, &CompressionAccounting::dense())),
        exp.evaluate(base, true)?,
    ));
    // one rewindable calibration stream feeds every method; artifacts
    // are evaluated and dropped one at a time (bounded peak memory)
    let mut calib =
        exp.calib_stream(exp.xcfg.calib_rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
    exp.session().sweep_with(methods, base, budget, &mut calib, |method, cm| {
        rows.push(method_row(exp, &cm, &format!("{method}@{pct}%"), true)?);
        if ft_steps > 0 && cm.masks.is_some() {
            let ft = exp.finetune_compressed(&cm, ft_steps, |_, _, _| {})?;
            let rep = exp.evaluate(&ft, true)?;
            rows.push((
                format!("{method}+ft@{pct}% ({})", cost_label(exp, &cm.accounting)),
                rep,
            ));
        }
        Ok(())
    })?;
    Ok(format_table(
        &format!("Method sweep @ {pct}% global budget"),
        &rows,
    ))
}

/// Dense vs factored serving comparison on one artifact: identical
/// synthetic workload through both execution modes of the serving engine,
/// reporting MACs/token, per-token latency, throughput, and the max
/// absolute logits disagreement — the empirical `r(d1+d2)` vs `d1·d2`
/// evidence behind `repro bench-serve`.
pub fn serve_table(
    cm: &CompressedModel,
    requests: usize,
    seq: usize,
    config: ServeConfig,
    seed: u64,
) -> Result<String> {
    let cfg = cm.params.config();
    let mut rows = Vec::new();
    let mut logits: Vec<Vec<f32>> = Vec::new();
    for mode in [ExecMode::Dense, ExecMode::Factored] {
        let model = ServeModel::from_artifact(cm, mode)?;
        let n_factored = model.n_factored();
        let engine = ServeEngine::new(model, config);
        let reqs = synth_requests(cfg, requests, seq, seed);
        let (results, stats) = engine.run(reqs)?;
        logits.push(results.into_iter().flat_map(|r| r.logits).collect());
        rows.push((mode, n_factored, stats));
    }
    ensure!(logits[0].len() == logits[1].len(), "mode outputs diverge in shape");
    let max_diff = logits[0]
        .iter()
        .zip(&logits[1])
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);

    let mut out = String::from(
        "Serve: dense vs factored execution\n\
         mode      layers(lr)   MMACs/tok   µs/tok     tok/s     p95 lat\n",
    );
    for (mode, n_factored, s) in &rows {
        out.push_str(&format!(
            "{:<9} {:>10} {:>11.3} {:>8.1} {:>9.0} {:>9.1}ms\n",
            mode.name(),
            n_factored,
            s.macs_per_token() as f64 / 1e6,
            s.s_per_token() * 1e6,
            s.tokens_per_s(),
            s.p95_latency_s * 1e3,
        ));
    }
    let (dense_s, fact_s) = (&rows[0].2, &rows[1].2);
    let mac_ratio = if fact_s.macs > 0 {
        dense_s.macs as f64 / fact_s.macs as f64
    } else {
        1.0
    };
    let speedup = if fact_s.wall_s > 0.0 { dense_s.wall_s / fact_s.wall_s } else { 1.0 };
    out.push_str(&format!(
        "MAC reduction {mac_ratio:.2}x, wall-clock speedup {speedup:.2}x, \
         max |Δlogits| {max_diff:.2e}\n"
    ));
    Ok(out)
}

/// CLI entry: run the requested table(s) and print.
///
/// `budget` applies to the ablation tables 2-4 (the paper runs them at its
/// 80% operating point; at budgets where ROM is near-lossless on a given
/// substrate, the calibration knobs only bind at tighter budgets).
pub fn run_tables(
    exp: &Experiment,
    base: &ParamStore,
    which: &str,
    ft_steps: usize,
    budget: f64,
) -> Result<()> {
    match which {
        "1" => println!("{}", table1(exp, base, ft_steps)?),
        "2" => println!("{}", table2(exp, base, budget)?),
        "3" => println!("{}", table3(exp, base, budget)?),
        "4" => println!("{}", table4(exp, base, budget)?),
        "all" => {
            println!("{}", table1(exp, base, ft_steps)?);
            println!("{}", table2(exp, base, budget)?);
            println!("{}", table3(exp, base, budget)?);
            println!("{}", table4(exp, base, budget)?);
        }
        other => anyhow::bail!("unknown table `{other}` (1|2|3|4|all)"),
    }
    Ok(())
}
