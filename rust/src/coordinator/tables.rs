//! Table harness: regenerate every table of the paper's evaluation section
//! on the MiniLLaMA reproduction (see DESIGN.md §4 for the mapping).
//!
//! Every row is produced through the unified compression API
//! ([`crate::compress`]): methods are resolved by registry name and return
//! [`CompressedModel`] artifacts, so adding a method to the registry adds
//! it to `repro sweep` with no harness changes.
//!
//! - **Table 1** — dense vs ROM vs structured pruning (± fine-tune) at 80%
//!   and 50% global budgets, with #Params/#MACs columns.
//! - **Table 2** — calibration batch-size sweep (512/128/32 rows).
//! - **Table 3** — calibration sequence-length sweep (128/64/32).
//! - **Table 4** — calibration distribution (combination / single-task /
//!   generic corpus).
//! - **Method sweep** — any registered method list at one budget, in a
//!   single comparison table (`repro sweep --methods a,b,c`).

use anyhow::Result;

use crate::compress::CompressedModel;
use crate::data::{CalibSource, TaskKind};
use crate::eval::{format_table, EvalReport};
use crate::model::macs::{self, CompressionAccounting};
use crate::model::ParamStore;

use super::experiment::Experiment;

/// MAC horizon used for the cost columns (paper ≈ 64-token forward).
const MACS_TOKENS: usize = 64;

fn cost_label(exp: &Experiment, acc: &CompressionAccounting) -> String {
    let rep = macs::report(&exp.cfg, acc, MACS_TOKENS);
    format!("{:.2}M/{:.2}G", rep.n_params as f64 / 1e6, rep.macs_giga())
}

/// Evaluate one compressed artifact into a labelled table row.
fn method_row(
    exp: &Experiment,
    cm: &CompressedModel,
    label: &str,
    with_ppl: bool,
) -> Result<(String, EvalReport)> {
    let rep = exp.evaluate(&cm.params, with_ppl)?;
    Ok((format!("{label} ({})", cost_label(exp, &cm.accounting)), rep))
}

/// Table 1: the headline comparison, via the method registry.
pub fn table1(exp: &Experiment, base: &ParamStore, ft_steps: usize) -> Result<String> {
    let mut rows: Vec<(String, EvalReport)> = Vec::new();

    let dense_acc = CompressionAccounting::dense();
    let dense_rep = exp.evaluate(base, true)?;
    rows.push((format!("dense ({})", cost_label(exp, &dense_acc)), dense_rep));

    for budget in [0.8, 0.5] {
        let pct = (budget * 100.0) as u32;

        let pruned = exp.compress_method(base, "prune-activation", budget)?;
        rows.push(method_row(exp, &pruned, &format!("prune@{pct}%"), true)?);

        if ft_steps > 0 {
            let ft = exp.finetune_compressed(&pruned, ft_steps, |_, _, _| {})?;
            let rep = exp.evaluate(&ft, true)?;
            rows.push((
                format!("prune+ft@{pct}% ({})", cost_label(exp, &pruned.accounting)),
                rep,
            ));
        }

        let rom = exp.compress_method(base, "rom-feature", budget)?;
        rows.push(method_row(exp, &rom, &format!("LLM-ROM@{pct}%"), true)?);
    }
    Ok(format_table("Table 1 — ROM vs structured pruning", &rows))
}

/// Table 2: calibration batch-size (row-count) sweep at fixed seq len.
/// The paper sweeps 512/128/32 (a 16:4:1 ratio); we sweep the same ratio
/// anchored at the configured `calib_rows` so wall-clock stays bounded.
pub fn table2(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    let top = exp.xcfg.calib_rows.max(64);
    for rows_n in [top, top / 4, top / 16] {
        let calib = exp.calibration(rows_n, exp.xcfg.calib_seq, exp.xcfg.calib_source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((format!("batch {rows_n}"), rep));
    }
    Ok(format_table("Table 2 — effect of calibration batch size", &rows))
}

/// Table 3: calibration sequence-length sweep at fixed batch size.
pub fn table3(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    for seq in [128usize, 64, 32] {
        let calib = exp.calibration(exp.xcfg.calib_rows, seq, exp.xcfg.calib_source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((format!("seq {seq}"), rep));
    }
    Ok(format_table("Table 3 — effect of calibration sequence length", &rows))
}

/// Table 4: calibration distribution sweep.
pub fn table4(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    for (label, source) in [
        ("combination", CalibSource::Combination),
        ("arc-c only", CalibSource::SingleTask(TaskKind::QaHard)),
        ("corpus", CalibSource::Corpus),
    ] {
        let calib = exp.calibration(exp.xcfg.calib_rows, exp.xcfg.calib_seq, source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((label.to_string(), rep));
    }
    Ok(format_table("Table 4 — choice of calibration dataset", &rows))
}

/// Multi-method comparison at one budget: dense, then each requested
/// registry method (plus a fine-tuned row for mask-carrying methods when
/// `ft_steps > 0`), in one table — the `repro sweep` payload.
pub fn sweep_table(
    exp: &Experiment,
    base: &ParamStore,
    methods: &[String],
    budget: f64,
    ft_steps: usize,
) -> Result<String> {
    let pct = (budget * 100.0).round() as u32;
    let mut rows: Vec<(String, EvalReport)> = Vec::new();
    rows.push((
        format!("dense ({})", cost_label(exp, &CompressionAccounting::dense())),
        exp.evaluate(base, true)?,
    ));
    // one rewindable calibration stream feeds every method; artifacts
    // are evaluated and dropped one at a time (bounded peak memory)
    let mut calib =
        exp.calib_stream(exp.xcfg.calib_rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
    exp.session().sweep_with(methods, base, budget, &mut calib, |method, cm| {
        rows.push(method_row(exp, &cm, &format!("{method}@{pct}%"), true)?);
        if ft_steps > 0 && cm.masks.is_some() {
            let ft = exp.finetune_compressed(&cm, ft_steps, |_, _, _| {})?;
            let rep = exp.evaluate(&ft, true)?;
            rows.push((
                format!("{method}+ft@{pct}% ({})", cost_label(exp, &cm.accounting)),
                rep,
            ));
        }
        Ok(())
    })?;
    Ok(format_table(
        &format!("Method sweep @ {pct}% global budget"),
        &rows,
    ))
}

/// CLI entry: run the requested table(s) and print.
///
/// `budget` applies to the ablation tables 2-4 (the paper runs them at its
/// 80% operating point; at budgets where ROM is near-lossless on a given
/// substrate, the calibration knobs only bind at tighter budgets).
pub fn run_tables(
    exp: &Experiment,
    base: &ParamStore,
    which: &str,
    ft_steps: usize,
    budget: f64,
) -> Result<()> {
    match which {
        "1" => println!("{}", table1(exp, base, ft_steps)?),
        "2" => println!("{}", table2(exp, base, budget)?),
        "3" => println!("{}", table3(exp, base, budget)?),
        "4" => println!("{}", table4(exp, base, budget)?),
        "all" => {
            println!("{}", table1(exp, base, ft_steps)?);
            println!("{}", table2(exp, base, budget)?);
            println!("{}", table3(exp, base, budget)?);
            println!("{}", table4(exp, base, budget)?);
        }
        other => anyhow::bail!("unknown table `{other}` (1|2|3|4|all)"),
    }
    Ok(())
}
