//! Table harness: regenerate every table of the paper's evaluation section
//! on the MiniLLaMA reproduction (see DESIGN.md §4 for the mapping).
//!
//! Every row is produced through the unified compression API
//! ([`crate::compress`]): methods are resolved by registry name and return
//! [`CompressedModel`] artifacts, so adding a method to the registry adds
//! it to `repro sweep` with no harness changes.
//!
//! - **Table 1** — dense vs ROM vs structured pruning (± fine-tune) at 80%
//!   and 50% global budgets, with #Params/#MACs columns.
//! - **Table 2** — calibration batch-size sweep (512/128/32 rows).
//! - **Table 3** — calibration sequence-length sweep (128/64/32).
//! - **Table 4** — calibration distribution (combination / single-task /
//!   generic corpus).
//! - **Method sweep** — any registered method list at one budget, in a
//!   single comparison table (`repro sweep --methods a,b,c`).
//! - **Serve table** — dense vs factored execution of one artifact through
//!   the serving engine, with MAC/latency/throughput columns and the
//!   logits agreement bound (`repro bench-serve`).
//! - **Decode table** — recompute vs KV-cached generation, dense vs
//!   factored, with MACs/token, tokens/sec, TTFT and inter-token latency
//!   columns, plus a speculative row pairing the factored verifier with a
//!   same-checkpoint lower-budget draft (acceptance rate, exact draft /
//!   verify MAC split, throughput vs verifier-only decode)
//!   (`repro bench-decode`). Both benches also serialize to JSON via
//!   `--json` ([`ServeBench::to_json`] / [`DecodeBench::to_json`]).
//! - **Kernels bench** — the serving hot path's matmul variants (scalar /
//!   SIMD / packed / int8-quantized) on one microbenchmark shape, plus an
//!   end-to-end factored vs factored-quant serve of the same artifact
//!   (`repro bench-kernels`, [`KernelsBench::to_json`]).
//! - **Daemon bench** — self-hosted HTTP/SSE daemon driven open-loop by
//!   the wire-path load generator over loopback, reporting achieved RPS
//!   and TTFT / inter-token percentiles from both sides of the wire
//!   (`repro bench-daemon`, [`DaemonBench::to_json`]).
//! - **Obs table** — the flight-recorder transcript of an adversarial
//!   tiered trace tabulated against the engine's analytic accounting,
//!   asserting exact agreement along the way (`repro tables --table obs`).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::compress::{CompressedModel, CompressionSession, EmptyStream};
use crate::daemon::{DaemonReport, LoadReport};
use crate::data::{CalibSource, TaskKind};
use crate::decode::{
    run_recompute, synth_gen_requests, DecodeConfig, DecodeScheduler, DecodeStats, SpecDecoder,
};
use crate::eval::{format_table, EvalReport};
use crate::exec::ExecConfig;
use crate::model::macs::{self, CompressionAccounting};
use crate::model::ParamStore;
use crate::serve::{synth_requests, ExecMode, ServeConfig, ServeEngine, ServeModel, ServeStats};
use crate::util::json::Json;

use super::experiment::Experiment;

/// MAC horizon used for the cost columns (paper ≈ 64-token forward).
const MACS_TOKENS: usize = 64;

fn cost_label(exp: &Experiment, acc: &CompressionAccounting) -> String {
    let rep = macs::report(&exp.cfg, acc, MACS_TOKENS);
    format!("{:.2}M/{:.2}G", rep.n_params as f64 / 1e6, rep.macs_giga())
}

/// Evaluate one compressed artifact into a labelled table row.
fn method_row(
    exp: &Experiment,
    cm: &CompressedModel,
    label: &str,
    with_ppl: bool,
) -> Result<(String, EvalReport)> {
    let rep = exp.evaluate(&cm.params, with_ppl)?;
    Ok((format!("{label} ({})", cost_label(exp, &cm.accounting)), rep))
}

/// Table 1: the headline comparison, via the method registry.
pub fn table1(exp: &Experiment, base: &ParamStore, ft_steps: usize) -> Result<String> {
    let mut rows: Vec<(String, EvalReport)> = Vec::new();

    let dense_acc = CompressionAccounting::dense();
    let dense_rep = exp.evaluate(base, true)?;
    rows.push((format!("dense ({})", cost_label(exp, &dense_acc)), dense_rep));

    for budget in [0.8, 0.5] {
        let pct = (budget * 100.0) as u32;

        let pruned = exp.compress_method(base, "prune-activation", budget)?;
        rows.push(method_row(exp, &pruned, &format!("prune@{pct}%"), true)?);

        if ft_steps > 0 {
            let ft = exp.finetune_compressed(&pruned, ft_steps, |_, _, _| {})?;
            let rep = exp.evaluate(&ft, true)?;
            rows.push((
                format!("prune+ft@{pct}% ({})", cost_label(exp, &pruned.accounting)),
                rep,
            ));
        }

        let rom = exp.compress_method(base, "rom-feature", budget)?;
        rows.push(method_row(exp, &rom, &format!("LLM-ROM@{pct}%"), true)?);
    }
    Ok(format_table("Table 1 — ROM vs structured pruning", &rows))
}

/// Table 2: calibration batch-size (row-count) sweep at fixed seq len.
/// The paper sweeps 512/128/32 (a 16:4:1 ratio); we sweep the same ratio
/// anchored at the configured `calib_rows` so wall-clock stays bounded.
pub fn table2(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    let top = exp.xcfg.calib_rows.max(64);
    for rows_n in [top, top / 4, top / 16] {
        let calib = exp.calibration(rows_n, exp.xcfg.calib_seq, exp.xcfg.calib_source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((format!("batch {rows_n}"), rep));
    }
    Ok(format_table("Table 2 — effect of calibration batch size", &rows))
}

/// Table 3: calibration sequence-length sweep at fixed batch size.
pub fn table3(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    for seq in [128usize, 64, 32] {
        let calib = exp.calibration(exp.xcfg.calib_rows, seq, exp.xcfg.calib_source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((format!("seq {seq}"), rep));
    }
    Ok(format_table("Table 3 — effect of calibration sequence length", &rows))
}

/// Table 4: calibration distribution sweep.
pub fn table4(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    let mut rows = Vec::new();
    for (label, source) in [
        ("combination", CalibSource::Combination),
        ("arc-c only", CalibSource::SingleTask(TaskKind::QaHard)),
        ("corpus", CalibSource::Corpus),
    ] {
        let calib = exp.calibration(exp.xcfg.calib_rows, exp.xcfg.calib_seq, source);
        let sched = crate::rom::paper_preset(&exp.cfg, budget);
        let rom = exp.compress_scheduled(base, "rom-feature", sched, Some(&calib))?;
        let rep = exp.evaluate(&rom.params, false)?;
        rows.push((label.to_string(), rep));
    }
    Ok(format_table("Table 4 — choice of calibration dataset", &rows))
}

/// Multi-method comparison at one budget: dense, then each requested
/// registry method (plus a fine-tuned row for mask-carrying methods when
/// `ft_steps > 0`), in one table — the `repro sweep` payload.
pub fn sweep_table(
    exp: &Experiment,
    base: &ParamStore,
    methods: &[String],
    budget: f64,
    ft_steps: usize,
) -> Result<String> {
    sweep_table_with(exp, base, methods, budget, ft_steps, |_, _| Ok(()))
}

/// [`sweep_table`] that also hands every finished artifact to `visit`
/// before it is dropped — the hook `repro sweep --budgets` uses to save
/// the rank ladder and write its `ladder.json` manifest without running
/// compression twice.
pub fn sweep_table_with(
    exp: &Experiment,
    base: &ParamStore,
    methods: &[String],
    budget: f64,
    ft_steps: usize,
    mut visit: impl FnMut(&str, &CompressedModel) -> Result<()>,
) -> Result<String> {
    let pct = (budget * 100.0).round() as u32;
    let mut rows: Vec<(String, EvalReport)> = Vec::new();
    rows.push((
        format!("dense ({})", cost_label(exp, &CompressionAccounting::dense())),
        exp.evaluate(base, true)?,
    ));
    // one rewindable calibration stream feeds every method; artifacts
    // are evaluated and dropped one at a time (bounded peak memory)
    let mut calib =
        exp.calib_stream(exp.xcfg.calib_rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
    exp.session().sweep_with(methods, base, budget, &mut calib, |method, cm| {
        rows.push(method_row(exp, &cm, &format!("{method}@{pct}%"), true)?);
        if ft_steps > 0 && cm.masks.is_some() {
            let ft = exp.finetune_compressed(&cm, ft_steps, |_, _, _| {})?;
            let rep = exp.evaluate(&ft, true)?;
            rows.push((
                format!("{method}+ft@{pct}% ({})", cost_label(exp, &cm.accounting)),
                rep,
            ));
        }
        visit(method, &cm)
    })?;
    Ok(format_table(
        &format!("Method sweep @ {pct}% global budget"),
        &rows,
    ))
}

/// One mode's row of the serve benchmark.
pub struct ServeBenchRow {
    pub mode: ExecMode,
    /// Matrices executing in factored form under this mode's dispatch.
    pub n_factored: usize,
    pub stats: ServeStats,
}

/// Dense vs factored serving comparison on one artifact: identical
/// synthetic workload through both execution modes of the serving engine —
/// the empirical `r(d1+d2)` vs `d1·d2` evidence behind
/// `repro bench-serve`, renderable as a table ([`ServeBench::format`]) or
/// machine-readable JSON ([`ServeBench::to_json`], `--json`).
pub struct ServeBench {
    pub rows: Vec<ServeBenchRow>,
    /// Max absolute logits disagreement between the two modes.
    pub max_logit_diff: f64,
    pub requests: usize,
    pub seq: usize,
    pub workers: usize,
    pub max_batch: usize,
    /// Resolved worker-pool budget the run executed under (`--threads`).
    pub threads: usize,
    pub seed: u64,
}

impl ServeBench {
    /// Dense-to-factored total MAC ratio.
    pub fn mac_reduction(&self) -> f64 {
        let (d, f) = (&self.rows[0].stats.core, &self.rows[1].stats.core);
        if f.macs > 0 {
            d.macs as f64 / f.macs as f64
        } else {
            1.0
        }
    }

    /// Dense-to-factored wall-clock ratio.
    pub fn speedup(&self) -> f64 {
        let (d, f) = (&self.rows[0].stats.core, &self.rows[1].stats.core);
        if f.wall_s > 0.0 {
            d.wall_s / f.wall_s
        } else {
            1.0
        }
    }

    pub fn format(&self) -> String {
        let mut out = String::from(
            "Serve: dense vs factored execution\n\
             mode      layers(lr)   MMACs/tok   µs/tok     tok/s     p95 lat   threads\n",
        );
        for row in &self.rows {
            let s = &row.stats;
            out.push_str(&format!(
                "{:<9} {:>10} {:>11.3} {:>8.1} {:>9.0} {:>9.1}ms {:>9}\n",
                row.mode.name(),
                row.n_factored,
                s.macs_per_token() as f64 / 1e6,
                s.s_per_token() * 1e6,
                s.tokens_per_s(),
                s.core.latency.p95 * 1e3,
                self.threads,
            ));
        }
        out.push_str(&format!(
            "MAC reduction {:.2}x, wall-clock speedup {:.2}x, max |Δlogits| {:.2e}\n",
            self.mac_reduction(),
            self.speedup(),
            self.max_logit_diff
        ));
        out
    }

    /// Machine-readable form (the `BENCH_serve.json` payload).
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|row| {
                let s = &row.stats;
                json_obj(vec![
                    ("mode", Json::Str(row.mode.name().to_string())),
                    ("factored_layers", Json::Num(row.n_factored as f64)),
                    ("requests", Json::Num(s.core.requests as f64)),
                    ("tokens", Json::Num(s.core.tokens as f64)),
                    ("macs_per_token", Json::Num(s.macs_per_token() as f64)),
                    ("tokens_per_s", Json::Num(s.tokens_per_s())),
                    ("us_per_token", Json::Num(s.s_per_token() * 1e6)),
                    ("wall_s", Json::Num(s.core.wall_s)),
                    ("mean_latency_s", Json::Num(s.core.latency.mean)),
                    ("p50_latency_s", Json::Num(s.core.latency.p50)),
                    ("p95_latency_s", Json::Num(s.core.latency.p95)),
                    ("max_latency_s", Json::Num(s.core.latency.max)),
                ])
            })
            .collect();
        json_obj(vec![
            ("bench", Json::Str("serve".to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("seq", Json::Num(self.seq as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("batch", Json::Num(self.max_batch as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("mac_reduction", Json::Num(self.mac_reduction())),
            ("speedup", Json::Num(self.speedup())),
            ("max_abs_logit_diff", Json::Num(self.max_logit_diff)),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Run the dense-vs-factored serve comparison on one artifact.
pub fn serve_bench(
    cm: &CompressedModel,
    requests: usize,
    seq: usize,
    config: ServeConfig,
    seed: u64,
) -> Result<ServeBench> {
    let cfg = cm.params.config();
    let mut rows = Vec::new();
    let mut logits: Vec<Vec<f32>> = Vec::new();
    for mode in [ExecMode::Dense, ExecMode::Factored] {
        let model = ServeModel::from_artifact(cm, mode)?;
        let n_factored = model.n_factored();
        let engine = ServeEngine::new(model, config);
        let reqs = synth_requests(cfg, requests, seq, seed);
        let (results, stats) = engine.run(reqs)?;
        logits.push(results.into_iter().flat_map(|r| r.logits).collect());
        rows.push(ServeBenchRow { mode, n_factored, stats });
    }
    ensure!(logits[0].len() == logits[1].len(), "mode outputs diverge in shape");
    let max_logit_diff = logits[0]
        .iter()
        .zip(&logits[1])
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    Ok(ServeBench {
        rows,
        max_logit_diff,
        requests,
        seq,
        workers: config.workers,
        max_batch: config.max_batch,
        threads: config.exec.resolve(),
        seed,
    })
}

/// Back-compat text form of [`serve_bench`].
pub fn serve_table(
    cm: &CompressedModel,
    requests: usize,
    seq: usize,
    config: ServeConfig,
    seed: u64,
) -> Result<String> {
    Ok(serve_bench(cm, requests, seq, config, seed)?.format())
}

/// One kernel's row of the microbenchmark: `reps` repetitions of an
/// `m×k×n` `A·Bᵀ` matmul through one code path.
pub struct KernelsBenchRow {
    pub kernel: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub reps: usize,
    pub wall_s: f64,
}

impl KernelsBenchRow {
    pub fn gflops(&self) -> f64 {
        if self.wall_s > 0.0 {
            2.0 * (self.m * self.k * self.n * self.reps) as f64 / self.wall_s / 1e9
        } else {
            0.0
        }
    }
}

/// One execution mode's end-to-end row (the factored vs factored-quant
/// tokens/sec comparison behind the kernel rows).
pub struct KernelsModeRow {
    pub mode: ExecMode,
    pub stats: ServeStats,
}

/// `repro bench-kernels`: the serving hot path's matmul variants head to
/// head — naive scalar, the SIMD-dotted blocked kernel, the packed-panel
/// kernel, and the int8-quantized kernel — on one shared `m×k×n`
/// microbenchmark, plus an end-to-end factored vs factored-quant serve of
/// the same artifact. Renders as a table ([`KernelsBench::format`]) or as
/// the `BENCH_kernels.json` payload ([`KernelsBench::to_json`], `--json`;
/// `scripts/verify.sh` gates the `gflops` and `tokens_per_s` samples
/// against the committed numbers).
pub struct KernelsBench {
    pub rows: Vec<KernelsBenchRow>,
    pub modes: Vec<KernelsModeRow>,
    /// Max absolute logits disagreement, factored vs factored-quant.
    pub max_quant_diff: f64,
    pub threads: usize,
    pub seed: u64,
}

impl KernelsBench {
    pub fn format(&self) -> String {
        let mut out = String::from(
            "Kernels: scalar vs SIMD vs packed vs quantized\n\
             kernel        m     k     n   reps    wall_s   GFLOP/s\n",
        );
        for row in &self.rows {
            out.push_str(&format!(
                "{:<11} {:>3} {:>5} {:>5} {:>6} {:>9.4} {:>9.2}\n",
                row.kernel, row.m, row.k, row.n, row.reps, row.wall_s,
                row.gflops()
            ));
        }
        out.push_str("mode            MMACs/tok   µs/tok     tok/s\n");
        for row in &self.modes {
            let s = &row.stats;
            out.push_str(&format!(
                "{:<15} {:>9.3} {:>8.1} {:>9.0}\n",
                row.mode.name(),
                s.macs_per_token() as f64 / 1e6,
                s.s_per_token() * 1e6,
                s.tokens_per_s(),
            ));
        }
        out.push_str(&format!(
            "max |Δlogits| factored vs factored-quant: {:.2e} ({} threads)\n",
            self.max_quant_diff, self.threads
        ));
        out
    }

    /// Machine-readable form (the `BENCH_kernels.json` payload).
    pub fn to_json(&self) -> Json {
        let kernels = self
            .rows
            .iter()
            .map(|row| {
                json_obj(vec![
                    ("kernel", Json::Str(row.kernel.to_string())),
                    ("m", Json::Num(row.m as f64)),
                    ("k", Json::Num(row.k as f64)),
                    ("n", Json::Num(row.n as f64)),
                    ("reps", Json::Num(row.reps as f64)),
                    ("wall_s", Json::Num(row.wall_s)),
                    ("gflops", Json::Num(row.gflops())),
                ])
            })
            .collect();
        let modes = self
            .modes
            .iter()
            .map(|row| {
                let s = &row.stats;
                json_obj(vec![
                    ("mode", Json::Str(row.mode.name().to_string())),
                    ("macs_per_token", Json::Num(s.macs_per_token() as f64)),
                    ("tokens_per_s", Json::Num(s.tokens_per_s())),
                    ("us_per_token", Json::Num(s.s_per_token() * 1e6)),
                ])
            })
            .collect();
        json_obj(vec![
            ("bench", Json::Str("kernels".to_string())),
            ("threads", Json::Num(self.threads as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("max_abs_quant_logit_diff", Json::Num(self.max_quant_diff)),
            ("kernels", Json::Arr(kernels)),
            ("modes", Json::Arr(modes)),
        ])
    }
}

/// Run the kernel microbenchmark + end-to-end mode comparison on one
/// artifact. The microbenchmark shape is fixed (not taken from the
/// artifact) so committed `BENCH_kernels.json` numbers stay comparable
/// across model configs; the mode rows serve the artifact itself.
pub fn kernels_bench(cm: &CompressedModel, exec: ExecConfig, seed: u64) -> Result<KernelsBench> {
    use crate::linalg::simd::{
        matmul_transb_packed_into, matmul_transb_quant_into, PackedWeight, QuantizedWeight,
    };
    use crate::linalg::{matmul_transb_blocked_into, matmul_transb_f32};
    use crate::util::Rng;

    const M: usize = 64;
    const K: usize = 256;
    const N: usize = 256;
    const REPS: usize = 40;

    fn time_reps(reps: usize, mut f: impl FnMut()) -> f64 {
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            f();
        }
        t0.elapsed().as_secs_f64().max(1e-9)
    }

    let mut rng = Rng::new(seed ^ 0x4E75);
    let a: Vec<f32> = (0..M * K).map(|_| rng.normal() as f32 * 0.1).collect();
    let b: Vec<f32> = (0..N * K).map(|_| rng.normal() as f32 * 0.1).collect();
    let packed = PackedWeight::pack(&b, N, K);
    let quant = QuantizedWeight::quantize(&b, N, K);
    let mut out = vec![0.0f32; M * N];

    // `sink` keeps every timed result observable so the optimizer cannot
    // discard the kernel calls.
    let mut sink = 0.0f32;
    let mut rows = Vec::new();
    let wall = time_reps(REPS, || {
        let o = matmul_transb_f32(&a, &b, M, K, N);
        sink += o[0];
    });
    rows.push(KernelsBenchRow { kernel: "scalar", m: M, k: K, n: N, reps: REPS, wall_s: wall });
    let wall = time_reps(REPS, || {
        matmul_transb_blocked_into(&a, &b, M, K, N, &mut out);
        sink += out[0];
    });
    rows.push(KernelsBenchRow { kernel: "simd", m: M, k: K, n: N, reps: REPS, wall_s: wall });
    let wall = time_reps(REPS, || {
        matmul_transb_packed_into(&a, &packed, M, &mut out);
        sink += out[0];
    });
    rows.push(KernelsBenchRow { kernel: "packed", m: M, k: K, n: N, reps: REPS, wall_s: wall });
    let wall = time_reps(REPS, || {
        matmul_transb_quant_into(&a, &quant, M, &mut out);
        sink += out[0];
    });
    rows.push(KernelsBenchRow { kernel: "quantized", m: M, k: K, n: N, reps: REPS, wall_s: wall });
    ensure!(sink.is_finite(), "kernel microbenchmark produced non-finite output");

    let cfg = cm.params.config();
    let config = ServeConfig { workers: 2, max_batch: 4, exec };
    let mut modes = Vec::new();
    let mut logits: Vec<Vec<f32>> = Vec::new();
    for mode in [ExecMode::Factored, ExecMode::FactoredQuant] {
        let model = ServeModel::from_artifact(cm, mode)?;
        let engine = ServeEngine::new(model, config);
        let (results, stats) = engine.run(synth_requests(cfg, 8, 32, seed))?;
        logits.push(results.into_iter().flat_map(|r| r.logits).collect());
        modes.push(KernelsModeRow { mode, stats });
    }
    ensure!(logits[0].len() == logits[1].len(), "mode outputs diverge in shape");
    let max_quant_diff = logits[0]
        .iter()
        .zip(&logits[1])
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    Ok(KernelsBench { rows, modes, max_quant_diff, threads: exec.resolve(), seed })
}

/// One method's row of the decode benchmark.
pub struct DecodeBenchRow {
    /// `dense-recompute`, `dense-kv`, or `factored-kv`.
    pub method: &'static str,
    pub stats: DecodeStats,
}

/// Speculative row of the decode benchmark: the factored verifier paired
/// with a lower-budget draft of the *same* checkpoint, driven over the
/// identical greedy workload. The draft is produced by re-compressing the
/// benched artifact's own (dense-schema) parameters with `rom-weight-svd`
/// at [`SPEC_DRAFT_BUDGET`] scaled by the verifier's own budget, so the
/// pair passes `check_spec_draft` by construction and no second artifact
/// file is needed.
pub struct SpecDecodeBench {
    /// Draft tokens proposed per speculative round.
    pub spec_k: usize,
    /// Budget the draft was re-compressed at.
    pub draft_budget: f64,
    /// Engine stats of the speculative scheduler run (executed MACs in
    /// `stats.core.macs` include draft + verify + rollback waste).
    pub stats: DecodeStats,
    /// Run-wide drafted / accepted totals (engine counters).
    pub drafted: usize,
    pub accepted: usize,
    /// Exact analytic MAC split of the speculative machinery, summed over
    /// the per-request round traces via [`macs::spec_report`].
    pub draft_prefill_macs: u128,
    pub draft_macs: u128,
    pub verify_macs: u128,
    /// Subset of `verify_macs` spent past each round's accepted prefix and
    /// rolled back.
    pub wasted_macs: u128,
    /// Speculative vs verifier-only factored-KV throughput.
    pub speedup_vs_verifier: f64,
    /// Speculative greedy streams bitwise identical to the verifier-only
    /// factored-KV streams — the correctness contract of the whole path.
    pub streams_match: bool,
}

impl SpecDecodeBench {
    /// Fraction of drafted tokens the verifier confirmed.
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Total MACs the speculative machinery executed beyond the
    /// verifier's prompt prefill.
    pub fn spec_macs(&self) -> u128 {
        self.draft_prefill_macs + self.draft_macs + self.verify_macs
    }
}

/// Recompute-vs-KV-cached, dense-vs-factored decode comparison on one
/// artifact: the same synthetic generation workload driven three ways —
/// the `repro bench-decode` payload, renderable as a table or JSON.
pub struct DecodeBench {
    pub rows: Vec<DecodeBenchRow>,
    /// Speculative companion row (verifier + same-checkpoint draft).
    pub spec: SpecDecodeBench,
    /// Whether KV-cached decode produced token streams identical to the
    /// cache-less recompute baseline on the same (dense) model — the cache
    /// correctness invariant. (Dense and factored streams may legitimately
    /// diverge on near-tie argmaxes, since their logits differ within the
    /// 1e-4 bound.)
    pub streams_match: bool,
    pub requests: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub slots: usize,
    /// Resolved worker-pool budget the run executed under (`--threads`).
    pub threads: usize,
    pub seed: u64,
}

impl DecodeBench {
    /// dense-recompute vs factored-KV MACs per generated token — the
    /// headline `r(d1+d2)` × KV-cache saving.
    pub fn mac_reduction(&self) -> f64 {
        let base = self.rows[0].stats.macs_per_generated_token();
        let fact = self.rows[2].stats.macs_per_generated_token();
        if fact > 0 {
            base as f64 / fact as f64
        } else {
            1.0
        }
    }

    pub fn format(&self) -> String {
        let mut out = String::from(
            "Decode: recompute vs KV-cached, dense vs factored\n\
             method            MMACs/tok   tok/s   ttft p50    itl p95   vs recompute   threads\n",
        );
        for row in &self.rows {
            let s = &row.stats;
            out.push_str(&format!(
                "{:<17} {:>9.3} {:>7.0} {:>8.2}ms {:>8.2}ms {:>11.2}x {:>9}\n",
                row.method,
                s.macs_per_generated_token() as f64 / 1e6,
                s.tokens_per_s(),
                s.ttft.p50 * 1e3,
                s.inter_token.p95 * 1e3,
                s.mac_savings(),
                self.threads,
            ));
        }
        out.push_str(&format!(
            "factored-KV executes {:.2}x fewer MACs/token than dense-recompute; \
             KV streams ≡ recompute streams: {}\n",
            self.mac_reduction(),
            self.streams_match
        ));
        let sp = &self.spec;
        let total = sp.spec_macs().max(1) as f64;
        out.push_str(&format!(
            "speculative (k={}, draft rom-weight-svd@{:.0}%): {:.0} tok/s \
             ({:.2}x vs factored-kv), acceptance {}/{} ({:.0}%), MAC split \
             draft {:.0}% / verify {:.0}% (rollback waste {:.0}% of verify); \
             spec streams ≡ verifier streams: {}\n",
            sp.spec_k,
            sp.draft_budget * 100.0,
            sp.stats.tokens_per_s(),
            sp.speedup_vs_verifier,
            sp.accepted,
            sp.drafted,
            sp.accept_rate() * 100.0,
            (sp.draft_prefill_macs + sp.draft_macs) as f64 / total * 100.0,
            sp.verify_macs as f64 / total * 100.0,
            sp.wasted_macs as f64 / sp.verify_macs.max(1) as f64 * 100.0,
            sp.streams_match,
        ));
        out
    }

    /// Machine-readable form (the `BENCH_decode.json` payload).
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|row| {
                let s = &row.stats;
                json_obj(vec![
                    ("method", Json::Str(row.method.to_string())),
                    ("requests", Json::Num(s.core.requests as f64)),
                    ("prompt_tokens", Json::Num(s.prompt_tokens as f64)),
                    ("generated_tokens", Json::Num(s.generated_tokens() as f64)),
                    ("macs_per_token", Json::Num(s.macs_per_generated_token() as f64)),
                    ("mac_savings_vs_recompute", Json::Num(s.mac_savings())),
                    ("tokens_per_s", Json::Num(s.tokens_per_s())),
                    ("wall_s", Json::Num(s.core.wall_s)),
                    ("ttft_mean_s", Json::Num(s.ttft.mean)),
                    ("ttft_p50_s", Json::Num(s.ttft.p50)),
                    ("ttft_p95_s", Json::Num(s.ttft.p95)),
                    ("itl_mean_s", Json::Num(s.inter_token.mean)),
                    ("itl_p50_s", Json::Num(s.inter_token.p50)),
                    ("itl_p95_s", Json::Num(s.inter_token.p95)),
                    ("peak_active", Json::Num(s.peak_active as f64)),
                    ("mid_run_admissions", Json::Num(s.mid_run_admissions as f64)),
                ])
            })
            .collect();
        json_obj(vec![
            ("bench", Json::Str("decode".to_string())),
            // TTFT/inter-token percentiles in `rows` are derived from the
            // engine core's per-token event timestamps (the event timeline)
            ("latency_source", Json::Str("event-timeline".to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("prompt_len", Json::Num(self.prompt_len as f64)),
            ("max_new", Json::Num(self.max_new as f64)),
            ("slots", Json::Num(self.slots as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("mac_reduction", Json::Num(self.mac_reduction())),
            ("streams_match", Json::Bool(self.streams_match)),
            ("rows", Json::Arr(rows)),
            ("speculative", {
                let sp = &self.spec;
                json_obj(vec![
                    ("spec_k", Json::Num(sp.spec_k as f64)),
                    ("draft_budget", Json::Num(sp.draft_budget)),
                    ("generated_tokens", Json::Num(sp.stats.generated_tokens() as f64)),
                    (
                        "macs_per_token",
                        Json::Num(sp.stats.macs_per_generated_token() as f64),
                    ),
                    ("tokens_per_s", Json::Num(sp.stats.tokens_per_s())),
                    ("speedup_vs_verifier", Json::Num(sp.speedup_vs_verifier)),
                    ("drafted", Json::Num(sp.drafted as f64)),
                    ("accepted", Json::Num(sp.accepted as f64)),
                    ("accept_rate", Json::Num(sp.accept_rate())),
                    ("draft_prefill_macs", Json::Num(sp.draft_prefill_macs as f64)),
                    ("draft_macs", Json::Num(sp.draft_macs as f64)),
                    ("verify_macs", Json::Num(sp.verify_macs as f64)),
                    ("wasted_macs", Json::Num(sp.wasted_macs as f64)),
                    ("streams_match", Json::Bool(sp.streams_match)),
                ])
            }),
        ])
    }
}

/// Base budget the speculative decode bench re-compresses the artifact at
/// (scaled by the verifier's own global budget) to obtain its
/// same-checkpoint draft model.
pub const SPEC_DRAFT_BUDGET: f64 = 0.35;

/// Draft tokens per round the speculative decode bench proposes.
pub const SPEC_BENCH_K: usize = 3;

/// Run the three-way decode comparison on one artifact: dense-recompute
/// (cache-less baseline), dense-KV, and factored-KV, on the same greedy
/// synthetic workload — plus a speculative row pairing the factored
/// verifier with a lower-budget draft of the same checkpoint.
pub fn decode_bench(
    cm: &CompressedModel,
    requests: usize,
    prompt_len: usize,
    max_new: usize,
    slots: usize,
    exec: ExecConfig,
    seed: u64,
) -> Result<DecodeBench> {
    let cfg = cm.params.config();
    let reqs = synth_gen_requests(cfg, requests, prompt_len, seed);
    let config = DecodeConfig {
        slots,
        capacity: prompt_len + max_new,
        max_new,
        seed,
        exec,
        ..DecodeConfig::default()
    };
    let dense = ServeModel::from_artifact(cm, ExecMode::Dense)?;
    let fact = ServeModel::from_artifact(cm, ExecMode::Factored)?;

    let (rc_results, rc_stats) = run_recompute(&dense, &reqs, &config)?;
    let (dk_results, dk_stats) = DecodeScheduler::new(&dense, config).run(reqs.clone())?;
    let (fk_results, fk_stats) = DecodeScheduler::new(&fact, config).run(reqs.clone())?;

    let streams_match = rc_results.len() == dk_results.len()
        && rc_results.iter().zip(&dk_results).all(|(x, y)| x.tokens == y.tokens);

    // Speculative row: the draft is the benched artifact itself compressed
    // harder (rom-weight-svd over its own dense-schema params), so the pair
    // is the same checkpoint by construction. The draft budget is scaled by
    // the verifier's own budget so the draft's unit MACs stay strictly below
    // the verifier's even for aggressively-compressed input artifacts.
    let draft_budget =
        (SPEC_DRAFT_BUDGET * cm.provenance.global_budget.clamp(0.0, 1.0)).max(0.05);
    let draft_cm = CompressionSession::offline(cfg.clone()).compress_at(
        "rom-weight-svd",
        &cm.params,
        draft_budget,
        &mut EmptyStream,
    )?;
    let draft_fact = ServeModel::from_artifact(&draft_cm, ExecMode::Factored)?;
    let spec_config = DecodeConfig { spec_k: SPEC_BENCH_K, ..config };
    let (sp_results, sp_stats) =
        DecodeScheduler::with_draft(&fact, &draft_fact, spec_config)?.run(reqs.clone())?;
    let spec_streams_match = sp_results.len() == fk_results.len()
        && sp_results.iter().zip(&fk_results).all(|(x, y)| x.tokens == y.tokens);

    // Exact draft/verify MAC split: replay each request through the
    // per-request SpecDecoder (its round schedule is scheduling-independent,
    // so it matches what the engine lanes executed) and bill the round
    // traces analytically.
    let spec_dec = SpecDecoder::from_artifacts(cm, &draft_cm, ExecMode::Factored, SPEC_BENCH_K)?;
    let (mut dp, mut dm, mut vm, mut wm) = (0u128, 0u128, 0u128, 0u128);
    for req in &reqs {
        let stream = spec_dec.generate(&req.prompt, max_new, None, exec)?;
        let rep = macs::spec_report(
            cfg,
            &draft_cm.accounting,
            &cm.accounting,
            req.prompt.len(),
            &stream.rounds,
        );
        dp += rep.draft_prefill_macs;
        dm += rep.draft_macs;
        vm += rep.verify_macs;
        wm += rep.wasted_macs;
    }
    let fk_tps = fk_stats.tokens_per_s();
    let spec = SpecDecodeBench {
        spec_k: SPEC_BENCH_K,
        draft_budget,
        drafted: sp_stats.spec_drafted,
        accepted: sp_stats.spec_accepted,
        draft_prefill_macs: dp,
        draft_macs: dm,
        verify_macs: vm,
        wasted_macs: wm,
        speedup_vs_verifier: if fk_tps > 0.0 { sp_stats.tokens_per_s() / fk_tps } else { 1.0 },
        streams_match: spec_streams_match,
        stats: sp_stats,
    };

    Ok(DecodeBench {
        rows: vec![
            DecodeBenchRow { method: "dense-recompute", stats: rc_stats },
            DecodeBenchRow { method: "dense-kv", stats: dk_stats },
            DecodeBenchRow { method: "factored-kv", stats: fk_stats },
        ],
        spec,
        streams_match,
        requests,
        prompt_len,
        max_new,
        slots,
        threads: exec.resolve(),
        seed,
    })
}

/// One thread count's measurements of the scaling benchmark.
pub struct ParallelBenchRow {
    pub threads: usize,
    /// Factored serve throughput (engine, batched full forwards).
    pub serve_tokens_per_s: f64,
    /// Factored KV-decode throughput (scheduler, continuous batching).
    pub decode_tokens_per_s: f64,
    /// Offline `rom-weight-svd` compression wall-clock.
    pub compress_s: f64,
}

/// 1-vs-N-thread scaling comparison on one artifact: factored serve
/// throughput, factored KV-decode throughput, and offline compression
/// wall-clock at `--threads 1` and `--threads N`, plus the determinism
/// verdicts (logits and greedy streams bitwise identical across the two
/// thread counts). The `repro bench-parallel` payload — `make bench`
/// writes it as `BENCH_parallel.json` so the perf trajectory captures
/// scaling.
pub struct ParallelBench {
    /// Exactly two rows: serial first, then the N-thread run.
    pub rows: Vec<ParallelBenchRow>,
    /// Serve logits bitwise identical across the two thread counts.
    pub serve_logits_match: bool,
    /// Greedy decode token streams identical across the two thread counts.
    pub decode_streams_match: bool,
    pub requests: usize,
    pub seq: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub slots: usize,
    pub seed: u64,
}

impl ParallelBench {
    pub fn serve_speedup(&self) -> f64 {
        ratio(self.rows[1].serve_tokens_per_s, self.rows[0].serve_tokens_per_s)
    }

    pub fn decode_speedup(&self) -> f64 {
        ratio(self.rows[1].decode_tokens_per_s, self.rows[0].decode_tokens_per_s)
    }

    pub fn compress_speedup(&self) -> f64 {
        ratio(self.rows[0].compress_s, self.rows[1].compress_s)
    }

    pub fn format(&self) -> String {
        let mut out = String::from(
            "Parallel scaling: 1 vs N threads (factored path)\n\
             threads   serve tok/s   decode tok/s   compress s\n",
        );
        for row in &self.rows {
            out.push_str(&format!(
                "{:>7} {:>13.0} {:>14.0} {:>12.3}\n",
                row.threads, row.serve_tokens_per_s, row.decode_tokens_per_s, row.compress_s,
            ));
        }
        out.push_str(&format!(
            "speedup: serve {:.2}x, decode {:.2}x, compress {:.2}x — \
             logits identical: {}, streams identical: {}\n",
            self.serve_speedup(),
            self.decode_speedup(),
            self.compress_speedup(),
            self.serve_logits_match,
            self.decode_streams_match,
        ));
        out
    }

    /// Machine-readable form (the `BENCH_parallel.json` payload).
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|row| {
                json_obj(vec![
                    ("threads", Json::Num(row.threads as f64)),
                    ("serve_tokens_per_s", Json::Num(row.serve_tokens_per_s)),
                    ("decode_tokens_per_s", Json::Num(row.decode_tokens_per_s)),
                    ("compress_s", Json::Num(row.compress_s)),
                ])
            })
            .collect();
        json_obj(vec![
            ("bench", Json::Str("parallel".to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("seq", Json::Num(self.seq as f64)),
            ("prompt_len", Json::Num(self.prompt_len as f64)),
            ("max_new", Json::Num(self.max_new as f64)),
            ("slots", Json::Num(self.slots as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("serve_speedup", Json::Num(self.serve_speedup())),
            ("decode_speedup", Json::Num(self.decode_speedup())),
            ("compress_speedup", Json::Num(self.compress_speedup())),
            ("serve_logits_match", Json::Bool(self.serve_logits_match)),
            ("decode_streams_match", Json::Bool(self.decode_streams_match)),
            ("rows", Json::Arr(rows)),
        ])
    }
}

fn ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else {
        1.0
    }
}

/// Run the scaling comparison: the same factored serve + decode workloads
/// and an offline `rom-weight-svd` compression of the artifact's params,
/// once at `--threads 1` and once at `threads`, asserting along the way
/// that outputs are identical (the determinism contract under load).
#[allow(clippy::too_many_arguments)]
pub fn parallel_bench(
    cm: &CompressedModel,
    requests: usize,
    seq: usize,
    prompt_len: usize,
    max_new: usize,
    slots: usize,
    threads: usize,
    seed: u64,
) -> Result<ParallelBench> {
    use crate::compress::{CompressionSession, EmptyStream};

    let cfg = cm.params.config();
    let mut rows = Vec::new();
    let mut serve_logits: Vec<Vec<f32>> = Vec::new();
    let mut decode_streams: Vec<Vec<Vec<i32>>> = Vec::new();
    for t in [1usize, threads.max(1)] {
        let exec = ExecConfig::with_threads(t);
        // factored serve throughput
        let model = ServeModel::from_artifact(cm, ExecMode::Factored)?;
        let engine = ServeEngine::new(model, ServeConfig { workers: t, max_batch: 2, exec });
        let (results, serve_stats) = engine.run(synth_requests(cfg, requests, seq, seed))?;
        serve_logits.push(results.into_iter().flat_map(|r| r.logits).collect());

        // factored KV-decode throughput
        let fact = ServeModel::from_artifact(cm, ExecMode::Factored)?;
        let config = DecodeConfig {
            slots,
            capacity: prompt_len + max_new,
            max_new,
            seed,
            exec,
            ..DecodeConfig::default()
        };
        let reqs = synth_gen_requests(cfg, requests, prompt_len, seed);
        let (dresults, decode_stats) = DecodeScheduler::new(&fact, config).run(reqs)?;
        decode_streams.push(dresults.into_iter().map(|r| r.tokens).collect());

        // offline compression wall-clock (data-free weight-space ROM)
        let session = CompressionSession::offline(cfg.clone()).with_exec(exec);
        let t0 = std::time::Instant::now();
        let _ = session.compress_at("rom-weight-svd", &cm.params, 0.5, &mut EmptyStream)?;
        let compress_s = t0.elapsed().as_secs_f64();

        rows.push(ParallelBenchRow {
            threads: t,
            serve_tokens_per_s: serve_stats.tokens_per_s(),
            decode_tokens_per_s: decode_stats.tokens_per_s(),
            compress_s,
        });
    }
    Ok(ParallelBench {
        rows,
        serve_logits_match: serve_logits[0] == serve_logits[1],
        decode_streams_match: decode_streams[0] == decode_streams[1],
        requests,
        seq,
        prompt_len,
        max_new,
        slots,
        seed,
    })
}

/// Wire-path benchmark of the HTTP/SSE daemon: a self-hosted
/// [`crate::daemon::Daemon`] run driven open-loop by the `repro loadgen`
/// client over loopback — achieved RPS, TTFT / inter-token / completion
/// latency through the full transport, plus the server-side shed and
/// error counters. The `repro bench-daemon` payload — `make bench`
/// writes it as `BENCH_daemon.json`.
pub struct DaemonBench {
    /// Client-side view: what the load generator observed on the wire.
    pub load: LoadReport,
    /// Server-side view: the drained daemon's engine stats + counters.
    pub daemon: DaemonReport,
    pub connections: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub slots: usize,
    pub queue_cap: usize,
    /// Resolved worker-pool budget the engine executed under.
    pub threads: usize,
    pub seed: u64,
    /// `interactive:batch` request mix the load generator drove.
    pub mix: (u32, u32),
}

impl DaemonBench {
    pub fn format(&self) -> String {
        let mut out = format!(
            "Daemon wire-path bench: {} conns over loopback, {} slots, queue {} \
             ({} threads, mix {}:{})\n",
            self.connections, self.slots, self.queue_cap, self.threads, self.mix.0, self.mix.1,
        );
        out.push_str(&self.load.format());
        let s = &self.daemon.stats;
        out.push_str(&format!(
            "server: {} retired, {} generated tokens, {} SSE streams, \
             {} shed_429, {} shed_503, {} bad requests, {} disconnect cancels\n",
            s.requests,
            s.generated_tokens,
            self.daemon.sse_streams,
            self.daemon.shed_429,
            self.daemon.shed_503,
            self.daemon.bad_requests,
            self.daemon.disconnect_cancels,
        ));
        out
    }

    /// Machine-readable form (the `BENCH_daemon.json` payload).
    pub fn to_json(&self) -> Json {
        let s = &self.daemon.stats;
        json_obj(vec![
            ("bench", Json::Str("daemon".to_string())),
            ("connections", Json::Num(self.connections as f64)),
            ("prompt_len", Json::Num(self.prompt_len as f64)),
            ("max_new", Json::Num(self.max_new as f64)),
            ("slots", Json::Num(self.slots as f64)),
            ("queue_cap", Json::Num(self.queue_cap as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("mix", Json::Str(format!("{}:{}", self.mix.0, self.mix.1))),
            ("load", self.load.to_json()),
            (
                "server",
                json_obj(vec![
                    ("requests", Json::Num(s.requests as f64)),
                    ("generated_tokens", Json::Num(s.generated_tokens as f64)),
                    ("wall_s", Json::Num(s.wall_s)),
                    ("http_requests", Json::Num(self.daemon.http_requests as f64)),
                    ("sse_streams", Json::Num(self.daemon.sse_streams as f64)),
                    ("shed_429", Json::Num(self.daemon.shed_429 as f64)),
                    ("shed_503", Json::Num(self.daemon.shed_503 as f64)),
                    ("bad_requests", Json::Num(self.daemon.bad_requests as f64)),
                    (
                        "disconnect_cancels",
                        Json::Num(self.daemon.disconnect_cancels as f64),
                    ),
                ]),
            ),
        ])
    }
}

/// Self-hosted wire-path run: bind a daemon on an ephemeral loopback
/// port, drive it with the open-loop load generator, then drain and
/// join — both sides of the wire report into one [`DaemonBench`].
#[allow(clippy::too_many_arguments)]
pub fn daemon_bench(
    cm: &CompressedModel,
    connections: usize,
    rps: f64,
    duration_s: f64,
    prompt_len: usize,
    max_new: usize,
    slots: usize,
    queue_cap: usize,
    exec: ExecConfig,
    seed: u64,
    mix: (u32, u32),
) -> Result<DaemonBench> {
    use crate::daemon::{run_loadgen, Daemon, DaemonConfig, LoadgenConfig};
    use crate::engine::EngineConfig;

    let cfg = cm.params.config();
    let model = ServeModel::from_artifact(cm, ExecMode::Factored)?;
    let engine = EngineConfig {
        slots,
        queue_cap,
        max_new,
        capacity: prompt_len + max_new,
        seed,
        eos: None,
        exec,
        ..EngineConfig::default()
    };
    let server = Daemon::bind(
        &model,
        DaemonConfig { addr: "127.0.0.1:0".into(), engine, retry_after_s: 1, obs: true },
    )?;
    let ctl = server.control();
    let lg = LoadgenConfig {
        addr: server.addr().to_string(),
        connections,
        rps,
        duration_s,
        prompt_len,
        max_new,
        stream: true,
        seed,
        vocab: cfg.vocab,
        mix,
        deadline_ms: 250.0,
    };
    let (load, daemon) = std::thread::scope(|s| -> Result<(LoadReport, DaemonReport)> {
        let srv = s.spawn(move || server.serve());
        let load = run_loadgen(&lg);
        // drain unconditionally so the scope can join even if the load
        // generator failed mid-run
        ctl.drain();
        let daemon = srv.join().map_err(|_| anyhow::anyhow!("daemon thread panicked"))?;
        Ok((load?, daemon?))
    })?;
    Ok(DaemonBench {
        load,
        daemon,
        connections,
        prompt_len,
        max_new,
        slots,
        queue_cap,
        threads: exec.resolve(),
        seed,
        mix,
    })
}

fn json_obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Obs table: run the adversarial flood-plus-trickle trace once with both
/// observability planes attached and tabulate the causal transcript
/// against the engine's own accounting (`repro tables --table obs`). The
/// table *is* an exactness check — any divergence between the replayed
/// flight recorder, the metrics registry, and
/// [`crate::engine::CoreStats`] errors instead of printing a row. Every
/// value shown is round/MAC-denominated, so the output is deterministic
/// across thread counts.
pub fn obs_table(exp: &Experiment, base: &ParamStore, budget: f64) -> Result<String> {
    use crate::engine::{EngineConfig, EngineCore, InferenceRequest, Tier};
    use crate::obs::{self, MetricsRegistry, TraceEvent};
    use std::sync::Arc;

    const BATCH_N: usize = 6;
    const INTERACTIVE_N: usize = 2;
    const PROMPT: usize = 6;
    const MAX_NEW: usize = 4;

    let rom = exp.compress_method(base, "rom-feature", budget)?;
    let model = ServeModel::from_artifact(&rom, ExecMode::Factored)?;
    let cfg = model.config().clone();
    let total = BATCH_N + INTERACTIVE_N;
    let ecfg = EngineConfig {
        slots: 1,
        queue_cap: total,
        max_new: MAX_NEW,
        capacity: PROMPT + MAX_NEW,
        seed: 0,
        eos: None,
        ..EngineConfig::default()
    };
    let prompts = crate::engine::synth_token_streams(&cfg, total, PROMPT, 0x0B5);
    let mut session = EngineCore::new(&model, ecfg).session();
    let registry = Arc::new(MetricsRegistry::new());
    session.enable_tracing(obs::DEFAULT_TRACE_CAP);
    session.attach_metrics(Arc::clone(&registry));
    for (id, prompt) in prompts.iter().enumerate() {
        let mut req = InferenceRequest::generate(id, prompt.clone(), None);
        req = if id < BATCH_N {
            req.with_tenant("flood")
        } else {
            req.with_tier(Tier::Interactive).with_tenant("trickle")
        };
        ensure!(session.try_submit(req)?.is_none(), "obs-table request {id} bounced");
    }
    while session.has_work() {
        session.step()?;
        session.take_events();
    }
    let trace = session.take_trace();
    let (_finished, stats) = session.finish();

    let mut counts: BTreeMap<&'static str, usize> = BTreeMap::new();
    for ev in &trace {
        let key = match ev {
            TraceEvent::Enqueued { .. } => "enqueued",
            TraceEvent::Admitted { .. } => "admitted",
            TraceEvent::Deferred { .. } => "deferred",
            TraceEvent::Preempted { .. } => "preempted",
            TraceEvent::PrefillDone { .. } => "prefill_done",
            TraceEvent::DecodeRound { .. } => "decode_round",
            TraceEvent::Finished { .. } => "finished",
        };
        *counts.entry(key).or_insert(0) += 1;
    }
    let at = |k: &str| counts.get(k).copied().unwrap_or(0);

    let replay = obs::reconstruct(&trace);
    ensure!(
        replay.admitted == total
            && replay.finished == total
            && replay.finished == stats.requests
            && replay.preemptions == stats.preemptions
            && replay.decode_rounds == stats.decode_rounds
            && replay.admitted_macs == stats.admitted_macs
            && replay.executed_macs == stats.macs,
        "obs table: flight-recorder replay diverges from CoreStats: {replay:?}"
    );
    ensure!(
        registry.requests.get() == stats.requests as u64
            && registry.admitted_macs.get() == obs::sat_u64(stats.admitted_macs)
            && registry.executed_macs.get() == obs::sat_u64(stats.macs),
        "obs table: metrics registry diverges from CoreStats"
    );

    let mut out = String::new();
    out.push_str(&format!(
        "Obs table — flight recorder vs engine accounting (LLM-ROM@{:.0}%; {total} requests: \
         {BATCH_N} batch flood + {INTERACTIVE_N} interactive through 1 slot)\n",
        budget * 100.0,
    ));
    out.push_str(&format!(
        "  causal plane : {} events — {} enqueued, {} admitted ({} deferrals, {} preemptions), \
         {} prefills, {} decode rounds, {} finished\n",
        trace.len(),
        at("enqueued"),
        at("admitted"),
        at("deferred"),
        at("preempted"),
        at("prefill_done"),
        at("decode_round"),
        at("finished"),
    ));
    out.push_str(&format!(
        "  replay       : admitted {} MACs, executed {} MACs — equal to CoreStats exactly\n",
        replay.admitted_macs, replay.executed_macs,
    ));
    out.push_str(&format!(
        "  timing plane : {} requests, {} generated tokens; tier batch/interactive {}/{}; \
         tenant flood/trickle {}/{} — equal to the fairness ledger\n",
        registry.requests.get(),
        registry.generated_tokens.get(),
        registry.tier_admissions.get("batch"),
        registry.tier_admissions.get("interactive"),
        registry.tenant_requests.get("flood"),
        registry.tenant_requests.get("trickle"),
    ));
    Ok(out)
}

/// CLI entry: run the requested table(s) and print.
///
/// `budget` applies to the ablation tables 2-4 (the paper runs them at its
/// 80% operating point; at budgets where ROM is near-lossless on a given
/// substrate, the calibration knobs only bind at tighter budgets).
pub fn run_tables(
    exp: &Experiment,
    base: &ParamStore,
    which: &str,
    ft_steps: usize,
    budget: f64,
) -> Result<()> {
    match which {
        "1" => println!("{}", table1(exp, base, ft_steps)?),
        "2" => println!("{}", table2(exp, base, budget)?),
        "3" => println!("{}", table3(exp, base, budget)?),
        "4" => println!("{}", table4(exp, base, budget)?),
        "obs" => println!("{}", obs_table(exp, base, budget)?),
        "all" => {
            println!("{}", table1(exp, base, ft_steps)?);
            println!("{}", table2(exp, base, budget)?);
            println!("{}", table3(exp, base, budget)?);
            println!("{}", table4(exp, base, budget)?);
        }
        other => anyhow::bail!("unknown table `{other}` (1|2|3|4|obs|all)"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{demo_artifact, demo_config};

    fn two_worker_config() -> ServeConfig {
        ServeConfig { workers: 2, max_batch: 2, exec: ExecConfig::with_threads(2) }
    }

    #[test]
    fn serve_bench_reports_both_modes_with_json() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 3).unwrap();
        let b = serve_bench(&cm, 4, 10, two_worker_config(), 9).unwrap();
        assert_eq!(b.rows.len(), 2);
        assert_eq!(b.rows[0].mode, ExecMode::Dense);
        assert_eq!(b.rows[1].mode, ExecMode::Factored);
        assert_eq!(b.rows[0].n_factored, 0);
        assert!(b.rows[1].n_factored > 0);
        assert_eq!(b.threads, 2, "resolved thread budget lands in the bench");
        assert!(b.max_logit_diff <= 1e-4, "modes disagree: {}", b.max_logit_diff);
        assert!(b.mac_reduction() > 1.0);
        let text = b.format();
        assert!(text.contains("dense") && text.contains("factored"));
        assert!(text.contains("threads"), "threads column missing: {text}");
        // JSON payload round-trips through the parser with both rows
        let j = Json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "serve");
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("mac_reduction").unwrap().as_f64().unwrap() > 1.0);
        assert_eq!(j.get("threads").unwrap().as_f64().unwrap(), 2.0);
        // text form stays available under the old name
        assert!(serve_table(&cm, 4, 10, two_worker_config(), 9).is_ok());
    }

    #[test]
    fn decode_bench_three_way_acceptance() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 5).unwrap();
        let b = decode_bench(&cm, 4, 8, 6, 2, ExecConfig::serial(), 11).unwrap();
        assert_eq!(b.threads, 1);
        assert_eq!(b.rows.len(), 3);
        let methods: Vec<&str> = b.rows.iter().map(|r| r.method).collect();
        assert_eq!(methods, ["dense-recompute", "dense-kv", "factored-kv"]);
        // the PR's acceptance bar: factored-KV strictly fewer MACs/token
        // than dense-recompute
        let rc = b.rows[0].stats.macs_per_generated_token();
        let dk = b.rows[1].stats.macs_per_generated_token();
        let fk = b.rows[2].stats.macs_per_generated_token();
        assert!(fk < dk, "factorization must save on top of the cache");
        assert!(dk < rc, "the cache must save on top of recompute");
        assert!(b.mac_reduction() > 1.0);
        assert!(b.streams_match, "dense KV streams must equal dense recompute streams");
        assert!(b.rows[1].stats.mid_run_admissions > 0, "4 requests / 2 slots admit mid-run");
        // speculative companion row: bitwise identical to the verifier-only
        // factored-kv streams, with an exact analytic MAC accounting
        let sp = &b.spec;
        assert!(sp.streams_match, "speculative streams must equal verifier-only streams");
        assert!(sp.drafted > 0, "the speculative row must actually draft");
        assert!(sp.accepted <= sp.drafted);
        assert!((0.0..=1.0).contains(&sp.accept_rate()));
        assert!(sp.draft_prefill_macs > 0 && sp.draft_macs > 0 && sp.verify_macs > 0);
        assert!(sp.wasted_macs <= sp.verify_macs);
        let prefill = macs::decode_report(&cfg, &cm.accounting, 8, 1).prefill_macs * 4;
        assert_eq!(
            sp.stats.core.macs,
            prefill + sp.spec_macs(),
            "executed speculative MACs must equal the analytic accounting"
        );
        let j = Json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "decode");
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("streams_match").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("threads").unwrap().as_f64().unwrap(), 1.0);
        let sp_j = j.get("speculative").unwrap();
        assert_eq!(sp_j.get("streams_match").unwrap(), &Json::Bool(true));
        assert_eq!(sp_j.get("spec_k").unwrap().as_f64().unwrap(), SPEC_BENCH_K as f64);
        assert!(sp_j.get("accept_rate").unwrap().as_f64().unwrap() <= 1.0);
        let text = b.format();
        assert!(text.contains("factored-kv") && text.contains("dense-recompute"));
        assert!(text.contains("speculative (k="));
    }

    #[test]
    fn kernels_bench_reports_all_variants_with_json() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 17).unwrap();
        let b = kernels_bench(&cm, ExecConfig::with_threads(2), 19).unwrap();
        let kernels: Vec<&str> = b.rows.iter().map(|r| r.kernel).collect();
        assert_eq!(kernels, ["scalar", "simd", "packed", "quantized"]);
        assert!(b.rows.iter().all(|r| r.gflops() > 0.0));
        assert_eq!(b.modes.len(), 2);
        assert_eq!(b.modes[0].mode, ExecMode::Factored);
        assert_eq!(b.modes[1].mode, ExecMode::FactoredQuant);
        // quantization changes bytes, not MACs
        assert_eq!(
            b.modes[0].stats.macs_per_token(),
            b.modes[1].stats.macs_per_token(),
        );
        assert!(b.max_quant_diff.is_finite());
        let j = Json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "kernels");
        assert_eq!(j.get("kernels").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(j.get("modes").unwrap().as_arr().unwrap().len(), 2);
        let text = b.format();
        assert!(text.contains("quantized") && text.contains("GFLOP/s"), "{text}");
    }

    #[test]
    fn parallel_bench_scales_and_stays_deterministic() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 7).unwrap();
        let b = parallel_bench(&cm, 4, 10, 6, 5, 2, 4, 13).unwrap();
        assert_eq!(b.rows.len(), 2);
        assert_eq!(b.rows[0].threads, 1);
        assert_eq!(b.rows[1].threads, 4);
        assert!(b.serve_logits_match, "serve logits moved under threads");
        assert!(b.decode_streams_match, "decode streams moved under threads");
        assert!(b.rows.iter().all(|r| r.compress_s >= 0.0));
        let j = Json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "parallel");
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("serve_logits_match").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("decode_streams_match").unwrap(), &Json::Bool(true));
        let text = b.format();
        assert!(text.contains("serve tok/s") && text.contains("compress s"), "{text}");
    }
}
