//! Process-level metrics: peak RSS and stage accounting — the measured
//! side of the paper's §4 memory claim (the analytic bound lives in
//! [`super::cost`]).

use std::fs;

/// Current resident set size in bytes (Linux `/proc/self/status`).
pub fn current_rss_bytes() -> Option<usize> {
    let status = fs::read_to_string("/proc/self/status").ok()?;
    parse_status_kb(&status, "VmRSS:").map(|kb| kb * 1024)
}

/// Peak resident set size in bytes since process start.
pub fn peak_rss_bytes() -> Option<usize> {
    let status = fs::read_to_string("/proc/self/status").ok()?;
    parse_status_kb(&status, "VmHWM:").map(|kb| kb * 1024)
}

fn parse_status_kb(status: &str, key: &str) -> Option<usize> {
    status
        .lines()
        .find(|l| l.starts_with(key))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Stage-scoped metric snapshot (RSS before/after + wall time).
#[derive(Debug, Clone)]
pub struct StageMetrics {
    pub name: String,
    pub wall_s: f64,
    pub rss_before: Option<usize>,
    pub rss_after: Option<usize>,
    pub peak_rss: Option<usize>,
}

impl StageMetrics {
    pub fn format(&self) -> String {
        let mb = |x: Option<usize>| {
            x.map(|b| format!("{:.0} MB", b as f64 / 1e6)).unwrap_or_else(|| "n/a".into())
        };
        format!(
            "{:<18} {:>8.2}s  rss {} -> {} (peak {})",
            self.name,
            self.wall_s,
            mb(self.rss_before),
            mb(self.rss_after),
            mb(self.peak_rss),
        )
    }
}

/// Run a closure as a named stage, capturing wall time and RSS.
pub fn stage<T>(name: &str, f: impl FnOnce() -> T) -> (T, StageMetrics) {
    let rss_before = current_rss_bytes();
    let t0 = std::time::Instant::now();
    let out = f();
    let m = StageMetrics {
        name: name.to_string(),
        wall_s: t0.elapsed().as_secs_f64(),
        rss_before,
        rss_after: current_rss_bytes(),
        peak_rss: peak_rss_bytes(),
    };
    (out, m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_readable_on_linux() {
        // this box is linux; both counters must parse
        let rss = current_rss_bytes().expect("VmRSS");
        let peak = peak_rss_bytes().expect("VmHWM");
        assert!(rss > 1_000_000, "rss {rss}");
        assert!(peak >= rss || peak > 1_000_000);
    }

    #[test]
    fn parse_status_kb_extracts_value() {
        let fake = "Name:\tx\nVmRSS:\t  12345 kB\nVmHWM:\t 99999 kB\n";
        assert_eq!(parse_status_kb(fake, "VmRSS:"), Some(12345));
        assert_eq!(parse_status_kb(fake, "VmHWM:"), Some(99999));
        assert_eq!(parse_status_kb(fake, "Nope:"), None);
    }

    #[test]
    fn stage_measures_allocation() {
        let (v, m) = stage("alloc", || vec![0u8; 32 << 20]);
        assert_eq!(v.len(), 32 << 20);
        assert!(m.wall_s >= 0.0);
        assert!(m.format().contains("alloc"));
    }
}
