//! Latent-feature spectrum analysis — the paper's *motivation* made
//! measurable: how low-rank are the activation covariances actually?
//!
//! For every decomposable matrix, computes the eigenvalue spectrum of its
//! calibration covariance and reports the energy-based effective rank at
//! several thresholds, next to the budget-based rank the paper would
//! assign. This is the evidence behind "identify the finite set of most
//! useful latent feature modes" (paper §5) and feeds EXPERIMENTS.md.

use anyhow::Result;

use crate::data::CalibBatch;
use crate::linalg::eigh;
use crate::model::ParamStore;
use crate::rom::budget::rank_for_budget;
use crate::rom::decompose::rank_for_energy;
use crate::rom::RomPipeline;

/// Spectrum summary for one matrix.
#[derive(Debug, Clone)]
pub struct SpectrumRow {
    pub name: String,
    pub dim: usize,
    /// energy-based effective ranks at 90/99/99.9% eigenvalue mass
    pub rank_e90: usize,
    pub rank_e99: usize,
    pub rank_e999: usize,
    /// budget-based rank at module budget 0.46 (the 80% preset)
    pub rank_b46: usize,
    /// top-1 eigenvalue share
    pub top1_share: f64,
}

/// Measure spectra of every matrix in `blocks` via the pipeline's own
/// covariance machinery (no compression happens).
pub fn measure_spectra(
    pipeline: &RomPipeline,
    params: &ParamStore,
    calib: &[CalibBatch],
    blocks: std::ops::Range<usize>,
) -> Result<Vec<SpectrumRow>> {
    pipeline
        .measure_covariances(params, calib, blocks)?
        .into_iter()
        .map(|(name, cov, d_out, d_in)| spectrum_of_covariance(&name, &cov, d_out, d_in))
        .collect()
}

/// Spectrum rows from explicitly accumulated covariances.
pub fn spectrum_of_covariance(
    name: &str,
    cov: &crate::linalg::Matrix,
    d_out: usize,
    d_in: usize,
) -> Result<SpectrumRow> {
    let dec = eigh(cov)?;
    let total: f64 = dec.values.iter().map(|l| l.max(0.0)).sum();
    let top1 = dec.values.first().copied().unwrap_or(0.0).max(0.0) / total.max(1e-300);
    Ok(SpectrumRow {
        name: name.to_string(),
        dim: cov.rows(),
        rank_e90: rank_for_energy(&dec, 0.90),
        rank_e99: rank_for_energy(&dec, 0.99),
        rank_e999: rank_for_energy(&dec, 0.999),
        rank_b46: rank_for_budget(d_out, d_in, 0.46),
        top1_share: top1,
    })
}

/// Format rows as the EXPERIMENTS.md table.
pub fn format_spectra(rows: &[SpectrumRow]) -> String {
    let mut s = String::from(
        "\n## Latent-feature spectra (effective rank of activation covariance)\n\
         matrix                     dim   r@90%   r@99%  r@99.9%  r(b=.46)  top1\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<25} {:>4} {:>7} {:>7} {:>8} {:>9} {:>5.1}%\n",
            r.name, r.dim, r.rank_e90, r.rank_e99, r.rank_e999, r.rank_b46,
            100.0 * r.top1_share
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, Matrix};
    use crate::util::Rng;

    #[test]
    fn lowrank_activations_have_small_effective_rank() {
        let mut rng = Rng::new(0);
        // activations in an 8-dim subspace of a 64-dim space + noise
        let basis = Matrix::from_fn(8, 64, |_, _| rng.normal());
        let coef = Matrix::from_fn(500, 8, |_, _| rng.normal());
        let noise = Matrix::from_fn(500, 64, |_, _| rng.normal() * 0.01);
        let y = matmul(&coef, &basis).add(&noise);
        let cov = matmul(&y.transpose(), &y);
        let row = spectrum_of_covariance("test", &cov, 64, 64).unwrap();
        assert!(row.rank_e99 <= 10, "rank_e99 {}", row.rank_e99);
        assert!(row.rank_e90 <= row.rank_e99);
        assert!(row.rank_e99 <= row.rank_e999);
        assert!(row.top1_share > 0.05);
    }

    #[test]
    fn isotropic_activations_have_full_effective_rank() {
        let mut rng = Rng::new(1);
        let y = Matrix::from_fn(2000, 32, |_, _| rng.normal());
        let cov = matmul(&y.transpose(), &y);
        let row = spectrum_of_covariance("iso", &cov, 32, 32).unwrap();
        assert!(row.rank_e999 >= 30, "{}", row.rank_e999);
    }

    #[test]
    fn format_contains_names() {
        let mut rng = Rng::new(2);
        let y = Matrix::from_fn(100, 8, |_, _| rng.normal());
        let cov = matmul(&y.transpose(), &y);
        let row = spectrum_of_covariance("blocks.0.wq", &cov, 8, 8).unwrap();
        let s = format_spectra(&[row]);
        assert!(s.contains("blocks.0.wq"));
    }
}
