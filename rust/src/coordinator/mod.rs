//! L3 coordinator: end-to-end experiment orchestration.
//!
//! Owns the process lifecycle the paper implies but never spells out:
//! generate world + corpus → train the base model → build calibration sets
//! → ROM-compress / prune → evaluate → account cost. Everything below here
//! is pure Rust over the PJRT runtime; per-stage wall-clock and memory
//! metrics feed the §4 cost table.

pub mod cost;
pub mod metrics;
pub mod spectrum;
pub mod experiment;
pub mod tables;

pub use cost::{CostReport, CostRow};
pub use experiment::{Experiment, ExperimentConfig, TrainedArtifacts};
pub use tables::{
    daemon_bench, decode_bench, kernels_bench, obs_table, parallel_bench, run_tables, serve_bench,
    serve_table, sweep_table, sweep_table_with, table1, table2, table3, table4, DaemonBench,
    DecodeBench, DecodeBenchRow, KernelsBench, KernelsBenchRow, KernelsModeRow, ParallelBench,
    ParallelBenchRow, ServeBench, ServeBenchRow, SpecDecodeBench,
};
