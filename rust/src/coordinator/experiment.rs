//! High-level experiment driver: the one-stop API used by the CLI, the
//! examples, and the table harness.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::{
    build_calibration, pack_lm_batches, render_corpus, CalibBatch, CalibSource, World,
};
use crate::eval::{EvalReport, Evaluator};
use crate::model::{ModelConfig, ParamStore};
use crate::prune::{Importance, PrunedModel, Pruner};
use crate::rom::{paper_preset, ModuleSchedule, RomConfig, RomModel, RomPipeline};
use crate::runtime::Runtime;
use crate::train::{LrSchedule, Trainer};
use crate::util::Stopwatch;

/// Experiment-wide knobs (defaults reproduce the headline tables).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Characters of training corpus.
    pub corpus_chars: usize,
    /// Fact up-weighting in the corpus mix.
    pub fact_repeat: usize,
    /// Base-model training steps.
    pub train_steps: usize,
    pub peak_lr: f32,
    /// Calibration rows (the paper's "batch size", Table 2's knob).
    pub calib_rows: usize,
    /// Calibration sequence length (Table 3's knob).
    pub calib_seq: usize,
    /// Calibration distribution (Table 4's knob).
    pub calib_source: CalibSource,
    /// Eval instances per task.
    pub eval_per_task: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 42,
            corpus_chars: 400_000,
            fact_repeat: 4,
            train_steps: 1200,
            peak_lr: 1.5e-3,
            calib_rows: 512,
            calib_seq: 128,
            calib_source: CalibSource::Combination,
            eval_per_task: 200,
        }
    }
}

/// Outputs of the training stage.
pub struct TrainedArtifacts {
    pub params: ParamStore,
    pub losses: Vec<f32>,
    pub train_seconds: f64,
}

/// The orchestrator.
pub struct Experiment<'rt> {
    pub runtime: &'rt Runtime,
    pub cfg: ModelConfig,
    pub xcfg: ExperimentConfig,
    pub world: World,
}

impl<'rt> Experiment<'rt> {
    pub fn new(runtime: &'rt Runtime, xcfg: ExperimentConfig) -> Experiment<'rt> {
        let cfg = ModelConfig::from_manifest(&runtime.manifest().model_config);
        let world = World::default_world(xcfg.seed);
        Experiment { runtime, cfg, xcfg, world }
    }

    /// Training corpus for the current world.
    pub fn corpus(&self) -> String {
        render_corpus(&self.world, self.xcfg.seed, self.xcfg.corpus_chars, self.xcfg.fact_repeat)
    }

    /// Held-out text for perplexity (disjoint render seed).
    pub fn ppl_text(&self) -> String {
        render_corpus(&self.world, self.xcfg.seed ^ 0x9999, 40_000, 1)
    }

    /// Train the base model from `init` (or fresh artifacts init).
    pub fn train(
        &self,
        init: ParamStore,
        mut log: impl FnMut(usize, f32, f32),
    ) -> Result<TrainedArtifacts> {
        let mut sw = Stopwatch::new();
        let corpus = self.corpus();
        let batches = pack_lm_batches(
            &corpus,
            self.cfg.train_batch,
            self.cfg.train_seq,
            self.xcfg.train_steps,
            self.xcfg.seed,
        );
        let sched = LrSchedule {
            peak: self.xcfg.peak_lr,
            warmup_steps: (self.xcfg.train_steps / 20).max(5),
            total_steps: self.xcfg.train_steps,
            min_lr: self.xcfg.peak_lr / 20.0,
        };
        let mut trainer = Trainer::new(self.runtime, init);
        trainer.run(&batches, &sched, 10, &mut log)?;
        Ok(TrainedArtifacts {
            params: trainer.params.clone(),
            losses: trainer.losses.clone(),
            train_seconds: sw.lap("train"),
        })
    }

    /// Build calibration batches per the experiment config (overridable for
    /// the ablation tables).
    pub fn calibration(
        &self,
        rows: usize,
        seq_used: usize,
        source: CalibSource,
    ) -> Vec<CalibBatch> {
        build_calibration(
            &self.world,
            source,
            rows,
            self.cfg.eval_batch,
            self.cfg.eval_seq,
            seq_used,
            self.xcfg.seed ^ 0xCAFE,
        )
    }

    /// ROM-compress at a global budget using the paper's preset schedule.
    pub fn compress_at(&self, params: &ParamStore, global_budget: f64) -> Result<RomModel> {
        let schedule = paper_preset(&self.cfg, global_budget);
        self.compress_with(params, schedule, None)
    }

    /// ROM-compress with an explicit schedule (and optional calibration
    /// override for Tables 2-4).
    pub fn compress_with(
        &self,
        params: &ParamStore,
        schedule: ModuleSchedule,
        calib_override: Option<&[CalibBatch]>,
    ) -> Result<RomModel> {
        let calib_own;
        let calib = match calib_override {
            Some(c) => c,
            None => {
                calib_own = self.calibration(
                    self.xcfg.calib_rows,
                    self.xcfg.calib_seq,
                    self.xcfg.calib_source,
                );
                &calib_own
            }
        };
        let pipeline = RomPipeline::new(self.runtime);
        let rcfg = RomConfig { schedule, ..RomConfig::default() };
        pipeline.compress(params, calib, &rcfg)
    }

    /// Structured-pruning baseline at a global budget (same schedule family
    /// as ROM so Table 1 compares like for like).
    pub fn prune_at(
        &self,
        params: &ParamStore,
        global_budget: f64,
        importance: Importance,
    ) -> Result<PrunedModel> {
        let schedule = paper_preset(&self.cfg, global_budget);
        let calib = self.calibration(
            self.xcfg.calib_rows.min(128),
            self.xcfg.calib_seq,
            self.xcfg.calib_source,
        );
        Pruner::new(self.runtime).prune(params, &calib, schedule, importance)
    }

    /// Recovery fine-tune for a pruned model (LLM-Pruner's ✓ rows).
    pub fn finetune_pruned(
        &self,
        pruned: &PrunedModel,
        steps: usize,
        mut log: impl FnMut(usize, f32, f32),
    ) -> Result<ParamStore> {
        let corpus = self.corpus();
        let batches = pack_lm_batches(
            &corpus,
            self.cfg.train_batch,
            self.cfg.train_seq,
            steps,
            self.xcfg.seed ^ 0xF17E,
        );
        let sched = LrSchedule {
            peak: self.xcfg.peak_lr / 3.0,
            warmup_steps: (steps / 10).max(2),
            total_steps: steps,
            min_lr: self.xcfg.peak_lr / 60.0,
        };
        let mut trainer =
            Trainer::new(self.runtime, pruned.params.clone()).with_masks(pruned.masks.clone())?;
        trainer.run(&batches, &sched, 10, &mut log)?;
        Ok(trainer.params.clone())
    }

    /// Full six-task evaluation (+ perplexity).
    pub fn evaluate(&self, params: &ParamStore, with_ppl: bool) -> Result<EvalReport> {
        let evaluator = Evaluator::new(self.runtime);
        let ppl_text = if with_ppl { Some(self.ppl_text()) } else { None };
        evaluator.eval_suite(
            params,
            &self.world,
            self.xcfg.eval_per_task,
            self.xcfg.seed ^ 0xE7A1,
            ppl_text.as_deref(),
        )
    }

    /// Load the init checkpoint exported by `make artifacts`.
    pub fn init_params(&self, artifacts_dir: impl AsRef<Path>) -> Result<ParamStore> {
        ParamStore::load(&self.cfg, artifacts_dir.as_ref().join("init.rtz"))
            .context("load init.rtz")
    }

    /// Canonical checkpoint path inside a run directory.
    pub fn ckpt_path(run_dir: impl AsRef<Path>, tag: &str) -> PathBuf {
        run_dir.as_ref().join(format!("{tag}.rtz"))
    }
}
