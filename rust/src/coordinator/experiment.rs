//! High-level experiment driver: the one-stop API used by the CLI, the
//! examples, and the table harness.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::compress::{
    resolve, CalibrationStream, CompressedModel, CompressionSession, VecStream, WorldStream,
};
use crate::data::{
    build_calibration, pack_lm_batches, render_corpus, CalibBatch, CalibSource, World,
};
use crate::eval::{EvalReport, Evaluator};
use crate::exec::ExecConfig;
use crate::model::{ModelConfig, ParamStore};
use crate::rom::ModuleSchedule;
use crate::runtime::Runtime;
use crate::train::{LrSchedule, Trainer};
use crate::util::Stopwatch;

/// Experiment-wide knobs (defaults reproduce the headline tables).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Characters of training corpus.
    pub corpus_chars: usize,
    /// Fact up-weighting in the corpus mix.
    pub fact_repeat: usize,
    /// Base-model training steps.
    pub train_steps: usize,
    pub peak_lr: f32,
    /// Calibration rows (the paper's "batch size", Table 2's knob).
    pub calib_rows: usize,
    /// Calibration sequence length (Table 3's knob).
    pub calib_seq: usize,
    /// Calibration distribution (Table 4's knob).
    pub calib_source: CalibSource,
    /// Eval instances per task.
    pub eval_per_task: usize,
    /// Worker-pool budget for compression runs (the `--threads` knob;
    /// artifacts are bitwise identical for any value).
    pub exec: ExecConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 42,
            corpus_chars: 400_000,
            fact_repeat: 4,
            train_steps: 1200,
            peak_lr: 1.5e-3,
            calib_rows: 512,
            calib_seq: 128,
            calib_source: CalibSource::Combination,
            eval_per_task: 200,
            exec: ExecConfig::default(),
        }
    }
}

/// Outputs of the training stage.
pub struct TrainedArtifacts {
    pub params: ParamStore,
    pub losses: Vec<f32>,
    pub train_seconds: f64,
}

/// The orchestrator.
pub struct Experiment<'rt> {
    pub runtime: &'rt Runtime,
    pub cfg: ModelConfig,
    pub xcfg: ExperimentConfig,
    pub world: World,
}

impl<'rt> Experiment<'rt> {
    pub fn new(runtime: &'rt Runtime, xcfg: ExperimentConfig) -> Experiment<'rt> {
        let cfg = ModelConfig::from_manifest(&runtime.manifest().model_config);
        let world = World::default_world(xcfg.seed);
        Experiment { runtime, cfg, xcfg, world }
    }

    /// Training corpus for the current world.
    pub fn corpus(&self) -> String {
        render_corpus(&self.world, self.xcfg.seed, self.xcfg.corpus_chars, self.xcfg.fact_repeat)
    }

    /// Held-out text for perplexity (disjoint render seed).
    pub fn ppl_text(&self) -> String {
        render_corpus(&self.world, self.xcfg.seed ^ 0x9999, 40_000, 1)
    }

    /// Train the base model from `init` (or fresh artifacts init).
    pub fn train(
        &self,
        init: ParamStore,
        mut log: impl FnMut(usize, f32, f32),
    ) -> Result<TrainedArtifacts> {
        let mut sw = Stopwatch::new();
        let corpus = self.corpus();
        let batches = pack_lm_batches(
            &corpus,
            self.cfg.train_batch,
            self.cfg.train_seq,
            self.xcfg.train_steps,
            self.xcfg.seed,
        );
        let sched = LrSchedule {
            peak: self.xcfg.peak_lr,
            warmup_steps: (self.xcfg.train_steps / 20).max(5),
            total_steps: self.xcfg.train_steps,
            min_lr: self.xcfg.peak_lr / 20.0,
        };
        let mut trainer = Trainer::new(self.runtime, init);
        trainer.run(&batches, &sched, 10, &mut log)?;
        Ok(TrainedArtifacts {
            params: trainer.params.clone(),
            losses: trainer.losses.clone(),
            train_seconds: sw.lap("train"),
        })
    }

    /// Build calibration batches per the experiment config (overridable for
    /// the ablation tables).
    pub fn calibration(
        &self,
        rows: usize,
        seq_used: usize,
        source: CalibSource,
    ) -> Vec<CalibBatch> {
        build_calibration(
            &self.world,
            source,
            rows,
            self.cfg.eval_batch,
            self.cfg.eval_seq,
            seq_used,
            self.xcfg.seed ^ 0xCAFE,
        )
    }

    /// Compression session bound to this experiment's runtime and thread
    /// budget.
    pub fn session(&self) -> CompressionSession<'rt> {
        CompressionSession::new(self.runtime).with_exec(self.xcfg.exec)
    }

    /// Calibration as a pluggable stream (the [`crate::compress`] form of
    /// [`Experiment::calibration`]).
    pub fn calib_stream(
        &self,
        rows: usize,
        seq_used: usize,
        source: CalibSource,
    ) -> WorldStream<'_> {
        WorldStream::new(
            &self.world,
            source,
            rows,
            self.cfg.eval_batch,
            self.cfg.eval_seq,
            seq_used,
            self.xcfg.seed ^ 0xCAFE,
        )
    }

    /// Compress with a registered method at a global budget, using the
    /// paper's preset schedule family and this experiment's calibration
    /// configuration. The single entry point behind `repro compress`,
    /// `repro sweep`, the tables harness, and the examples.
    pub fn compress_method(
        &self,
        params: &ParamStore,
        method: &str,
        global_budget: f64,
    ) -> Result<CompressedModel> {
        let mut stream = self.calib_stream(
            self.xcfg.calib_rows,
            self.xcfg.calib_seq,
            self.xcfg.calib_source,
        );
        self.session().compress_at(method, params, global_budget, &mut stream)
    }

    /// Compress with an explicit schedule and optional calibration
    /// override (the Tables 2-4 knobs).
    pub fn compress_scheduled(
        &self,
        params: &ParamStore,
        method: &str,
        schedule: ModuleSchedule,
        calib_override: Option<&[CalibBatch]>,
    ) -> Result<CompressedModel> {
        let mut vec_stream;
        let mut world_stream;
        let stream: &mut dyn CalibrationStream = match calib_override {
            Some(c) => {
                vec_stream = VecStream::new("override", c.to_vec());
                &mut vec_stream
            }
            None => {
                world_stream = self.calib_stream(
                    self.xcfg.calib_rows,
                    self.xcfg.calib_seq,
                    self.xcfg.calib_source,
                );
                &mut world_stream
            }
        };
        let compressor = resolve(method)?;
        let global = schedule.global_budget(&self.cfg);
        self.session().run(compressor.as_ref(), params, schedule, global, stream)
    }

    /// Recovery fine-tune of a compressed model. Pruned artifacts carry
    /// masks and train masked (zeros stay zero); ROM artifacts train all
    /// parameters.
    pub fn finetune_compressed(
        &self,
        cm: &CompressedModel,
        steps: usize,
        mut log: impl FnMut(usize, f32, f32),
    ) -> Result<ParamStore> {
        let corpus = self.corpus();
        let batches = pack_lm_batches(
            &corpus,
            self.cfg.train_batch,
            self.cfg.train_seq,
            steps,
            self.xcfg.seed ^ 0xF17E,
        );
        let sched = LrSchedule {
            peak: self.xcfg.peak_lr / 3.0,
            warmup_steps: (steps / 10).max(2),
            total_steps: steps,
            min_lr: self.xcfg.peak_lr / 60.0,
        };
        let mut trainer = Trainer::new(self.runtime, cm.params.clone());
        if let Some(masks) = &cm.masks {
            trainer = trainer.with_masks(masks.clone())?;
        }
        trainer.run(&batches, &sched, 10, &mut log)?;
        Ok(trainer.params.clone())
    }

    /// Full six-task evaluation (+ perplexity).
    pub fn evaluate(&self, params: &ParamStore, with_ppl: bool) -> Result<EvalReport> {
        let evaluator = Evaluator::new(self.runtime);
        let ppl_text = if with_ppl { Some(self.ppl_text()) } else { None };
        evaluator.eval_suite(
            params,
            &self.world,
            self.xcfg.eval_per_task,
            self.xcfg.seed ^ 0xE7A1,
            ppl_text.as_deref(),
        )
    }

    /// Load the init checkpoint exported by `make artifacts`.
    pub fn init_params(&self, artifacts_dir: impl AsRef<Path>) -> Result<ParamStore> {
        ParamStore::load(&self.cfg, artifacts_dir.as_ref().join("init.rtz"))
            .context("load init.rtz")
    }

    /// Canonical checkpoint path inside a run directory.
    pub fn ckpt_path(run_dir: impl AsRef<Path>, tag: &str) -> PathBuf {
        run_dir.as_ref().join(format!("{tag}.rtz"))
    }
}
