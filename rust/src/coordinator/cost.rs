//! §4 cost accounting: per-layer ROM wall time, totals per budget, and the
//! layerwise peak-memory bound.
//!
//! The paper's claim has three parts we reproduce at our scale: (1) ROM is
//! CPU-only, (2) time scales with the number of compressed layers (13 s ×
//! 224 layers ⇒ 15.8–28.9 min across budgets), (3) processing layerwise
//! bounds peak memory by one layer's weights + calibration activations
//! (<10 GB for LLaMA-7B), not the whole model.

use crate::compress::CompressedModel;
use crate::model::ModelConfig;

/// One row of the cost table.
#[derive(Debug, Clone)]
pub struct CostRow {
    pub label: String,
    pub layers_compressed: usize,
    pub total_seconds: f64,
    pub mean_seconds_per_layer: f64,
    pub peak_capture_bytes: usize,
}

/// Aggregated cost report across budgets.
#[derive(Debug, Clone, Default)]
pub struct CostReport {
    pub rows: Vec<CostRow>,
}

impl CostReport {
    pub fn push(&mut self, label: impl Into<String>, cm: &CompressedModel) {
        self.rows.push(CostRow {
            label: label.into(),
            layers_compressed: cm.timings.len(),
            total_seconds: cm.total_seconds(),
            mean_seconds_per_layer: cm.mean_seconds_per_layer(),
            peak_capture_bytes: cm.peak_capture_bytes,
        });
    }

    pub fn format(&self) -> String {
        let mut s = String::from(
            "\n## Computational cost (paper §4 analog)\nbudget        layers   total(s)   s/layer   peak-capture(MB)\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{:<12} {:>7} {:>10.2} {:>9.3} {:>14.1}\n",
                r.label,
                r.layers_compressed,
                r.total_seconds,
                r.mean_seconds_per_layer,
                r.peak_capture_bytes as f64 / 1e6,
            ));
        }
        s
    }
}

/// Analytic layerwise memory bound (paper: "<10 GB for LLaMA-7B"):
/// largest single layer's weights + one calibration batch of its
/// activations (`calib_rows × calib_seq` samples), in bytes — what a fully
/// streaming implementation must hold at once.
pub fn layerwise_memory_bound(cfg: &ModelConfig, calib_rows: usize, calib_seq: usize) -> usize {
    let largest_w = (cfg.d_model * cfg.d_ff).max(cfg.d_model * cfg.d_model);
    let act = calib_rows * calib_seq * cfg.d_ff.max(cfg.d_model);
    let cov = cfg.d_ff.max(cfg.d_model).pow(2);
    4 * (largest_w + act) + 8 * cov
}

/// The same bound for LLaMA-7B at the paper's calibration size (batch 512,
/// seq 128, §3.1) — the test asserts it lands under the paper's 10 GB.
pub fn llama7b_memory_bound_bytes() -> usize {
    let cfg = ModelConfig::llama7b();
    layerwise_memory_bound(&cfg, 512, 128)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_bound_under_10gb() {
        let b = llama7b_memory_bound_bytes();
        assert!(b < 10_000_000_000, "bound {b} bytes");
        // but far more than one weight matrix alone — it's dominated by
        // the calibration activations
        assert!(b > 4 * 4096 * 11008);
    }

    #[test]
    fn mini_bound_is_tiny() {
        let cfg = ModelConfig::mini();
        let b = layerwise_memory_bound(&cfg, 512, 128);
        assert!(b < 200_000_000);
    }

    #[test]
    fn format_includes_rows() {
        let mut rep = CostReport::default();
        rep.rows.push(CostRow {
            label: "80%".into(),
            layers_compressed: 21,
            total_seconds: 12.5,
            mean_seconds_per_layer: 0.59,
            peak_capture_bytes: 30_000_000,
        });
        let s = rep.format();
        assert!(s.contains("80%"));
        assert!(s.contains("21"));
    }
}
